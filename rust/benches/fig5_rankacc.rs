//! cargo bench --bench fig5_rankacc — regenerates Fig 5: pairwise
//! RankAcc of the hidden-state step scorer vs token confidence as a
//! function of observed prefix fraction (256 traces/question).
use step::harness::{fig5, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(10), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    let r = fig5::run(&opts).expect("fig5 (needs `make artifacts`)");
    // Shape assertions (the paper's two claims).
    let n = r.fractions.len();
    assert!(r.scorer_rankacc[n - 1] > r.scorer_rankacc[0], "RankAcc must grow");
    let dominated = r
        .scorer_rankacc
        .iter()
        .zip(&r.confidence_rankacc)
        .filter(|(s, c)| s > c)
        .count();
    assert!(dominated >= n - 1, "scorer must dominate confidence");
    println!("\n[bench] fig5 regenerated in {:.1}s (claims hold)", t0.elapsed().as_secs_f64());
}
