//! cargo bench --bench fig67_dynamics — regenerates Fig 6/7 (trace-level
//! prefix-mean score dynamics, correct vs incorrect, 1024-token bins).
use step::harness::{fig67, overhead, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(8), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    let ds = fig67::run(&opts).expect("fig67 (needs `make artifacts`)");
    for d in &ds {
        let sep: Vec<f64> = d
            .bins
            .iter()
            .filter(|(_, _, nc, ni)| *nc > 10 && *ni > 10)
            .map(|(c, i, _, _)| c - i)
            .collect();
        let pos = sep.iter().filter(|&&x| x > 0.0).count();
        assert!(pos * 10 >= sep.len() * 9, "{:?}: separation must hold", d.model);
    }
    overhead::run(); // Appendix D alongside
    println!("\n[bench] fig67+overhead regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
