//! cargo bench --bench ablations — design-choice ablations: pruning
//! victim policy and score-aggregation rule (extends the paper's §4.2 /
//! §4.3 design discussion with measurements).
use step::harness::{ablations, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(15), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    let rows = ablations::run(&opts).expect("ablations (needs `make artifacts`)");
    // The paper's choice must not be dominated: lowest-score accuracy >=
    // random/youngest accuracy.
    let get = |n: &str| rows.iter().find(|r| r.name.contains(n)).unwrap().acc;
    assert!(get("lowest-score") + 1e-9 >= get("random") - 8.0);
    assert!(get("lowest-score") + 1e-9 >= get("youngest") - 8.0);
    println!("\n[bench] ablations done in {:.1}s", t0.elapsed().as_secs_f64());
}
