//! cargo bench --bench micro_hotpath — microbenchmarks of the serving
//! hot paths (the §Perf targets in EXPERIMENTS.md):
//!   * step-scorer MLP matvec (runs at every step boundary),
//!   * KV block allocator ops (every decode iteration),
//!   * scheduler memory-horizon + full DES question throughput,
//!   * voting aggregation.

use step::coordinator::method::Method;
use step::coordinator::scorer::StepScorer;
use step::coordinator::voting::{weighted_vote, Vote};
use step::kvcache::{KvCacheManager, OwnerId, SharedKvPool};
use step::obs::{EventBuf, EventKind, NullRecorder, Recorder, SimEvent};
use step::sim::des::{DesEngine, Scratch, SimConfig};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::sched::{self, EventIndex};
use step::sim::serve::{ServeEngine, ServeSimConfig};
use step::sim::tracegen::{GenParams, TraceGen};
use step::sim::workload::{Arrival, WorkloadSpec};
use step::util::bench::{black_box, Bench};
use step::util::rng::Rng;

fn main() {
    let mut b = Bench::default();
    let mut rng = Rng::new(0);

    // ---- scorer matvec (d=64, hidden=512 — the trained architecture).
    let (d, hidden) = (64usize, 512usize);
    let w1: Vec<f32> = (0..d * hidden).map(|_| rng.normal() as f32 * 0.05).collect();
    let b1 = vec![0.01f32; hidden];
    let w2: Vec<f32> = (0..hidden).map(|_| rng.normal() as f32 * 0.05).collect();
    let scorer = StepScorer::new(d, hidden, w1, b1, w2, 0.0).unwrap();
    let h: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let mut one_z = vec![0.0f32; hidden];
    b.run_with_items("scorer/score_one(d=64,h=512)", 1.0, || {
        scorer.score_into(black_box(&h), &mut one_z)
    });

    let batch: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect();
    let (mut fused_out, mut fused_z) = (Vec::new(), Vec::new());
    b.run_with_items("scorer/score_batch_fused(64)", 64.0, || {
        scorer.score_batch_into(black_box(&batch), &mut fused_out, &mut fused_z);
        fused_out.len()
    });
    // Pre-tiling reference path: one independent matvec per input, the
    // w1 matrix streamed from memory 64 times instead of 8.
    let mut naive_z = vec![0.0f32; hidden];
    b.run_with_items("scorer/score_batch_naive(64)", 64.0, || {
        let out: Vec<f32> =
            black_box(&batch).iter().map(|h| scorer.score_into(h, &mut naive_z)).collect();
        out
    });
    // ---- paged KV allocator.
    b.run_with_items("kvcache/alloc_free_seq(32k tokens)", 2000.0, || {
        let mut m = KvCacheManager::new(4096, 16);
        m.allocate_seq(1, 100);
        for _ in 0..2000 {
            m.append_tokens(1, 16);
        }
        m.free_seq(1)
    });

    b.run_with_items("kvcache/can_step_all(64 seqs)", 64.0, || {
        let mut m = KvCacheManager::new(8192, 16);
        for i in 0..64 {
            m.allocate_seq(i, 1000 + i as usize);
        }
        let ids: Vec<u64> = (0..64).collect();
        let ok = m.can_step_all(black_box(&ids));
        for i in 0..64 {
            m.free_seq(i);
        }
        ok
    });

    // Steady-state sequence churn on a warm manager: after the first
    // lap every admit reuses a recycled block-table Vec and every append
    // extends it in place (no temporary Vec per boundary crossing).
    let mut churn_mgr = KvCacheManager::new(8192, 16);
    b.run_with_items("kvcache/seq_churn(64 lifecycles)", 64.0, || {
        let mut freed = 0usize;
        for i in 0..64u64 {
            churn_mgr.allocate_seq(i, 100);
            for _ in 0..8 {
                churn_mgr.append_tokens(i, 64);
            }
            freed += churn_mgr.free_seq(i);
        }
        freed
    });

    // ---- prefix registry lookup: the O(1) digest the router's
    // affinity stamping reads per (request, GPU) placement vs the
    // registry-walk reference, on a registry holding many pinned
    // prefixes.
    let mut reg_pool = SharedKvPool::new(65536, 16, None);
    for q in 0..512usize {
        let share = reg_pool
            .allocate_seq_shared(q as OwnerId, q as u64, q, 401 + (q % 7) * 16, 0)
            .expect("pool sized for every prefix");
        assert!(!share.hit, "distinct questions each pin their own prefix");
    }
    for q in 0..512usize {
        assert_eq!(
            reg_pool.prefix_hit_blocks(q),
            reg_pool.prefix_hit_blocks_scan(q),
            "digest must equal the registry walk"
        );
    }
    b.run_with_items("kvcache/prefix_lookup_scan(512)", 512.0, || {
        let mut sum = 0usize;
        for q in 0..512usize {
            sum += reg_pool.prefix_hit_blocks_scan(black_box(q));
        }
        sum
    });
    b.run_with_items("kvcache/prefix_lookup_digest(512)", 512.0, || {
        let mut sum = 0usize;
        for q in 0..512usize {
            sum += reg_pool.prefix_hit_blocks(black_box(q));
        }
        sum
    });

    // ---- CoW prompt fork: steady-state sibling churn against one hot
    // pinned prefix (the shared-admission hot path — registry hit,
    // fork the private tail, free it again) vs the plain full-prompt
    // lifecycle it replaces. Seq 0 stays live so the prefix never goes
    // cold mid-bench.
    let mut cow_pool = SharedKvPool::new(8192, 16, None);
    let first = cow_pool
        .allocate_seq_shared(0, 0, 0, 1000, 0)
        .expect("the first trace pins the prefix");
    assert!(!first.hit, "an empty registry misses");
    assert_eq!(
        cow_pool.prefix_hit_blocks(0) + cow_pool.shared_blocks_needed(0, 1000, 0),
        1000usize.div_ceil(16),
        "pinned blocks plus the private tail must cover the full prompt"
    );
    let cow_free0 = cow_pool.free_blocks();
    b.run_with_items("kvcache/cow_fork_churn(64)", 64.0, || {
        let mut blocks = 0usize;
        for i in 1..=64u64 {
            let share = cow_pool
                .allocate_seq_shared(i as OwnerId, i, 0, 1000, 0)
                .expect("the hit path admits");
            debug_assert!(share.hit, "sibling admissions reuse the pin");
            blocks += share.shared_blocks;
            blocks += cow_pool.free_seq(i);
        }
        blocks
    });
    assert_eq!(cow_pool.free_blocks(), cow_free0, "fork churn leaks no blocks");
    let mut plain_pool = SharedKvPool::new(8192, 16, None);
    b.run_with_items("kvcache/plain_prompt_churn(64)", 64.0, || {
        let mut blocks = 0usize;
        for i in 1..=64u64 {
            assert!(plain_pool.allocate_seq(i as OwnerId, i, 1000));
            blocks += plain_pool.free_seq(i);
        }
        blocks
    });

    // ---- voting.
    let votes: Vec<Vote> = (0..64)
        .map(|i| Vote { answer: Some(i % 7), weight: 0.3 + 0.01 * i as f64 })
        .collect();
    b.run_with_items("voting/weighted_vote(64)", 64.0, || weighted_vote(black_box(&votes)));

    // ---- serving event horizons under many live traces: the
    // incremental EventIndex (O(1) d_event peek + closed-form
    // histogram demand per probe) vs the retired per-event scan
    // (min fold + per-probe O(live) block-demand regather).
    let m = 512usize;
    let bs = 16u64;
    let mut resident: Vec<u64> = Vec::with_capacity(m);
    let mut dist: Vec<u64> = Vec::with_capacity(m);
    let mut idx = EventIndex::new(bs as usize, false);
    for i in 0..m {
        let r = 100 + rng.below(3900) as u64;
        let dd = 200 + rng.below(200) as u64;
        resident.push(r);
        dist.push(dd);
        idx.insert(i, 0, r, dd);
    }
    let free = 3000u64;
    let scan_event = |resident: &[u64], dist: &[u64]| -> (u64, u64) {
        let d_event = dist.iter().copied().min().expect("non-empty");
        let fits = |d: u64| {
            resident.iter().map(|&c| (c + d).div_ceil(bs) - c.div_ceil(bs)).sum::<u64>()
                <= free
        };
        (d_event, sched::max_fitting(d_event, fits))
    };
    let scanned = scan_event(&resident, &dist);
    let indexed = {
        let d_event = idx.d_event().expect("non-empty");
        (d_event, sched::max_fitting(d_event, |d| idx.pool_demand(d) <= free))
    };
    assert_eq!(scanned, indexed, "indexed horizons must equal the scan");
    b.run_with_items("serve/event_scan(512)", m as f64, || {
        scan_event(black_box(&resident), black_box(&dist))
    });
    b.run_with_items("serve/event_indexed(512)", m as f64, || {
        let d_event = idx.d_event().expect("non-empty");
        (d_event, sched::max_fitting(d_event, |d| idx.pool_demand(d) <= free))
    });

    // ---- full DES question (the experiment engine's unit of work).
    let gp = GenParams::default_d64();
    let gen = TraceGen::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, gp.clone(), 1);
    let proj_scorer = step::harness::cells::projection_scorer(&gp);
    for method in [Method::Sc, Method::Step] {
        let cfg = SimConfig::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, method, 64);
        let engine = DesEngine::new(&cfg, &gen, &proj_scorer);
        let mut qid = 0usize;
        b.run(&format!("des/question(HMMT,N=64,{})", method.name()), || {
            qid += 1;
            engine.run_question(black_box(qid % 30))
        });
        // Reused per-worker scratch: the steady-state harness path.
        let mut scratch = Scratch::new();
        let mut qid = 0usize;
        b.run(&format!("des/question_scratch(HMMT,N=64,{})", method.name()), || {
            qid += 1;
            engine.run_question_with(black_box(qid % 30), &mut scratch)
        });
    }

    // ---- observability emission path on the full DES question: the
    // disabled branch (no recorder attached — one `is_some()` test per
    // emission site, no event construction) vs a NullRecorder attached
    // (event construction + one dynamic call per site, every event
    // discarded). The disabled case is the §Perf "tracing off is free"
    // target; the gap between the two is the enabled-path floor.
    {
        let cfg = SimConfig::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::Step, 64);
        let engine = DesEngine::new(&cfg, &gen, &proj_scorer);
        let mut off = Scratch::new();
        let mut qid = 0usize;
        b.run("obs/question_recorder_off(HMMT,N=64,step)", || {
            qid += 1;
            engine.run_question_with(black_box(qid % 30), &mut off)
        });
        let mut on = Scratch::new();
        on.rec = Some(Box::new(NullRecorder));
        let mut qid = 0usize;
        b.run("obs/question_null_recorder(HMMT,N=64,step)", || {
            qid += 1;
            engine.run_question_with(black_box(qid % 30), &mut on)
        });
    }

    // Raw sink cost: recording into the bounded flight-recorder ring
    // (the always-on chaos configuration).
    let mut ring = EventBuf::ring(256);
    b.run_with_items("obs/ring_record(x64)", 64.0, || {
        for i in 0..64usize {
            ring.record(SimEvent::new(i as f64, EventKind::StepScore { score: 0.5 }).rid(i));
        }
        ring.len()
    });

    // ---- router view: the incrementally maintained score multiset vs
    // the sort-per-call scan, on a mid-run engine holding many live
    // traces (the state every cluster placement queries per GPU).
    let rv_cfg = {
        let mut c = ServeSimConfig::new(
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            Method::Step,
            64,
            WorkloadSpec::poisson(0.05, 4),
        );
        c.seed = 7;
        c.route_views = true;
        c
    };
    let rv_gen = TraceGen::new(rv_cfg.model, rv_cfg.bench, gp.clone(), rv_cfg.seed ^ 0x5EED);
    let mut eng = ServeEngine::new(&rv_cfg, &rv_gen, &proj_scorer);
    for rid in 0..4 {
        eng.submit(&Arrival { rid, qid: rid, t_arrive: 0.0 });
    }
    for _ in 0..64 {
        eng.run_one_event();
    }
    let live = eng.live_traces();
    assert!(live > 32, "mid-run engine should hold many live traces, got {live}");
    assert_eq!(
        eng.survivor_demand_blocks(),
        eng.survivor_demand_blocks_scan(),
        "incremental router view must equal the scan"
    );
    b.run_with_items(&format!("router/pressure_scan(live={live})"), live as f64, || {
        eng.survivor_demand_blocks_scan()
    });
    b.run_with_items(&format!("router/pressure_incremental(live={live})"), live as f64, || {
        eng.survivor_demand_blocks()
    });

    println!("\n{} cases done.", b.results.len());
}
