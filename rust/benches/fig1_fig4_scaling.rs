//! cargo bench --bench fig1_fig4_scaling — regenerates Fig 1 (accuracy
//! vs latency scatter) and Fig 4 (latency scaling, N in {1,16,32,64}).
use step::harness::{fig1_fig4, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(12), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    fig1_fig4::run_fig1(&opts).expect("fig1 (needs `make artifacts`)");
    fig1_fig4::run_fig4(&opts).expect("fig4");
    println!("\n[bench] fig1+fig4 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
