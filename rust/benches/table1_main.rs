//! cargo bench --bench table1_main — regenerates Table 1 (main results:
//! Acc/Tok/Lat for CoT/SC/Slim-SC/DeepConf/STEP x 3 models x 5 benches)
//! at bench scale (12 questions/bench; run `step table1` for the
//! paper-faithful counts) and prints paper-vs-measured rows.
use step::harness::{table1, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(12), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    table1::run(&opts).expect("table1 (needs `make artifacts`)");
    println!("\n[bench] table1 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
