//! cargo bench --bench table4_memory — regenerates Table 4 (STEP
//! accuracy across gpu_memory_utilization 0.5..0.9) and asserts the
//! stability claim.
use step::harness::{table4, HarnessOpts};
use step::util::stats::stddev;

fn main() {
    let opts = HarnessOpts { max_questions: Some(20), n_traces: 32, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    let rows = table4::run(&opts).expect("table4 (needs `make artifacts`)");
    let accs: Vec<f64> = rows.iter().map(|r| r.1).collect();
    assert!(stddev(&accs) < 8.0, "accuracy must be stable across budgets");
    println!("\n[bench] table4 regenerated in {:.1}s (stability holds)", t0.elapsed().as_secs_f64());
}
