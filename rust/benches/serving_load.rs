//! cargo bench --bench serving_load — wall-clock of the multi-request
//! serving simulator plus its SLO metric blocks, asserting (a) the
//! metric blocks are byte-identical for any thread count and (b) STEP's
//! p99 end-to-end latency lands below self-consistency's at the same
//! arrival rate (the serving-scale rendering of the paper's claim).
//! Writes `results/BENCH_serving.json`.
//!
//! Runs self-contained on the built-in generator defaults (no artifacts
//! needed), so CI and fresh checkouts can benchmark the serving layer.

use std::time::Instant;

use step::coordinator::method::Method;
use step::harness::cells::projection_scorer;
use step::harness::table5::{metrics_json, run_methods, ServingOpts};
use step::harness::write_results;
use step::sim::tracegen::GenParams;
use step::util::json::Json;
use step::util::pool;

fn main() {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let opts = ServingOpts { seed: 7, threads: 1, ..ServingOpts::quick() };
    let threads = pool::available_parallelism();
    println!(
        "serving grid: {} requests @ {} rps, N={} traces, {:?} on {}; {} hardware threads",
        opts.n_requests,
        opts.rate_rps,
        opts.n_traces,
        opts.model,
        opts.bench.name(),
        threads
    );

    let t0 = Instant::now();
    let serial = run_methods(&opts, &gp, &scorer);
    let serial_s = t0.elapsed().as_secs_f64();
    println!("serial:   {serial_s:.2}s");

    let par_opts = ServingOpts { threads, ..opts.clone() };
    let t1 = Instant::now();
    let parallel = run_methods(&par_opts, &gp, &scorer);
    let parallel_s = t1.elapsed().as_secs_f64();
    println!("parallel: {parallel_s:.2}s  ({threads} threads)");

    let ser_json = metrics_json(&opts, &serial).to_string_pretty();
    let par_json = metrics_json(&par_opts, &parallel).to_string_pretty();
    assert_eq!(ser_json, par_json, "serving metric blocks must be thread-invariant");

    for c in &serial {
        println!(
            "  {:>8}: {:.4} req/s  p50={:.1}s p95={:.1}s p99={:.1}s  acc={:.1}%  \
             preempt={} pruned={}",
            c.method.name(),
            c.throughput_rps,
            c.p50_s,
            c.p95_s,
            c.p99_s,
            c.acc,
            c.preemptions,
            c.pruned,
        );
    }
    let p99 = |m: Method| serial.iter().find(|c| c.method == m).unwrap().p99_s;
    assert!(
        p99(Method::Step) < p99(Method::Sc),
        "STEP p99 {} must undercut SC p99 {} under load",
        p99(Method::Step),
        p99(Method::Sc)
    );
    println!(
        "p99: STEP {:.1}s < SC {:.1}s (serving claim holds; metrics thread-invariant)",
        p99(Method::Step),
        p99(Method::Sc)
    );

    let mut report = metrics_json(&opts, &serial);
    if let Json::Obj(map) = &mut report {
        map.insert("bench_serial_s".to_string(), Json::Num(serial_s));
        map.insert("bench_parallel_s".to_string(), Json::Num(parallel_s));
        map.insert("bench_threads".to_string(), Json::Num(threads as f64));
        map.insert("identical_across_threads".to_string(), Json::Bool(true));
    }
    let path = write_results("BENCH_serving", &report).expect("writing BENCH_serving.json");
    println!("wrote {path:?}");
}
