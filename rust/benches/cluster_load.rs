//! cargo bench --bench cluster_load — wall-clock of the multi-GPU
//! cluster simulator plus its metric blocks, asserting (a) the metric
//! blocks are byte-identical for any thread count — including the
//! intra-simulation `step_threads` axis that advances the R per-GPU
//! engines in parallel between interaction points — and (b) the
//! KV-pressure-aware router beats round-robin on p99 end-to-end latency
//! for STEP under a skewed closed-loop workload at R >= 4 GPUs — the
//! cluster-scale rendering of the paper's claim (step scores are a
//! schedulable signal; per-trace confidence is not). Records the
//! serial-vs-parallel *stepping* wall-clock and speedup alongside the
//! cell-sharding numbers. Writes `results/BENCH_cluster.json`.
//!
//! Runs self-contained on the built-in generator defaults (no artifacts
//! needed), so CI and fresh checkouts can benchmark the cluster layer.

use std::time::Instant;

use step::harness::cells::projection_scorer;
use step::harness::table6::{metrics_json, run_grids, ClusterOpts};
use step::harness::write_results;
use step::sim::router::RouterKind;
use step::sim::tracegen::GenParams;
use step::util::json::Json;
use step::util::pool;

fn main() {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let opts = ClusterOpts { seed: 7, threads: 1, ..ClusterOpts::quick() };
    assert!(opts.gpus >= 4, "the router claim is asserted at R >= 4");
    let threads = pool::available_parallelism();
    println!(
        "cluster grid: {} GPUs, {} requests from {} closed-loop clients \
         (think {}s, heavy {:.0}%), N={} traces, {:?} on {}; {} hardware threads",
        opts.gpus,
        opts.n_requests,
        opts.clients,
        opts.think_s,
        100.0 * opts.heavy_frac,
        opts.n_traces,
        opts.model,
        opts.bench.name(),
        threads
    );

    let t0 = Instant::now();
    let (m_serial, r_serial) = run_grids(&opts, &gp, &scorer);
    let serial_s = t0.elapsed().as_secs_f64();
    println!("serial:   {serial_s:.2}s");

    let par_opts = ClusterOpts { threads, ..opts.clone() };
    let t1 = Instant::now();
    let (m_par, r_par) = run_grids(&par_opts, &gp, &scorer);
    let parallel_s = t1.elapsed().as_secs_f64();
    println!("parallel: {parallel_s:.2}s  ({threads} threads)");

    // Intra-simulation parallelism: keep the cells serial and advance
    // each cluster's R engines concurrently between interaction points.
    // The serial run above (threads 1, step_threads 1) is the baseline.
    let step_opts = ClusterOpts { step_threads: threads, ..opts.clone() };
    let t2 = Instant::now();
    let (m_step, r_step) = run_grids(&step_opts, &gp, &scorer);
    let step_parallel_s = t2.elapsed().as_secs_f64();
    let step_speedup = serial_s / step_parallel_s.max(1e-9);
    println!(
        "parallel engine stepping: {step_parallel_s:.2}s  ({threads} step threads, \
         {step_speedup:.2}x vs serial stepping{})",
        if step_speedup > 1.0 { "" } else { " — WARNING: no speedup on this machine" }
    );

    let ser_json = metrics_json(&opts, &m_serial, &r_serial).to_string_pretty();
    let par_json = metrics_json(&par_opts, &m_par, &r_par).to_string_pretty();
    assert_eq!(ser_json, par_json, "cluster metric blocks must be thread-invariant");
    let step_json = metrics_json(&step_opts, &m_step, &r_step).to_string_pretty();
    assert_eq!(
        ser_json, step_json,
        "parallel-stepped cluster metric blocks must match serial stepping"
    );

    for c in m_serial.iter().chain(&r_serial) {
        println!(
            "  {:>18}: {:.4} good/s  shed={:.1}%  p50={:.1}s p95={:.1}s p99={:.1}s  \
             acc={:.1}%  preempt={} pruned={} bal={:.2}",
            c.label,
            c.goodput_rps,
            100.0 * c.shed_rate,
            c.p50_s,
            c.p95_s,
            c.p99_s,
            c.acc,
            c.preemptions,
            c.pruned,
            c.max_gpu_share,
        );
    }

    let p99 = |label: &str| {
        r_serial
            .iter()
            .find(|c| c.label == label)
            .unwrap_or_else(|| panic!("router row '{label}' missing"))
            .p99_s
    };
    let kv = p99(RouterKind::KvPressure.name());
    let rr = p99(RouterKind::RoundRobin.name());
    assert!(
        kv < rr,
        "kv-pressure p99 {kv} must undercut round-robin p99 {rr} under skewed \
         closed-loop load at {} GPUs",
        opts.gpus
    );
    println!(
        "p99: kv-pressure {kv:.1}s < round-robin {rr:.1}s \
         (cluster claim holds; metrics thread-invariant)"
    );

    let mut report = metrics_json(&opts, &m_serial, &r_serial);
    if let Json::Obj(map) = &mut report {
        map.insert("bench_serial_s".to_string(), Json::Num(serial_s));
        map.insert("bench_parallel_s".to_string(), Json::Num(parallel_s));
        map.insert("bench_threads".to_string(), Json::Num(threads as f64));
        map.insert("identical_across_threads".to_string(), Json::Bool(true));
        // Intra-simulation engine-stepping fields (expected speedup > 1
        // at R >= 4 GPUs on >= 4 cores; asserted byte-identical above).
        map.insert("step_parallel_s".to_string(), Json::Num(step_parallel_s));
        map.insert("step_threads".to_string(), Json::Num(threads as f64));
        map.insert("step_speedup".to_string(), Json::Num(step_speedup));
        map.insert("identical_across_step_threads".to_string(), Json::Bool(true));
    }
    let path = write_results("BENCH_cluster", &report).expect("writing BENCH_cluster.json");
    println!("wrote {path:?}");
}
