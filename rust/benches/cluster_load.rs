//! cargo bench --bench cluster_load — wall-clock of the multi-GPU
//! cluster simulator plus its metric blocks, asserting (a) the metric
//! blocks are byte-identical for any thread count — including the
//! intra-simulation `step_threads` axis that advances the R per-GPU
//! engines in parallel between interaction points — and (b) the
//! KV-pressure-aware router beats round-robin on p99 end-to-end latency
//! for STEP under a skewed closed-loop workload at R >= 4 GPUs — the
//! cluster-scale rendering of the paper's claim (step scores are a
//! schedulable signal; per-trace confidence is not), and (c) on a
//! heterogeneous pool squeezed to the shedding point, cross-GPU trace
//! migration (`migrate=on-shed`) sheds strictly less than
//! `migrate=never` while staying byte-identical across `step_threads`
//! — work is preserved, not thrown away. Records the
//! serial-vs-parallel *stepping* wall-clock and speedup alongside the
//! cell-sharding numbers, plus the migration gate ratios.
//!
//! A fleet-scale grid then runs STEP under the two-stage `kv-sharded`
//! router at R in {4, 64, 256, 1024}, recording scheduler events/sec
//! and the `step_threads` scaling curve per fleet size, asserting each
//! cell byte-identical across step-thread counts, and asserting the
//! sharded router reproduces the flat kv-pressure placements
//! byte-for-byte at small R (one shard). Writes
//! `results/BENCH_cluster.json` (to `$STEP_RESULTS_DIR` when set).
//!
//! A prefix-cache row reruns the skewed closed loop through the
//! affinity sweep (cache off, then on at every credit weight),
//! asserting the registry shares prompts (hit rate > 0), prunes
//! strictly less than the no-cache baseline at no accuracy cost, keeps
//! the p99 tail at or under the baseline, and that cache-off stays
//! byte-identical to the default cluster — recording
//! `prefix_hit_rate` / `prefix_saved_blocks` / `prefix_p99_ratio` /
//! `prefix_off_identical` for the bench gate.
//!
//! A signal Pareto grid then races every pruning signal (hidden-mlp /
//! latent-temporal / confidence / prm-oracle) × pruning method ×
//! memory pressure on the same workload, asserting hidden-mlp STEP
//! accuracy does not fall below intrinsic confidence at the matched
//! load, and that an explicit `--signal hidden-mlp` run stays
//! byte-identical to the default cell — recording `signal_pareto` /
//! `signal_acc_hidden_mlp` / `signal_acc_confidence` /
//! `signal_default_identical` for the bench gate.
//!
//! Runs self-contained on the built-in generator defaults (no artifacts
//! needed), so CI and fresh checkouts can benchmark the cluster layer.

use std::time::Instant;

use step::coordinator::method::Method;
use step::coordinator::signal::SignalSpec;
use step::harness::cells::projection_scorer;
use step::harness::table6::{
    attach_affinity_grid, attach_migration_grid, attach_signal_grid, cells_fingerprint,
    config_json, elasticity_schedule, metrics_json, run_affinity_grid, run_cell, run_grids,
    run_migration_grid, run_signal_grid, run_traced_cell, signal_step_acc, AffinityCell,
    ClusterOpts,
};
use step::harness::write_results;
use step::sim::cluster::{GpuProfile, MigrationPolicy};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::router::RouterKind;
use step::sim::tracegen::GenParams;
use step::util::json::Json;
use step::util::pool;

fn main() {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let opts = ClusterOpts { seed: 7, threads: 1, ..ClusterOpts::quick() };
    assert!(opts.gpus >= 4, "the router claim is asserted at R >= 4");
    let threads = pool::available_parallelism();
    println!(
        "cluster grid: {} GPUs, {} requests from {} closed-loop clients \
         (think {}s, heavy {:.0}%), N={} traces, {:?} on {}; {} hardware threads",
        opts.gpus,
        opts.n_requests,
        opts.clients,
        opts.think_s,
        100.0 * opts.heavy_frac,
        opts.n_traces,
        opts.model,
        opts.bench.name(),
        threads
    );

    let t0 = Instant::now();
    let (m_serial, r_serial) = run_grids(&opts, &gp, &scorer);
    let serial_s = t0.elapsed().as_secs_f64();
    println!("serial:   {serial_s:.2}s");

    let par_opts = ClusterOpts { threads, ..opts.clone() };
    let t1 = Instant::now();
    let (m_par, r_par) = run_grids(&par_opts, &gp, &scorer);
    let parallel_s = t1.elapsed().as_secs_f64();
    println!("parallel: {parallel_s:.2}s  ({threads} threads)");

    // Intra-simulation parallelism: keep the cells serial and advance
    // each cluster's R engines concurrently between interaction points.
    // The serial run above (threads 1, step_threads 1) is the baseline.
    let step_opts = ClusterOpts { step_threads: threads, ..opts.clone() };
    let t2 = Instant::now();
    let (m_step, r_step) = run_grids(&step_opts, &gp, &scorer);
    let step_parallel_s = t2.elapsed().as_secs_f64();
    let step_speedup = serial_s / step_parallel_s.max(1e-9);
    println!(
        "parallel engine stepping: {step_parallel_s:.2}s  ({threads} step threads, \
         {step_speedup:.2}x vs serial stepping{})",
        if step_speedup > 1.0 { "" } else { " — WARNING: no speedup on this machine" }
    );

    let ser_json = metrics_json(&opts, &m_serial, &r_serial).to_string_pretty();
    let par_json = metrics_json(&par_opts, &m_par, &r_par).to_string_pretty();
    assert_eq!(ser_json, par_json, "cluster metric blocks must be thread-invariant");
    let step_json = metrics_json(&step_opts, &m_step, &r_step).to_string_pretty();
    assert_eq!(
        ser_json, step_json,
        "parallel-stepped cluster metric blocks must match serial stepping"
    );

    for c in m_serial.iter().chain(&r_serial) {
        println!(
            "  {:>18}: {:.4} good/s  shed={:.1}%  p50={:.1}s p95={:.1}s p99={:.1}s  \
             acc={:.1}%  preempt={} pruned={} bal={:.2}",
            c.label,
            c.goodput_rps,
            100.0 * c.shed_rate,
            c.p50_s,
            c.p95_s,
            c.p99_s,
            c.acc,
            c.preemptions,
            c.pruned,
            c.max_gpu_share,
        );
    }

    let p99 = |label: &str| {
        r_serial
            .iter()
            .find(|c| c.label == label)
            .unwrap_or_else(|| panic!("router row '{label}' missing"))
            .p99_s
    };
    let kv = p99(RouterKind::KvPressure.name());
    let rr = p99(RouterKind::RoundRobin.name());
    assert!(
        kv < rr,
        "kv-pressure p99 {kv} must undercut round-robin p99 {rr} under skewed \
         closed-loop load at {} GPUs",
        opts.gpus
    );
    println!(
        "p99: kv-pressure {kv:.1}s < round-robin {rr:.1}s \
         (cluster claim holds; metrics thread-invariant)"
    );

    // ---- heterogeneous-pool migration grid: never / on-shed /
    // on-pressure under STEP on a mixed fleet (one baseline GPU, three
    // small 2.5x-slower ones) squeezed hard enough that admission must
    // shed when it cannot relocate (per-GPU quota 1, no queue).
    let mig_opts = ClusterOpts {
        gpus: 4,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 24,
        clients: 8,
        think_s: 15.0,
        heavy_frac: 0.5,
        n_traces: 6,
        mem_util: 0.5,
        queue_cap: 0,
        max_outstanding: 1,
        gpu_profiles: GpuProfile::default_hetero(4),
        seed: 7,
        threads: 1,
        ..ClusterOpts::default()
    };
    let t3 = Instant::now();
    let migration = run_migration_grid(&mig_opts, &gp, &scorer);
    let migration_s = t3.elapsed().as_secs_f64();
    println!("migration grid: {migration_s:.2}s");
    for c in &migration {
        println!(
            "  {:>12}: shed={:.1}%  good/s={:.4}  p99={:.1}s  migrated={} \
             saved={} recompute_tok_k={:.1}",
            c.label,
            100.0 * c.shed_rate,
            c.goodput_rps,
            c.p99_s,
            c.migrated,
            c.migration_saved,
            c.migration_recompute_tok_k,
        );
    }
    // Byte-identity of the grid across engine-stepping parallelism.
    let mig_step_opts = ClusterOpts { step_threads: threads, ..mig_opts.clone() };
    let migration_stepped = run_migration_grid(&mig_step_opts, &gp, &scorer);
    assert_eq!(
        cells_fingerprint(&migration),
        cells_fingerprint(&migration_stepped),
        "migration grid must be byte-identical across step_threads"
    );
    let mig_cell = |label: &str| {
        migration
            .iter()
            .find(|c| c.label == label)
            .unwrap_or_else(|| panic!("migration row '{label}' missing"))
    };
    let never = mig_cell(MigrationPolicy::Never.name());
    let on_shed = mig_cell(MigrationPolicy::OnShed.name());
    assert_eq!(never.migrated, 0, "the never row must not migrate");
    assert!(
        never.shed_rate > 0.0,
        "the harsh heterogeneous config must shed under never (shed={})",
        never.shed
    );
    assert!(
        on_shed.shed_rate < never.shed_rate,
        "on-shed migration must shed less than never ({} vs {})",
        on_shed.shed_rate,
        never.shed_rate
    );
    assert!(on_shed.migrated > 0, "the on-shed row must actually migrate");
    let shed_ratio = on_shed.shed_rate / never.shed_rate;
    let goodput_ratio = on_shed.goodput_rps / never.goodput_rps.max(1e-12);
    let p99_ratio = on_shed.p99_s / never.p99_s.max(1e-12);
    println!(
        "migration: on-shed sheds {:.1}% of never's rate, goodput x{goodput_ratio:.2}, \
         p99 x{p99_ratio:.2} (work preserved instead of shed)",
        100.0 * shed_ratio
    );
    if goodput_ratio < 1.0 {
        println!("  WARNING: on-shed goodput below never at this load");
    }

    // ---- fleet-scale grid: STEP under the two-stage kv-sharded router
    // at R in {4, 64, 256, 1024}. The closed-loop population scales
    // with the fleet so every GPU sees work, while per-request cost
    // stays small (N=4 traces, modest pools) so the R=1024 cell
    // finishes in seconds. Each cell runs serially stepped (the
    // events/sec baseline) and again with parallel engine stepping,
    // asserting byte-identity and recording the scaling curve.
    let fleet_opts = |gpus: usize| ClusterOpts {
        gpus,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 2 * gpus,
        clients: gpus,
        think_s: 20.0,
        heavy_frac: 0.5,
        n_traces: 4,
        mem_util: 0.4,
        max_outstanding: 2,
        router: RouterKind::KvPressureSharded,
        seed: 7,
        threads: 1,
        ..ClusterOpts::default()
    };
    let mut fleet_rows: Vec<Json> = Vec::new();
    for &gpus in &[4usize, 64, 256, 1024] {
        let o = fleet_opts(gpus);
        let label = format!("R{gpus}");
        let t = Instant::now();
        let cell = run_cell(Method::Step, o.router, &label, &gp, &scorer, &o);
        let wall_s = t.elapsed().as_secs_f64().max(1e-9);
        let events_per_sec = cell.events as f64 / wall_s;

        let stepped_opts = ClusterOpts { step_threads: threads, ..o.clone() };
        let t = Instant::now();
        let stepped =
            run_cell(Method::Step, stepped_opts.router, &label, &gp, &scorer, &stepped_opts);
        let step_wall_s = t.elapsed().as_secs_f64().max(1e-9);
        let step_events_per_sec = stepped.events as f64 / step_wall_s;
        let fleet_speedup = wall_s / step_wall_s;
        let identical = cells_fingerprint(std::slice::from_ref(&cell))
            == cells_fingerprint(std::slice::from_ref(&stepped));
        assert!(
            identical,
            "fleet cell R={gpus} must be byte-identical across step_threads"
        );
        println!(
            "  fleet R={gpus:>4}: {} events in {wall_s:.2}s = {events_per_sec:.0} ev/s \
             serial; {step_wall_s:.2}s with {threads} step threads ({fleet_speedup:.2}x)",
            cell.events
        );
        fleet_rows.push(Json::obj(vec![
            ("gpus", Json::Num(gpus as f64)),
            ("requests", Json::Num(o.n_requests as f64)),
            ("events", Json::Num(cell.events as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("events_per_sec", Json::Num(events_per_sec)),
            ("step_wall_s", Json::Num(step_wall_s)),
            ("step_events_per_sec", Json::Num(step_events_per_sec)),
            ("step_speedup", Json::Num(fleet_speedup)),
            ("identical_across_step_threads", Json::Bool(identical)),
        ]));
    }

    // Sharded-vs-flat identity at small R: auto shard size covers the
    // whole 4-GPU fleet, so the two-stage router must reproduce the
    // flat kv-pressure placements byte-for-byte.
    let small = fleet_opts(4);
    let flat = ClusterOpts { router: RouterKind::KvPressure, ..small.clone() };
    let sharded_cell = run_cell(Method::Step, small.router, "small", &gp, &scorer, &small);
    let flat_cell = run_cell(Method::Step, flat.router, "small", &gp, &scorer, &flat);
    let shard_flat_identical = cells_fingerprint(std::slice::from_ref(&sharded_cell))
        == cells_fingerprint(std::slice::from_ref(&flat_cell));
    assert!(
        shard_flat_identical,
        "kv-sharded must reproduce flat kv-pressure placements at R=4 (one shard)"
    );
    println!("  fleet: kv-sharded == kv-pressure at R=4 (single-shard identity)");

    // ---- elasticity row: R=64 under a fixed revocation schedule
    // (4 spot revocations, 10s drain deadline, distinct victims),
    // drain-relocate vs shed-everything. Capacity is ample (quota 8 x
    // 64 GPUs vs 128 requests), so every request dropped is revocation
    // damage — goodput_lost_per_revocation isolates what the drain
    // controller saves. Runs under the two-stage sharded router so the
    // dirty-shard aggregates see engines disappear mid-run, and each
    // row is asserted byte-identical across step-thread counts.
    let ela_base = ClusterOpts {
        gpus: 64,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 128,
        clients: 0,
        rate_rps: 4.0,
        n_traces: 4,
        mem_util: 0.4,
        max_outstanding: 8,
        router: RouterKind::KvPressureSharded,
        fleet_events: elasticity_schedule(4, 10.0, 64),
        seed: 7,
        threads: 1,
        ..ClusterOpts::default()
    };
    let mut ela_rows: Vec<Json> = Vec::new();
    let mut ela_cells = Vec::new();
    for (policy, label) in [
        (MigrationPolicy::Never, "shed-everything"),
        (MigrationPolicy::OnShed, "drain-relocate"),
    ] {
        let o = ClusterOpts { migrate: policy, ..ela_base.clone() };
        let t = Instant::now();
        let cell = run_cell(Method::Step, o.router, label, &gp, &scorer, &o);
        let wall_s = t.elapsed().as_secs_f64();
        let stepped_opts = ClusterOpts { step_threads: threads, ..o.clone() };
        let stepped =
            run_cell(Method::Step, stepped_opts.router, label, &gp, &scorer, &stepped_opts);
        let identical = cells_fingerprint(std::slice::from_ref(&cell))
            == cells_fingerprint(std::slice::from_ref(&stepped));
        assert!(
            identical,
            "elasticity row '{label}' must be byte-identical across step_threads"
        );
        println!(
            "  elasticity {label:>16}: revocations={} drained={} rescued={} abandoned={} \
             lost/revocation={:.2} ({wall_s:.2}s)",
            cell.revocations,
            cell.drained,
            cell.rescue_migrated,
            cell.shed_on_revoke,
            cell.goodput_lost_per_revocation,
        );
        let mut row = cell.to_json();
        if let Json::Obj(map) = &mut row {
            map.insert("wall_s".to_string(), Json::Num(wall_s));
            map.insert("identical_across_step_threads".to_string(), Json::Bool(identical));
        }
        ela_rows.push(row);
        ela_cells.push(cell);
    }
    let (shed_all, drain) = (&ela_cells[0], &ela_cells[1]);
    assert_eq!(shed_all.revocations, 4, "every scheduled revocation must fire");
    assert_eq!(drain.revocations, 4, "every scheduled revocation must fire");
    assert!(
        shed_all.shed_on_revoke > 0,
        "shed-everything must abandon residents at this load"
    );
    assert!(drain.rescue_migrated > 0, "the drain controller must relocate residents");
    assert!(
        drain.goodput_lost_per_revocation < shed_all.goodput_lost_per_revocation,
        "drain-relocate must lose strictly less goodput per revocation ({} vs {})",
        drain.goodput_lost_per_revocation,
        shed_all.goodput_lost_per_revocation
    );
    let elasticity_loss_ratio =
        drain.goodput_lost_per_revocation / shed_all.goodput_lost_per_revocation.max(1e-12);
    println!(
        "  elasticity: drain-relocate loses {:.0}% of shed-everything's \
         goodput per revocation",
        100.0 * elasticity_loss_ratio
    );

    // ---- tracing identity + overhead: the canonical STEP cell with
    // the unbounded event log attached vs untraced. The metric row
    // must be byte-identical (recorders never influence scheduling —
    // the `trace_identical` gate), and the wall ratio bounds what
    // tracing costs when it is switched on (`trace_wall_ratio` gate;
    // the disabled-path cost is measured by micro_hotpath).
    let t4 = Instant::now();
    let untraced_cell =
        run_cell(Method::Step, opts.router, Method::Step.name(), &gp, &scorer, &opts);
    let untraced_wall = t4.elapsed().as_secs_f64().max(1e-9);
    let t5 = Instant::now();
    let (traced_cell, trace_events, trace_dropped) = run_traced_cell(&opts, &gp, &scorer);
    let traced_wall = t5.elapsed().as_secs_f64().max(1e-9);
    let trace_identical = cells_fingerprint(std::slice::from_ref(&untraced_cell))
        == cells_fingerprint(std::slice::from_ref(&traced_cell));
    assert!(
        trace_identical,
        "traced STEP cell must be byte-identical to the untraced run"
    );
    assert_eq!(trace_dropped, 0, "the unbounded event log never drops");
    assert!(!trace_events.is_empty(), "the traced cell must record a stream");
    let trace_wall_ratio = traced_wall / untraced_wall;
    println!(
        "  tracing: {} events, wall x{trace_wall_ratio:.2} vs untraced \
         (metric rows byte-identical)",
        trace_events.len()
    );

    // ---- prefix-cache row: the same skewed closed loop rerun through
    // the affinity sweep — cache off first, then on at every credit
    // weight. The gates this section feeds: the registry must actually
    // share prompts (hit rate > 0), sharing must relieve KV pressure
    // (strictly fewer prunes than the no-cache baseline at no accuracy
    // cost), the cache-plus-affinity tail must not exceed the no-cache
    // tail (prefix_p99_ratio <= 1), and the cache-off configuration —
    // whatever the affinity weight says — must stay byte-identical to
    // the default cluster.
    let t6 = Instant::now();
    let affinity = run_affinity_grid(&opts, &gp, &scorer);
    let affinity_s = t6.elapsed().as_secs_f64();
    println!("affinity sweep: {affinity_s:.2}s");
    for c in &affinity {
        println!(
            "  {:>10}: hit={:.1}%  saved_blocks={}  evicted={}  p99={:.1}s  pruned={} \
             acc={:.1}%  shed={:.1}%",
            c.label,
            100.0 * c.prefix_hit_rate,
            c.prefix_saved_blocks,
            c.prefix_evictions,
            c.p99_s,
            c.pruned,
            c.acc,
            100.0 * c.shed_rate,
        );
    }
    // Byte-identity of the prefix-enabled sweep across engine-stepping
    // parallelism (the determinism contract extends to the registry).
    let aff_fp = |cells: &[AffinityCell]| -> String {
        cells
            .iter()
            .map(|c| c.to_json().to_string_pretty())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let aff_step_opts = ClusterOpts { step_threads: threads, ..opts.clone() };
    let affinity_stepped = run_affinity_grid(&aff_step_opts, &gp, &scorer);
    assert_eq!(
        aff_fp(&affinity),
        aff_fp(&affinity_stepped),
        "affinity sweep must be byte-identical across step_threads"
    );
    let base = &affinity[0];
    assert!(!base.prefix_cache && base.prefix_hit_rate == 0.0, "baseline runs cache-off");
    let w_on = affinity
        .iter()
        .find(|c| c.prefix_cache && c.affinity_weight == 0.5)
        .expect("the sweep carries the w0.5 row");
    assert!(
        w_on.prefix_hit_rate > 0.0,
        "the skewed closed loop must share prompts (hit rate 0)"
    );
    assert!(w_on.prefix_saved_blocks > 0, "shared admissions must save blocks");
    assert!(
        base.pruned > 0,
        "the no-cache baseline must prune under this load (else the claim is vacuous)"
    );
    assert!(
        w_on.pruned < base.pruned,
        "shared prompts must relieve pruning pressure ({} vs {})",
        w_on.pruned,
        base.pruned
    );
    assert!(
        w_on.acc >= base.acc,
        "prefix sharing must not cost accuracy ({} vs {})",
        w_on.acc,
        base.acc
    );
    let prefix_p99_ratio = w_on.p99_s / base.p99_s.max(1e-12);
    assert!(
        prefix_p99_ratio <= 1.0 + 1e-9,
        "cache-plus-affinity p99 must not exceed the no-cache tail (x{prefix_p99_ratio:.3})"
    );
    println!(
        "  prefix: hit={:.1}%  saved_blocks={}  pruned {} -> {}  p99 x{prefix_p99_ratio:.2} \
         vs no-cache",
        100.0 * w_on.prefix_hit_rate,
        w_on.prefix_saved_blocks,
        base.pruned,
        w_on.pruned,
    );
    // Off-path identity: prefix off with a non-zero affinity weight is
    // byte-identical to the default STEP cell (the `prefix_off_identical`
    // gate).
    let off_opts = ClusterOpts { affinity_weight: 0.7, ..opts.clone() };
    let off_cell =
        run_cell(Method::Step, off_opts.router, Method::Step.name(), &gp, &scorer, &off_opts);
    let prefix_off_identical = cells_fingerprint(std::slice::from_ref(&untraced_cell))
        == cells_fingerprint(std::slice::from_ref(&off_cell));
    assert!(
        prefix_off_identical,
        "prefix-cache off must stay byte-identical to the default cluster"
    );
    println!("  prefix: cache-off == default (byte-identical metric row)");

    // ---- signal Pareto grid: every pruning signal × pruning method ×
    // memory pressure on the skewed closed loop. Feeds the
    // `signal_pareto` gates: hidden states must not rank below
    // intrinsic confidence on STEP accuracy (same workload, same
    // memory events — only the victim selection differs), and an
    // explicit `--signal hidden-mlp` run must stay byte-identical to
    // the default cell (the trait-refactor identity contract).
    let t7 = Instant::now();
    let pareto = run_signal_grid(&opts, &gp, &scorer);
    let pareto_s = t7.elapsed().as_secs_f64();
    println!("signal pareto grid: {pareto_s:.2}s");
    for c in &pareto {
        println!(
            "  {:>28}: acc={:.1}%  p99={:.1}s  pruned={}  scores={}  prune/step={:.4}",
            c.label, c.acc, c.p99_s, c.pruned, c.step_scores, c.pruned_step_frac,
        );
    }
    let signal_acc_hidden_mlp = signal_step_acc(&pareto, "hidden-mlp");
    let signal_acc_confidence = signal_step_acc(&pareto, "confidence");
    assert!(
        signal_acc_hidden_mlp >= signal_acc_confidence,
        "hidden-mlp STEP accuracy must not fall below confidence \
         ({signal_acc_hidden_mlp} vs {signal_acc_confidence})"
    );
    println!(
        "  signal: STEP acc hidden-mlp {signal_acc_hidden_mlp:.1}% >= confidence \
         {signal_acc_confidence:.1}% (hidden states beat intrinsic confidence)"
    );
    let explicit_opts = ClusterOpts {
        signal: SignalSpec::parse("hidden-mlp").expect("the default signal parses"),
        ..opts.clone()
    };
    let explicit_cell = run_cell(
        Method::Step,
        explicit_opts.router,
        Method::Step.name(),
        &gp,
        &scorer,
        &explicit_opts,
    );
    let signal_default_identical = cells_fingerprint(std::slice::from_ref(&untraced_cell))
        == cells_fingerprint(std::slice::from_ref(&explicit_cell));
    assert!(
        signal_default_identical,
        "--signal hidden-mlp must stay byte-identical to the default cell"
    );
    println!("  signal: explicit hidden-mlp == default (byte-identical metric row)");

    let mut report = metrics_json(&opts, &m_serial, &r_serial);
    attach_migration_grid(&mut report, &mig_opts, &migration);
    attach_affinity_grid(&mut report, &opts, &affinity);
    attach_signal_grid(&mut report, &opts, &pareto);
    if let Json::Obj(map) = &mut report {
        map.insert("bench_serial_s".to_string(), Json::Num(serial_s));
        map.insert("bench_parallel_s".to_string(), Json::Num(parallel_s));
        map.insert("bench_threads".to_string(), Json::Num(threads as f64));
        map.insert("identical_across_threads".to_string(), Json::Bool(true));
        // Intra-simulation engine-stepping fields (expected speedup > 1
        // at R >= 4 GPUs on >= 4 cores; asserted byte-identical above).
        map.insert("step_parallel_s".to_string(), Json::Num(step_parallel_s));
        map.insert("step_threads".to_string(), Json::Num(threads as f64));
        map.insert("step_speedup".to_string(), Json::Num(step_speedup));
        map.insert("identical_across_step_threads".to_string(), Json::Bool(true));
        // Migration-grid gate ratios (on-shed relative to never):
        // shed must not grow; goodput should not fall.
        map.insert("migration_shed_ratio".to_string(), Json::Num(shed_ratio));
        map.insert("migration_goodput_ratio".to_string(), Json::Num(goodput_ratio));
        map.insert("migration_p99_ratio".to_string(), Json::Num(p99_ratio));
        // Fleet-scale events/sec grid (R in {4, 64, 256, 1024}) plus
        // the small-R sharded-vs-flat placement-identity witness.
        map.insert("fleet".to_string(), Json::Arr(fleet_rows));
        map.insert("fleet_threads".to_string(), Json::Num(threads as f64));
        map.insert("shard_flat_identical".to_string(), Json::Bool(shard_flat_identical));
        // Elasticity rows (fixed revocation schedule, R=64):
        // drain-relocate vs shed-everything, with the loss ratio the
        // bench gate bounds at <= 1.
        map.insert("elasticity".to_string(), Json::Arr(ela_rows));
        map.insert("elasticity_config".to_string(), config_json(&ela_base));
        map.insert("elasticity_loss_ratio".to_string(), Json::Num(elasticity_loss_ratio));
        // Observability gates: traced == untraced metric bytes on the
        // canonical STEP cell, and the enabled-tracing wall ratio.
        map.insert("trace_identical".to_string(), Json::Bool(trace_identical));
        map.insert("trace_wall_ratio".to_string(), Json::Num(trace_wall_ratio));
        map.insert("trace_events".to_string(), Json::Num(trace_events.len() as f64));
        // Prefix-cache gates: the w0.5 row's hit rate and saved blocks,
        // its p99 relative to the no-cache baseline (bounded at <= 1),
        // and the cache-off byte-identity witness.
        map.insert("prefix_hit_rate".to_string(), Json::Num(w_on.prefix_hit_rate));
        map.insert(
            "prefix_saved_blocks".to_string(),
            Json::Num(w_on.prefix_saved_blocks as f64),
        );
        map.insert("prefix_p99_ratio".to_string(), Json::Num(prefix_p99_ratio));
        map.insert("prefix_off_identical".to_string(), Json::Bool(prefix_off_identical));
        // Signal-grid identity witness: an explicit `--signal
        // hidden-mlp` run byte-identical to the default STEP cell (the
        // accuracy comparison fields ride in via attach_signal_grid).
        map.insert(
            "signal_default_identical".to_string(),
            Json::Bool(signal_default_identical),
        );
    }
    let path = write_results("BENCH_cluster", &report).expect("writing BENCH_cluster.json");
    println!("wrote {path:?}");
}
