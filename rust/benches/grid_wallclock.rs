//! cargo bench --bench grid_wallclock — end-to-end wall-clock of a quick
//! Table-1-style evaluation grid, serial vs parallel, asserting the two
//! runs produce byte-identical results. Writes the measurement to
//! `results/BENCH_grid.json`.
//!
//! Runs self-contained on the built-in generator defaults (no artifacts
//! needed), so CI and fresh checkouts can benchmark the harness.

use std::time::Instant;

use step::coordinator::method::Method;
use step::harness::cells::{projection_scorer, run_cells, CellJob, CellOpts};
use step::harness::write_results;
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::GenParams;
use step::util::json::Json;
use step::util::pool;

fn main() {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);

    let mut jobs = Vec::new();
    for model in [ModelId::Qwen3_4B, ModelId::DeepSeek8B] {
        for bench in [BenchId::Aime25, BenchId::GpqaDiamond] {
            for method in Method::ALL {
                jobs.push(CellJob {
                    model,
                    bench,
                    method,
                    opts: CellOpts {
                        n_traces: 32,
                        max_questions: Some(6),
                        ..Default::default()
                    },
                });
            }
        }
    }
    let threads = pool::available_parallelism();
    println!(
        "grid: {} cells x 6 questions x 32 traces; {} hardware threads",
        jobs.len(),
        threads
    );

    let t0 = Instant::now();
    let serial = run_cells(&jobs, &gp, &scorer, 1);
    let serial_s = t0.elapsed().as_secs_f64();
    println!("serial:   {serial_s:.2}s");

    let t1 = Instant::now();
    let parallel = run_cells(&jobs, &gp, &scorer, threads);
    let parallel_s = t1.elapsed().as_secs_f64();
    println!("parallel: {parallel_s:.2}s  ({threads} threads)");

    let ser_json = Json::Arr(serial.iter().map(|c| c.to_json()).collect()).to_string_pretty();
    let par_json = Json::Arr(parallel.iter().map(|c| c.to_json()).collect()).to_string_pretty();
    assert_eq!(ser_json, par_json, "parallel grid must be byte-identical to serial");

    let speedup = serial_s / parallel_s.max(1e-9);
    println!("speedup:  {speedup:.2}x (results byte-identical)");

    let report = Json::obj(vec![
        ("cells", Json::Num(jobs.len() as f64)),
        ("questions_per_cell", Json::Num(6.0)),
        ("n_traces", Json::Num(32.0)),
        ("threads", Json::Num(threads as f64)),
        ("serial_s", Json::Num(serial_s)),
        ("parallel_s", Json::Num(parallel_s)),
        ("speedup", Json::Num(speedup)),
        ("identical", Json::Bool(true)),
    ]);
    let path = write_results("BENCH_grid", &report).expect("writing BENCH_grid.json");
    println!("wrote {path:?}");
}
