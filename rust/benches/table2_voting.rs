//! cargo bench --bench table2_voting — regenerates Table 2 (majority vs
//! PRM-weighted vs STEP-weighted voting on identical trace sets).
use step::harness::{table2, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(20), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    table2::run(&opts).expect("table2 (needs `make artifacts`)");
    println!("\n[bench] table2 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
