//! cargo bench --bench table3_breakdown — regenerates Table 3 (waiting
//! vs decoding wall-clock, DeepSeek-8B / HMMT-25 / N=64) and asserts the
//! paper's headline systems claims.
use step::coordinator::method::Method;
use step::harness::{table3, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(15), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    let rows = table3::run(&opts).expect("table3 (needs `make artifacts`)");
    let get = |m: Method| rows.iter().find(|r| r.method == m).unwrap();
    assert_eq!(get(Method::Step).wait_s, 0.0, "STEP must have zero wait");
    assert!(get(Method::Sc).wait_s > get(Method::Sc).decode_s * 0.5,
            "SC must wait substantially");
    println!("\n[bench] table3 regenerated in {:.1}s (claims hold)", t0.elapsed().as_secs_f64());
}
