//! cargo bench --bench fig2_motivation — regenerates Fig 2: (a) score
//! distributions at 25/50/75% of steps, (b) incorrect-longer token skew,
//! (c) the SC waiting/decoding time split.
use step::harness::{fig2, HarnessOpts};

fn main() {
    let opts = HarnessOpts { max_questions: Some(15), n_traces: 64, seed: 0, ..Default::default() };
    let t0 = std::time::Instant::now();
    fig2::run(&opts).expect("fig2 (needs `make artifacts`)");
    println!("\n[bench] fig2 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
