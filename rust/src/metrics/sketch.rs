//! Streaming latency percentile sketch: fixed log-spaced buckets,
//! O(1) memory, deterministic, mergeable.
//!
//! [`super::LatencyHistogram`] stores every sample, which is fine for a
//! few hundred questions but wrong for a serving harness meant to scale
//! to millions of requests. [`LatencySketch`] keeps only bucket counts
//! over a geometric grid (2% resolution from 0.1 ms to weeks), so:
//!
//! * `record` is O(1) and allocation-free,
//! * quantiles have bounded *relative* error (at most one bucket, ~2%,
//!   always on the high side),
//! * sketches from independent shards [`merge`](LatencySketch::merge)
//!   exactly (bucket-wise addition), and
//! * results are bit-deterministic: counts are integers and the reported
//!   quantile is a pure function of the counts.
//!
//! The `table5_serving` harness reports its p50/p95/p99 figures from
//! this sketch.

/// Smallest resolvable latency (lower bound of bucket 0), seconds.
const LO: f64 = 1e-4;
/// Geometric bucket growth factor (2% relative resolution).
const GAMMA: f64 = 1.02;
/// Bucket count: covers up to `LO * GAMMA^(N-1)` ≈ 2e6 s (~3 weeks).
const N_BUCKETS: usize = 1200;

/// Mergeable log-bucket quantile sketch over non-negative latencies.
///
/// # Examples
///
/// ```
/// use step::metrics::LatencySketch;
///
/// let mut s = LatencySketch::new();
/// for v in 1..=100 {
///     s.record(v as f64);
/// }
/// assert_eq!(s.count(), 100);
/// let p50 = s.percentile_s(50.0);
/// assert!((p50 - 50.0).abs() / 50.0 < 0.03, "p50 = {p50}");
/// assert_eq!(s.percentile_s(100.0), 100.0);
/// ```
#[derive(Debug, Clone)]
pub struct LatencySketch {
    counts: Vec<u64>,
    total: u64,
    min_s: f64,
    max_s: f64,
    sum_s: f64,
}

impl Default for LatencySketch {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket index of a value: bucket 0 is `(-inf, LO]`, bucket i > 0 is
/// `(LO * GAMMA^(i-1), LO * GAMMA^i]`.
fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= LO {
        return 0;
    }
    let i = ((v / LO).ln() / GAMMA.ln()).ceil();
    (i as usize).min(N_BUCKETS - 1)
}

/// Representative value of a bucket: its upper bound, so quantile
/// estimates are biased at most one bucket (2%) high and never low.
fn bucket_value(i: usize) -> f64 {
    LO * GAMMA.powf(i as f64)
}

impl LatencySketch {
    /// An empty sketch.
    pub fn new() -> LatencySketch {
        LatencySketch {
            counts: vec![0; N_BUCKETS],
            total: 0,
            min_s: f64::INFINITY,
            max_s: 0.0,
            sum_s: 0.0,
        }
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        self.counts[bucket_of(seconds)] += 1;
        self.total += 1;
        self.min_s = self.min_s.min(seconds);
        self.max_s = self.max_s.max(seconds);
        self.sum_s += seconds;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Arithmetic mean of the samples (exact; tracked outside buckets).
    pub fn mean_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_s / self.total as f64
        }
    }

    /// Smallest recorded sample (exact). 0.0 when empty.
    pub fn min_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    /// Largest recorded sample (exact). 0.0 when empty.
    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Quantile estimate for `q` in [0, 100]: the upper bound of the
    /// bucket holding the ceil(q% * n)-th order statistic, clamped to the
    /// exact observed [min, max]. The estimate is biased at most one
    /// bucket (~2%) high and never low; p100 is exact.
    pub fn percentile_s(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        if rank == self.total {
            return self.max_s; // p100 (and tiny n) are exact
        }
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_value(i).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Fold another sketch into this one (exact bucket-wise addition).
    pub fn merge(&mut self, other: &LatencySketch) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
        self.sum_s += other.sum_s;
    }

    /// One-line report: `name: n=… mean=… p50=… p95=… p99=… max=…`.
    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s max={:.3}s",
            self.count(),
            self.mean_s(),
            self.percentile_s(50.0),
            self.percentile_s(95.0),
            self.percentile_s(99.0),
            self.max_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_track_exact_within_resolution() {
        let mut s = LatencySketch::new();
        for v in 1..=1000u32 {
            s.record(v as f64 / 10.0); // 0.1 .. 100.0 s
        }
        assert_eq!(s.count(), 1000);
        for (q, exact) in [(50.0, 50.0), (95.0, 95.0), (99.0, 99.0)] {
            let est = s.percentile_s(q);
            assert!(
                (est - exact).abs() / exact < 0.03,
                "p{q}: {est} vs {exact}"
            );
        }
        assert_eq!(s.percentile_s(100.0), 100.0);
        assert!((s.mean_s() - 50.05).abs() < 1e-9);
    }

    #[test]
    fn extremes_clamp_to_observed_range() {
        let mut s = LatencySketch::new();
        s.record(1e-9); // below the grid
        s.record(1e9); // above the grid
        assert_eq!(s.count(), 2);
        assert_eq!(s.min_s(), 1e-9);
        assert_eq!(s.max_s(), 1e9);
        assert!(s.percentile_s(0.0) >= 1e-9);
        assert_eq!(s.percentile_s(100.0), 1e9);
    }

    #[test]
    fn empty_sketch_reports_zeros() {
        let s = LatencySketch::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean_s(), 0.0);
        assert_eq!(s.percentile_s(99.0), 0.0);
        assert_eq!(s.min_s(), 0.0);
    }

    #[test]
    fn merge_equals_union() {
        let mut a = LatencySketch::new();
        let mut b = LatencySketch::new();
        let mut whole = LatencySketch::new();
        for v in 1..=200u32 {
            let x = v as f64 / 7.0;
            whole.record(x);
            if v % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for q in [10.0, 50.0, 90.0, 99.0] {
            assert_eq!(a.percentile_s(q), whole.percentile_s(q));
        }
        assert_eq!(a.max_s(), whole.max_s());
    }

    #[test]
    fn deterministic_summary() {
        let mut s = LatencySketch::new();
        for v in [0.5, 1.5, 2.5] {
            s.record(v);
        }
        assert_eq!(s.summary("x"), s.clone().summary("x"));
        assert!(s.summary("x").contains("n=3"));
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for v in [1e-5, 1e-4, 1e-3, 0.1, 1.0, 60.0, 3600.0, 1e5] {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of not monotone at {v}");
            last = b;
        }
        assert!(bucket_of(f64::INFINITY) == N_BUCKETS - 1);
    }
}
