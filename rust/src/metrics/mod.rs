//! Serving metrics: latency histograms, streaming percentile sketches,
//! engine and cluster counters, and the wait/decode timeline recorder
//! behind Table 3 / Fig 2c-style reports, the `table5_serving` SLO
//! report, and the `table6_cluster` goodput/shed-rate report (the
//! cluster merges its per-GPU [`LatencySketch`]es bucket-wise into the
//! cluster-wide percentiles).

pub mod sketch;

pub use sketch::LatencySketch;

use crate::util::stats::{mean, percentile};

/// Fixed-boundary log-scale histogram (ns .. hours).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds (log-spaced).
    bounds: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram over the fixed log-spaced grid.
    pub fn new() -> Self {
        // 1us .. ~3h in x2 steps.
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 10_000.0 {
            bounds.push(b);
            b *= 2.0;
        }
        let n = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; n + 1], samples: Vec::new() }
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.samples.push(seconds);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Arithmetic mean of the samples.
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }

    /// Exact sample percentile (`q` in [0, 100]; sorts the samples).
    pub fn percentile_s(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    /// One-line report: `name: n=… mean=… p50=… p95=… p99=… max=…`.
    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s max={:.3}s",
            self.count(),
            self.mean_s(),
            self.percentile_s(50.0),
            self.percentile_s(95.0),
            self.percentile_s(99.0),
            self.percentile_s(100.0),
        )
    }
}

/// Engine-level counters for one run (requests, tokens, policy events).
#[derive(Debug, Clone, Default)]
pub struct EngineCounters {
    /// Requests served (1 for the single-question engines).
    pub requests: u64,
    /// Tokens generated across all traces.
    pub generated_tokens: u64,
    /// Continuous-batching decode iterations executed.
    pub decode_iterations: u64,
    /// Preemption events (SC-family memory events).
    pub preemptions: u64,
    /// Waiting-queue resumes (recompute-on-resume prefills).
    pub resumes: u64,
    /// Traces removed by pruning policies.
    pub pruned: u64,
    /// Traces stopped early by DeepConf's confidence check.
    pub early_stopped: u64,
    /// Step-scorer invocations.
    pub step_scores: u64,
    /// Scheduler events processed: every `step_event` call that
    /// advanced engine state (a decode interval, a memory event, or a
    /// resume/drop pass). The denominator of the cluster bench's
    /// events/sec throughput metric.
    pub events: u64,
    /// Prefix-cache admissions that reused pinned prompt blocks
    /// (registry hits; zero with `--prefix-cache` off).
    pub prefix_hits: u64,
    /// Prefix-cache admissions that pinned prompt blocks fresh
    /// (registry misses — sub-block prompts with nothing shareable
    /// included).
    pub prefix_misses: u64,
    /// Prompt KV blocks registry hits did not have to allocate or
    /// prefill — the capacity the cache multiplied.
    pub prefix_saved_blocks: u64,
    /// Zero-reference registry entries evicted under pool pressure.
    pub prefix_evictions: u64,
}

impl EngineCounters {
    /// Fold another engine's counters into this one (the cluster
    /// simulator aggregates its per-GPU engines this way).
    pub fn add(&mut self, other: &EngineCounters) {
        self.requests += other.requests;
        self.generated_tokens += other.generated_tokens;
        self.decode_iterations += other.decode_iterations;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.pruned += other.pruned;
        self.early_stopped += other.early_stopped;
        self.step_scores += other.step_scores;
        self.events += other.events;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefix_saved_blocks += other.prefix_saved_blocks;
        self.prefix_evictions += other.prefix_evictions;
    }

    /// Fraction of prefix-cache admissions that hit the registry
    /// (0 when the cache saw no admissions, e.g. `--prefix-cache` off).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    /// One-line `key=value` report of every counter.
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} iters={} preemptions={} resumes={} \
             pruned={} early_stopped={} scores={} events={} prefix_hits={} \
             prefix_misses={} prefix_saved_blocks={} prefix_evictions={}",
            self.requests,
            self.generated_tokens,
            self.decode_iterations,
            self.preemptions,
            self.resumes,
            self.pruned,
            self.early_stopped,
            self.step_scores,
            self.events,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_saved_blocks,
            self.prefix_evictions,
        )
    }
}

/// Cluster-level request accounting: what the admission layer did with
/// every offered request. Conservation laws (asserted by
/// `tests/prop_invariants.rs` and `tests/chaos.rs`):
/// `offered == placed + shed`, and at the end of a run
/// `completed + shed_on_revoke == placed` (with a static fleet
/// `shed_on_revoke == 0` and the old `completed == placed` holds).
#[derive(Debug, Clone, Default)]
pub struct ClusterCounters {
    /// Arrivals presented to admission control.
    pub offered: u64,
    /// Requests routed onto some GPU (directly or after queueing).
    pub placed: u64,
    /// Requests rejected by admission control (bounded queue overflow
    /// or SLO-aware early reject).
    pub shed: u64,
    /// Requests that ran to completion.
    pub completed: u64,
    /// Peak depth of the cluster-wide admission queue.
    pub queue_peak: u64,
    /// Requests relocated to another GPU by the migration policy
    /// (shed rescues, pressure rebalances, and last-survivor rescues).
    pub migrated: u64,
    /// Prefix tokens (prompt + generated) the targets recompute to
    /// resume migrated traces — the work-preservation bill.
    pub migration_recompute_tokens: u64,
    /// Migrations that rescued a request from losing work outright: a
    /// memory event about to prune its last surviving trace.
    pub migration_saved: u64,
    /// Spot revocations fired by the fleet schedule.
    pub revocations: u64,
    /// Requests that completed naturally on a draining GPU before its
    /// revocation deadline.
    pub drained: u64,
    /// Residents relocated off a draining GPU by the drain controller
    /// before the deadline (a subset of `migrated`).
    pub rescue_migrated: u64,
    /// Residents the deadline force-clear had to abandon — placed work
    /// that never completes. Zero with a static fleet.
    pub shed_on_revoke: u64,
}

impl ClusterCounters {
    /// Fraction of offered requests shed (0 when nothing was offered).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Completed requests per second of cluster makespan — the serving
    /// goodput (sheds do not count).
    pub fn goodput_rps(&self, makespan_s: f64) -> f64 {
        if makespan_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / makespan_s
        }
    }

    /// Goodput lost per revocation: every request that was dropped —
    /// shed at admission or abandoned by a deadline force-clear —
    /// amortized over the revocations that destabilized the fleet.
    /// Zero when no revocation fired.
    pub fn goodput_lost_per_revocation(&self) -> f64 {
        if self.revocations == 0 {
            0.0
        } else {
            (self.shed + self.shed_on_revoke) as f64 / self.revocations as f64
        }
    }

    /// One-line `key=value` report of every counter.
    pub fn report(&self) -> String {
        format!(
            "offered={} placed={} shed={} completed={} queue_peak={} \
             migrated={} migration_recompute_tok={} migration_saved={} \
             revocations={} drained={} rescue_migrated={} shed_on_revoke={}",
            self.offered,
            self.placed,
            self.shed,
            self.completed,
            self.queue_peak,
            self.migrated,
            self.migration_recompute_tokens,
            self.migration_saved,
            self.revocations,
            self.drained,
            self.rescue_migrated,
            self.shed_on_revoke,
        )
    }
}

/// Wall-clock split between queue-empty (decode) and queue-non-empty
/// (wait) engine phases — Table 3's decomposition.
#[derive(Debug, Clone, Copy, Default)]
pub struct TimelineSplit {
    /// Wall-clock with a non-empty waiting queue.
    pub wait_s: f64,
    /// Wall-clock with an empty waiting queue.
    pub decode_s: f64,
}

impl TimelineSplit {
    /// Accrue `dt` seconds into the wait or decode bucket.
    pub fn accrue(&mut self, dt: f64, queue_non_empty: bool) {
        if queue_non_empty {
            self.wait_s += dt;
        } else {
            self.decode_s += dt;
        }
    }

    /// Total accrued wall-clock.
    pub fn total(&self) -> f64 {
        self.wait_s + self.decode_s
    }

    /// Fraction of wall-clock spent with a non-empty waiting queue.
    pub fn wait_fraction(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.wait_s / self.total()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_summarizes() {
        let mut h = LatencyHistogram::new();
        for v in [0.001, 0.002, 0.004, 1.0, 2.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_s() > 0.5 && h.mean_s() < 1.0);
        assert!(h.percentile_s(100.0) == 2.0);
        assert!(h.summary("x").contains("n=5"));
    }

    #[test]
    fn histogram_handles_extremes() {
        let mut h = LatencyHistogram::new();
        h.record(1e-9); // below first bound
        h.record(1e9); // above last bound
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn timeline_split_accrues() {
        let mut t = TimelineSplit::default();
        t.accrue(3.0, true);
        t.accrue(1.0, false);
        assert_eq!(t.wait_s, 3.0);
        assert_eq!(t.decode_s, 1.0);
        assert!((t.wait_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counters_report() {
        let mut c = EngineCounters::default();
        c.requests = 2;
        c.pruned = 5;
        let r = c.report();
        assert!(r.contains("requests=2") && r.contains("pruned=5"));
    }

    #[test]
    fn engine_counters_add_is_fieldwise() {
        let mut a = EngineCounters { requests: 1, pruned: 2, ..Default::default() };
        let b = EngineCounters { requests: 3, preemptions: 7, ..Default::default() };
        a.add(&b);
        assert_eq!(a.requests, 4);
        assert_eq!(a.pruned, 2);
        assert_eq!(a.preemptions, 7);
    }

    #[test]
    fn prefix_counters_fold_and_rate() {
        let mut a = EngineCounters {
            prefix_hits: 3,
            prefix_misses: 1,
            prefix_saved_blocks: 12,
            ..Default::default()
        };
        let b = EngineCounters {
            prefix_hits: 1,
            prefix_misses: 3,
            prefix_evictions: 2,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.prefix_hits, 4);
        assert_eq!(a.prefix_misses, 4);
        assert_eq!(a.prefix_saved_blocks, 12);
        assert_eq!(a.prefix_evictions, 2);
        assert!((a.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(EngineCounters::default().prefix_hit_rate(), 0.0);
        assert!(a.report().contains("prefix_hits=4"));
    }

    #[test]
    fn cluster_counters_rates() {
        let c = ClusterCounters {
            offered: 10,
            placed: 8,
            shed: 2,
            completed: 6,
            queue_peak: 3,
            migrated: 4,
            migration_recompute_tokens: 1200,
            migration_saved: 1,
            revocations: 2,
            drained: 1,
            rescue_migrated: 3,
            shed_on_revoke: 2,
        };
        assert!((c.shed_rate() - 0.2).abs() < 1e-12);
        assert!((c.goodput_rps(4.0) - 1.5).abs() < 1e-12);
        assert_eq!(ClusterCounters::default().shed_rate(), 0.0);
        assert_eq!(c.goodput_rps(0.0), 0.0);
        // (shed + shed_on_revoke) / revocations = (2 + 2) / 2.
        assert!((c.goodput_lost_per_revocation() - 2.0).abs() < 1e-12);
        assert_eq!(ClusterCounters::default().goodput_lost_per_revocation(), 0.0);
        assert!(c.report().contains("shed=2"));
        assert!(c.report().contains("migrated=4"));
        assert!(c.report().contains("migration_recompute_tok=1200"));
        assert!(c.report().contains("migration_saved=1"));
        assert!(c.report().contains("revocations=2"));
        assert!(c.report().contains("drained=1"));
        assert!(c.report().contains("rescue_migrated=3"));
        assert!(c.report().contains("shed_on_revoke=2"));
    }
}
