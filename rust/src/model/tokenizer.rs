//! Minimal tokenizer for the e2e tiny reasoning LM. Token-id conventions
//! are shared with `python/compile/model.py` (ModelConfig):
//! 0 = PAD, 1 = BOS, 2 = EOS (`</think>`), 3 = STEP (`\n\n`),
//! 4..=13 = digits 0-9, 14 = '+', 15 = '=', 16.. = hashed word ids.

/// Padding token id.
pub const PAD: i32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: i32 = 1;
/// End-of-sequence token id (`</think>`).
pub const EOS: i32 = 2;
/// Step-boundary token id ("\n\n").
pub const STEP: i32 = 3;
/// First digit token id; digits 0-9 are `DIGIT_BASE..DIGIT_BASE + 10`.
pub const DIGIT_BASE: i32 = 4;
/// '+' token id.
pub const PLUS: i32 = 14;
/// '=' token id.
pub const EQUALS: i32 = 15;
const WORD_BASE: i32 = 16;

/// Tokenizer over a fixed vocab size (the LM's `vocab`).
#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    /// Vocabulary size of the served LM.
    pub vocab: usize,
}

impl Tokenizer {
    /// Tokenizer for a vocab of the given size (> 16 for the word region).
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > WORD_BASE as usize);
        Tokenizer { vocab }
    }

    fn word_id(&self, w: &str) -> i32 {
        // FNV-1a into the word region of the vocab.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in w.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        WORD_BASE + (h % (self.vocab as u64 - WORD_BASE as u64)) as i32
    }

    /// Encode text: words split on whitespace; digits/+/= tokenized
    /// character-wise; "\n\n" becomes STEP.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = vec![BOS];
        for seg in text.split("\n\n") {
            if out.len() > 1 {
                out.push(STEP);
            }
            for w in seg.split_whitespace() {
                if w.chars().all(|c| c.is_ascii_digit() || c == '+' || c == '=') {
                    for c in w.chars() {
                        out.push(match c {
                            '+' => PLUS,
                            '=' => EQUALS,
                            d => DIGIT_BASE + (d as u8 - b'0') as i32,
                        });
                    }
                } else {
                    out.push(self.word_id(w));
                }
            }
        }
        out
    }

    /// Decode the digits of a generated suffix into an answer string
    /// (what the rule-based verifier parses). Non-digit tokens break the
    /// number; the last complete run of digits wins.
    pub fn extract_answer(&self, tokens: &[i32]) -> Option<String> {
        let mut runs: Vec<String> = Vec::new();
        let mut cur = String::new();
        for &t in tokens {
            if (DIGIT_BASE..DIGIT_BASE + 10).contains(&t) {
                cur.push((b'0' + (t - DIGIT_BASE) as u8) as char);
            } else if !cur.is_empty() {
                runs.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            runs.push(cur);
        }
        runs.pop()
    }

    /// Is this the step-boundary token?
    pub fn is_step(&self, t: i32) -> bool {
        t == STEP
    }

    /// Is this the end-of-sequence token?
    pub fn is_eos(&self, t: i32) -> bool {
        t == EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_structure() {
        let tk = Tokenizer::new(512);
        let ids = tk.encode("compute 12+7\n\nthink hard");
        assert_eq!(ids[0], BOS);
        assert!(ids.contains(&STEP));
        assert!(ids.contains(&(DIGIT_BASE + 1))); // '1'
        assert!(ids.contains(&(DIGIT_BASE + 2))); // '2'
        assert!(ids.contains(&PLUS));
        assert!(ids.iter().all(|&t| (t as usize) < 512));
    }

    #[test]
    fn word_ids_deterministic_and_in_range() {
        let tk = Tokenizer::new(512);
        assert_eq!(tk.word_id("hello"), tk.word_id("hello"));
        assert_ne!(tk.word_id("hello"), tk.word_id("world"));
        assert!(tk.word_id("anything") >= WORD_BASE);
    }

    #[test]
    fn extracts_last_digit_run() {
        let tk = Tokenizer::new(512);
        let toks = [
            DIGIT_BASE + 3, // 3
            STEP,
            DIGIT_BASE + 4,
            DIGIT_BASE + 2, // 42
            EOS,
        ];
        assert_eq!(tk.extract_answer(&toks).as_deref(), Some("42"));
        assert_eq!(tk.extract_answer(&[STEP, EOS]), None);
    }
}
