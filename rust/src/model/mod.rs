//! Serving-side model utilities for the e2e engine: the tokenizer shared
//! with `python/compile/model.py` and the rust-side sampler.

pub mod sampler;
pub mod tokenizer;

pub use sampler::{sample, SamplerConfig};
pub use tokenizer::Tokenizer;
