//! Token sampling on the rust side of the serving loop: temperature +
//! top-k + top-p (the Appendix-B sampling parameters). Operates on raw
//! f32 logits returned by the decode graph; PJRT never samples.

use crate::util::rng::Rng;

/// Sampling parameters (paper Appendix B): temperature, top-k, top-p.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    /// Softmax temperature; <= 0 means greedy argmax.
    pub temperature: f64,
    /// Keep only the k highest-logit candidates.
    pub top_k: usize,
    /// Nucleus threshold: smallest prefix with cumulative mass >= top_p.
    pub top_p: f64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { temperature: 0.6, top_k: 20, top_p: 0.95 }
    }
}

/// Sample a token id from logits.
pub fn sample(logits: &[f32], cfg: &SamplerConfig, rng: &mut Rng) -> usize {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    // Top-k: indices of the k largest logits.
    let k = cfg.top_k.max(1).min(logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        logits[b].partial_cmp(&logits[a]).unwrap()
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());

    // Softmax at temperature over the k candidates.
    let inv_t = 1.0 / cfg.temperature;
    let max = logits[idx[0]] as f64;
    let mut probs: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] as f64 - max) * inv_t).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= total;
    }

    // Top-p: smallest prefix with cumulative mass >= top_p.
    let mut cum = 0.0;
    let mut cut = probs.len();
    for (i, &p) in probs.iter().enumerate() {
        cum += p;
        if cum >= cfg.top_p {
            cut = i + 1;
            break;
        }
    }
    probs.truncate(cut);
    idx[rng.categorical(&probs)]
}

/// Index of the largest logit (greedy decoding).
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_at_zero_temperature() {
        let mut rng = Rng::new(0);
        let logits = [0.1, 5.0, -2.0, 1.0];
        let cfg = SamplerConfig { temperature: 0.0, top_k: 4, top_p: 1.0 };
        for _ in 0..10 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn top_k_one_is_greedy() {
        let mut rng = Rng::new(1);
        let logits = [0.0, 3.0, 2.9, -1.0];
        let cfg = SamplerConfig { temperature: 1.0, top_k: 1, top_p: 1.0 };
        for _ in 0..10 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 1);
        }
    }

    #[test]
    fn respects_top_k_support() {
        let mut rng = Rng::new(2);
        let logits = [10.0, 9.5, -50.0, -50.0];
        let cfg = SamplerConfig { temperature: 1.0, top_k: 2, top_p: 1.0 };
        for _ in 0..100 {
            let t = sample(&logits, &cfg, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn distribution_tracks_probabilities() {
        let mut rng = Rng::new(3);
        // logit gap of ln(3): P(0) = 0.75, P(1) = 0.25.
        let logits = [3.0f32.ln(), 0.0, -100.0, -100.0];
        let cfg = SamplerConfig { temperature: 1.0, top_k: 4, top_p: 1.0 };
        let n = 20_000;
        let zeros = (0..n).filter(|_| sample(&logits, &cfg, &mut rng) == 0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn top_p_truncates_tail() {
        let mut rng = Rng::new(4);
        // P = [0.5, 0.3, 0.15, 0.05]; top_p=0.7 keeps {0, 1}.
        let logits: Vec<f32> =
            [0.5f64, 0.3, 0.15, 0.05].iter().map(|p| p.ln() as f32).collect();
        let cfg = SamplerConfig { temperature: 1.0, top_k: 4, top_p: 0.7 };
        for _ in 0..200 {
            let t = sample(&logits, &cfg, &mut rng);
            assert!(t <= 1, "sampled tail token {t}");
        }
    }
}
