//! In-tree substrates forced by the offline vendor set (DESIGN.md §3):
//! JSON, PRNG/distributions, statistics, and a bench harness.

pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
