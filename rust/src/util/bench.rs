//! In-tree micro-benchmark harness (no criterion in the offline vendor
//! set). Benches are `harness = false` binaries that call [`Bench::run`]
//! per case; output is a criterion-like line per case plus a summary
//! suitable for EXPERIMENTS.md.

use std::time::Instant;

/// One timed case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Case label.
    pub name: String,
    /// Samples collected.
    pub iters: u64,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Population standard deviation, nanoseconds.
    pub stddev_ns: f64,
    /// Fastest sample, nanoseconds.
    pub min_ns: f64,
    /// Slowest sample, nanoseconds.
    pub max_ns: f64,
    /// Optional throughput annotation (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl CaseResult {
    /// Print the criterion-style one-line report.
    pub fn print(&self) {
        let (mean, unit) = humanize(self.mean_ns);
        let (sd, sd_unit) = humanize(self.stddev_ns);
        let mut line = format!(
            "{:<44} {:>10.3} {:<3} (+/- {:.3} {}) [{} iters]",
            self.name, mean, unit, sd, sd_unit, self.iters
        );
        if let Some(items) = self.items_per_iter {
            let rate = items / (self.mean_ns / 1e9);
            line.push_str(&format!("  {:.2e} items/s", rate));
        }
        println!("{line}");
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1_000.0 {
        (ns, "ns")
    } else if ns < 1_000_000.0 {
        (ns / 1e3, "us")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Bench runner: warms up, then samples until `target_time_s` or
/// `max_iters`, whichever first.
pub struct Bench {
    /// Untimed warmup iterations before sampling.
    pub warmup_iters: u64,
    /// Sampling budget per case, seconds.
    pub target_time_s: f64,
    /// Hard cap on samples per case.
    pub max_iters: u64,
    /// Results of every case run so far.
    pub results: Vec<CaseResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            target_time_s: 2.0,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// Short sampling budget for smoke runs.
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, target_time_s: 0.5, max_iters: 1000, ..Default::default() }
    }

    /// Time `f`, which must do one unit of work per call. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &CaseResult {
        self.run_items(name, None, &mut f)
    }

    /// Like [`Bench::run`], annotating throughput as `items` per iteration.
    pub fn run_with_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &CaseResult {
        self.run_items(name, Some(items), &mut f)
    }

    fn run_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut impl FnMut() -> T,
    ) -> &CaseResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let started = Instant::now();
        while started.elapsed().as_secs_f64() < self.target_time_s
            && (samples_ns.len() as u64) < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let n = samples_ns.len().max(1) as f64;
        let mean = samples_ns.iter().sum::<f64>() / n;
        let var = samples_ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let result = CaseResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: samples_ns.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ns: samples_ns.iter().cloned().fold(0.0, f64::max),
            items_per_iter: items,
        };
        result.print();
        self.results.push(result);
        self.results.last().unwrap()
    }
}

/// Optimization barrier (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench { warmup_iters: 1, target_time_s: 0.05, max_iters: 100, results: vec![] };
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(super::humanize(500.0).1, "ns");
        assert_eq!(super::humanize(5_000.0).1, "us");
        assert_eq!(super::humanize(5_000_000.0).1, "ms");
        assert_eq!(super::humanize(5e9).1, "s");
    }
}
