//! Minimal JSON parser/serializer substrate.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure (no serde/serde_json), so artifact manifests, scorer weight
//! bundles, config files and result reports are handled by this in-tree
//! implementation. It supports the full JSON grammar we emit from
//! `python/compile` (objects, arrays, numbers incl. exponents, strings
//! with escapes, bools, null) and pretty/compact serialization.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------ typed accessors

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrowed string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrowed element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Borrowed key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// `[f32]` view of a numeric array (scorer weights, signal dirs).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// `Vec<usize>` view of a numeric array (shapes, batch lists).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as usize);
        }
        Some(out)
    }

    // --------------------------------------------------------- construction

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array from an `f64` slice.
    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v)).collect())
    }

    // -------------------------------------------------------- serialization

    /// Serialize without whitespace (one line).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation (the `results/*.json` format).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    Self::newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * depth {
                out.push(' ');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""A\t\"q\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"q\"");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,-3],"s":"a\"b","t":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.0, 2.0])),
            ("y", Json::Str("z".into())),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn f32_vec_accessor() {
        let v = Json::parse("[1, 2.5, -1e-3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -0.001]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec().is_none());
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(Json::Num(1.0).get("k"), &Json::Null);
    }
}
