//! In-tree work-stealing parallel runner (no rayon in the offline vendor
//! set; std::thread only).
//!
//! [`parallel_map`] executes `f(0) .. f(n_items - 1)` on a fixed set of
//! worker threads and returns the results **in index order**, so output
//! is independent of scheduling. Each worker owns a contiguous slice of
//! the index space and pops from its front; an idle worker steals single
//! indices from the *back* of the busiest remaining queue, which keeps
//! owners and thieves off each other's cache lines for coarse-grained
//! jobs (a DES question costs milliseconds, so per-index locking is
//! noise).
//!
//! Determinism contract: as long as `f` is a pure function of its index
//! (the harness derives every RNG stream from `(seed, qid)`), the result
//! vector is bit-identical for any thread count — the property
//! `tests/parallel_determinism.rs` locks in.
//!
//! [`parallel_for_each_mut`] is the in-place sibling: disjoint `&mut`
//! items (e.g. the cluster simulator's independent per-GPU engines)
//! mutated concurrently, one contiguous chunk per worker.

use std::sync::Mutex;

/// Half-open index range owned by one worker.
struct Span {
    lo: usize,
    hi: usize,
}

/// Hardware parallelism (>= 1).
pub fn available_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested worker count: 0 means "auto" (all cores).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_parallelism()
    } else {
        requested
    }
}

/// Pop the next index for worker `w`: own queue front first, then steal
/// one index from the back of the victim with the most remaining work.
fn next_index(queues: &[Mutex<Span>], w: usize) -> Option<usize> {
    {
        let mut q = queues[w].lock().unwrap();
        if q.lo < q.hi {
            let i = q.lo;
            q.lo += 1;
            return Some(i);
        }
    }
    loop {
        let mut best: Option<(usize, usize)> = None; // (victim, remaining)
        for (v, m) in queues.iter().enumerate() {
            if v == w {
                continue;
            }
            let q = m.lock().unwrap();
            let rem = q.hi - q.lo;
            let better = match best {
                None => rem > 0,
                Some((_, b)) => rem > b,
            };
            if better {
                best = Some((v, rem));
            }
        }
        let (v, _) = best?;
        let mut q = queues[v].lock().unwrap();
        if q.lo < q.hi {
            q.hi -= 1;
            return Some(q.hi);
        }
        // Lost the race to the owner; rescan for another victim.
    }
}

/// Map `f` over `0..n_items` on up to `threads` workers (0 = auto).
/// Results are returned in index order.
pub fn parallel_map<T, F>(threads: usize, n_items: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_with(threads, n_items, || (), |(), i| f(i))
}

/// Like [`parallel_map`], with a per-worker scratch state created by
/// `init` once per worker and threaded through every call that worker
/// executes — the hook that lets hot paths reuse allocation-heavy
/// buffers (e.g. `sim::des::Scratch`) across work items.
pub fn parallel_map_with<S, T, FS, F>(threads: usize, n_items: usize, init: FS, f: F) -> Vec<T>
where
    T: Send,
    FS: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = resolve_threads(threads).min(n_items.max(1));
    if threads <= 1 || n_items <= 1 {
        let mut state = init();
        return (0..n_items).map(|i| f(&mut state, i)).collect();
    }

    let queues: Vec<Mutex<Span>> = (0..threads)
        .map(|w| {
            Mutex::new(Span {
                lo: w * n_items / threads,
                hi: (w + 1) * n_items / threads,
            })
        })
        .collect();

    let mut slots: Vec<Option<T>> = Vec::with_capacity(n_items);
    slots.resize_with(n_items, || None);

    std::thread::scope(|scope| {
        let queues = &queues;
        let init = &init;
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                scope.spawn(move || {
                    let mut state = init();
                    let mut out: Vec<(usize, T)> = Vec::new();
                    while let Some(i) = next_index(queues, w) {
                        out.push((i, f(&mut state, i)));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("pool worker panicked") {
                slots[i] = Some(v);
            }
        }
    });

    slots
        .into_iter()
        .map(|s| s.expect("pool lost a work item"))
        .collect()
}

/// Run `f(i, &mut items[i])` over every item, partitioning `items` into
/// one contiguous chunk per worker (0 = auto). The items are disjoint
/// `&mut` borrows, so there is no result ordering to preserve and no
/// stealing needed: each worker mutates its chunk in place. This is the
/// primitive behind the cluster simulator's parallel engine stepping —
/// R independent engines advanced concurrently between interaction
/// points, with identical per-item effects for any thread count.
pub fn parallel_for_each_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = resolve_threads(threads).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        for (c, slice) in items.chunks_mut(chunk).enumerate() {
            scope.spawn(move || {
                for (k, item) in slice.iter_mut().enumerate() {
                    f(c * chunk + k, item);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
        for threads in [1, 2, 4, 7] {
            assert_eq!(parallel_map(threads, 100, |i| i * i), expect);
        }
    }

    #[test]
    fn edge_sizes() {
        assert!(parallel_map(8, 0, |i| i).is_empty());
        assert_eq!(parallel_map(8, 1, |i| i + 1), vec![1]);
        assert_eq!(parallel_map(8, 3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn skewed_workloads_still_complete_in_order() {
        // Front-loaded work forces the later workers to steal.
        let out = parallel_map(4, 64, |i| {
            let spins = if i < 4 { 200_000u64 } else { 10 };
            let mut acc = i as u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            (i, acc)
        });
        for (idx, (i, _)) in out.iter().enumerate() {
            assert_eq!(idx, *i);
        }
    }

    #[test]
    fn worker_state_is_reused() {
        // Each worker's counter only grows; every item sees a state that
        // was initialized exactly once per worker.
        let out = parallel_map_with(3, 24, || 0usize, |calls, _i| {
            *calls += 1;
            *calls
        });
        assert_eq!(out.len(), 24);
        assert!(out.iter().all(|&c| (1..=24).contains(&c)));
        // Exactly one "first call" per worker that ran, and at most
        // `threads` workers exist.
        let fresh = out.iter().filter(|&&c| c == 1).count();
        assert!((1..=3).contains(&fresh), "fresh states: {fresh}");
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        for threads in [1, 2, 3, 8, 64] {
            let mut items: Vec<usize> = (0..37).collect();
            parallel_for_each_mut(threads, &mut items, |i, item| {
                assert_eq!(i, *item, "index must match the item's slot");
                *item += 100;
            });
            assert!(
                items.iter().enumerate().all(|(i, &v)| v == i + 100),
                "threads={threads}: every item mutated exactly once"
            );
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_for_each_mut(4, &mut empty, |_, _| unreachable!());
    }

    #[test]
    fn resolve_thread_counts() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(6), 6);
    }
}
