//! Small statistics helpers shared by the harness and metrics modules.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Pairwise ranking accuracy (the paper's RankAcc, §5.3.2): proportion of
/// (positive, negative) pairs where the positive outscores the negative.
/// Ties count half. Returns None when either class is empty.
pub fn rank_acc(pos_scores: &[f64], neg_scores: &[f64]) -> Option<f64> {
    if pos_scores.is_empty() || neg_scores.is_empty() {
        return None;
    }
    let mut wins = 0.0;
    for &p in pos_scores {
        for &n in neg_scores {
            if p > n {
                wins += 1.0;
            } else if p == n {
                wins += 0.5;
            }
        }
    }
    Some(wins / (pos_scores.len() * neg_scores.len()) as f64)
}

/// Mann-Whitney AUC over (score, label) pairs — equals RankAcc.
pub fn auc(scores: &[f64], labels: &[bool]) -> Option<f64> {
    let pos: Vec<f64> = scores
        .iter()
        .zip(labels)
        .filter(|(_, &l)| l)
        .map(|(&s, _)| s)
        .collect();
    let neg: Vec<f64> = scores
        .iter()
        .zip(labels)
        .filter(|(_, &l)| !l)
        .map(|(&s, _)| s)
        .collect();
    rank_acc(&pos, &neg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn rank_acc_basic() {
        assert_eq!(rank_acc(&[0.9, 0.8], &[0.1, 0.2]), Some(1.0));
        assert_eq!(rank_acc(&[0.1], &[0.9]), Some(0.0));
        assert_eq!(rank_acc(&[0.5], &[0.5]), Some(0.5));
        assert_eq!(rank_acc(&[], &[0.5]), None);
    }

    #[test]
    fn auc_matches_rank_acc() {
        let scores = [0.9, 0.2, 0.7, 0.4];
        let labels = [true, false, true, false];
        assert_eq!(auc(&scores, &labels), Some(1.0));
    }
}
