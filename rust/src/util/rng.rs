//! Seedable PRNG + sampling distributions substrate.
//!
//! The offline environment has no `rand` crate, so the trace simulator's
//! randomness is built in-tree: xoshiro256++ (Blackman/Vigna) seeded via
//! SplitMix64, plus the distributions the generator needs (uniform,
//! normal via Box-Muller, lognormal, Bernoulli, beta via Jöhnk/gamma,
//! categorical). Deterministic for a given seed — experiment outputs are
//! reproducible bit-for-bit.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a new stream (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per trace / per question).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough for our use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Coin flip with success probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Marsaglia's polar method (spare-cached; no
    /// sin/cos on the hot path — hidden-state generation draws ~1e6
    /// normals per simulated question).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let f = (-2.0 * s.ln() / s).sqrt();
            self.spare_normal = Some(v * f);
            return u * f;
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// LogNormal parameterized by the *log-space* mean/sigma.
    pub fn lognormal(&mut self, mu_log: f64, sigma_log: f64) -> f64 {
        (mu_log + sigma_log * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a).
            let g = self.gamma(shape + 1.0);
            return g * self.f64().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        for &x in &xs {
            m += x;
        }
        m /= n as f64;
        for &x in &xs {
            v += (x - m) * (x - m);
        }
        v /= n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(4);
        let mut xs: Vec<f64> = (0..20_000).map(|_| r.lognormal(3.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 3.0f64.exp()).abs() / 3.0f64.exp() < 0.05);
    }

    #[test]
    fn beta_mean() {
        let mut r = Rng::new(5);
        let (a, b) = (2.0, 5.0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.beta(a, b)).sum::<f64>() / n as f64;
        assert!((mean - a / (a + b)).abs() < 0.01, "mean {mean}");
        let x = r.beta(0.5, 0.5); // shape < 1 path
        assert!((0.0..=1.0).contains(&x));
    }

    #[test]
    fn categorical_proportions() {
        let mut r = Rng::new(6);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.6).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..50).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
