//! Configuration system: a JSON config file (`step.config.json` or
//! `--config <path>`) layered under CLI flags, covering the serving
//! engine, the simulator, and method hyper-parameters. JSON rather than
//! TOML because the offline vendor set has neither serde nor toml — the
//! in-tree `util::json` substrate is the parser (DESIGN.md §3).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::method::{Method, MethodParams};
use crate::model::SamplerConfig;
use crate::util::json::Json;

/// Root configuration (every field optional in the file; defaults match
/// the paper's Appendix-B settings).
#[derive(Debug, Clone)]
pub struct StepConfig {
    /// Trace budget N (paper main results: 64).
    pub n_traces: usize,
    /// vLLM-style gpu_memory_utilization (paper default 0.9).
    pub mem_util: f64,
    /// PagedAttention block size in tokens.
    pub block_size: usize,
    /// Test-time-scaling method to serve with.
    pub method: Method,
    /// Method hyper-parameters (paper Appendix B.3).
    pub method_params: MethodParams,
    /// Appendix-B sampling parameters for the e2e engine.
    pub sampler: SamplerConfig,
    /// Master RNG seed.
    pub seed: u64,
    /// Artifact directory override.
    pub artifacts_dir: Option<String>,
}

impl Default for StepConfig {
    fn default() -> Self {
        StepConfig {
            n_traces: 64,
            mem_util: 0.9,
            block_size: 16,
            method: Method::Step,
            method_params: MethodParams::default(),
            sampler: SamplerConfig::default(),
            seed: 0,
            artifacts_dir: None,
        }
    }
}

impl StepConfig {
    /// Parse a config object, validating ranges and rejecting unknown
    /// keys.
    pub fn from_json(j: &Json) -> Result<StepConfig> {
        let mut c = StepConfig::default();
        let obj = j.as_obj().context("config root must be an object")?;
        for key in obj.keys() {
            match key.as_str() {
                "n_traces" | "mem_util" | "block_size" | "method" | "seed"
                | "artifacts_dir" | "method_params" | "sampler" => {}
                other => bail!("unknown config key '{other}'"),
            }
        }
        if let Some(v) = j.get("n_traces").as_usize() {
            c.n_traces = v;
        }
        if let Some(v) = j.get("mem_util").as_f64() {
            if !(0.0..=1.0).contains(&v) {
                bail!("mem_util must be in [0, 1], got {v}");
            }
            c.mem_util = v;
        }
        if let Some(v) = j.get("block_size").as_usize() {
            if v == 0 {
                bail!("block_size must be positive");
            }
            c.block_size = v;
        }
        if let Some(name) = j.get("method").as_str() {
            c.method = Method::parse(name)
                .with_context(|| format!("unknown method '{name}'"))?;
        }
        if let Some(v) = j.get("seed").as_f64() {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("artifacts_dir").as_str() {
            c.artifacts_dir = Some(v.to_string());
        }
        let mp = j.get("method_params");
        if mp.as_obj().is_some() {
            if let Some(v) = mp.get("slim_similarity_threshold").as_f64() {
                c.method_params.slim_similarity_threshold = v;
            }
            if let Some(v) = mp.get("slim_check_interval_steps").as_usize() {
                c.method_params.slim_check_interval_steps = v;
            }
            if let Some(v) = mp.get("deepconf_n_init").as_usize() {
                c.method_params.deepconf_n_init = v;
            }
            if let Some(v) = mp.get("deepconf_keep_top").as_f64() {
                c.method_params.deepconf_keep_top = v;
            }
            if let Some(v) = mp.get("deepconf_window").as_usize() {
                c.method_params.deepconf_window = v;
            }
            if let Some(v) = mp.get("default_score").as_f64() {
                c.method_params.default_score = v;
            }
        }
        let sp = j.get("sampler");
        if sp.as_obj().is_some() {
            if let Some(v) = sp.get("temperature").as_f64() {
                c.sampler.temperature = v;
            }
            if let Some(v) = sp.get("top_k").as_usize() {
                c.sampler.top_k = v;
            }
            if let Some(v) = sp.get("top_p").as_f64() {
                c.sampler.top_p = v;
            }
        }
        Ok(c)
    }

    /// Parse a config file from disk.
    pub fn from_file(path: &Path) -> Result<StepConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("config: {e}"))?;
        Self::from_json(&j)
    }

    /// Load `step.config.json` from the working directory if present.
    pub fn load_default() -> Result<StepConfig> {
        let p = Path::new("step.config.json");
        if p.exists() {
            Self::from_file(p)
        } else {
            Ok(StepConfig::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = StepConfig::default();
        assert_eq!(c.n_traces, 64);
        assert_eq!(c.mem_util, 0.9);
        assert_eq!(c.method_params.deepconf_n_init, 16);
        assert_eq!(c.method_params.slim_similarity_threshold, 0.95);
    }

    #[test]
    fn parses_overrides() {
        let j = Json::parse(
            r#"{"n_traces": 32, "mem_util": 0.7, "method": "deepconf",
                "method_params": {"deepconf_keep_top": 0.2},
                "sampler": {"temperature": 0.8, "top_k": 50}}"#,
        )
        .unwrap();
        let c = StepConfig::from_json(&j).unwrap();
        assert_eq!(c.n_traces, 32);
        assert_eq!(c.mem_util, 0.7);
        assert_eq!(c.method, Method::DeepConf);
        assert_eq!(c.method_params.deepconf_keep_top, 0.2);
        assert_eq!(c.sampler.temperature, 0.8);
        assert_eq!(c.sampler.top_k, 50);
    }

    #[test]
    fn rejects_invalid() {
        assert!(StepConfig::from_json(&Json::parse(r#"{"mem_util": 1.5}"#).unwrap()).is_err());
        assert!(StepConfig::from_json(&Json::parse(r#"{"method": "bogus"}"#).unwrap()).is_err());
        assert!(StepConfig::from_json(&Json::parse(r#"{"block_size": 0}"#).unwrap()).is_err());
        assert!(StepConfig::from_json(&Json::parse(r#"{"typo_key": 1}"#).unwrap()).is_err());
        assert!(StepConfig::from_json(&Json::parse("[1]").unwrap()).is_err());
    }
}
