//! Table 1 — main results: Acc / Tok / Lat for all five methods across
//! three models and five benchmark columns. `step bench table1`.

use anyhow::Result;

use super::cells::{run_cells, CellJob, CellOpts, CellResult};
use super::paper_ref;
use super::HarnessOpts;
use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};
use crate::util::json::Json;

/// Regenerate Table 1: the full (method x model x benchmark) grid.
pub fn run(opts: &HarnessOpts) -> Result<Vec<CellResult>> {
    let (gen, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    // The full 75-cell grid is computed first (sharded across workers),
    // then printed in table order.
    let mut jobs = Vec::new();
    for model in ModelId::ALL {
        for bench in BenchId::ALL {
            for method in Method::ALL {
                jobs.push(CellJob {
                    model,
                    bench,
                    method,
                    opts: CellOpts {
                        n_traces: opts.n_traces,
                        max_questions: opts.max_questions,
                        seed: opts.seed,
                        ..Default::default()
                    },
                });
            }
        }
    }
    let all = run_cells(&jobs, &gen, &scorer, opts.threads);

    let mut rows = all.iter();
    for model in ModelId::ALL {
        println!("\n## {:?}", model);
        println!(
            "{:<10} {:<13} | {:>6} {:>8} {:>7} | paper: {:>6} {:>8} {:>7}",
            "method", "bench", "acc%", "tok(k)", "lat(s)", "acc%", "tok(k)", "lat(s)"
        );
        for bench in BenchId::ALL {
            for method in Method::ALL {
                let r = rows.next().expect("one result per job");
                let (pa, pt, pl) = paper_ref::table1(model, bench, method);
                println!(
                    "{:<10} {:<13} | {:>6.1} {:>8.1} {:>7.0} | paper: {:>6.1} {:>8.1} {:>7.0}",
                    method.name(),
                    bench.name(),
                    r.acc,
                    r.tok_k,
                    r.lat_s,
                    pa,
                    pt,
                    pl
                );
            }
        }
    }
    let json = Json::Arr(all.iter().map(|c| c.to_json()).collect());
    let path = super::write_results("table1", &json)?;
    println!("\nwrote {path:?}");
    print_shape_checks(&all);
    Ok(all)
}

/// The qualitative claims Table 1 must reproduce (DESIGN.md §6).
pub fn print_shape_checks(cells: &[CellResult]) {
    let get = |m: ModelId, b: BenchId, me: Method| {
        cells
            .iter()
            .find(|c| c.model == m && c.bench == b && c.method == me)
            .cloned()
    };
    let mut pass = 0;
    let mut total = 0;
    let mut check = |name: String, ok: bool| {
        total += 1;
        pass += ok as usize;
        println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
    };
    println!("\n### shape checks (paper claims)");
    for m in ModelId::ALL {
        let mut speedups = Vec::new();
        for b in BenchId::ALL {
            let (Some(sc), Some(st)) = (get(m, b, Method::Sc), get(m, b, Method::Step)) else {
                continue;
            };
            check(
                format!("{m:?}/{}: STEP latency < SC ({:.0}s vs {:.0}s)", b.name(), st.lat_s, sc.lat_s),
                st.lat_s < sc.lat_s,
            );
            check(
                format!("{m:?}/{}: STEP acc >= SC - 1.5pp ({:.1} vs {:.1})", b.name(), st.acc, sc.acc),
                st.acc >= sc.acc - 1.5,
            );
            check(
                format!("{m:?}/{}: STEP tokens < SC", b.name()),
                st.tok_k < sc.tok_k,
            );
            speedups.push(1.0 - st.lat_s / sc.lat_s);
        }
        let mean_speedup = 100.0 * speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
        // Compare against the reduction the paper's own Table 1 implies
        // (the abstract's "45-70% on average" reflects the math-heavy
        // settings; the table-wide means are 28/34/57% per model).
        let paper_mean: f64 = 100.0
            * BenchId::ALL
                .iter()
                .map(|&b| {
                    let (_, _, sc) = paper_ref::table1(m, b, Method::Sc);
                    let (_, _, st) = paper_ref::table1(m, b, Method::Step);
                    1.0 - st / sc
                })
                .sum::<f64>()
            / BenchId::ALL.len() as f64;
        check(
            format!(
                "{m:?}: mean latency reduction {:.0}% within 12pp of paper's {:.0}%",
                mean_speedup, paper_mean
            ),
            (mean_speedup - paper_mean).abs() <= 12.0,
        );
    }
    println!("  shape checks: {pass}/{total} passed");
}
