//! Figure 2 — the motivation experiments.
//!
//! (a) hidden-state score distributions, correct vs incorrect, computed
//!     over the first 25/50/75% of steps (HMMT-25 traces);
//! (b) token counts of correct vs incorrect traces for one hard AIME
//!     question (paper: 42.5k incorrect vs 35.3k correct);
//! (c) time breakdown of SC generation: waiting ~40% / decoding ~59%.

use anyhow::Result;

use super::cells::{run_cell, CellOpts};
use super::HarnessOpts;
use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::TraceGen;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::stats::{auc, mean, stddev};

/// Fig-2a data: step-score separation across prefix fractions.
pub struct Fig2a {
    /// (prefix fraction, mean/std correct, mean/std incorrect, auc).
    pub rows: Vec<(f64, f64, f64, f64, f64, f64)>,
}

/// Regenerate Fig 2a: score distributions of correct vs incorrect.
pub fn run_fig2a(opts: &HarnessOpts) -> Result<Fig2a> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let gen = TraceGen::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, gen_params, opts.seed);
    let n_questions = opts.max_questions.unwrap_or(20).min(30);
    let traces_per_q = 32;

    println!("## Fig 2a: score distributions at 25/50/75% of steps (HMMT-25)");
    println!(
        "{:>7} | {:>8} {:>8} | {:>8} {:>8} | {:>6}",
        "prefix", "mu_corr", "sd_corr", "mu_inc", "sd_inc", "AUC"
    );
    let threads = opts.threads; // parallel_map clamps to n_questions internally
    let mut rows = Vec::new();
    for frac in [0.25, 0.50, 0.75] {
        // Questions shard across workers; per-question score/label runs
        // are concatenated in qid order (identical to a serial loop).
        let per_q: Vec<(Vec<f64>, Vec<bool>)> = pool::parallel_map(threads, n_questions, |qid| {
            let q = gen.question(qid);
            let mut q_scores = Vec::with_capacity(traces_per_q);
            let mut q_labels = Vec::with_capacity(traces_per_q);
            let (mut sbuf, mut zbuf) = (Vec::new(), Vec::new());
            for i in 0..traces_per_q {
                let t = gen.trace(&q, i);
                let k = ((t.n_steps() as f64 * frac).ceil() as usize).max(1);
                let hs: Vec<Vec<f32>> =
                    (1..=k).map(|n| gen.hidden_state(&q, &t, n)).collect();
                // Fused batch path, bit-exact with summing score_into() calls.
                scorer.score_batch_into(&hs, &mut sbuf, &mut zbuf);
                let s: f64 = sbuf.iter().map(|&x| x as f64).sum();
                q_scores.push(s / k as f64);
                q_labels.push(t.label);
            }
            (q_scores, q_labels)
        });
        let mut scores = Vec::new();
        let mut labels = Vec::new();
        for (s, l) in per_q {
            scores.extend(s);
            labels.extend(l);
        }
        let corr: Vec<f64> = scores
            .iter()
            .zip(&labels)
            .filter(|(_, &l)| l)
            .map(|(&s, _)| s)
            .collect();
        let inc: Vec<f64> = scores
            .iter()
            .zip(&labels)
            .filter(|(_, &l)| !l)
            .map(|(&s, _)| s)
            .collect();
        let a = auc(&scores, &labels).unwrap_or(0.5);
        println!(
            "{:>6.0}% | {:>8.3} {:>8.3} | {:>8.3} {:>8.3} | {:>6.3}",
            frac * 100.0,
            mean(&corr),
            stddev(&corr),
            mean(&inc),
            stddev(&inc),
            a
        );
        rows.push((frac, mean(&corr), stddev(&corr), mean(&inc), stddev(&inc), a));
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| Json::arr_f64(&[r.0, r.1, r.2, r.3, r.4, r.5]))
            .collect(),
    );
    super::write_results("fig2a", &json)?;
    Ok(Fig2a { rows })
}

/// Regenerate Fig 2b: token skew of correct vs incorrect traces.
pub fn run_fig2b(opts: &HarnessOpts) -> Result<(f64, f64)> {
    let (gen_params, _) = super::load_sim_bundle(&super::artifact_dir())?;
    let gen = TraceGen::new(ModelId::Qwen3_4B, BenchId::Aime25, gen_params, opts.seed);
    // The hardest still-solvable question (lowest p in [0.2, 0.7]) à la
    // AIME Q28 — hard questions also run longest (tracegen len_mult).
    let q = (0..30)
        .map(|i| gen.question(i))
        .filter(|q| (0.2..0.7).contains(&q.p_solve))
        .min_by(|a, b| a.p_solve.partial_cmp(&b.p_solve).unwrap())
        .unwrap_or_else(|| gen.question(0));
    let (mut ct, mut it, mut cn, mut inn) = (0.0, 0.0, 0, 0);
    for i in 0..64 {
        let t = gen.trace(&q, i);
        if t.label {
            ct += t.total_tokens as f64;
            cn += 1;
        } else {
            it += t.total_tokens as f64;
            inn += 1;
        }
    }
    let (mc, mi) = (ct / cn.max(1) as f64 / 1000.0, it / inn.max(1) as f64 / 1000.0);
    println!("## Fig 2b: token counts on a hard AIME question (p={:.2})", q.p_solve);
    println!("  correct traces:   {mc:.1}k tokens (n={cn})   [paper: 35.3k]");
    println!("  incorrect traces: {mi:.1}k tokens (n={inn})   [paper: 42.5k]");
    super::write_results("fig2b", &Json::arr_f64(&[mc, mi]))?;
    Ok((mc, mi))
}

/// Regenerate Fig 2c: wait vs decode share of SC latency.
pub fn run_fig2c(opts: &HarnessOpts) -> Result<(f64, f64)> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let cell_opts = CellOpts {
        n_traces: opts.n_traces,
        max_questions: opts.max_questions.or(Some(10)),
        seed: opts.seed,
        threads: opts.threads,
        ..Default::default()
    };
    let r = run_cell(
        ModelId::Qwen3_4B,
        BenchId::Aime25,
        Method::Sc,
        &gen_params,
        &scorer,
        &cell_opts,
    );
    let lifetime = r.wait_s + r.decode_s;
    let wait_pct = 100.0 * r.wait_s / lifetime.max(1e-9);
    let dec_pct = 100.0 * r.decode_s / lifetime.max(1e-9);
    println!("## Fig 2c: SC per-trace time breakdown (Qwen3-4B, AIME-25, N={})", r.n_traces);
    println!("  waiting:  {wait_pct:.0}%   [paper: ~40%]");
    println!("  decoding: {dec_pct:.0}%   [paper: ~59%]");
    super::write_results("fig2c", &Json::arr_f64(&[wait_pct, dec_pct]))?;
    Ok((wait_pct, dec_pct))
}

/// Regenerate all three Fig-2 panels.
pub fn run(opts: &HarnessOpts) -> Result<()> {
    run_fig2a(opts)?;
    run_fig2b(opts)?;
    run_fig2c(opts)?;
    Ok(())
}
