//! The paper's published numbers (Table 1 etc.), used by the harness to
//! print paper-vs-measured comparisons and by tests to check *shape*
//! (method orderings, speedup factors), never to fabricate results.

use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};

/// (accuracy %, mean tokens x1e3 per question, latency seconds).
pub type Row = (f64, f64, f64);

/// Table 1 of the paper.
pub fn table1(model: ModelId, bench: BenchId, method: Method) -> Row {
    use BenchId::*;
    use Method::*;
    use ModelId::*;
    match (model, method, bench) {
        // ---------------- Qwen3-4B-Thinking-2507
        (Qwen3_4B, Cot, Aime25) => (81.3, 22.7, 145.0),
        (Qwen3_4B, Cot, Hmmt2425) => (51.7, 28.3, 184.0),
        (Qwen3_4B, Cot, GpqaDiamond) => (65.8, 8.9, 54.0),
        (Qwen3_4B, Cot, EquiBench) => (67.2, 7.8, 41.0),
        (Qwen3_4B, Cot, DivLogicEval) => (51.0, 8.7, 49.0),
        (Qwen3_4B, Sc, Aime25) => (86.7, 1454.3, 1430.0),
        (Qwen3_4B, Sc, Hmmt2425) => (57.9, 1809.9, 2055.0),
        (Qwen3_4B, Sc, GpqaDiamond) => (68.1, 569.1, 252.0),
        (Qwen3_4B, Sc, EquiBench) => (70.4, 498.9, 237.0),
        (Qwen3_4B, Sc, DivLogicEval) => (54.3, 554.7, 228.0),
        (Qwen3_4B, SlimSc, Aime25) => (86.7, 957.5, 767.0),
        (Qwen3_4B, SlimSc, Hmmt2425) => (57.9, 966.7, 937.0),
        (Qwen3_4B, SlimSc, GpqaDiamond) => (64.9, 414.7, 236.0),
        (Qwen3_4B, SlimSc, EquiBench) => (73.7, 445.8, 232.0),
        (Qwen3_4B, SlimSc, DivLogicEval) => (54.8, 547.6, 240.0),
        (Qwen3_4B, DeepConf, Aime25) => (90.0, 841.5, 933.0),
        (Qwen3_4B, DeepConf, Hmmt2425) => (62.5, 1053.2, 1313.0),
        (Qwen3_4B, DeepConf, GpqaDiamond) => (67.6, 379.1, 257.0),
        (Qwen3_4B, DeepConf, EquiBench) => (71.5, 379.5, 324.0),
        (Qwen3_4B, DeepConf, DivLogicEval) => (53.8, 313.8, 296.0),
        (Qwen3_4B, Step, Aime25) => (88.3, 1131.5, 675.0),
        (Qwen3_4B, Step, Hmmt2425) => (64.2, 1129.6, 856.0),
        (Qwen3_4B, Step, GpqaDiamond) => (68.5, 539.6, 223.0),
        (Qwen3_4B, Step, EquiBench) => (74.0, 432.1, 214.0),
        (Qwen3_4B, Step, DivLogicEval) => (55.7, 509.3, 209.0),
        // ---------------- DeepSeek-R1-0528-Qwen3-8B
        (DeepSeek8B, Cot, Aime25) => (77.5, 26.4, 204.0),
        (DeepSeek8B, Cot, Hmmt2425) => (55.2, 31.5, 282.0),
        (DeepSeek8B, Cot, GpqaDiamond) => (62.3, 11.4, 81.0),
        (DeepSeek8B, Cot, EquiBench) => (69.5, 5.3, 40.0),
        (DeepSeek8B, Cot, DivLogicEval) => (39.0, 5.7, 44.0),
        (DeepSeek8B, Sc, Aime25) => (83.3, 1691.0, 2259.0),
        (DeepSeek8B, Sc, Hmmt2425) => (62.9, 2014.6, 2891.0),
        (DeepSeek8B, Sc, GpqaDiamond) => (67.1, 729.8, 484.0),
        (DeepSeek8B, Sc, EquiBench) => (75.6, 331.5, 189.0),
        (DeepSeek8B, Sc, DivLogicEval) => (44.1, 363.5, 192.0),
        (DeepSeek8B, SlimSc, Aime25) => (83.3, 1519.9, 1960.0),
        (DeepSeek8B, SlimSc, Hmmt2425) => (62.1, 1782.0, 2589.0),
        (DeepSeek8B, SlimSc, GpqaDiamond) => (66.2, 564.1, 424.0),
        (DeepSeek8B, SlimSc, EquiBench) => (75.0, 341.3, 177.0),
        (DeepSeek8B, SlimSc, DivLogicEval) => (45.0, 361.8, 180.0),
        (DeepSeek8B, DeepConf, Aime25) => (81.7, 916.4, 1475.0),
        (DeepSeek8B, DeepConf, Hmmt2425) => (64.2, 1038.7, 1666.0),
        (DeepSeek8B, DeepConf, GpqaDiamond) => (68.7, 419.8, 409.0),
        (DeepSeek8B, DeepConf, EquiBench) => (74.8, 232.2, 221.0),
        (DeepSeek8B, DeepConf, DivLogicEval) => (45.2, 276.4, 202.0),
        (DeepSeek8B, Step, Aime25) => (85.0, 989.7, 891.0),
        (DeepSeek8B, Step, Hmmt2425) => (66.3, 1096.5, 1061.0),
        (DeepSeek8B, Step, GpqaDiamond) => (68.2, 635.7, 378.0),
        (DeepSeek8B, Step, EquiBench) => (77.3, 282.8, 173.0),
        (DeepSeek8B, Step, DivLogicEval) => (45.6, 293.7, 162.0),
        // ---------------- Phi-4-reasoning-plus
        (Phi4_14B, Cot, Aime25) => (78.3, 16.0, 194.0),
        (Phi4_14B, Cot, Hmmt2425) => (55.2, 21.5, 270.0),
        (Phi4_14B, Cot, GpqaDiamond) => (69.5, 11.9, 105.0),
        (Phi4_14B, Cot, EquiBench) => (62.0, 12.1, 108.0),
        (Phi4_14B, Cot, DivLogicEval) => (42.3, 8.2, 98.0),
        (Phi4_14B, Sc, Aime25) => (86.7, 1026.7, 1687.0),
        (Phi4_14B, Sc, Hmmt2425) => (65.9, 1373.1, 2467.0),
        (Phi4_14B, Sc, GpqaDiamond) => (76.3, 762.5, 1081.0),
        (Phi4_14B, Sc, EquiBench) => (66.2, 772.3, 929.0),
        (Phi4_14B, Sc, DivLogicEval) => (46.7, 520.4, 445.0),
        (Phi4_14B, SlimSc, Aime25) => (85.0, 875.8, 1354.0),
        (Phi4_14B, SlimSc, Hmmt2425) => (64.6, 1149.7, 1804.0),
        (Phi4_14B, SlimSc, GpqaDiamond) => (72.3, 560.6, 655.0),
        (Phi4_14B, SlimSc, EquiBench) => (65.8, 578.4, 603.0),
        (Phi4_14B, SlimSc, DivLogicEval) => (45.3, 463.6, 433.0),
        (Phi4_14B, DeepConf, Aime25) => (85.8, 537.2, 1165.0),
        (Phi4_14B, DeepConf, Hmmt2425) => (66.3, 735.3, 1647.0),
        (Phi4_14B, DeepConf, GpqaDiamond) => (74.8, 401.9, 1285.0),
        (Phi4_14B, DeepConf, EquiBench) => (64.5, 396.0, 718.0),
        (Phi4_14B, DeepConf, DivLogicEval) => (45.8, 284.7, 402.0),
        (Phi4_14B, Step, Aime25) => (87.5, 503.4, 519.0),
        (Phi4_14B, Step, Hmmt2425) => (67.1, 582.5, 637.0),
        (Phi4_14B, Step, GpqaDiamond) => (76.7, 441.5, 445.0),
        (Phi4_14B, Step, EquiBench) => (67.9, 453.8, 421.0),
        (Phi4_14B, Step, DivLogicEval) => (47.0, 423.2, 319.0),
    }
}

/// Table 3: (wait s, decode s) on DeepSeek-8B / HMMT-25 / N=64.
pub fn table3(method: Method) -> (f64, f64) {
    match method {
        Method::Sc => (1526.0, 1256.0),
        Method::SlimSc => (1155.0, 983.0),
        Method::Step => (0.0, 1024.0),
        // DeepConf is reported per stage; combined here.
        Method::DeepConf => (69.0 + 194.0, 680.0 + 726.0),
        Method::Cot => (0.0, f64::NAN),
    }
}

/// Table 4 sweep: gpu_memory_utilization settings (DeepSeek-8B, HMMT-25, N=32).
pub const TABLE4_UTILS: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];
/// Table 4 reference: STEP accuracy at each utilization setting.
pub const TABLE4_ACC: [f64; 5] = [70.0, 69.1, 70.0, 68.3, 73.3];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_defined() {
        for m in ModelId::ALL {
            for b in BenchId::ALL {
                for me in Method::ALL {
                    let (acc, tok, lat) = table1(m, b, me);
                    assert!(acc > 30.0 && acc < 95.0);
                    assert!(tok > 1.0);
                    assert!(lat > 10.0);
                }
            }
        }
    }

    #[test]
    fn paper_claims_hold_in_reference_data() {
        // STEP reduces latency vs SC on every cell (the 45-70% claim).
        for m in ModelId::ALL {
            for b in BenchId::ALL {
                let (_, _, sc) = table1(m, b, Method::Sc);
                let (_, _, st) = table1(m, b, Method::Step);
                assert!(st < sc, "{m:?}/{b:?}");
            }
        }
    }
}
