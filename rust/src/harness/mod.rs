//! Experiment harness: one module per paper table/figure (DESIGN.md §6),
//! plus the beyond-the-paper serving cell ([`table5`], `step serve-sim`)
//! and the multi-GPU cluster cell ([`table6`], `step cluster-sim`).
//!
//! Every runner prints the regenerated rows next to the paper's published
//! numbers (from [`paper_ref`]) and returns structured results the bench
//! binaries and the CLI write into `results/*.json`.

pub mod ablations;
pub mod bench_gate;
pub mod cells;
pub mod fig1_fig4;
pub mod fig2;
pub mod fig5;
pub mod fig67;
pub mod overhead;
pub mod paper_ref;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::scorer::StepScorer;
use crate::sim::tracegen::GenParams;
use crate::util::json::Json;

/// Load the trained sim scorer + its generator params from artifacts.
pub fn load_sim_bundle(artifact_dir: &Path) -> Result<(GenParams, StepScorer)> {
    let manifest = std::fs::read_to_string(artifact_dir.join("manifest.json"))
        .with_context(|| format!("{artifact_dir:?}/manifest.json (run `make artifacts`)"))?;
    let man = Json::parse(&manifest).map_err(|e| anyhow!("manifest: {e}"))?;
    let scorer_file = man
        .get("scorers")
        .get("sim")
        .as_str()
        .context("manifest: scorers.sim")?;
    let text = std::fs::read_to_string(artifact_dir.join(scorer_file))?;
    let blob = Json::parse(&text).map_err(|e| anyhow!("scorer json: {e}"))?;
    let gen = GenParams::from_json(&blob)?;
    let scorer = StepScorer::from_json(&blob)?;
    Ok((gen, scorer))
}

/// Artifact dir from $STEP_ARTIFACTS_DIR or ./artifacts.
pub fn artifact_dir() -> std::path::PathBuf {
    crate::runtime::Artifacts::default_dir()
}

/// Write a results JSON under results/ (created on demand).
pub fn write_results(name: &str, value: &Json) -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var_os("STEP_RESULTS_DIR").unwrap_or_else(|| "results".into()),
    );
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string_pretty())?;
    Ok(path)
}

/// Harness-wide options (question subsampling for quick runs).
#[derive(Debug, Clone)]
pub struct HarnessOpts {
    /// Cap on questions per benchmark (None = paper-faithful counts).
    pub max_questions: Option<usize>,
    /// Trace budget N per question.
    pub n_traces: usize,
    /// Master RNG seed.
    pub seed: u64,
    /// Worker threads for the question/cell sharding (0 = all cores,
    /// 1 = serial). Results are bit-identical for any value.
    pub threads: usize,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        HarnessOpts { max_questions: None, n_traces: 64, seed: 0, threads: 0 }
    }
}

impl HarnessOpts {
    /// Quick mode for benches / smoke runs.
    pub fn quick() -> Self {
        HarnessOpts { max_questions: Some(8), n_traces: 32, ..Default::default() }
    }
}
