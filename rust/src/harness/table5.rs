//! Table 5 (beyond the paper) — multi-request serving under load:
//! throughput, p50/p95/p99 end-to-end latency, time-to-first-vote, and
//! accuracy for each method against the same open-loop workload.
//!
//! The paper evaluates one question's trace set at a time; this cell is
//! the ROADMAP's serving-scale rendering of the same claim: under GPU
//! memory pressure from *concurrent* requests, STEP's cross-request
//! pruning keeps the engine decoding while the SC family thrashes in
//! preempt/recompute cycles — so STEP's tail latency (p99) lands below
//! self-consistency's at the same arrival rate.
//!
//! Runs self-contained (built-in generator defaults) when artifacts are
//! absent, so `step serve-sim` works on a fresh checkout. Metric blocks
//! are bit-identical for any `--threads` value: each method's simulation
//! is single-threaded and deterministic in the seed; threads only shard
//! the methods across workers.

use anyhow::Result;

use super::cells::projection_scorer;
use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::signal::SignalSpec;
use crate::metrics::LatencySketch;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::serve::{ServeSim, ServeSimConfig};
use crate::sim::tracegen::{GenParams, TraceGen};
use crate::sim::workload::WorkloadSpec;
use crate::util::json::Json;
use crate::util::pool;

/// The methods the serving cell compares (DeepConf's two-stage warmup
/// has no continuous-batching rendering; see `sim::serve`).
pub const METHODS: [Method; 4] = [Method::Cot, Method::Sc, Method::SlimSc, Method::Step];

/// Options of one serving-load run (`step serve-sim`).
#[derive(Debug, Clone)]
pub struct ServingOpts {
    /// Served model.
    pub model: ModelId,
    /// Benchmark whose question pool the workload draws from.
    pub bench: BenchId,
    /// Number of requests in the workload.
    pub n_requests: usize,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// Requests per burst (`None` = Poisson arrivals).
    pub burst: Option<usize>,
    /// Traces per request (N).
    pub n_traces: usize,
    /// vLLM-style gpu_memory_utilization of the shared pool.
    pub mem_util: f64,
    /// Optional per-request KV quota as a fraction of the pool.
    pub quota_frac: Option<f64>,
    /// Master seed.
    pub seed: u64,
    /// Worker threads sharding the methods (0 = all cores). Metric
    /// output is bit-identical for any value.
    pub threads: usize,
    /// Pruning signal scoring every decoded step (`--signal`).
    pub signal: SignalSpec,
}

impl Default for ServingOpts {
    fn default() -> Self {
        ServingOpts {
            model: ModelId::DeepSeek8B,
            bench: BenchId::Aime25,
            n_requests: 32,
            rate_rps: 0.05,
            burst: None,
            n_traces: 16,
            mem_util: 0.9,
            quota_frac: None,
            seed: 0,
            threads: 0,
            signal: SignalSpec::default(),
        }
    }
}

impl ServingOpts {
    /// Quick scale for benches / smoke tests.
    pub fn quick() -> Self {
        ServingOpts { n_requests: 12, n_traces: 8, ..Default::default() }
    }

    fn workload(&self) -> WorkloadSpec {
        match self.burst {
            Some(b) => WorkloadSpec::bursty(self.rate_rps, b, self.n_requests),
            None => WorkloadSpec::poisson(self.rate_rps, self.n_requests),
        }
    }
}

/// Aggregated SLO metrics of one (method, workload) serving cell.
#[derive(Debug, Clone)]
pub struct ServingCell {
    /// The method this row measures.
    pub method: Method,
    /// Completed requests per second of simulated wall-clock.
    pub throughput_rps: f64,
    /// Median end-to-end latency, seconds.
    pub p50_s: f64,
    /// 95th-percentile end-to-end latency, seconds.
    pub p95_s: f64,
    /// 99th-percentile end-to-end latency, seconds.
    pub p99_s: f64,
    /// Mean end-to-end latency, seconds.
    pub mean_latency_s: f64,
    /// Median time-to-first-vote, seconds.
    pub ttfv_p50_s: f64,
    /// Mean queue (admission) delay, seconds.
    pub mean_queue_s: f64,
    /// Accuracy over the workload's requests, percent.
    pub acc: f64,
    /// Mean generated tokens per request, thousands.
    pub tok_k: f64,
    /// Total preemption events.
    pub preemptions: u64,
    /// Total pruned traces.
    pub pruned: u64,
    /// Peak KV blocks in use / pool blocks.
    pub peak_block_frac: f64,
}

impl ServingCell {
    /// Serialize as one metric block of `BENCH_serving.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.name().to_string())),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("p99_s", Json::Num(self.p99_s)),
            ("mean_latency_s", Json::Num(self.mean_latency_s)),
            ("ttfv_p50_s", Json::Num(self.ttfv_p50_s)),
            ("mean_queue_s", Json::Num(self.mean_queue_s)),
            ("acc", Json::Num(self.acc)),
            ("tok_k", Json::Num(self.tok_k)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("peak_block_frac", Json::Num(self.peak_block_frac)),
        ])
    }
}

/// Run one method against the workload and aggregate its SLO metrics.
pub fn run_cell(
    method: Method,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &ServingOpts,
) -> ServingCell {
    let cfg = ServeSimConfig::builder(opts.model, opts.bench, method, opts.n_traces, opts.workload())
        .mem_util(opts.mem_util)
        .seed(opts.seed)
        .quota_frac(opts.quota_frac)
        .signal(opts.signal.clone())
        .build();
    let gen = TraceGen::new(opts.model, opts.bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let r = ServeSim::new(&cfg, &gen, scorer).run();

    let mut lat = LatencySketch::new();
    let mut ttfv = LatencySketch::new();
    let mut queue_sum = 0.0;
    let mut tok_sum = 0.0;
    let mut correct = 0usize;
    for o in &r.outcomes {
        lat.record(o.latency_s);
        ttfv.record(o.ttfv_s);
        queue_sum += o.queue_s;
        tok_sum += o.gen_tokens as f64;
        correct += o.correct as usize;
    }
    let n = r.outcomes.len().max(1) as f64;
    ServingCell {
        method,
        throughput_rps: r.throughput_rps(),
        p50_s: lat.percentile_s(50.0),
        p95_s: lat.percentile_s(95.0),
        p99_s: lat.percentile_s(99.0),
        mean_latency_s: lat.mean_s(),
        ttfv_p50_s: ttfv.percentile_s(50.0),
        mean_queue_s: queue_sum / n,
        acc: 100.0 * correct as f64 / n,
        tok_k: tok_sum / n / 1000.0,
        preemptions: r.counters.preemptions,
        pruned: r.counters.pruned,
        peak_block_frac: r.peak_used_blocks as f64 / r.pool_blocks.max(1) as f64,
    }
}

/// Run every method of [`METHODS`] against the same workload. Methods
/// shard across up to `opts.threads` workers; each simulation is
/// deterministic in the seed and results return in method order, so the
/// output is bit-identical for any thread count.
pub fn run_methods(
    opts: &ServingOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> Vec<ServingCell> {
    let threads = pool::resolve_threads(opts.threads).min(METHODS.len());
    if threads <= 1 {
        METHODS.iter().map(|&m| run_cell(m, gen_params, scorer, opts)).collect()
    } else {
        pool::parallel_map(threads, METHODS.len(), |i| {
            run_cell(METHODS[i], gen_params, scorer, opts)
        })
    }
}

/// Assemble the `BENCH_serving.json` payload: the workload config plus
/// one metric block per method. Pure function of the cells and options —
/// no timestamps, no thread counts — so reruns compare byte-for-byte.
pub fn metrics_json(opts: &ServingOpts, cells: &[ServingCell]) -> Json {
    let burst = match opts.burst {
        Some(b) => Json::Num(b as f64),
        None => Json::Null,
    };
    let quota = match opts.quota_frac {
        Some(f) => Json::Num(f),
        None => Json::Null,
    };
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("model", Json::Str(format!("{:?}", opts.model))),
                ("bench", Json::Str(opts.bench.name().to_string())),
                ("n_requests", Json::Num(opts.n_requests as f64)),
                ("rate_rps", Json::Num(opts.rate_rps)),
                ("burst", burst),
                ("n_traces", Json::Num(opts.n_traces as f64)),
                ("mem_util", Json::Num(opts.mem_util)),
                ("quota_frac", quota),
                ("signal", Json::Str(opts.signal.spec_string())),
                ("seed", Json::Num(opts.seed as f64)),
            ]),
        ),
        ("methods", Json::Arr(cells.iter().map(|c| c.to_json()).collect())),
    ])
}

/// `step serve-sim`: run the serving grid, print the table, write
/// `results/BENCH_serving.json`. Uses the trained scorer bundle when
/// artifacts exist and falls back to the built-in generator defaults on
/// a fresh checkout.
pub fn run(opts: &ServingOpts) -> Result<Vec<ServingCell>> {
    let (gen_params, scorer) = match super::load_sim_bundle(&super::artifact_dir()) {
        Ok(bundle) => bundle,
        Err(_) => {
            println!("(no artifacts found — using built-in generator defaults)");
            let gp = GenParams::default_d64();
            let sc = projection_scorer(&gp);
            (gp, sc)
        }
    };
    let cells = run_methods(opts, &gen_params, &scorer);

    println!(
        "## Table 5: serving under load ({:?}, {}, N={}, {} req @ {} rps{})",
        opts.model,
        opts.bench.name(),
        opts.n_traces,
        opts.n_requests,
        opts.rate_rps,
        match opts.burst {
            Some(b) => format!(", bursts of {b}"),
            None => ", poisson".to_string(),
        }
    );
    println!(
        "{:>8} | {:>7} | {:>8} {:>8} {:>8} | {:>8} | {:>7} | {:>6} | {:>8} {:>7}",
        "method", "req/s", "p50(s)", "p95(s)", "p99(s)", "ttfv50", "queue", "acc%", "preempt", "pruned"
    );
    for c in &cells {
        println!(
            "{:>8} | {:>7.4} | {:>8.1} {:>8.1} {:>8.1} | {:>8.1} | {:>7.1} | {:>6.1} | {:>8} {:>7}",
            c.method.name(),
            c.throughput_rps,
            c.p50_s,
            c.p95_s,
            c.p99_s,
            c.ttfv_p50_s,
            c.mean_queue_s,
            c.acc,
            c.preemptions,
            c.pruned,
        );
    }
    let sc_p99 = cells.iter().find(|c| c.method == Method::Sc).map(|c| c.p99_s);
    let step_p99 = cells.iter().find(|c| c.method == Method::Step).map(|c| c.p99_s);
    if let (Some(sc), Some(step)) = (sc_p99, step_p99) {
        println!(
            "  p99 STEP {step:.1}s vs SC {sc:.1}s — {}",
            if step < sc {
                "STEP holds the tail under load (the serving-scale claim)"
            } else {
                "WARNING: STEP tail not below SC at this load"
            }
        );
    }
    let json = metrics_json(opts, &cells);
    // Harness-convention artifact for this cell, plus the canonical
    // BENCH_serving.json metric blocks (also written by the
    // serving_load bench at its own quick config — last writer wins;
    // the embedded config block records which).
    super::write_results("table5_serving", &json)?;
    let path = super::write_results("BENCH_serving", &json)?;
    println!("wrote {path:?} (and results/table5_serving.json)");
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServingOpts {
        ServingOpts {
            model: ModelId::Qwen3_4B,
            bench: BenchId::GpqaDiamond,
            n_requests: 4,
            rate_rps: 0.05,
            n_traces: 4,
            seed: 3,
            threads: 1,
            ..Default::default()
        }
    }

    #[test]
    fn cells_cover_all_methods_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let cells = run_methods(&tiny(), &gp, &sc);
        assert_eq!(cells.len(), METHODS.len());
        for (c, &m) in cells.iter().zip(&METHODS) {
            assert_eq!(c.method, m);
            assert!(c.throughput_rps > 0.0, "{m:?}");
            assert!(c.p50_s <= c.p95_s && c.p95_s <= c.p99_s, "{m:?}");
            assert!((0.0..=100.0).contains(&c.acc), "{m:?}");
        }
    }

    #[test]
    fn metric_block_is_deterministic() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let a = metrics_json(&opts, &run_methods(&opts, &gp, &sc));
        let b = metrics_json(&opts, &run_methods(&opts, &gp, &sc));
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
    }
}
