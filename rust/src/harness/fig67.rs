//! Figures 6/7 — trace-level score dynamics on AIME-25: prefix-mean step
//! score vs token position (1024-token bins), averaged separately over
//! correct and incorrect traces, for Qwen3-4B and DeepSeek-8B.

use anyhow::Result;

use super::HarnessOpts;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::TraceGen;
use crate::util::json::Json;
use crate::util::pool;

/// Fig-6/7 data: prefix-score dynamics over token position.
pub struct Dynamics {
    /// Model the dynamics were collected on.
    pub model: ModelId,
    /// Bin index -> (mean prefix score of correct, of incorrect, counts).
    pub bins: Vec<(f64, f64, usize, usize)>,
}

const BIN: u64 = 1024;

/// Collect score dynamics for one model (AIME-25).
pub fn run_model(opts: &HarnessOpts, model: ModelId) -> Result<Dynamics> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let gen = TraceGen::new(model, BenchId::Aime25, gen_params, opts.seed);
    let n_questions = opts.max_questions.unwrap_or(8).min(30);

    // Questions shard across workers, each returning its own bin
    // partial; partials merge in qid order, so the output is identical
    // for any thread count (though the float-summation tree differs
    // from the old fully-serial fold by design).
    let threads = opts.threads; // parallel_map clamps to n_questions internally
    let partials: Vec<Vec<(f64, f64, usize, usize)>> =
        pool::parallel_map(threads, n_questions, |qid| {
            let q = gen.question(qid);
            let mut acc: Vec<(f64, f64, usize, usize)> = Vec::new();
            let (mut scores, mut zbuf) = (Vec::new(), Vec::new());
            for i in 0..opts.n_traces {
                let t = gen.trace(&q, i);
                // Fused batch path over the trace's step hidden states
                // (bit-exact with per-step score_into()).
                let hs: Vec<Vec<f32>> = (1..=t.n_steps())
                    .map(|n| gen.hidden_state(&q, &t, n))
                    .collect();
                scorer.score_batch_into(&hs, &mut scores, &mut zbuf);
                let mut sum = 0.0;
                for (j, &s) in scores.iter().enumerate() {
                    sum += s as f64;
                    let prefix_mean = sum / (j + 1) as f64;
                    let bin = (t.step_ends[j] / BIN) as usize;
                    if acc.len() <= bin {
                        acc.resize(bin + 1, (0.0, 0.0, 0, 0));
                    }
                    let e = &mut acc[bin];
                    if t.label {
                        e.0 += prefix_mean;
                        e.2 += 1;
                    } else {
                        e.1 += prefix_mean;
                        e.3 += 1;
                    }
                }
            }
            acc
        });
    let mut acc: Vec<(f64, f64, usize, usize)> = Vec::new();
    for part in partials {
        if acc.len() < part.len() {
            acc.resize(part.len(), (0.0, 0.0, 0, 0));
        }
        for (e, p) in acc.iter_mut().zip(part) {
            e.0 += p.0;
            e.1 += p.1;
            e.2 += p.2;
            e.3 += p.3;
        }
    }
    let bins: Vec<(f64, f64, usize, usize)> = acc
        .into_iter()
        .map(|(sc, si, nc, ni)| (sc / nc.max(1) as f64, si / ni.max(1) as f64, nc, ni))
        .collect();
    Ok(Dynamics { model, bins })
}

/// Regenerate Fig 6/7: trace-level score dynamics per model.
pub fn run(opts: &HarnessOpts) -> Result<Vec<Dynamics>> {
    let mut out = Vec::new();
    for model in [ModelId::Qwen3_4B, ModelId::DeepSeek8B] {
        let d = run_model(opts, model)?;
        println!("\n## Fig 6/7: score dynamics, {:?} on AIME-25 (1024-token bins)", model);
        println!("{:>8} | {:>9} | {:>9}", "tokens", "correct", "incorrect");
        for (i, (c, inc, nc, ni)) in d.bins.iter().enumerate().take(24) {
            if *nc == 0 && *ni == 0 {
                continue;
            }
            println!(
                "{:>7}k | {:>9.3} | {:>9.3}",
                (i as u64 * BIN) / 1000,
                c,
                inc
            );
        }
        // Separation check: the green line must sit above the red line.
        let sep: Vec<f64> = d
            .bins
            .iter()
            .filter(|(_, _, nc, ni)| *nc > 5 && *ni > 5)
            .map(|(c, i, _, _)| c - i)
            .collect();
        let frac_pos = sep.iter().filter(|&&x| x > 0.0).count() as f64 / sep.len().max(1) as f64;
        println!("(separation: correct > incorrect in {:.0}% of bins; paper: everywhere)", frac_pos * 100.0);
        out.push(d);
    }
    let json = Json::Arr(
        out.iter()
            .map(|d| {
                Json::obj(vec![
                    ("model", Json::Str(format!("{:?}", d.model))),
                    (
                        "bins",
                        Json::Arr(
                            d.bins
                                .iter()
                                .map(|(c, i, nc, ni)| {
                                    Json::arr_f64(&[*c, *i, *nc as f64, *ni as f64])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    super::write_results("fig67", &json)?;
    Ok(out)
}
