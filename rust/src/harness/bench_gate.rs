//! `step bench-gate` — the CI bench-regression gate.
//!
//! CI regenerates three bench artifacts on every run
//! (`BENCH_grid.json`, `BENCH_serving.json`, `BENCH_cluster.json`,
//! written to `$STEP_RESULTS_DIR`). Until this gate existed they were
//! write-and-upload: a perf or determinism regression only surfaced if
//! a human opened the artifact. The gate turns them into a pass/fail
//! signal:
//!
//! 1. **Schema key-set match** — each fresh artifact must have exactly
//!    the key structure of its checked-in schema document under
//!    `results/` (underscore-prefixed annotation keys like `_note` are
//!    ignored; schema `null`s are value slots that match anything).
//!    Catches silently dropped metrics and shape drift between the
//!    bench binaries and the documented artifacts.
//! 2. **Perf/determinism gates** — the ratios the benches exist to
//!    defend must be present (non-null) and hold:
//!    * grid: parallel speedup ≥ 1 and byte-identity across threads;
//!    * serving: STEP p99 < SC p99, byte-identity across threads;
//!    * cluster: kv-pressure p99 < round-robin p99, byte-identity
//!      across `--threads` *and* `--step-threads`, and (when the
//!      migration grid is present) on-shed shed-rate ≤ never;
//!    * fleet (when the cluster artifact carries the fleet-scale
//!      grid): every R's cell byte-identical across step threads, the
//!      largest fleet's events/sec positive and its wall clock under
//!      the cap, and the sharded router's placements byte-identical
//!      to the flat kv-pressure router at small R;
//!    * elasticity (when the cluster artifact carries the elasticity
//!      rows): drain-relocate must not lose more goodput per
//!      revocation than the shed-everything baseline, and every
//!      chaos row must be byte-identical across step threads;
//!    * tracing (when the cluster artifact carries the observability
//!      fields): the traced STEP cell's metric row byte-identical to
//!      the untraced run — recorders must never influence scheduling —
//!      and the enabled-tracing wall ratio under its cap;
//!    * signal Pareto (when the cluster artifact carries the signal
//!      grid): hidden-mlp STEP accuracy must not fall below intrinsic
//!      confidence at the grid's matched load, and the default
//!      hidden-mlp path must stay byte-identical to the pre-trait
//!      scorer;
//!    * prefix cache (when the cluster artifact carries the
//!      prefix-cache fields): the skewed closed loop must actually
//!      share prompts (hit rate above zero), affinity-weighted
//!      placement must not worsen the p99 tail over the cache-on
//!      unweighted baseline, and the cache-off configuration must stay
//!      byte-identical to the default cluster.
//!
//! The verdict is printed as a markdown table, appended to
//! `$GITHUB_STEP_SUMMARY` when that file is set (the job-summary
//! surface on GitHub Actions), and any violation fails the process.

use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Where the gate reads fresh artifacts and checked-in schemas from.
#[derive(Debug, Clone)]
pub struct GateOpts {
    /// Directory holding the freshly generated `BENCH_*.json` files
    /// (`--results`; defaults to `$STEP_RESULTS_DIR` or `./results`).
    pub results_dir: PathBuf,
    /// Directory holding the checked-in schema documents (`--schemas`;
    /// defaults to `./results`, the repo-root copies).
    pub schemas_dir: PathBuf,
}

impl Default for GateOpts {
    fn default() -> Self {
        GateOpts {
            results_dir: PathBuf::from(
                std::env::var_os("STEP_RESULTS_DIR").unwrap_or_else(|| "results".into()),
            ),
            schemas_dir: PathBuf::from("results"),
        }
    }
}

/// One row of the gate report.
#[derive(Debug, Clone)]
pub struct GateRow {
    /// Artifact the check ran against.
    pub artifact: &'static str,
    /// What was checked.
    pub check: String,
    /// The observed value (rendered).
    pub value: String,
    /// Did the check pass?
    pub ok: bool,
}

impl GateRow {
    fn new(artifact: &'static str, check: &str, value: String, ok: bool) -> GateRow {
        GateRow { artifact, check: check.to_string(), value, ok }
    }
}

/// The three artifacts the gate covers.
const ARTIFACTS: [&str; 3] = ["BENCH_grid.json", "BENCH_serving.json", "BENCH_cluster.json"];

/// Recursively compare the *shape* of `fresh` against `schema`:
/// objects must carry identical key sets (annotation keys starting
/// with `_` are ignored on both sides), arrays must match in length
/// and element-wise, and leaves must agree on type — except a schema
/// `null`, which is a value slot matching anything. Returns the list
/// of mismatch descriptions (empty = shapes match).
fn shape_mismatches(schema: &Json, fresh: &Json, path: &str, out: &mut Vec<String>) {
    match (schema, fresh) {
        (Json::Null, _) => {}
        (Json::Obj(s), Json::Obj(f)) => {
            for (k, sv) in s {
                if k.starts_with('_') {
                    continue;
                }
                match f.get(k) {
                    Some(fv) => shape_mismatches(sv, fv, &format!("{path}/{k}"), out),
                    None => out.push(format!("{path}/{k}: missing from fresh artifact")),
                }
            }
            for k in f.keys() {
                if !k.starts_with('_') && !s.contains_key(k) {
                    out.push(format!("{path}/{k}: not in schema"));
                }
            }
        }
        (Json::Arr(s), Json::Arr(f)) => {
            if s.len() != f.len() {
                out.push(format!(
                    "{path}: schema has {} elements, fresh has {}",
                    s.len(),
                    f.len()
                ));
                return;
            }
            for (i, (sv, fv)) in s.iter().zip(f).enumerate() {
                shape_mismatches(sv, fv, &format!("{path}[{i}]"), out);
            }
        }
        (Json::Num(_), Json::Num(_))
        | (Json::Str(_), Json::Str(_))
        | (Json::Bool(_), Json::Bool(_)) => {}
        // A measured slot may legitimately come back null only if the
        // schema said null — handled above; anything else is drift.
        (s, f) => out.push(format!("{path}: schema {} vs fresh {}", kind(s), kind(f))),
    }
}

fn kind(j: &Json) -> &'static str {
    match j {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// A required numeric gate value: `None` (missing or null) fails.
fn num_at(json: &Json, keys: &[&str]) -> Option<f64> {
    let mut cur = json;
    for k in keys {
        cur = cur.get(k);
    }
    cur.as_f64()
}

fn bool_at(json: &Json, keys: &[&str]) -> Option<bool> {
    let mut cur = json;
    for k in keys {
        cur = cur.get(k);
    }
    cur.as_bool()
}

/// Find the row of `grid` (an array of metric objects) whose
/// `label_key` equals `label`, and return its `field`.
fn row_num(json: &Json, grid: &str, label_key: &str, label: &str, field: &str) -> Option<f64> {
    json.get(grid).as_arr().and_then(|rows| {
        rows.iter()
            .find(|r| r.get(label_key).as_str() == Some(label))
            .and_then(|r| r.get(field).as_f64())
    })
}

/// Render a gate over two comparable numbers. `ok` decides the
/// verdict; missing values fail with a diagnostic.
fn compare_row(
    artifact: &'static str,
    check: &str,
    a: Option<f64>,
    b: Option<f64>,
    ok: impl Fn(f64, f64) -> bool,
) -> GateRow {
    match (a, b) {
        (Some(a), Some(b)) => {
            GateRow::new(artifact, check, format!("{a:.4} vs {b:.4}"), ok(a, b))
        }
        _ => GateRow::new(artifact, check, "missing/null".to_string(), false),
    }
}

fn flag_row(artifact: &'static str, check: &str, v: Option<bool>) -> GateRow {
    match v {
        Some(b) => GateRow::new(artifact, check, b.to_string(), b),
        None => GateRow::new(artifact, check, "missing/null".to_string(), false),
    }
}

/// Evaluate every check over loaded `(schema, fresh)` pairs, in
/// [`ARTIFACTS`] order.
fn evaluate(pairs: &[(Json, Json)]) -> Vec<GateRow> {
    let mut rows = Vec::new();
    for (name, (schema, fresh)) in ARTIFACTS.into_iter().zip(pairs) {
        let mut mismatches = Vec::new();
        shape_mismatches(schema, fresh, "", &mut mismatches);
        rows.push(GateRow::new(
            name,
            "schema key-set match",
            if mismatches.is_empty() {
                "ok".to_string()
            } else {
                mismatches.join("; ")
            },
            mismatches.is_empty(),
        ));
    }
    let grid = &pairs[0].1;
    rows.push(compare_row(
        ARTIFACTS[0],
        "parallel speedup >= 1",
        num_at(grid, &["speedup"]),
        Some(1.0),
        |s, one| s >= one,
    ));
    rows.push(flag_row(ARTIFACTS[0], "identical across threads", bool_at(grid, &["identical"])));

    let serving = &pairs[1].1;
    rows.push(compare_row(
        ARTIFACTS[1],
        "STEP p99 < SC p99",
        row_num(serving, "methods", "method", "STEP", "p99_s"),
        row_num(serving, "methods", "method", "SC", "p99_s"),
        |step, sc| step < sc,
    ));
    rows.push(flag_row(
        ARTIFACTS[1],
        "identical across threads",
        bool_at(serving, &["identical_across_threads"]),
    ));

    let cluster = &pairs[2].1;
    rows.push(compare_row(
        ARTIFACTS[2],
        "kv-pressure p99 < round-robin p99",
        row_num(cluster, "routers", "label", "kv-pressure", "p99_s"),
        row_num(cluster, "routers", "label", "round-robin", "p99_s"),
        |kv, rr| kv < rr,
    ));
    rows.push(flag_row(
        ARTIFACTS[2],
        "identical across threads",
        bool_at(cluster, &["identical_across_threads"]),
    ));
    rows.push(flag_row(
        ARTIFACTS[2],
        "identical across step threads",
        bool_at(cluster, &["identical_across_step_threads"]),
    ));
    // The migration grid gate only applies when the artifact carries
    // the grid (older artifacts without it skip the row entirely).
    if cluster.get("migration").as_arr().is_some() {
        rows.push(compare_row(
            ARTIFACTS[2],
            "on-shed shed-rate <= never",
            row_num(cluster, "migration", "label", "on-shed", "shed_rate"),
            row_num(cluster, "migration", "label", "never", "shed_rate"),
            |on_shed, never| on_shed <= never,
        ));
    }
    // Likewise the fleet-scale grid: gates apply only when present.
    if let Some(fleet) = cluster.get("fleet").as_arr() {
        let all_identical = fleet.iter().fold(Some(true), |acc, r| {
            match (acc, r.get("identical_across_step_threads").as_bool()) {
                (Some(a), Some(b)) => Some(a && b),
                _ => None,
            }
        });
        rows.push(flag_row(
            ARTIFACTS[2],
            "fleet rows identical across step threads",
            all_identical,
        ));
        let largest = fleet.iter().max_by(|a, b| {
            let ga = a.get("gpus").as_f64().unwrap_or(0.0);
            let gb = b.get("gpus").as_f64().unwrap_or(0.0);
            ga.partial_cmp(&gb).unwrap_or(std::cmp::Ordering::Equal)
        });
        rows.push(compare_row(
            ARTIFACTS[2],
            "largest-fleet events/sec > 0",
            largest.and_then(|r| r.get("events_per_sec").as_f64()),
            Some(0.0),
            |eps, zero| eps > zero,
        ));
        rows.push(compare_row(
            ARTIFACTS[2],
            "largest-fleet wall clock <= 60s",
            largest.and_then(|r| r.get("wall_s").as_f64()),
            Some(FLEET_WALL_CAP_S),
            |wall, cap| wall <= cap,
        ));
        rows.push(flag_row(
            ARTIFACTS[2],
            "kv-sharded == kv-pressure at small R",
            bool_at(cluster, &["shard_flat_identical"]),
        ));
    }
    // Elasticity rows (fixed revocation schedule under fleet chaos):
    // the drain controller must not lose more goodput per revocation
    // than abandoning the victims' residents, and every chaos row must
    // be byte-identical across step threads.
    if let Some(ela) = cluster.get("elasticity").as_arr() {
        rows.push(compare_row(
            ARTIFACTS[2],
            "drain-relocate loss/revocation <= shed-everything",
            row_num(
                cluster,
                "elasticity",
                "label",
                "drain-relocate",
                "goodput_lost_per_revocation",
            ),
            row_num(
                cluster,
                "elasticity",
                "label",
                "shed-everything",
                "goodput_lost_per_revocation",
            ),
            |drain, shed| drain <= shed,
        ));
        let all_identical = ela.iter().fold(Some(true), |acc, r| {
            match (acc, r.get("identical_across_step_threads").as_bool()) {
                (Some(a), Some(b)) => Some(a && b),
                _ => None,
            }
        });
        rows.push(flag_row(
            ARTIFACTS[2],
            "elasticity rows identical across step threads",
            all_identical,
        ));
    }
    // Observability gates, applied when the artifact carries the
    // tracing fields (cluster_load writes them; a table6 run without
    // tracing flags legitimately omits them).
    if let Some(identical) = bool_at(cluster, &["trace_identical"]) {
        rows.push(flag_row(
            ARTIFACTS[2],
            "traced == untraced metric bytes",
            Some(identical),
        ));
    }
    if let Some(ratio) = num_at(cluster, &["trace_wall_ratio"]) {
        rows.push(compare_row(
            ARTIFACTS[2],
            "traced wall ratio <= cap",
            Some(ratio),
            Some(TRACE_WALL_CAP),
            |r, cap| r > 0.0 && r <= cap,
        ));
    }
    // Signal Pareto gates, applied when the artifact carries the
    // signal grid: hidden states must not rank worse than intrinsic
    // confidence on STEP accuracy at the grid's matched load (same
    // workload, same memory events — only the victim selection
    // differs), and the default hidden-mlp path must stay
    // byte-identical to the pre-trait scorer.
    if cluster.get("signal_pareto").as_arr().is_some() {
        rows.push(compare_row(
            ARTIFACTS[2],
            "hidden-mlp STEP acc >= confidence",
            num_at(cluster, &["signal_acc_hidden_mlp"]),
            num_at(cluster, &["signal_acc_confidence"]),
            |mlp, conf| mlp >= conf,
        ));
    }
    if let Some(identical) = bool_at(cluster, &["signal_default_identical"]) {
        rows.push(flag_row(
            ARTIFACTS[2],
            "hidden-mlp == default metric bytes",
            Some(identical),
        ));
    }
    // Prefix-cache gates, applied when the artifact carries the
    // prefix-cache fields (cluster_load writes them; a table6 run
    // without the prefix row legitimately omits them).
    if let Some(hit) = num_at(cluster, &["prefix_hit_rate"]) {
        rows.push(compare_row(
            ARTIFACTS[2],
            "prefix hit rate > 0",
            Some(hit),
            Some(0.0),
            |h, zero| h > zero,
        ));
    }
    if let Some(ratio) = num_at(cluster, &["prefix_p99_ratio"]) {
        rows.push(compare_row(
            ARTIFACTS[2],
            "affinity-on p99 <= affinity-off",
            Some(ratio),
            Some(1.0),
            |r, one| r > 0.0 && r <= one + 1e-9,
        ));
    }
    if let Some(identical) = bool_at(cluster, &["prefix_off_identical"]) {
        rows.push(flag_row(
            ARTIFACTS[2],
            "prefix-off == default metric bytes",
            Some(identical),
        ));
    }
    rows
}

/// Cap on the traced-vs-untraced wall ratio of the canonical STEP
/// cell. Recording into an unbounded in-memory log should cost low
/// single-digit multiples at worst; the cap is generous because the
/// quick cells run sub-second and CI wall clocks are noisy, while
/// still catching a pathological emission path.
const TRACE_WALL_CAP: f64 = 25.0;

/// Wall-clock cap on the largest fleet cell (R=1024). The target is
/// single-digit seconds; the cap leaves headroom for slow CI machines
/// while still catching an order-of-magnitude regression.
const FLEET_WALL_CAP_S: f64 = 60.0;

/// Render the verdict as a GitHub-flavored markdown table.
fn markdown(rows: &[GateRow]) -> String {
    let mut md = String::from("## Bench regression gate\n\n");
    md.push_str("| artifact | check | value | status |\n|---|---|---|---|\n");
    for r in rows {
        md.push_str(&format!(
            "| {} | {} | {} | {} |\n",
            r.artifact,
            r.check,
            r.value,
            if r.ok { "✅" } else { "❌ FAIL" }
        ));
    }
    md
}

/// Run the gate: load the three artifact/schema pairs, evaluate every
/// check, publish the markdown table (stdout + `$GITHUB_STEP_SUMMARY`
/// when set), and fail on any violation.
pub fn run(opts: &GateOpts) -> Result<Vec<GateRow>> {
    let mut pairs = Vec::new();
    for name in ARTIFACTS {
        let load = |dir: &std::path::Path, what: &str| -> Result<Json> {
            let path = dir.join(name);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {what} {path:?}"))?;
            Json::parse(&text).map_err(|e| anyhow!("parsing {what} {path:?}: {e}"))
        };
        let schema = load(&opts.schemas_dir, "schema")?;
        let fresh = load(&opts.results_dir, "fresh artifact")?;
        pairs.push((schema, fresh));
    }
    let rows = evaluate(&pairs);
    let md = markdown(&rows);
    println!("{md}");
    if let Some(summary) = std::env::var_os("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&summary)
            .with_context(|| format!("opening $GITHUB_STEP_SUMMARY {summary:?}"))?;
        f.write_all(md.as_bytes())?;
    }
    let failures: Vec<&GateRow> = rows.iter().filter(|r| !r.ok).collect();
    if !failures.is_empty() {
        let list: Vec<String> = failures
            .iter()
            .map(|r| format!("{} — {} ({})", r.artifact, r.check, r.value))
            .collect();
        anyhow::bail!("bench regression gate failed:\n  {}", list.join("\n  "));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(speedup: f64, identical: bool) -> Json {
        Json::obj(vec![
            ("cells", Json::Num(20.0)),
            ("speedup", Json::Num(speedup)),
            ("identical", Json::Bool(identical)),
        ])
    }

    fn method_row(label_key: &str, label: &str, p99: f64) -> Json {
        Json::obj(vec![(label_key, Json::Str(label.to_string())), ("p99_s", Json::Num(p99))])
    }

    fn serving(step_p99: f64, sc_p99: f64) -> Json {
        Json::obj(vec![
            (
                "methods",
                Json::Arr(vec![
                    method_row("method", "SC", sc_p99),
                    method_row("method", "STEP", step_p99),
                ]),
            ),
            ("identical_across_threads", Json::Bool(true)),
        ])
    }

    fn mig_row(label: &str, shed: f64) -> Json {
        Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("shed_rate", Json::Num(shed)),
        ])
    }

    fn fleet_row(gpus: usize, eps: f64, wall_s: f64, identical: bool) -> Json {
        Json::obj(vec![
            ("gpus", Json::Num(gpus as f64)),
            ("events_per_sec", Json::Num(eps)),
            ("wall_s", Json::Num(wall_s)),
            ("identical_across_step_threads", Json::Bool(identical)),
        ])
    }

    fn ela_row(label: &str, loss: f64, identical: bool) -> Json {
        Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("goodput_lost_per_revocation", Json::Num(loss)),
            ("identical_across_step_threads", Json::Bool(identical)),
        ])
    }

    fn pareto_row(signal: &str, method: &str, acc: f64) -> Json {
        Json::obj(vec![
            ("label", Json::Str(format!("{signal}/{method}/mu0.9"))),
            ("signal", Json::Str(signal.to_string())),
            ("method", Json::Str(method.to_string())),
            ("acc", Json::Num(acc)),
        ])
    }

    fn cluster(kv: f64, rr: f64, shed_never: f64, shed_on_shed: f64) -> Json {
        Json::obj(vec![
            (
                "routers",
                Json::Arr(vec![
                    method_row("label", "round-robin", rr),
                    method_row("label", "kv-pressure", kv),
                ]),
            ),
            (
                "migration",
                Json::Arr(vec![
                    mig_row("never", shed_never),
                    mig_row("on-shed", shed_on_shed),
                ]),
            ),
            (
                "fleet",
                Json::Arr(vec![
                    fleet_row(4, 800.0, 0.2, true),
                    fleet_row(1024, 5000.0, 4.0, true),
                ]),
            ),
            (
                "elasticity",
                Json::Arr(vec![
                    ela_row("shed-everything", 2.0, true),
                    ela_row("drain-relocate", 0.25, true),
                ]),
            ),
            (
                "signal_pareto",
                Json::Arr(vec![
                    pareto_row("hidden-mlp", "STEP", 75.0),
                    pareto_row("confidence", "STEP", 62.5),
                ]),
            ),
            ("signal_acc_hidden_mlp", Json::Num(75.0)),
            ("signal_acc_confidence", Json::Num(62.5)),
            ("signal_default_identical", Json::Bool(true)),
            ("shard_flat_identical", Json::Bool(true)),
            ("identical_across_threads", Json::Bool(true)),
            ("identical_across_step_threads", Json::Bool(true)),
            ("trace_identical", Json::Bool(true)),
            ("trace_wall_ratio", Json::Num(1.4)),
            ("trace_events", Json::Num(5000.0)),
            ("prefix_hit_rate", Json::Num(0.35)),
            ("prefix_saved_blocks", Json::Num(420.0)),
            ("prefix_p99_ratio", Json::Num(0.95)),
            ("prefix_off_identical", Json::Bool(true)),
        ])
    }

    fn pairs(g: Json, s: Json, c: Json) -> Vec<(Json, Json)> {
        vec![(g.clone(), g), (s.clone(), s), (c.clone(), c)]
    }

    #[test]
    fn healthy_artifacts_pass_every_gate() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().all(|r| r.ok), "{rows:?}");
        assert!(rows.iter().any(|r| r.check.contains("on-shed")));
    }

    #[test]
    fn violated_gates_fail() {
        // speedup < 1; STEP worse than SC; kv-pressure worse than
        // round-robin; on-shed sheds more than never.
        let rows = evaluate(&pairs(
            grid(0.8, true),
            serving(300.0, 200.0),
            cluster(90.0, 80.0, 0.1, 0.4),
        ));
        let failed: Vec<&str> = rows
            .iter()
            .filter(|r| !r.ok)
            .map(|r| r.check.as_str())
            .collect();
        assert!(failed.iter().any(|c| c.contains("speedup")), "{failed:?}");
        assert!(failed.iter().any(|c| c.contains("STEP p99")), "{failed:?}");
        assert!(failed.iter().any(|c| c.contains("kv-pressure")), "{failed:?}");
        assert!(failed.iter().any(|c| c.contains("on-shed")), "{failed:?}");
    }

    #[test]
    fn null_gate_values_fail_loudly() {
        let mut g = grid(2.0, true);
        if let Json::Obj(map) = &mut g {
            map.insert("speedup".to_string(), Json::Null);
        }
        // The schema documents nulls, so shape still matches — but the
        // gate itself must refuse a null measurement.
        let rows = evaluate(&pairs(g, serving(1.0, 2.0), cluster(1.0, 2.0, 0.2, 0.1)));
        let speedup = rows.iter().find(|r| r.check.contains("speedup")).unwrap();
        assert!(!speedup.ok);
        assert_eq!(speedup.value, "missing/null");
    }

    #[test]
    fn healthy_artifacts_exercise_the_fleet_gates() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().any(|r| r.check.contains("fleet rows identical")));
        assert!(rows.iter().any(|r| r.check.contains("events/sec")));
        assert!(rows.iter().any(|r| r.check.contains("kv-sharded")));
    }

    #[test]
    fn fleet_gate_checks_identity_events_and_wall_clock() {
        let mut c = cluster(1.0, 2.0, 0.2, 0.1);
        if let Json::Obj(map) = &mut c {
            // The 1024-row is the largest fleet: blow its wall clock,
            // break a row's step-thread identity, and break the
            // small-R sharded-vs-flat witness.
            map.insert(
                "fleet".to_string(),
                Json::Arr(vec![
                    fleet_row(4, 800.0, 0.2, true),
                    fleet_row(1024, 900.0, 120.0, false),
                ]),
            );
            map.insert("shard_flat_identical".to_string(), Json::Bool(false));
        }
        let rows = evaluate(&pairs(grid(2.0, true), serving(1.0, 2.0), c));
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.ok).map(|r| r.check.as_str()).collect();
        assert!(failed.iter().any(|ch| ch.contains("fleet rows identical")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("wall clock")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("kv-sharded")), "{failed:?}");
        assert!(
            !failed.iter().any(|ch| ch.contains("events/sec")),
            "positive events/sec still passes: {failed:?}"
        );
    }

    #[test]
    fn healthy_artifacts_exercise_the_elasticity_gates() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().any(|r| r.check.contains("drain-relocate") && r.ok));
        assert!(rows.iter().any(|r| r.check.contains("elasticity rows identical") && r.ok));
    }

    #[test]
    fn elasticity_gate_checks_loss_and_identity() {
        let mut c = cluster(1.0, 2.0, 0.2, 0.1);
        if let Json::Obj(map) = &mut c {
            // Drain loses MORE than shedding everything, and one chaos
            // row breaks its step-thread identity: both gates trip.
            map.insert(
                "elasticity".to_string(),
                Json::Arr(vec![
                    ela_row("shed-everything", 0.5, true),
                    ela_row("drain-relocate", 1.5, false),
                ]),
            );
        }
        let rows = evaluate(&pairs(grid(2.0, true), serving(1.0, 2.0), c));
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.ok).map(|r| r.check.as_str()).collect();
        assert!(failed.iter().any(|ch| ch.contains("drain-relocate")), "{failed:?}");
        assert!(
            failed.iter().any(|ch| ch.contains("elasticity rows identical")),
            "{failed:?}"
        );
    }

    #[test]
    fn healthy_artifacts_exercise_the_tracing_gates() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().any(|r| r.check.contains("traced == untraced") && r.ok));
        assert!(rows.iter().any(|r| r.check.contains("traced wall ratio") && r.ok));
        // An artifact without the tracing fields (a table6 run with no
        // tracing flags) skips the rows instead of failing them.
        let mut bare = cluster(50.0, 80.0, 0.4, 0.1);
        if let Json::Obj(map) = &mut bare {
            map.remove("trace_identical");
            map.remove("trace_wall_ratio");
            map.remove("trace_events");
        }
        let rows = evaluate(&pairs(grid(3.2, true), serving(100.0, 200.0), bare));
        assert!(!rows.iter().any(|r| r.check.contains("traced")), "{rows:?}");
    }

    #[test]
    fn tracing_gate_checks_identity_and_overhead() {
        let mut c = cluster(1.0, 2.0, 0.2, 0.1);
        if let Json::Obj(map) = &mut c {
            map.insert("trace_identical".to_string(), Json::Bool(false));
            map.insert("trace_wall_ratio".to_string(), Json::Num(40.0));
        }
        let rows = evaluate(&pairs(grid(2.0, true), serving(1.0, 2.0), c));
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.ok).map(|r| r.check.as_str()).collect();
        assert!(failed.iter().any(|ch| ch.contains("traced == untraced")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("traced wall ratio")), "{failed:?}");
    }

    #[test]
    fn healthy_artifacts_exercise_the_prefix_gates() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().any(|r| r.check.contains("prefix hit rate") && r.ok));
        assert!(rows.iter().any(|r| r.check.contains("affinity-on p99") && r.ok));
        assert!(rows.iter().any(|r| r.check.contains("prefix-off ==") && r.ok));
        // An artifact without the prefix fields skips the rows instead
        // of failing them.
        let mut bare = cluster(50.0, 80.0, 0.4, 0.1);
        if let Json::Obj(map) = &mut bare {
            map.remove("prefix_hit_rate");
            map.remove("prefix_saved_blocks");
            map.remove("prefix_p99_ratio");
            map.remove("prefix_off_identical");
        }
        let rows = evaluate(&pairs(grid(3.2, true), serving(100.0, 200.0), bare));
        assert!(!rows.iter().any(|r| r.check.contains("prefix")), "{rows:?}");
    }

    #[test]
    fn prefix_gate_checks_hits_tail_and_identity() {
        let mut c = cluster(1.0, 2.0, 0.2, 0.1);
        if let Json::Obj(map) = &mut c {
            // A dead registry, a worsened affinity tail, and a broken
            // off-path identity: all three gates trip.
            map.insert("prefix_hit_rate".to_string(), Json::Num(0.0));
            map.insert("prefix_p99_ratio".to_string(), Json::Num(1.2));
            map.insert("prefix_off_identical".to_string(), Json::Bool(false));
        }
        let rows = evaluate(&pairs(grid(2.0, true), serving(1.0, 2.0), c));
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.ok).map(|r| r.check.as_str()).collect();
        assert!(failed.iter().any(|ch| ch.contains("prefix hit rate")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("affinity-on p99")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("prefix-off ==")), "{failed:?}");
    }

    #[test]
    fn healthy_artifacts_exercise_the_signal_gates() {
        let rows = evaluate(&pairs(
            grid(3.2, true),
            serving(100.0, 200.0),
            cluster(50.0, 80.0, 0.4, 0.1),
        ));
        assert!(rows.iter().any(|r| r.check.contains("hidden-mlp STEP acc") && r.ok));
        assert!(rows.iter().any(|r| r.check.contains("hidden-mlp == default") && r.ok));
        // An artifact without the signal grid (an older artifact)
        // skips the rows instead of failing them.
        let mut bare = cluster(50.0, 80.0, 0.4, 0.1);
        if let Json::Obj(map) = &mut bare {
            map.remove("signal_pareto");
            map.remove("signal_acc_hidden_mlp");
            map.remove("signal_acc_confidence");
            map.remove("signal_default_identical");
        }
        let rows = evaluate(&pairs(grid(3.2, true), serving(100.0, 200.0), bare));
        assert!(!rows.iter().any(|r| r.check.contains("hidden-mlp")), "{rows:?}");
    }

    #[test]
    fn signal_gate_checks_accuracy_ordering_and_default_identity() {
        let mut c = cluster(1.0, 2.0, 0.2, 0.1);
        if let Json::Obj(map) = &mut c {
            // Confidence out-ranks hidden states, and the default path
            // drifted from the pre-trait scorer: both gates trip.
            map.insert("signal_acc_hidden_mlp".to_string(), Json::Num(50.0));
            map.insert("signal_acc_confidence".to_string(), Json::Num(62.5));
            map.insert("signal_default_identical".to_string(), Json::Bool(false));
        }
        let rows = evaluate(&pairs(grid(2.0, true), serving(1.0, 2.0), c));
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.ok).map(|r| r.check.as_str()).collect();
        assert!(failed.iter().any(|ch| ch.contains("hidden-mlp STEP acc")), "{failed:?}");
        assert!(failed.iter().any(|ch| ch.contains("hidden-mlp == default")), "{failed:?}");
    }

    #[test]
    fn shape_mismatch_reports_added_and_missing_keys() {
        let schema = Json::obj(vec![
            ("_note", Json::Str("ignored".into())),
            ("kept", Json::Null),
            ("dropped", Json::Num(1.0)),
            ("rows", Json::Arr(vec![Json::obj(vec![("a", Json::Null)])])),
        ]);
        let fresh = Json::obj(vec![
            ("kept", Json::Num(4.0)),
            ("added", Json::Num(2.0)),
            ("rows", Json::Arr(vec![Json::obj(vec![("b", Json::Num(0.0))])])),
        ]);
        let mut out = Vec::new();
        shape_mismatches(&schema, &fresh, "", &mut out);
        let text = out.join("\n");
        assert!(text.contains("/dropped: missing"), "{text}");
        assert!(text.contains("/added: not in schema"), "{text}");
        assert!(text.contains("/rows[0]/a: missing"), "{text}");
        assert!(text.contains("/rows[0]/b: not in schema"), "{text}");
        assert!(!text.contains("_note"), "annotation keys are ignored: {text}");
        // Schema nulls accept any fresh value.
        assert!(!text.contains("/kept"), "{text}");
    }

    #[test]
    fn array_length_drift_is_shape_drift() {
        let schema = Json::Arr(vec![Json::Null, Json::Null]);
        let fresh = Json::Arr(vec![Json::Num(1.0)]);
        let mut out = Vec::new();
        shape_mismatches(&schema, &fresh, "rows", &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains("2 elements"), "{out:?}");
    }

    #[test]
    fn markdown_table_renders_status() {
        let rows = vec![
            GateRow::new("BENCH_grid.json", "x", "ok".into(), true),
            GateRow::new("BENCH_grid.json", "y", "bad".into(), false),
        ];
        let md = markdown(&rows);
        assert!(md.contains("| artifact | check | value | status |"));
        assert!(md.contains("✅"));
        assert!(md.contains("❌ FAIL"));
    }
}
