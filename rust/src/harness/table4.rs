//! Table 4 — GPU-memory sensitivity: STEP accuracy across
//! gpu_memory_utilization in {0.5 .. 0.9} (DeepSeek-8B, HMMT-25, N=32).
//! The paper's claim: accuracy is stable (70.1 +/- 1.8) because the
//! scorer identifies promising traces early enough that earlier pruning
//! does not hurt.

use anyhow::Result;

use super::cells::{run_cells, CellJob, CellOpts};
use super::{paper_ref, HarnessOpts};
use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};
use crate::util::json::Json;
use crate::util::stats::{mean, stddev};

/// Regenerate Table 4: STEP accuracy across the memory-utilization sweep.
pub fn run(opts: &HarnessOpts) -> Result<Vec<(f64, f64)>> {
    let (gen, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let n_traces = 32.min(opts.n_traces);
    let jobs: Vec<CellJob> = paper_ref::TABLE4_UTILS
        .iter()
        .map(|&util| CellJob {
            model: ModelId::DeepSeek8B,
            bench: BenchId::Hmmt2425,
            method: Method::Step,
            opts: CellOpts {
                n_traces,
                max_questions: opts.max_questions,
                mem_util: util,
                seed: opts.seed,
                ..Default::default()
            },
        })
        .collect();
    let cells = run_cells(&jobs, &gen, &scorer, opts.threads);

    let mut rows = Vec::new();
    println!("## Table 4: STEP accuracy vs gpu_memory_utilization (DeepSeek-8B, HMMT-25, N={n_traces})");
    println!("{:>6} | {:>8} | paper: {:>6}", "util", "acc%", "acc%");
    for (i, (&util, r)) in paper_ref::TABLE4_UTILS.iter().zip(&cells).enumerate() {
        println!("{:>6.1} | {:>8.1} | paper: {:>6.1}", util, r.acc, paper_ref::TABLE4_ACC[i]);
        rows.push((util, r.acc));
    }
    let accs: Vec<f64> = rows.iter().map(|r| r.1).collect();
    println!(
        "  measured: {:.1} +/- {:.1}   (paper: 70.1 +/- 1.8 — stability is the claim)",
        mean(&accs),
        stddev(&accs)
    );
    let json = Json::Arr(rows.iter().map(|r| Json::arr_f64(&[r.0, r.1])).collect());
    super::write_results("table4", &json)?;
    Ok(rows)
}
