//! The shared cell runner: one (model, benchmark, method) cell of the
//! evaluation grid = many simulated questions aggregated into the
//! accuracy / tokens / latency / wait / decode metrics the tables report.

use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::sim::des::{DesEngine, QuestionResult, Scratch, SimConfig};
use crate::sim::profiles::{BenchId, BenchProfile, ModelId};
use crate::sim::tracegen::{GenParams, TraceGen};
use crate::util::json::Json;
use crate::util::pool;

/// Aggregated metrics of one cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Model of the cell.
    pub model: ModelId,
    /// Benchmark of the cell.
    pub bench: BenchId,
    /// Method of the cell.
    pub method: Method,
    /// Trace budget N the cell ran with.
    pub n_traces: usize,
    /// Questions simulated.
    pub n_questions: usize,
    /// Accuracy in percent.
    pub acc: f64,
    /// Mean generated tokens per question, thousands (Table 1 Tok.).
    pub tok_k: f64,
    /// Mean end-to-end latency per question, seconds (Table 1 Lat.).
    pub lat_s: f64,
    /// Mean per-trace wait seconds (Fig 2c's per-trace view).
    pub wait_s: f64,
    /// Mean per-trace decode seconds.
    pub decode_s: f64,
    /// Engine-timeline wait seconds (Table 3's view).
    pub engine_wait_s: f64,
    /// Engine-timeline decode seconds.
    pub engine_decode_s: f64,
    /// DeepConf stage split, averaged: (warmup lat, prune lat).
    pub stage_lat: Option<(f64, f64)>,
    /// DeepConf stage wait/decode means ((w_wait, w_dec), (p_wait, p_dec)).
    pub stage_wait_decode: Option<((f64, f64), (f64, f64))>,
    /// Mean preemption events per question.
    pub n_preemptions: f64,
    /// Mean pruned traces per question.
    pub n_pruned: f64,
}

impl CellResult {
    /// Serialize as one row of a `results/*.json` table.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(format!("{:?}", self.model))),
            ("bench", Json::Str(self.bench.name().to_string())),
            ("method", Json::Str(self.method.name().to_string())),
            ("n_traces", Json::Num(self.n_traces as f64)),
            ("n_questions", Json::Num(self.n_questions as f64)),
            ("acc", Json::Num(self.acc)),
            ("tok_k", Json::Num(self.tok_k)),
            ("lat_s", Json::Num(self.lat_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("decode_s", Json::Num(self.decode_s)),
            ("engine_wait_s", Json::Num(self.engine_wait_s)),
            ("engine_decode_s", Json::Num(self.engine_decode_s)),
            ("preemptions", Json::Num(self.n_preemptions)),
            ("pruned", Json::Num(self.n_pruned)),
        ])
    }
}

/// Configuration for one cell run.
#[derive(Debug, Clone)]
pub struct CellOpts {
    /// Trace budget N per question.
    pub n_traces: usize,
    /// Cap on questions (None = the benchmark's full pool).
    pub max_questions: Option<usize>,
    /// vLLM-style gpu_memory_utilization.
    pub mem_util: f64,
    /// Master RNG seed.
    pub seed: u64,
    /// Score every trace regardless of method (figure harnesses).
    pub score_all: bool,
    /// Record (token, score) trajectories (Fig 6-7).
    pub record_dynamics: bool,
    /// Worker threads sharding the cell's questions (0 = all cores).
    /// Every question derives its RNG streams from `(seed, qid)` alone,
    /// so results are bit-identical for any thread count.
    pub threads: usize,
}

impl Default for CellOpts {
    fn default() -> Self {
        CellOpts {
            n_traces: 64,
            max_questions: None,
            mem_util: 0.9,
            seed: 0,
            score_all: false,
            record_dynamics: false,
            threads: 1,
        }
    }
}

/// Run one cell; `per_question` (if given) receives every QuestionResult
/// (used by the figure harnesses that need raw trajectories).
pub fn run_cell_with(
    model: ModelId,
    bench: BenchId,
    method: Method,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &CellOpts,
    mut per_question: Option<&mut dyn FnMut(&QuestionResult)>,
) -> CellResult {
    let bp = BenchProfile::get(bench);
    let n_questions = opts
        .max_questions
        .map(|m| m.min(bp.n_questions))
        .unwrap_or(bp.n_questions);

    let mut cfg = SimConfig::new(model, bench, method, opts.n_traces);
    cfg.mem_util = opts.mem_util;
    cfg.seed = opts.seed;
    cfg.score_all = opts.score_all;
    cfg.record_dynamics = opts.record_dynamics;

    let gen = TraceGen::new(model, bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let engine = DesEngine::new(&cfg, &gen, scorer);

    let mut correct = 0usize;
    let mut tok = 0.0;
    let mut lat = 0.0;
    let mut wait = 0.0;
    let mut decode = 0.0;
    let mut ewait = 0.0;
    let mut edecode = 0.0;
    let mut preempt = 0.0;
    let mut pruned = 0.0;
    let mut stage_lat_acc = (0.0, 0.0);
    let mut stage_wd_acc = ((0.0, 0.0), (0.0, 0.0));
    let mut stage_count = 0usize;

    {
        let mut fold = |r: &QuestionResult| {
            correct += r.correct as usize;
            tok += r.gen_tokens as f64;
            lat += r.latency_s;
            wait += r.mean_wait_s;
            decode += r.mean_decode_s;
            ewait += r.engine_wait_s;
            edecode += r.engine_decode_s;
            preempt += r.n_preemptions as f64;
            pruned += r.n_pruned as f64;
            if let Some((w, p)) = r.stage_latency {
                stage_lat_acc.0 += w;
                stage_lat_acc.1 += p;
                stage_count += 1;
            }
            if let Some(((ww, wd), (pw, pd))) = r.stage_wait_decode {
                stage_wd_acc.0 .0 += ww;
                stage_wd_acc.0 .1 += wd;
                stage_wd_acc.1 .0 += pw;
                stage_wd_acc.1 .1 += pd;
            }
            if let Some(cb) = per_question.as_deref_mut() {
                cb(r);
            }
        };

        // Questions are independent simulations whose RNG streams derive
        // from (seed, qid), so they shard freely across workers. The
        // parallel path collects into qid order before folding, which
        // keeps the aggregate float sums and the per_question callback
        // order bit-identical to the streaming serial path; each worker
        // reuses one Scratch across its questions.
        let threads = pool::resolve_threads(opts.threads).min(n_questions.max(1));
        if threads <= 1 {
            let mut scratch = Scratch::new();
            for qid in 0..n_questions {
                let r = engine.run_question_with(qid, &mut scratch);
                fold(&r);
            }
        } else {
            let results: Vec<QuestionResult> = pool::parallel_map_with(
                threads,
                n_questions,
                Scratch::new,
                |scratch, qid| engine.run_question_with(qid, scratch),
            );
            for r in &results {
                fold(r);
            }
        }
    }

    let nq = n_questions as f64;
    CellResult {
        model,
        bench,
        method,
        n_traces: opts.n_traces,
        n_questions,
        acc: 100.0 * correct as f64 / nq,
        tok_k: tok / nq / 1000.0,
        lat_s: lat / nq,
        wait_s: wait / nq,
        decode_s: decode / nq,
        engine_wait_s: ewait / nq,
        engine_decode_s: edecode / nq,
        stage_lat: (stage_count > 0).then(|| {
            (stage_lat_acc.0 / stage_count as f64, stage_lat_acc.1 / stage_count as f64)
        }),
        stage_wait_decode: (stage_count > 0).then(|| {
            let c = stage_count as f64;
            (
                (stage_wd_acc.0 .0 / c, stage_wd_acc.0 .1 / c),
                (stage_wd_acc.1 .0 / c, stage_wd_acc.1 .1 / c),
            )
        }),
        n_preemptions: preempt / nq,
        n_pruned: pruned / nq,
    }
}

/// Convenience wrapper without the per-question callback.
pub fn run_cell(
    model: ModelId,
    bench: BenchId,
    method: Method,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &CellOpts,
) -> CellResult {
    run_cell_with(model, bench, method, gen_params, scorer, opts, None)
}

/// Projection scorer onto the generator's signal direction — the
/// artifact-free stand-in for the trained MLP that tests and the
/// self-contained benches share (real runs load the trained weights
/// via `harness::load_sim_bundle`).
pub fn projection_scorer(gp: &GenParams) -> StepScorer {
    let d = gp.d;
    let mut w1 = vec![0.0f32; d * 2];
    for i in 0..d {
        w1[i * 2] = gp.signal_dir[i];
        w1[i * 2 + 1] = -gp.signal_dir[i];
    }
    StepScorer::new(d, 2, w1, vec![0.0; 2], vec![1.0, -1.0], 0.0)
        .expect("projection scorer shapes are consistent by construction")
}

/// One cell of a table grid, for batched execution via [`run_cells`].
#[derive(Debug, Clone)]
pub struct CellJob {
    /// Model of the cell.
    pub model: ModelId,
    /// Benchmark of the cell.
    pub bench: BenchId,
    /// Method of the cell.
    pub method: Method,
    /// Per-cell options.
    pub opts: CellOpts,
}

/// Run a whole table's cells with two-level sharding (0 threads = all
/// cores): with at least as many cells as workers, the grid shards
/// across cells (questions serial inside each); otherwise cells run
/// serially and each shards its questions. Results come back in job
/// order and are identical for any thread count.
pub fn run_cells(
    jobs: &[CellJob],
    gen_params: &GenParams,
    scorer: &StepScorer,
    threads: usize,
) -> Vec<CellResult> {
    let threads = pool::resolve_threads(threads);
    if threads > 1 && jobs.len() >= threads {
        pool::parallel_map(threads, jobs.len(), |i| {
            let j = &jobs[i];
            let mut opts = j.opts.clone();
            opts.threads = 1;
            run_cell(j.model, j.bench, j.method, gen_params, scorer, &opts)
        })
    } else {
        jobs.iter()
            .map(|j| {
                let mut opts = j.opts.clone();
                opts.threads = threads;
                run_cell(j.model, j.bench, j.method, gen_params, scorer, &opts)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scorer_for(gp: &GenParams) -> StepScorer {
        projection_scorer(gp)
    }

    #[test]
    fn cell_runs_and_aggregates() {
        let gp = GenParams::default_d64();
        let sc = scorer_for(&gp);
        let opts = CellOpts { n_traces: 8, max_questions: Some(3), ..Default::default() };
        let r = run_cell(ModelId::Qwen3_4B, BenchId::Aime25, Method::Sc, &gp, &sc, &opts);
        assert_eq!(r.n_questions, 3);
        assert!(r.tok_k > 0.0);
        assert!(r.lat_s > 0.0);
        assert!((0.0..=100.0).contains(&r.acc));
    }

    #[test]
    fn cell_and_grid_sharding_match_serial() {
        let gp = GenParams::default_d64();
        let sc = scorer_for(&gp);
        let jobs: Vec<CellJob> = [Method::Sc, Method::Step]
            .into_iter()
            .map(|method| CellJob {
                model: ModelId::Qwen3_4B,
                bench: BenchId::Aime25,
                method,
                opts: CellOpts { n_traces: 8, max_questions: Some(4), ..Default::default() },
            })
            .collect();
        let serial = run_cells(&jobs, &gp, &sc, 1);
        let sharded = run_cells(&jobs, &gp, &sc, 2); // cells-level split
        for (a, b) in serial.iter().zip(&sharded) {
            assert_eq!(a.to_json().to_string_compact(), b.to_json().to_string_compact());
        }
    }

    #[test]
    fn callback_sees_every_question() {
        let gp = GenParams::default_d64();
        let sc = scorer_for(&gp);
        let opts = CellOpts { n_traces: 4, max_questions: Some(4), ..Default::default() };
        let mut seen = 0;
        let mut cb = |_r: &crate::sim::des::QuestionResult| seen += 1;
        run_cell_with(
            ModelId::Qwen3_4B,
            BenchId::EquiBench,
            Method::Step,
            &gp,
            &sc,
            &opts,
            Some(&mut cb),
        );
        assert_eq!(seen, 4);
    }
}
