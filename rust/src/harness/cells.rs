//! The shared cell runner: one (model, benchmark, method) cell of the
//! evaluation grid = many simulated questions aggregated into the
//! accuracy / tokens / latency / wait / decode metrics the tables report.

use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::sim::des::{DesEngine, QuestionResult, SimConfig};
use crate::sim::profiles::{BenchId, BenchProfile, ModelId};
use crate::sim::tracegen::{GenParams, TraceGen};
use crate::util::json::Json;

/// Aggregated metrics of one cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub model: ModelId,
    pub bench: BenchId,
    pub method: Method,
    pub n_traces: usize,
    pub n_questions: usize,
    /// Accuracy in percent.
    pub acc: f64,
    /// Mean generated tokens per question, thousands (Table 1 Tok.).
    pub tok_k: f64,
    /// Mean end-to-end latency per question, seconds (Table 1 Lat.).
    pub lat_s: f64,
    /// Mean per-trace wait / decode seconds (Fig 2c's per-trace view).
    pub wait_s: f64,
    pub decode_s: f64,
    /// Engine-timeline wait / decode (Table 3's view).
    pub engine_wait_s: f64,
    pub engine_decode_s: f64,
    /// DeepConf stage split, averaged: (warmup lat, prune lat).
    pub stage_lat: Option<(f64, f64)>,
    pub stage_wait_decode: Option<((f64, f64), (f64, f64))>,
    pub n_preemptions: f64,
    pub n_pruned: f64,
}

impl CellResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(format!("{:?}", self.model))),
            ("bench", Json::Str(self.bench.name().to_string())),
            ("method", Json::Str(self.method.name().to_string())),
            ("n_traces", Json::Num(self.n_traces as f64)),
            ("n_questions", Json::Num(self.n_questions as f64)),
            ("acc", Json::Num(self.acc)),
            ("tok_k", Json::Num(self.tok_k)),
            ("lat_s", Json::Num(self.lat_s)),
            ("wait_s", Json::Num(self.wait_s)),
            ("decode_s", Json::Num(self.decode_s)),
            ("engine_wait_s", Json::Num(self.engine_wait_s)),
            ("engine_decode_s", Json::Num(self.engine_decode_s)),
            ("preemptions", Json::Num(self.n_preemptions)),
            ("pruned", Json::Num(self.n_pruned)),
        ])
    }
}

/// Configuration for one cell run.
#[derive(Debug, Clone)]
pub struct CellOpts {
    pub n_traces: usize,
    pub max_questions: Option<usize>,
    pub mem_util: f64,
    pub seed: u64,
    pub score_all: bool,
    pub record_dynamics: bool,
}

impl Default for CellOpts {
    fn default() -> Self {
        CellOpts {
            n_traces: 64,
            max_questions: None,
            mem_util: 0.9,
            seed: 0,
            score_all: false,
            record_dynamics: false,
        }
    }
}

/// Run one cell; `per_question` (if given) receives every QuestionResult
/// (used by the figure harnesses that need raw trajectories).
pub fn run_cell_with(
    model: ModelId,
    bench: BenchId,
    method: Method,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &CellOpts,
    mut per_question: Option<&mut dyn FnMut(&QuestionResult)>,
) -> CellResult {
    let bp = BenchProfile::get(bench);
    let n_questions = opts
        .max_questions
        .map(|m| m.min(bp.n_questions))
        .unwrap_or(bp.n_questions);

    let mut cfg = SimConfig::new(model, bench, method, opts.n_traces);
    cfg.mem_util = opts.mem_util;
    cfg.seed = opts.seed;
    cfg.score_all = opts.score_all;
    cfg.record_dynamics = opts.record_dynamics;

    let gen = TraceGen::new(model, bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let engine = DesEngine::new(&cfg, &gen, scorer);

    let mut correct = 0usize;
    let mut tok = 0.0;
    let mut lat = 0.0;
    let mut wait = 0.0;
    let mut decode = 0.0;
    let mut ewait = 0.0;
    let mut edecode = 0.0;
    let mut preempt = 0.0;
    let mut pruned = 0.0;
    let mut stage_lat_acc = (0.0, 0.0);
    let mut stage_wd_acc = ((0.0, 0.0), (0.0, 0.0));
    let mut stage_count = 0usize;

    for qid in 0..n_questions {
        let r = engine.run_question(qid);
        correct += r.correct as usize;
        tok += r.gen_tokens as f64;
        lat += r.latency_s;
        wait += r.mean_wait_s;
        decode += r.mean_decode_s;
        ewait += r.engine_wait_s;
        edecode += r.engine_decode_s;
        preempt += r.n_preemptions as f64;
        pruned += r.n_pruned as f64;
        if let Some((w, p)) = r.stage_latency {
            stage_lat_acc.0 += w;
            stage_lat_acc.1 += p;
            stage_count += 1;
        }
        if let Some(((ww, wd), (pw, pd))) = r.stage_wait_decode {
            stage_wd_acc.0 .0 += ww;
            stage_wd_acc.0 .1 += wd;
            stage_wd_acc.1 .0 += pw;
            stage_wd_acc.1 .1 += pd;
        }
        if let Some(cb) = per_question.as_deref_mut() {
            cb(&r);
        }
    }

    let nq = n_questions as f64;
    CellResult {
        model,
        bench,
        method,
        n_traces: opts.n_traces,
        n_questions,
        acc: 100.0 * correct as f64 / nq,
        tok_k: tok / nq / 1000.0,
        lat_s: lat / nq,
        wait_s: wait / nq,
        decode_s: decode / nq,
        engine_wait_s: ewait / nq,
        engine_decode_s: edecode / nq,
        stage_lat: (stage_count > 0).then(|| {
            (stage_lat_acc.0 / stage_count as f64, stage_lat_acc.1 / stage_count as f64)
        }),
        stage_wait_decode: (stage_count > 0).then(|| {
            let c = stage_count as f64;
            (
                (stage_wd_acc.0 .0 / c, stage_wd_acc.0 .1 / c),
                (stage_wd_acc.1 .0 / c, stage_wd_acc.1 .1 / c),
            )
        }),
        n_preemptions: preempt / nq,
        n_pruned: pruned / nq,
    }
}

/// Convenience wrapper without the per-question callback.
pub fn run_cell(
    model: ModelId,
    bench: BenchId,
    method: Method,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &CellOpts,
) -> CellResult {
    run_cell_with(model, bench, method, gen_params, scorer, opts, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scorer_for(gp: &GenParams) -> StepScorer {
        // Projection scorer onto the signal direction (tests run without
        // artifacts; real runs load the trained MLP).
        let d = gp.d;
        let mut w1 = vec![0.0f32; d * 2];
        for i in 0..d {
            w1[i * 2] = gp.signal_dir[i];
            w1[i * 2 + 1] = -gp.signal_dir[i];
        }
        StepScorer::new(d, 2, w1, vec![0.0; 2], vec![1.0, -1.0], 0.0).unwrap()
    }

    #[test]
    fn cell_runs_and_aggregates() {
        let gp = GenParams::default_d64();
        let sc = scorer_for(&gp);
        let opts = CellOpts { n_traces: 8, max_questions: Some(3), ..Default::default() };
        let r = run_cell(ModelId::Qwen3_4B, BenchId::Aime25, Method::Sc, &gp, &sc, &opts);
        assert_eq!(r.n_questions, 3);
        assert!(r.tok_k > 0.0);
        assert!(r.lat_s > 0.0);
        assert!((0.0..=100.0).contains(&r.acc));
    }

    #[test]
    fn callback_sees_every_question() {
        let gp = GenParams::default_d64();
        let sc = scorer_for(&gp);
        let opts = CellOpts { n_traces: 4, max_questions: Some(4), ..Default::default() };
        let mut seen = 0;
        let mut cb = |_r: &crate::sim::des::QuestionResult| seen += 1;
        run_cell_with(
            ModelId::Qwen3_4B,
            BenchId::EquiBench,
            Method::Step,
            &gp,
            &sc,
            &opts,
            Some(&mut cb),
        );
        assert_eq!(seen, 4);
    }
}
