//! Table 6 (beyond the paper) — multi-GPU cluster serving: goodput,
//! shed rate, and cluster-wide p50/p95/p99 latency per method, plus a
//! router-policy comparison for STEP.
//!
//! The serving cell ([`super::table5`]) measures one GPU; this cell is
//! the ROADMAP's cluster-scale rendering: R per-GPU engines behind a
//! router and admission control, driven by a closed-loop client
//! population (saturation self-throttles, so the knee is observable).
//! Two grids share one workload:
//!
//! * **methods** — CoT / SC / Slim-SC / STEP under the configured
//!   router, the serving claim at cluster scale;
//! * **routers** — round-robin vs least-outstanding vs kv-pressure with
//!   STEP, the claim this layer adds: a router that can see per-GPU KV
//!   pressure (resident blocks + score-weighted survivor demand) beats
//!   count-based and oblivious placement on tail latency under skewed
//!   load, because step scores are a *schedulable* signal while
//!   per-trace confidence is not.
//!
//! Runs self-contained (built-in generator defaults) when artifacts are
//! absent. Metric blocks are bit-identical for any `--threads` value:
//! each cell's simulation is single-threaded and deterministic in the
//! seed; threads only shard the cells.

use std::path::PathBuf;

use anyhow::Result;

use super::cells::projection_scorer;
use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::signal::{SignalKind, SignalSpec};
use crate::obs::{perfetto, to_jsonl, SimEvent};
use crate::sim::cluster::{
    parse_fleet_events, AdmissionConfig, ClusterConfig, ClusterResult, ClusterSim,
    ClusterWorkload, GpuProfile, MigrationPolicy,
};
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::router::RouterKind;
use crate::sim::tracegen::{GenParams, TraceGen};
use crate::sim::workload::{ClosedLoopSpec, WorkloadSpec};
use crate::util::json::Json;
use crate::util::pool;

/// The methods the cluster cell compares (DeepConf is unsupported by
/// the serving engines; see `sim::serve`).
pub const METHODS: [Method; 4] = [Method::Cot, Method::Sc, Method::SlimSc, Method::Step];

/// The policies the migration grid compares, baseline first.
pub const MIGRATIONS: [MigrationPolicy; 3] = [
    MigrationPolicy::Never,
    MigrationPolicy::OnShed,
    MigrationPolicy::OnPressure { ratio: MigrationPolicy::DEFAULT_PRESSURE_RATIO },
];

/// Affinity-credit weights the prefix-cache sweep compares, after the
/// cache-off baseline row. `0.0` proves the credit is inert (placement
/// arithmetic untouched); the rest trade placement pressure against
/// prefix locality.
pub const AFFINITY_WEIGHTS: [f64; 4] = [0.0, 0.25, 0.5, 1.0];

/// The signal axis of the Pareto grid ([`run_signal_grid`]), the
/// default `hidden-mlp` first so the identity and accuracy gates read
/// off the leading rows.
pub const PARETO_SIGNALS: [SignalKind; 4] = [
    SignalKind::HiddenMlp,
    SignalKind::LatentTemporal,
    SignalKind::Confidence,
    SignalKind::PrmOracle,
];

/// The method axis of the Pareto grid: `slim-sc` is the signal-inert
/// reference (similarity pruning never consults the signal, so its
/// rows must agree across signals), `step` is where the signals race.
pub const PARETO_METHODS: [Method; 2] = [Method::SlimSc, Method::Step];

/// The memory-pressure axis of the Pareto grid
/// (gpu_memory_utilization of each pool): roomy, then pressured.
pub const PARETO_MEM_UTILS: [f64; 2] = [0.9, 0.6];

/// Revocation counts the elasticity grid sweeps.
pub const ELASTICITY_REVOCATIONS: [usize; 2] = [2, 4];

/// Drain deadlines (seconds) the elasticity grid sweeps.
pub const ELASTICITY_DEADLINES: [f64; 2] = [10.0, 40.0];

/// The policy axis of the elasticity grid, baseline first:
/// `shed-everything` (no migration — the deadline force-clear abandons
/// every resident) vs `drain-relocate` (the drain controller moves
/// residents out over the migration hop).
pub const ELASTICITY_POLICIES: [(MigrationPolicy, &str); 2] = [
    (MigrationPolicy::Never, "shed-everything"),
    (MigrationPolicy::OnShed, "drain-relocate"),
];

/// Options of one cluster-serving run (`step cluster-sim`).
#[derive(Debug, Clone)]
pub struct ClusterOpts {
    /// Number of per-GPU engines (R).
    pub gpus: usize,
    /// Served model.
    pub model: ModelId,
    /// Benchmark whose question pool the workload draws from.
    pub bench: BenchId,
    /// Total requests the workload offers.
    pub n_requests: usize,
    /// Closed-loop client population (0 = open loop at `rate_rps`).
    pub clients: usize,
    /// Mean closed-loop think time, seconds.
    pub think_s: f64,
    /// Fraction of clients pinned to the longest-trace questions.
    pub heavy_frac: f64,
    /// Open-loop arrival rate, requests/second (used when `clients` is
    /// 0).
    pub rate_rps: f64,
    /// Open-loop burst size (`None` = Poisson arrivals).
    pub burst: Option<usize>,
    /// Traces per request (N).
    pub n_traces: usize,
    /// vLLM-style gpu_memory_utilization of each GPU's pool.
    pub mem_util: f64,
    /// Optional per-request KV quota as a fraction of each pool.
    pub quota_frac: Option<f64>,
    /// Placement policy for the methods grid.
    pub router: RouterKind,
    /// GPU-shard size of the two-stage `kv-sharded` router (0 = auto,
    /// ≈√R with a floor). Ignored by the flat routers.
    pub shard_size: usize,
    /// Bound on the cluster admission queue.
    pub queue_cap: usize,
    /// Per-GPU cap on outstanding requests.
    pub max_outstanding: usize,
    /// SLO budget for admission's early reject (`None` = off).
    pub slo_s: Option<f64>,
    /// Per-GPU capacity/speed profiles (`--gpu-profile`, repeatable;
    /// cycled over the GPUs). Empty = a uniform pool.
    pub gpu_profiles: Vec<GpuProfile>,
    /// Cross-GPU migration policy (`--migrate`).
    pub migrate: MigrationPolicy,
    /// Fleet-event schedule spec (`--fleet-events`): `;`-separated
    /// `T:GPU:ACTION[:DEADLINE]` entries or `rand:SEED:N:HORIZON`.
    /// Empty = the static fleet.
    pub fleet_events: String,
    /// Standby engines behind the initial fleet (`--standby`), indexed
    /// `gpus..gpus+standby`; activated by join events or the scaling
    /// controller.
    pub standby: usize,
    /// Admission-queue depth at which the scaling controller activates
    /// a standby engine (`--scale-up-queue-depth`, 0 = only on an
    /// imminent shed).
    pub scale_up_queue_depth: usize,
    /// JSONL event-log path (`--trace-out`): rerun the canonical STEP
    /// cell with the event log enabled and write the merged stream as
    /// JSON Lines. `None` = tracing off. Not part of the metric JSON —
    /// the determinism contract says it cannot change a byte of it,
    /// and the traced rerun is compared against the untraced cell to
    /// prove that.
    pub trace_out: Option<PathBuf>,
    /// Chrome/Perfetto trace path (`--perfetto-out`): write the traced
    /// STEP cell's stream as a trace-event JSON document loadable in
    /// `ui.perfetto.dev`. `None` = off.
    pub perfetto_out: Option<PathBuf>,
    /// Event-kind filter for the JSONL log (`--trace-filter`,
    /// comma-separated [`crate::obs::KIND_NAMES`]). Empty = every
    /// kind. The Perfetto export and the traced≡untraced comparison
    /// always see the full stream.
    pub trace_filter: Vec<String>,
    /// Master seed.
    pub seed: u64,
    /// Worker threads sharding the cells (0 = all cores). Metric
    /// output is bit-identical for any value.
    pub threads: usize,
    /// Worker threads advancing each cluster's per-GPU engines in
    /// parallel between interaction points (0 = all cores, 1 =
    /// serial). Bit-identical output for any value; default 1 because
    /// the cells themselves shard across `threads`. Not part of the
    /// metric JSON — it cannot change a single byte of it.
    pub step_threads: usize,
    /// Share each question's full prompt blocks copy-on-write through
    /// every engine's per-GPU prefix registry (`--prefix-cache`). Off
    /// (default) is byte-identical to the registry-free cluster.
    pub prefix_cache: bool,
    /// Affinity credit of the kv-pressure routers
    /// (`--affinity-weight`): the expected-footprint term of a
    /// candidate GPU is discounted by this weight times its pinned
    /// prefix blocks for the request's question. 0 (default) leaves
    /// placement arithmetic untouched.
    pub affinity_weight: f64,
    /// Pruning signal scoring every decoded step (`--signal`). The
    /// default `hidden-mlp` is byte-identical to the pre-trait scorer.
    pub signal: SignalSpec,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            gpus: 4,
            model: ModelId::DeepSeek8B,
            bench: BenchId::Aime25,
            n_requests: 48,
            clients: 12,
            think_s: 60.0,
            heavy_frac: 0.5,
            rate_rps: 0.05,
            burst: None,
            n_traces: 16,
            mem_util: 0.9,
            quota_frac: None,
            router: RouterKind::KvPressure,
            shard_size: 0,
            queue_cap: 64,
            max_outstanding: 8,
            slo_s: None,
            gpu_profiles: Vec::new(),
            migrate: MigrationPolicy::Never,
            fleet_events: String::new(),
            standby: 0,
            scale_up_queue_depth: 0,
            trace_out: None,
            perfetto_out: None,
            trace_filter: Vec::new(),
            seed: 0,
            threads: 0,
            step_threads: 1,
            prefix_cache: false,
            affinity_weight: 0.0,
            signal: SignalSpec::default(),
        }
    }
}

impl ClusterOpts {
    /// Quick scale for benches / smoke tests: 4 GPUs under a skewed
    /// closed loop with real memory pressure.
    pub fn quick() -> Self {
        ClusterOpts {
            model: ModelId::Phi4_14B,
            bench: BenchId::Hmmt2425,
            n_requests: 24,
            clients: 10,
            think_s: 45.0,
            n_traces: 8,
            mem_util: 0.5,
            max_outstanding: 4,
            ..Default::default()
        }
    }

    /// The workload this option set describes.
    pub fn workload(&self) -> ClusterWorkload {
        if self.clients > 0 {
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                self.clients,
                self.think_s,
                self.n_requests,
                self.heavy_frac,
            ))
        } else {
            ClusterWorkload::Open(match self.burst {
                Some(b) => WorkloadSpec::bursty(self.rate_rps, b, self.n_requests),
                None => WorkloadSpec::poisson(self.rate_rps, self.n_requests),
            })
        }
    }

    /// The cluster configuration for one (method, router) cell.
    pub fn config(&self, method: Method, router: RouterKind) -> ClusterConfig {
        ClusterConfig::builder(
            self.gpus,
            self.model,
            self.bench,
            method,
            self.n_traces,
            self.workload(),
        )
        .mem_util(self.mem_util)
        .seed(self.seed)
        .quota_frac(self.quota_frac)
        .router(router)
        .shard_size(self.shard_size)
        .admission(AdmissionConfig {
            queue_cap: self.queue_cap,
            max_outstanding_per_gpu: self.max_outstanding.max(1),
            slo_s: self.slo_s,
        })
        .gpu_profiles(self.gpu_profiles.clone())
        .migration(self.migrate)
        .fleet_events(
            parse_fleet_events(&self.fleet_events, self.gpus, self.standby)
                .expect("invalid --fleet-events spec (the CLI validates before running)"),
        )
        .standby(self.standby)
        .scale_up_queue_depth(self.scale_up_queue_depth)
        .step_threads(self.step_threads)
        .prefix_cache(self.prefix_cache)
        .affinity_weight(self.affinity_weight)
        .signal(self.signal.clone())
        .build()
    }

    /// The heterogeneous option set the migration grid runs at: the
    /// caller's options with [`GpuProfile::default_hetero`] substituted
    /// when no profiles were given (a uniform pool has nothing
    /// interesting to migrate between).
    pub fn migration_opts(&self) -> ClusterOpts {
        let mut o = self.clone();
        if o.gpu_profiles.is_empty() {
            o.gpu_profiles = GpuProfile::default_hetero(o.gpus);
        }
        o
    }

    /// The option set the elasticity grid runs at: the caller's model,
    /// fleet size, trace budget, and seed under a fixed open-loop
    /// workload on a uniform pool, with a standby pool as deep as the
    /// initial fleet so the scaling controller can backfill revoked
    /// capacity. Each grid row then substitutes its own revocation
    /// schedule and migration policy.
    pub fn elasticity_opts(&self) -> ClusterOpts {
        let mut o = self.clone();
        o.clients = 0;
        o.rate_rps = 1.0;
        o.burst = None;
        o.n_requests = o.n_requests.min(24);
        o.queue_cap = 64;
        o.max_outstanding = 8;
        o.slo_s = None;
        o.gpu_profiles = Vec::new();
        o.fleet_events = String::new();
        o.standby = o.gpus;
        o.scale_up_queue_depth = 4;
        o
    }
}

/// Aggregated metrics of one cluster cell (a method or router row).
#[derive(Debug, Clone)]
pub struct ClusterCell {
    /// Row label: the method's name in the methods grid, the router's
    /// in the routers grid.
    pub label: String,
    /// Completed requests per second of cluster makespan.
    pub goodput_rps: f64,
    /// Fraction of offered requests shed by admission.
    pub shed_rate: f64,
    /// Cluster-wide median end-to-end latency, seconds.
    pub p50_s: f64,
    /// Cluster-wide 95th-percentile latency, seconds.
    pub p95_s: f64,
    /// Cluster-wide 99th-percentile latency, seconds.
    pub p99_s: f64,
    /// Cluster-wide median time-to-first-vote, seconds.
    pub ttfv_p50_s: f64,
    /// Accuracy over completed requests, percent.
    pub acc: f64,
    /// Mean generated tokens per completed request, thousands.
    pub tok_k: f64,
    /// Total preemption events across GPUs.
    pub preemptions: u64,
    /// Total pruned traces across GPUs.
    pub pruned: u64,
    /// Total scheduler events processed across GPUs (the events/sec
    /// numerator of the fleet-scale bench).
    pub events: u64,
    /// Requests shed by admission.
    pub shed: u64,
    /// Requests relocated across GPUs by the migration policy.
    pub migrated: u64,
    /// Migrations that rescued a request from a last-survivor prune.
    pub migration_saved: u64,
    /// Prefix tokens recomputed to resume migrated traces, thousands.
    pub migration_recompute_tok_k: f64,
    /// Peak admission-queue depth.
    pub queue_peak: u64,
    /// Largest share of completions a single GPU took (placement
    /// balance: 1/R is perfect, 1.0 is a single hot GPU).
    pub max_gpu_share: f64,
    /// Largest per-GPU peak KV-block usage fraction.
    pub peak_block_frac: f64,
    /// Spot revocations fired by the fleet schedule.
    pub revocations: u64,
    /// Requests that completed naturally on a draining GPU.
    pub drained: u64,
    /// Residents the drain controller relocated off a draining GPU.
    pub rescue_migrated: u64,
    /// Residents abandoned by a revocation deadline force-clear.
    pub shed_on_revoke: u64,
    /// Requests dropped (shed + abandoned) per revocation — the
    /// elasticity grid's headline metric.
    pub goodput_lost_per_revocation: f64,
}

impl ClusterCell {
    /// Condense one cluster run into a report row.
    pub fn from_result(label: &str, r: &ClusterResult) -> ClusterCell {
        let n = r.outcomes.len().max(1) as f64;
        let correct = r.outcomes.iter().filter(|o| o.correct).count() as f64;
        let tok: f64 = r.outcomes.iter().map(|o| o.gen_tokens as f64).sum();
        let total: usize = r.per_gpu_requests.iter().sum();
        let max_share = if total == 0 {
            0.0
        } else {
            r.per_gpu_requests.iter().copied().max().unwrap_or(0) as f64 / total as f64
        };
        ClusterCell {
            label: label.to_string(),
            goodput_rps: r.goodput_rps(),
            shed_rate: r.counters.shed_rate(),
            p50_s: r.latency.percentile_s(50.0),
            p95_s: r.latency.percentile_s(95.0),
            p99_s: r.latency.percentile_s(99.0),
            ttfv_p50_s: r.ttfv.percentile_s(50.0),
            acc: 100.0 * correct / n,
            tok_k: tok / n / 1000.0,
            preemptions: r.engine_counters.preemptions,
            pruned: r.engine_counters.pruned,
            events: r.engine_counters.events,
            shed: r.counters.shed,
            migrated: r.counters.migrated,
            migration_saved: r.counters.migration_saved,
            migration_recompute_tok_k: r.counters.migration_recompute_tokens as f64 / 1000.0,
            queue_peak: r.counters.queue_peak,
            max_gpu_share: max_share,
            peak_block_frac: r
                .per_gpu_peak_block_frac
                .iter()
                .copied()
                .fold(0.0f64, f64::max),
            revocations: r.counters.revocations,
            drained: r.counters.drained,
            rescue_migrated: r.counters.rescue_migrated,
            shed_on_revoke: r.counters.shed_on_revoke,
            goodput_lost_per_revocation: r.counters.goodput_lost_per_revocation(),
        }
    }

    /// Serialize as one metric block of `BENCH_cluster.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("p99_s", Json::Num(self.p99_s)),
            ("ttfv_p50_s", Json::Num(self.ttfv_p50_s)),
            ("acc", Json::Num(self.acc)),
            ("tok_k", Json::Num(self.tok_k)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("events", Json::Num(self.events as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("migrated", Json::Num(self.migrated as f64)),
            ("migration_saved", Json::Num(self.migration_saved as f64)),
            ("migration_recompute_tok_k", Json::Num(self.migration_recompute_tok_k)),
            ("queue_peak", Json::Num(self.queue_peak as f64)),
            ("max_gpu_share", Json::Num(self.max_gpu_share)),
            ("peak_block_frac", Json::Num(self.peak_block_frac)),
            ("revocations", Json::Num(self.revocations as f64)),
            ("drained", Json::Num(self.drained as f64)),
            ("rescue_migrated", Json::Num(self.rescue_migrated as f64)),
            ("shed_on_revoke", Json::Num(self.shed_on_revoke as f64)),
            (
                "goodput_lost_per_revocation",
                Json::Num(self.goodput_lost_per_revocation),
            ),
        ])
    }
}

/// Run one (method, router) cluster cell.
pub fn run_cell(
    method: Method,
    router: RouterKind,
    label: &str,
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &ClusterOpts,
) -> ClusterCell {
    let cfg = opts.config(method, router);
    let gen = TraceGen::new(opts.model, opts.bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let r = ClusterSim::new(&cfg, &gen, scorer).run();
    ClusterCell::from_result(label, &r)
}

/// Run the canonical STEP cell with the event log enabled, returning
/// the metric row, the merged event stream, and the ring-drop count
/// (always 0 here — the CLI traces unbounded). The row must compare
/// byte-identical to the untraced STEP cell of the methods grid; that
/// comparison is the determinism contract's CLI-side enforcement
/// (`run` bails when it breaks).
pub fn run_traced_cell(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> (ClusterCell, Vec<SimEvent>, u64) {
    let mut cfg = opts.config(Method::Step, opts.router);
    cfg.event_log = Some(0);
    let gen = TraceGen::new(opts.model, opts.bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let r = ClusterSim::new(&cfg, &gen, scorer).run();
    let cell = ClusterCell::from_result(Method::Step.name(), &r);
    (cell, r.events, r.events_dropped)
}

/// Run both grids — methods under `opts.router`, then every router with
/// STEP — as one job list sharded across up to `opts.threads` workers.
/// Each cell is deterministic and single-threaded, and results return
/// in job order, so the output is bit-identical for any thread count.
pub fn run_grids(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> (Vec<ClusterCell>, Vec<ClusterCell>) {
    let jobs: Vec<(Method, RouterKind, String)> = METHODS
        .iter()
        .map(|&m| (m, opts.router, m.name().to_string()))
        .chain(
            RouterKind::ALL
                .iter()
                .map(|&r| (Method::Step, r, r.name().to_string())),
        )
        .collect();
    let threads = pool::resolve_threads(opts.threads).min(jobs.len());
    let cells: Vec<ClusterCell> = if threads <= 1 {
        jobs.iter()
            .map(|(m, r, label)| run_cell(*m, *r, label, gen_params, scorer, opts))
            .collect()
    } else {
        pool::parallel_map(threads, jobs.len(), |i| {
            let (m, r, label) = &jobs[i];
            run_cell(*m, *r, label, gen_params, scorer, opts)
        })
    };
    let mut cells = cells;
    let routers = cells.split_off(METHODS.len());
    (cells, routers)
}

/// Run the migration grid: STEP under the configured router on the
/// (heterogeneous) pool described by `opts`, one row per
/// [`MigrationPolicy`] in [`MIGRATIONS`] — `never` is the baseline the
/// work-preservation claim is measured against. Callers normally pass
/// [`ClusterOpts::migration_opts`] so a profile-less option set gets
/// the default heterogeneous fleet. Cells shard across `opts.threads`
/// like the other grids; output is bit-identical for any thread count.
pub fn run_migration_grid(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> Vec<ClusterCell> {
    let run_one = |policy: &MigrationPolicy| {
        let mut o = opts.clone();
        o.migrate = *policy;
        run_cell(Method::Step, o.router, policy.name(), gen_params, scorer, &o)
    };
    let threads = pool::resolve_threads(opts.threads).min(MIGRATIONS.len());
    if threads <= 1 {
        MIGRATIONS.iter().map(run_one).collect()
    } else {
        pool::parallel_map(threads, MIGRATIONS.len(), |i| run_one(&MIGRATIONS[i]))
    }
}

/// One row of the affinity-weight sweep: the prefix-cache/placement
/// metrics the other grids don't carry. The first row is the cache-off
/// baseline the hit-rate and prune claims are measured against.
#[derive(Debug, Clone)]
pub struct AffinityCell {
    /// Row label: `no-cache`, or `w{weight}` with the cache on.
    pub label: String,
    /// Whether this row ran with the prefix registry enabled.
    pub prefix_cache: bool,
    /// Affinity credit the row's placements used.
    pub affinity_weight: f64,
    /// Shared admissions over all admissions touching the registry.
    pub prefix_hit_rate: f64,
    /// KV blocks the registry served without re-prefilling.
    pub prefix_saved_blocks: u64,
    /// Cold registry entries reclaimed under pressure.
    pub prefix_evictions: u64,
    /// Cluster-wide 99th-percentile latency, seconds.
    pub p99_s: f64,
    /// Total pruned traces across GPUs.
    pub pruned: u64,
    /// Accuracy over completed requests, percent.
    pub acc: f64,
    /// Fraction of offered requests shed by admission.
    pub shed_rate: f64,
}

impl AffinityCell {
    /// Condense one cluster run into an affinity-sweep row.
    pub fn from_result(
        label: &str,
        prefix_cache: bool,
        affinity_weight: f64,
        r: &ClusterResult,
    ) -> AffinityCell {
        let n = r.outcomes.len().max(1) as f64;
        let correct = r.outcomes.iter().filter(|o| o.correct).count() as f64;
        AffinityCell {
            label: label.to_string(),
            prefix_cache,
            affinity_weight,
            prefix_hit_rate: r.engine_counters.prefix_hit_rate(),
            prefix_saved_blocks: r.engine_counters.prefix_saved_blocks,
            prefix_evictions: r.engine_counters.prefix_evictions,
            p99_s: r.latency.percentile_s(99.0),
            pruned: r.engine_counters.pruned,
            acc: 100.0 * correct / n,
            shed_rate: r.counters.shed_rate(),
        }
    }

    /// Serialize as one metric block of `BENCH_cluster.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("prefix_cache", Json::Bool(self.prefix_cache)),
            ("affinity_weight", Json::Num(self.affinity_weight)),
            ("prefix_hit_rate", Json::Num(self.prefix_hit_rate)),
            ("prefix_saved_blocks", Json::Num(self.prefix_saved_blocks as f64)),
            ("prefix_evictions", Json::Num(self.prefix_evictions as f64)),
            ("p99_s", Json::Num(self.p99_s)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("acc", Json::Num(self.acc)),
            ("shed_rate", Json::Num(self.shed_rate)),
        ])
    }
}

/// Run the affinity-weight sweep: STEP under the configured router on
/// the caller's workload — the cache-off baseline first, then the
/// prefix cache on at every weight in [`AFFINITY_WEIGHTS`]. Rows shard
/// across `opts.threads` like the other grids; output is bit-identical
/// for any thread count.
pub fn run_affinity_grid(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> Vec<AffinityCell> {
    let jobs: Vec<(bool, f64, String)> = std::iter::once((false, 0.0, "no-cache".to_string()))
        .chain(AFFINITY_WEIGHTS.iter().map(|&w| (true, w, format!("w{w}"))))
        .collect();
    let run_one = |(cache, w, label): &(bool, f64, String)| {
        let mut o = opts.clone();
        o.prefix_cache = *cache;
        o.affinity_weight = *w;
        let cfg = o.config(Method::Step, o.router);
        let gen =
            TraceGen::new(o.model, o.bench, gen_params.clone(), o.seed ^ 0x5EED);
        let r = ClusterSim::new(&cfg, &gen, scorer).run();
        AffinityCell::from_result(label, *cache, *w, &r)
    };
    let threads = pool::resolve_threads(opts.threads).min(jobs.len());
    if threads <= 1 {
        jobs.iter().map(run_one).collect()
    } else {
        pool::parallel_map(threads, jobs.len(), |i| run_one(&jobs[i]))
    }
}

/// Splice the affinity-weight sweep (rows + the option set it swept
/// over) into an assembled `BENCH_cluster.json` payload.
pub fn attach_affinity_grid(json: &mut Json, opts: &ClusterOpts, cells: &[AffinityCell]) {
    if let Json::Obj(map) = json {
        map.insert(
            "affinity".to_string(),
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        );
        map.insert("affinity_config".to_string(), config_json(opts));
    }
}

/// One row of the signal Pareto grid: a (signal × method × memory
/// pressure) cell's accuracy / tail-latency / prune trade-off.
#[derive(Debug, Clone)]
pub struct ParetoCell {
    /// Row label: `SIGNAL/METHOD/muU` (e.g. `confidence/step/mu0.6`).
    pub label: String,
    /// Signal the row ran (a [`crate::coordinator::signal::SIGNAL_NAMES`] entry).
    pub signal: String,
    /// Method the row ran.
    pub method: String,
    /// gpu_memory_utilization of each pool.
    pub mem_util: f64,
    /// Accuracy over completed requests, percent.
    pub acc: f64,
    /// Cluster-wide 99th-percentile latency, seconds.
    pub p99_s: f64,
    /// Completed requests per second of cluster makespan.
    pub goodput_rps: f64,
    /// Mean generated tokens per completed request, thousands.
    pub tok_k: f64,
    /// Total pruned traces across GPUs.
    pub pruned: u64,
    /// Signal invocations across GPUs (0 for the SC family —
    /// similarity pruning never consults the signal).
    pub step_scores: u64,
    /// Prunes per scored step (`pruned / step_scores`; 0 when the row
    /// never scored) — how aggressively the signal's victim selection
    /// fired per unit of scoring work.
    pub pruned_step_frac: f64,
}

impl ParetoCell {
    /// Condense one cluster run into a Pareto-grid row.
    pub fn from_result(
        label: &str,
        signal: &str,
        method: Method,
        mem_util: f64,
        r: &ClusterResult,
    ) -> ParetoCell {
        let n = r.outcomes.len().max(1) as f64;
        let correct = r.outcomes.iter().filter(|o| o.correct).count() as f64;
        let tok: f64 = r.outcomes.iter().map(|o| o.gen_tokens as f64).sum();
        let scores = r.engine_counters.step_scores;
        ParetoCell {
            label: label.to_string(),
            signal: signal.to_string(),
            method: method.name().to_string(),
            mem_util,
            acc: 100.0 * correct / n,
            p99_s: r.latency.percentile_s(99.0),
            goodput_rps: r.goodput_rps(),
            tok_k: tok / n / 1000.0,
            pruned: r.engine_counters.pruned,
            step_scores: scores,
            pruned_step_frac: if scores == 0 {
                0.0
            } else {
                r.engine_counters.pruned as f64 / scores as f64
            },
        }
    }

    /// Serialize as one metric block of `BENCH_cluster.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("signal", Json::Str(self.signal.clone())),
            ("method", Json::Str(self.method.clone())),
            ("mem_util", Json::Num(self.mem_util)),
            ("acc", Json::Num(self.acc)),
            ("p99_s", Json::Num(self.p99_s)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("tok_k", Json::Num(self.tok_k)),
            ("pruned", Json::Num(self.pruned as f64)),
            ("step_scores", Json::Num(self.step_scores as f64)),
            ("pruned_step_frac", Json::Num(self.pruned_step_frac)),
        ])
    }
}

/// Run the signal Pareto grid: every [`PARETO_SIGNALS`] signal ×
/// [`PARETO_METHODS`] method × [`PARETO_MEM_UTILS`] memory pressure on
/// the caller's workload, in that nesting order. Non-default signal
/// parameters ride along from `opts.signal` so `--signal` tuning
/// applies to the matching family's rows. Rows shard across
/// `opts.threads` like the other grids; output is bit-identical for
/// any thread count.
pub fn run_signal_grid(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> Vec<ParetoCell> {
    let jobs: Vec<(SignalSpec, Method, f64, String)> = PARETO_SIGNALS
        .iter()
        .flat_map(|&kind| {
            PARETO_METHODS.iter().flat_map(move |&m| {
                PARETO_MEM_UTILS.iter().map(move |&mu| {
                    let spec = SignalSpec { kind, ..opts.signal.clone() };
                    let label = format!("{}/{}/mu{mu}", spec.name(), m.name());
                    (spec, m, mu, label)
                })
            })
        })
        .collect();
    let run_one = |(spec, m, mu, label): &(SignalSpec, Method, f64, String)| {
        let mut o = opts.clone();
        o.signal = spec.clone();
        o.mem_util = *mu;
        let cfg = o.config(*m, o.router);
        let gen = TraceGen::new(o.model, o.bench, gen_params.clone(), o.seed ^ 0x5EED);
        let r = ClusterSim::new(&cfg, &gen, scorer).run();
        ParetoCell::from_result(label, spec.name(), *m, *mu, &r)
    };
    let threads = pool::resolve_threads(opts.threads).min(jobs.len());
    if threads <= 1 {
        jobs.iter().map(run_one).collect()
    } else {
        pool::parallel_map(threads, jobs.len(), |i| run_one(&jobs[i]))
    }
}

/// Mean accuracy of a signal's STEP rows across the grid's memory
/// pressures — the quantity the `hidden-mlp beats confidence` bench
/// gate compares (SC-family rows are signal-inert, so only STEP rows
/// measure the signal).
pub fn signal_step_acc(cells: &[ParetoCell], signal: &str) -> f64 {
    let v: Vec<f64> = cells
        .iter()
        .filter(|c| c.signal == signal && c.method == Method::Step.name())
        .map(|c| c.acc)
        .collect();
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Splice the signal Pareto grid (rows + the option set it swept over
/// + the headline accuracy comparison) into an assembled
/// `BENCH_cluster.json` payload.
pub fn attach_signal_grid(json: &mut Json, opts: &ClusterOpts, cells: &[ParetoCell]) {
    if let Json::Obj(map) = json {
        map.insert(
            "signal_pareto".to_string(),
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        );
        map.insert("signal_pareto_config".to_string(), config_json(opts));
        map.insert(
            "signal_acc_hidden_mlp".to_string(),
            Json::Num(signal_step_acc(cells, "hidden-mlp")),
        );
        map.insert(
            "signal_acc_confidence".to_string(),
            Json::Num(signal_step_acc(cells, "confidence")),
        );
    }
}

/// The fleet-event spec of one elasticity row: `n_revocations` spot
/// revocations from t = 30 s, cycling victims from GPU 0, each with
/// the same drain deadline. Revocations are spaced past the deadline
/// so a lapped victim is fully revoked before its re-join fires 5 s
/// ahead of the next revocation — every scheduled revocation lands on
/// an active engine. Deterministic and self-describing — the spec
/// string round-trips through [`parse_fleet_events`].
pub fn elasticity_schedule(n_revocations: usize, deadline_s: f64, gpus: usize) -> String {
    let g = gpus.max(1);
    // Strictly clear of the previous lap's force-clear even on a
    // single-GPU fleet (join and deadline at the same instant would
    // apply join-first onto a still-draining engine, a no-op).
    let spacing = 20.0f64.max(deadline_s + 10.0);
    let mut parts = Vec::new();
    for i in 0..n_revocations {
        let t = 30.0 + spacing * i as f64;
        let v = i % g;
        if i >= g {
            parts.push(format!("{}:{v}:join", t - 5.0));
        }
        parts.push(format!("{t}:{v}:revoke:{deadline_s}"));
    }
    parts.join(";")
}

/// Run the elasticity grid: STEP under the configured router while the
/// fleet is revoked out from under it — one row per (revocation count ×
/// drain deadline × policy) combination, `shed-everything` before
/// `drain-relocate` within each pair so the baseline is adjacent to the
/// treatment. Callers normally pass [`ClusterOpts::elasticity_opts`].
/// Rows shard across `opts.threads` like the other grids; output is
/// bit-identical for any thread count.
pub fn run_elasticity_grid(
    opts: &ClusterOpts,
    gen_params: &GenParams,
    scorer: &StepScorer,
) -> Vec<ClusterCell> {
    let jobs: Vec<(String, MigrationPolicy, String)> = ELASTICITY_REVOCATIONS
        .iter()
        .flat_map(|&n| {
            ELASTICITY_DEADLINES.iter().flat_map(move |&d| {
                ELASTICITY_POLICIES.iter().map(move |&(policy, plabel)| {
                    (
                        elasticity_schedule(n, d, opts.gpus),
                        policy,
                        format!("{n}rev/d{d:.0}/{plabel}"),
                    )
                })
            })
        })
        .collect();
    let run_one = |(schedule, policy, label): &(String, MigrationPolicy, String)| {
        let mut o = opts.clone();
        o.fleet_events = schedule.clone();
        o.migrate = *policy;
        run_cell(Method::Step, o.router, label, gen_params, scorer, &o)
    };
    let threads = pool::resolve_threads(opts.threads).min(jobs.len());
    if threads <= 1 {
        jobs.iter().map(run_one).collect()
    } else {
        pool::parallel_map(threads, jobs.len(), |i| run_one(&jobs[i]))
    }
}

/// Splice the elasticity grid (rows + the option set that produced
/// them) into an assembled `BENCH_cluster.json` payload.
pub fn attach_elasticity_grid(json: &mut Json, ela_opts: &ClusterOpts, cells: &[ClusterCell]) {
    if let Json::Obj(map) = json {
        map.insert(
            "elasticity".to_string(),
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        );
        map.insert("elasticity_config".to_string(), config_json(ela_opts));
    }
}

/// The option set serialized as the `config` block shared by
/// `BENCH_cluster.json`'s main payload and its `migration_config`.
pub fn config_json(opts: &ClusterOpts) -> Json {
    let opt_num = |v: Option<f64>| match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    };
    let burst = match opts.burst {
        Some(b) => Json::Num(b as f64),
        None => Json::Null,
    };
    let profiles = if opts.gpu_profiles.is_empty() {
        Json::Null
    } else {
        Json::Arr(
            opts.gpu_profiles
                .iter()
                .map(|p| Json::Str(p.spec()))
                .collect(),
        )
    };
    Json::obj(vec![
        ("gpus", Json::Num(opts.gpus as f64)),
        ("model", Json::Str(format!("{:?}", opts.model))),
        ("bench", Json::Str(opts.bench.name().to_string())),
        ("n_requests", Json::Num(opts.n_requests as f64)),
        ("clients", Json::Num(opts.clients as f64)),
        ("think_s", Json::Num(opts.think_s)),
        ("heavy_frac", Json::Num(opts.heavy_frac)),
        ("rate_rps", Json::Num(opts.rate_rps)),
        ("burst", burst),
        ("n_traces", Json::Num(opts.n_traces as f64)),
        ("mem_util", Json::Num(opts.mem_util)),
        ("quota_frac", opt_num(opts.quota_frac)),
        ("router", Json::Str(opts.router.name().to_string())),
        ("shard_size", Json::Num(opts.shard_size as f64)),
        ("queue_cap", Json::Num(opts.queue_cap as f64)),
        ("max_outstanding", Json::Num(opts.max_outstanding as f64)),
        ("slo_s", opt_num(opts.slo_s)),
        ("gpu_profiles", profiles),
        ("migrate", Json::Str(opts.migrate.spec())),
        ("fleet_events", Json::Str(opts.fleet_events.clone())),
        ("standby", Json::Num(opts.standby as f64)),
        ("scale_up_queue_depth", Json::Num(opts.scale_up_queue_depth as f64)),
        ("prefix_cache", Json::Bool(opts.prefix_cache)),
        ("affinity_weight", Json::Num(opts.affinity_weight)),
        ("signal", Json::Str(opts.signal.spec_string())),
        ("seed", Json::Num(opts.seed as f64)),
    ])
}

/// Assemble the `BENCH_cluster.json` payload: the workload config plus
/// the two metric-block grids. Pure function of the cells and options —
/// no timestamps, no thread counts — so reruns compare byte-for-byte.
pub fn metrics_json(
    opts: &ClusterOpts,
    methods: &[ClusterCell],
    routers: &[ClusterCell],
) -> Json {
    Json::obj(vec![
        ("config", config_json(opts)),
        ("methods", Json::Arr(methods.iter().map(|c| c.to_json()).collect())),
        ("routers", Json::Arr(routers.iter().map(|c| c.to_json()).collect())),
    ])
}

/// Canonical byte-comparison rendering of a cell grid: the pretty JSON
/// of every cell, newline-joined. The thread-/step-thread-invariance
/// gates (bench and test suite) compare these strings, so both sides
/// share one definition.
pub fn cells_fingerprint(cells: &[ClusterCell]) -> String {
    cells
        .iter()
        .map(|c| c.to_json().to_string_pretty())
        .collect::<Vec<_>>()
        .join("\n")
}

/// Splice the migration grid (rows + the heterogeneous option set that
/// produced them) into an assembled `BENCH_cluster.json` payload.
pub fn attach_migration_grid(json: &mut Json, mig_opts: &ClusterOpts, cells: &[ClusterCell]) {
    if let Json::Obj(map) = json {
        map.insert(
            "migration".to_string(),
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        );
        map.insert("migration_config".to_string(), config_json(mig_opts));
    }
}

fn print_grid(title: &str, cells: &[ClusterCell]) {
    println!("{title}");
    println!(
        "{:>18} | {:>7} | {:>6} | {:>8} {:>8} {:>8} | {:>8} | {:>6} | {:>8} {:>7} {:>5} | \
         {:>5}",
        "row", "good/s", "shed%", "p50(s)", "p95(s)", "p99(s)", "ttfv50", "acc%", "preempt",
        "pruned", "migr", "bal"
    );
    for c in cells {
        println!(
            "{:>18} | {:>7.4} | {:>6.1} | {:>8.1} {:>8.1} {:>8.1} | {:>8.1} | {:>6.1} | \
             {:>8} {:>7} {:>5} | {:>5.2}",
            c.label,
            c.goodput_rps,
            100.0 * c.shed_rate,
            c.p50_s,
            c.p95_s,
            c.p99_s,
            c.ttfv_p50_s,
            c.acc,
            c.preemptions,
            c.pruned,
            c.migrated,
            c.max_gpu_share,
        );
    }
}

/// `step cluster-sim`: run both grids, print the tables, write
/// `results/BENCH_cluster.json`. Uses the trained scorer bundle when
/// artifacts exist and falls back to the built-in generator defaults on
/// a fresh checkout.
pub fn run(opts: &ClusterOpts) -> Result<(Vec<ClusterCell>, Vec<ClusterCell>)> {
    let (gen_params, scorer) = match super::load_sim_bundle(&super::artifact_dir()) {
        Ok(bundle) => bundle,
        Err(_) => {
            println!("(no artifacts found — using built-in generator defaults)");
            let gp = GenParams::default_d64();
            let sc = projection_scorer(&gp);
            (gp, sc)
        }
    };
    let (methods, routers) = run_grids(opts, &gen_params, &scorer);

    let loop_desc = if opts.clients > 0 {
        format!(
            "closed loop: {} clients, think {}s, heavy {:.0}%",
            opts.clients,
            opts.think_s,
            100.0 * opts.heavy_frac
        )
    } else {
        format!("open loop @ {} rps", opts.rate_rps)
    };
    println!(
        "## Table 6: cluster serving ({} GPUs, {:?}, {}, N={}, {} req, {})",
        opts.gpus,
        opts.model,
        opts.bench.name(),
        opts.n_traces,
        opts.n_requests,
        loop_desc,
    );
    print_grid(
        &format!("-- methods ({} router)", opts.router.name()),
        &methods,
    );
    print_grid("-- routers (STEP)", &routers);

    // The migration grid runs on the heterogeneous pool (the user's
    // profiles, or the default mixed fleet): never / on-shed /
    // on-pressure under STEP.
    let mig_opts = opts.migration_opts();
    let migration = run_migration_grid(&mig_opts, &gen_params, &scorer);
    let profiles = &mig_opts.gpu_profiles;
    let profile_desc: Vec<String> = (0..mig_opts.gpus)
        .map(|g| profiles[g % profiles.len()].spec())
        .collect();
    print_grid(
        &format!("-- migration (STEP, hetero pool [{}])", profile_desc.join(", ")),
        &migration,
    );

    let p99 = |cells: &[ClusterCell], label: &str| {
        cells.iter().find(|c| c.label == label).map(|c| c.p99_s)
    };
    if let (Some(kv), Some(rr)) = (
        p99(&routers, RouterKind::KvPressure.name()),
        p99(&routers, RouterKind::RoundRobin.name()),
    ) {
        println!(
            "  p99 kv-pressure {kv:.1}s vs round-robin {rr:.1}s — {}",
            if kv < rr {
                "KV-aware placement holds the tail (the cluster-scale claim)"
            } else {
                "WARNING: kv-pressure tail not below round-robin at this load"
            }
        );
    }
    let shed_of = |cells: &[ClusterCell], label: &str| {
        cells.iter().find(|c| c.label == label).map(|c| c.shed_rate)
    };
    if let (Some(never), Some(on_shed)) = (
        shed_of(&migration, MigrationPolicy::Never.name()),
        shed_of(&migration, MigrationPolicy::OnShed.name()),
    ) {
        println!(
            "  shed-rate on-shed {:.1}% vs never {:.1}% — {}",
            100.0 * on_shed,
            100.0 * never,
            if on_shed <= never {
                "migration preserves work instead of shedding it"
            } else {
                "WARNING: on-shed migration shed more at this load"
            }
        );
    }
    // The elasticity grid: revocation count × drain deadline ×
    // (shed-everything vs drain-relocate) on the uniform pool with a
    // standby backfill.
    let ela_opts = opts.elasticity_opts();
    let elasticity = run_elasticity_grid(&ela_opts, &gen_params, &scorer);
    print_grid(
        &format!(
            "-- elasticity (STEP, standby {}, open @ {} rps)",
            ela_opts.standby, ela_opts.rate_rps
        ),
        &elasticity,
    );
    let mean_loss = |suffix: &str| {
        let v: Vec<f64> = elasticity
            .iter()
            .filter(|c| c.label.ends_with(suffix))
            .map(|c| c.goodput_lost_per_revocation)
            .collect();
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    let (drain, shed_all) = (mean_loss("drain-relocate"), mean_loss("shed-everything"));
    println!(
        "  goodput lost/revocation drain-relocate {drain:.2} vs shed-everything {shed_all:.2} \
         — {}",
        if drain <= shed_all {
            "draining over the migration hop beats abandoning residents"
        } else {
            "WARNING: drain-relocate lost more than shed-everything at this load"
        }
    );
    // The affinity sweep: prefix cache off, then on at every credit
    // weight, on the caller's workload.
    let affinity = run_affinity_grid(opts, &gen_params, &scorer);
    println!("-- affinity (STEP, prefix cache off then w sweep)");
    println!(
        "{:>10} | {:>6} | {:>9} | {:>7} | {:>8} | {:>7} | {:>6} | {:>6}",
        "row", "hit%", "saved_blk", "evicted", "p99(s)", "pruned", "acc%", "shed%"
    );
    for c in &affinity {
        println!(
            "{:>10} | {:>6.1} | {:>9} | {:>7} | {:>8.1} | {:>7} | {:>6.1} | {:>6.1}",
            c.label,
            100.0 * c.prefix_hit_rate,
            c.prefix_saved_blocks,
            c.prefix_evictions,
            c.p99_s,
            c.pruned,
            c.acc,
            100.0 * c.shed_rate,
        );
    }
    if let (Some(base), Some(on)) = (
        affinity.iter().find(|c| !c.prefix_cache),
        affinity.iter().find(|c| c.prefix_cache && c.affinity_weight > 0.0),
    ) {
        println!(
            "  pruned {} (cache, {}) vs {} (no cache) at p99 {:.1}s vs {:.1}s — {}",
            on.pruned,
            on.label,
            base.pruned,
            on.p99_s,
            base.p99_s,
            if on.pruned <= base.pruned {
                "shared prompts relieve KV pressure"
            } else {
                "WARNING: prefix cache pruned more at this load"
            }
        );
    }
    // The signal Pareto grid: every pruning signal × pruning method ×
    // memory pressure on the caller's workload.
    let pareto = run_signal_grid(opts, &gen_params, &scorer);
    println!("-- signal pareto (signal x method x mem pressure)");
    println!(
        "{:>28} | {:>6} | {:>8} | {:>7} | {:>7} | {:>8} | {:>9}",
        "row", "acc%", "p99(s)", "good/s", "pruned", "scores", "prune/stp"
    );
    for c in &pareto {
        println!(
            "{:>28} | {:>6.1} | {:>8.1} | {:>7.4} | {:>7} | {:>8} | {:>9.4}",
            c.label, c.acc, c.p99_s, c.goodput_rps, c.pruned, c.step_scores, c.pruned_step_frac,
        );
    }
    let (mlp_acc, conf_acc) = (
        signal_step_acc(&pareto, "hidden-mlp"),
        signal_step_acc(&pareto, "confidence"),
    );
    println!(
        "  STEP acc hidden-mlp {mlp_acc:.1}% vs confidence {conf_acc:.1}% — {}",
        if mlp_acc >= conf_acc {
            "hidden states beat intrinsic confidence (the paper's signal claim)"
        } else {
            "WARNING: hidden-mlp accuracy below confidence at this load"
        }
    );
    let mut json = metrics_json(opts, &methods, &routers);
    attach_migration_grid(&mut json, &mig_opts, &migration);
    attach_elasticity_grid(&mut json, &ela_opts, &elasticity);
    attach_affinity_grid(&mut json, opts, &affinity);
    attach_signal_grid(&mut json, opts, &pareto);
    // Harness-convention artifact plus the canonical BENCH_cluster.json
    // metric blocks (also written by the cluster_load bench at its own
    // quick config — last writer wins; the embedded config block
    // records which).
    super::write_results("table6_cluster", &json)?;
    let path = super::write_results("BENCH_cluster", &json)?;
    println!("wrote {path:?} (and results/table6_cluster.json)");

    // Tracing sinks: rerun the canonical STEP cell with the event log
    // on, prove the metric row is byte-identical to the untraced one
    // (the determinism contract), then write the requested sinks.
    if opts.trace_out.is_some() || opts.perfetto_out.is_some() {
        let (traced, events, dropped) = run_traced_cell(opts, &gen_params, &scorer);
        let untraced = methods
            .iter()
            .find(|c| c.label == Method::Step.name())
            .expect("methods grid always carries the STEP row");
        let same = traced.to_json().to_string_pretty()
            == untraced.to_json().to_string_pretty();
        println!(
            "-- tracing (STEP cell rerun: {} events, {dropped} dropped)",
            events.len()
        );
        if !same {
            anyhow::bail!(
                "determinism contract broken: traced STEP cell diverged from the \
                 untraced run (recorders must never influence scheduling)"
            );
        }
        println!("  traced == untraced: metric block byte-identical");
        if let Some(p) = &opts.trace_out {
            let text = to_jsonl(&events, &opts.trace_filter);
            std::fs::write(p, &text)?;
            println!("wrote {p:?} ({} JSONL events)", text.lines().count());
        }
        if let Some(p) = &opts.perfetto_out {
            std::fs::write(p, perfetto::chrome_trace(&events).to_string_compact())?;
            println!("wrote {p:?} (open in ui.perfetto.dev)");
        }
    }
    Ok((methods, routers))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ClusterOpts {
        ClusterOpts {
            gpus: 2,
            model: ModelId::Qwen3_4B,
            bench: BenchId::GpqaDiamond,
            n_requests: 4,
            clients: 2,
            think_s: 20.0,
            heavy_frac: 0.5,
            n_traces: 4,
            seed: 3,
            threads: 1,
            ..Default::default()
        }
    }

    #[test]
    fn grids_cover_methods_and_routers_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let (methods, routers) = run_grids(&tiny(), &gp, &sc);
        assert_eq!(methods.len(), METHODS.len());
        for (c, &m) in methods.iter().zip(&METHODS) {
            assert_eq!(c.label, m.name());
            assert!(c.goodput_rps > 0.0, "{m:?}");
            assert!(c.p50_s <= c.p95_s && c.p95_s <= c.p99_s, "{m:?}");
            assert!((0.0..=100.0).contains(&c.acc), "{m:?}");
            assert!((0.0..=1.0).contains(&c.max_gpu_share), "{m:?}");
        }
        assert_eq!(routers.len(), RouterKind::ALL.len());
        for (c, &r) in routers.iter().zip(&RouterKind::ALL) {
            assert_eq!(c.label, r.name());
            assert!(c.goodput_rps > 0.0, "{r:?}");
        }
    }

    #[test]
    fn metric_block_is_deterministic() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let (m1, r1) = run_grids(&opts, &gp, &sc);
        let (m2, r2) = run_grids(&opts, &gp, &sc);
        assert_eq!(
            metrics_json(&opts, &m1, &r1).to_string_pretty(),
            metrics_json(&opts, &m2, &r2).to_string_pretty()
        );
    }

    #[test]
    fn migration_grid_covers_every_policy_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny().migration_opts();
        assert!(!opts.gpu_profiles.is_empty(), "migration grid runs heterogeneous");
        let cells = run_migration_grid(&opts, &gp, &sc);
        assert_eq!(cells.len(), MIGRATIONS.len());
        for (c, p) in cells.iter().zip(&MIGRATIONS) {
            assert_eq!(c.label, p.name());
            assert!(c.goodput_rps > 0.0, "{}", p.name());
        }
        // The baseline row never migrates by definition.
        assert_eq!(cells[0].migrated, 0);
        // Attached to the payload, the grid and its config are present.
        let (m, r) = run_grids(&tiny(), &gp, &sc);
        let mut json = metrics_json(&tiny(), &m, &r);
        attach_migration_grid(&mut json, &opts, &cells);
        let text = json.to_string_pretty();
        assert!(text.contains("\"migration\""));
        assert!(text.contains("\"migration_config\""));
        assert!(text.contains("\"gpu_profiles\""));
    }

    #[test]
    fn elasticity_grid_covers_the_sweep_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny().elasticity_opts();
        assert_eq!(opts.standby, opts.gpus, "standby backfill as deep as the fleet");
        assert!(opts.clients == 0, "elasticity rows run open loop");
        let cells = run_elasticity_grid(&opts, &gp, &sc);
        let n_rows =
            ELASTICITY_REVOCATIONS.len() * ELASTICITY_DEADLINES.len() * ELASTICITY_POLICIES.len();
        assert_eq!(cells.len(), n_rows);
        let mut i = 0;
        for &n in &ELASTICITY_REVOCATIONS {
            for &d in &ELASTICITY_DEADLINES {
                for &(_, plabel) in &ELASTICITY_POLICIES {
                    assert_eq!(cells[i].label, format!("{n}rev/d{d:.0}/{plabel}"));
                    assert_eq!(
                        cells[i].revocations, n as u64,
                        "{}: every scheduled revocation fires",
                        cells[i].label
                    );
                    i += 1;
                }
            }
        }
        // Within every (count, deadline) pair, draining never loses
        // more goodput than abandoning residents outright.
        for pair in cells.chunks(2) {
            assert!(
                pair[1].goodput_lost_per_revocation <= pair[0].goodput_lost_per_revocation,
                "{} vs {}",
                pair[1].label,
                pair[0].label
            );
        }
        // Attached to the payload, the grid and its config are present.
        let (m, r) = run_grids(&tiny(), &gp, &sc);
        let mut json = metrics_json(&tiny(), &m, &r);
        attach_elasticity_grid(&mut json, &opts, &cells);
        let text = json.to_string_pretty();
        assert!(text.contains("\"elasticity\""));
        assert!(text.contains("\"elasticity_config\""));
        assert!(text.contains("\"goodput_lost_per_revocation\""));
        assert!(text.contains("\"standby\""));
    }

    #[test]
    fn affinity_grid_covers_baseline_and_every_weight_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let cells = run_affinity_grid(&opts, &gp, &sc);
        assert_eq!(cells.len(), 1 + AFFINITY_WEIGHTS.len());
        assert_eq!(cells[0].label, "no-cache");
        assert!(!cells[0].prefix_cache);
        assert_eq!(cells[0].prefix_hit_rate, 0.0, "no registry, no hits");
        assert_eq!(cells[0].prefix_saved_blocks, 0);
        for (c, &w) in cells[1..].iter().zip(&AFFINITY_WEIGHTS) {
            assert_eq!(c.label, format!("w{w}"));
            assert!(c.prefix_cache);
            assert_eq!(c.affinity_weight, w);
            assert!(
                c.prefix_hit_rate > 0.0,
                "{}: sibling traces must share their prompt",
                c.label
            );
            assert!(c.prefix_saved_blocks > 0, "{}", c.label);
            assert!((0.0..=100.0).contains(&c.acc), "{}", c.label);
        }
        // Attached to the payload, the grid and its config are present.
        let (m, r) = run_grids(&opts, &gp, &sc);
        let mut json = metrics_json(&opts, &m, &r);
        attach_affinity_grid(&mut json, &opts, &cells);
        let text = json.to_string_pretty();
        assert!(text.contains("\"affinity\""));
        assert!(text.contains("\"affinity_config\""));
        assert!(text.contains("\"prefix_hit_rate\""));
        assert!(text.contains("\"prefix_saved_blocks\""));
    }

    #[test]
    fn signal_grid_covers_the_cross_product_in_order() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let cells = run_signal_grid(&opts, &gp, &sc);
        let n_rows =
            PARETO_SIGNALS.len() * PARETO_METHODS.len() * PARETO_MEM_UTILS.len();
        assert_eq!(cells.len(), n_rows);
        let mut i = 0;
        for &kind in &PARETO_SIGNALS {
            let spec = SignalSpec { kind, ..SignalSpec::default() };
            for &m in &PARETO_METHODS {
                for &mu in &PARETO_MEM_UTILS {
                    let c = &cells[i];
                    assert_eq!(c.label, format!("{}/{}/mu{mu}", spec.name(), m.name()));
                    assert_eq!(c.signal, spec.name());
                    assert_eq!(c.method, m.name());
                    assert_eq!(c.mem_util, mu);
                    assert!((0.0..=100.0).contains(&c.acc), "{}", c.label);
                    if m == Method::Step {
                        assert!(c.step_scores > 0, "{}: STEP scores every step", c.label);
                    } else {
                        assert_eq!(
                            c.step_scores, 0,
                            "{}: similarity pruning never consults the signal",
                            c.label
                        );
                    }
                    i += 1;
                }
            }
        }
        // The SC-family rows are signal-inert: within a memory
        // pressure they must agree bit-for-bit across every signal.
        for &mu in &PARETO_MEM_UTILS {
            let slim: Vec<&ParetoCell> = cells
                .iter()
                .filter(|c| c.method == Method::SlimSc.name() && c.mem_util == mu)
                .collect();
            for c in &slim[1..] {
                assert_eq!(c.acc, slim[0].acc, "{}", c.label);
                assert_eq!(c.p99_s, slim[0].p99_s, "{}", c.label);
                assert_eq!(c.pruned, slim[0].pruned, "{}", c.label);
            }
        }
    }

    #[test]
    fn signal_grid_default_row_matches_methods_grid_step_row() {
        // The hidden-mlp/step row at the option set's memory pressure
        // runs the exact configuration of the methods grid's STEP cell,
        // so its metrics must agree bit-for-bit — the Pareto grid's
        // rendering of the default-signal identity contract.
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        assert_eq!(opts.mem_util, 0.9, "tiny() runs at the grid's roomy pressure");
        let (methods, _) = run_grids(&opts, &gp, &sc);
        let step = methods.iter().find(|c| c.label == Method::Step.name()).unwrap();
        let cells = run_signal_grid(&opts, &gp, &sc);
        let row = cells
            .iter()
            .find(|c| c.label == "hidden-mlp/STEP/mu0.9")
            .expect("default row present");
        assert_eq!(row.acc, step.acc);
        assert_eq!(row.p99_s, step.p99_s);
        assert_eq!(row.goodput_rps, step.goodput_rps);
        assert_eq!(row.pruned, step.pruned);
    }

    #[test]
    fn signal_grid_attaches_rows_config_and_acc_summary() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let cells = run_signal_grid(&opts, &gp, &sc);
        let (m, r) = run_grids(&opts, &gp, &sc);
        let mut json = metrics_json(&opts, &m, &r);
        attach_signal_grid(&mut json, &opts, &cells);
        let text = json.to_string_pretty();
        assert!(text.contains("\"signal_pareto\""));
        assert!(text.contains("\"signal_pareto_config\""));
        assert!(text.contains("\"signal_acc_hidden_mlp\""));
        assert!(text.contains("\"signal_acc_confidence\""));
        assert!(text.contains("\"pruned_step_frac\""));
        // The summary fields reproduce the STEP-row means.
        assert_eq!(
            signal_step_acc(&cells, "hidden-mlp"),
            cells
                .iter()
                .filter(|c| c.signal == "hidden-mlp" && c.method == Method::Step.name())
                .map(|c| c.acc)
                .sum::<f64>()
                / PARETO_MEM_UTILS.len() as f64
        );
    }

    #[test]
    fn elasticity_schedule_round_trips() {
        let spec = elasticity_schedule(3, 10.0, 2);
        assert_eq!(spec, "30:0:revoke:10;50:1:revoke:10;65:0:join;70:0:revoke:10");
        let evs = parse_fleet_events(&spec, 2, 2).expect("schedule parses");
        assert_eq!(evs.len(), 4);
        // A long deadline pushes laps apart so the victim is clear
        // before its re-join.
        let long = elasticity_schedule(3, 40.0, 2);
        assert_eq!(long, "30:0:revoke:40;80:1:revoke:40;125:0:join;130:0:revoke:40");
    }

    #[test]
    fn traced_cell_matches_untraced_step_row() {
        let gp = GenParams::default_d64();
        let sc = projection_scorer(&gp);
        let opts = tiny();
        let (methods, _) = run_grids(&opts, &gp, &sc);
        let step = methods
            .iter()
            .find(|c| c.label == Method::Step.name())
            .expect("STEP row present");
        let (traced, events, dropped) = run_traced_cell(&opts, &gp, &sc);
        assert_eq!(
            traced.to_json().to_string_pretty(),
            step.to_json().to_string_pretty(),
            "recorders must never influence scheduling"
        );
        assert!(!events.is_empty(), "the traced rerun records the stream");
        assert_eq!(dropped, 0, "the CLI traces unbounded");
    }

    #[test]
    fn open_loop_opts_build_open_workload() {
        let mut opts = tiny();
        opts.clients = 0;
        match opts.workload() {
            ClusterWorkload::Open(w) => assert_eq!(w.n_requests, 4),
            ClusterWorkload::Closed(_) => panic!("clients=0 must mean open loop"),
        }
    }
}
