//! Table 2 — voting-strategy comparison on identical trace sets:
//! majority vs PRM-weighted vs STEP-scorer-weighted, averaged over 4
//! independent runs (paper §5.3.3).

use anyhow::Result;

use super::HarnessOpts;
use crate::coordinator::voting::{weighted_vote, Vote};
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::TraceGen;
use crate::util::json::Json;
use crate::util::pool;

/// One Table-2 row: accuracy under three voting strategies.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Model of the row.
    pub model: ModelId,
    /// Benchmark of the row.
    pub bench: BenchId,
    /// Plain majority-vote accuracy, percent.
    pub majority: f64,
    /// PRM-weighted voting accuracy, percent.
    pub prm_weighted: f64,
    /// STEP score-weighted voting accuracy, percent.
    pub step_weighted: f64,
}

/// Paper Table 2 reference rows (majority, PRM, STEP).
pub fn paper_row(model: ModelId, bench: BenchId) -> (f64, f64, f64) {
    use BenchId::*;
    use ModelId::*;
    match (model, bench) {
        (Qwen3_4B, Aime25) => (86.7, 87.5, 90.0),
        (Qwen3_4B, Hmmt2425) => (65.0, 67.5, 71.7),
        (Qwen3_4B, GpqaDiamond) => (68.1, 68.7, 69.2),
        (DeepSeek8B, Aime25) => (83.3, 83.3, 85.0),
        (DeepSeek8B, Hmmt2425) => (70.0, 71.7, 75.8),
        (DeepSeek8B, GpqaDiamond) => (67.1, 66.4, 68.5),
        _ => (f64::NAN, f64::NAN, f64::NAN),
    }
}

/// Regenerate Table 2: voting-strategy comparison.
pub fn run(opts: &HarnessOpts) -> Result<Vec<Table2Row>> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let n_runs = 4;
    let mut rows = Vec::new();

    println!("## Table 2: voting strategies on the same 64-trace sets (4 runs)");
    println!(
        "{:<12} {:<11} | {:>8} {:>8} {:>8} | paper: {:>5} {:>5} {:>5}",
        "model", "bench", "majority", "PRM-wt", "STEP-wt", "maj", "prm", "step"
    );
    for model in [ModelId::Qwen3_4B, ModelId::DeepSeek8B] {
        for bench in [BenchId::Aime25, BenchId::Hmmt2425, BenchId::GpqaDiamond] {
            let (mut acc_m, mut acc_p, mut acc_s) = (0.0, 0.0, 0.0);
            for run in 0..n_runs {
                let gen = TraceGen::new(
                    model,
                    bench,
                    gen_params.clone(),
                    opts.seed ^ (run as u64) << 8,
                );
                let n_questions = opts.max_questions.unwrap_or(30).min(60);
                // Questions shard across workers; the three per-question
                // verdicts fold in qid order (integer counts, identical
                // for any thread count).
                let threads = opts.threads; // parallel_map clamps to n_questions internally
                let verdicts: Vec<(bool, bool, bool)> =
                    pool::parallel_map(threads, n_questions, |qid| {
                        let q = gen.question(qid);
                        // The same completed trace set for all three strategies.
                        let traces: Vec<_> =
                            (0..opts.n_traces).map(|i| gen.trace(&q, i)).collect();
                        let mut votes_m = Vec::new();
                        let mut votes_p = Vec::new();
                        let mut votes_s = Vec::new();
                        let (mut sbuf, mut zbuf) = (Vec::new(), Vec::new());
                        for t in &traces {
                            let Some(ans) = t.answer else { continue };
                            // STEP weight: mean step score over the full
                            // trace, via the fused batch path (bit-exact
                            // with summing per-step score_into()).
                            let k = t.n_steps();
                            let hs: Vec<Vec<f32>> =
                                (1..=k).map(|n| gen.hidden_state(&q, t, n)).collect();
                            scorer.score_batch_into(&hs, &mut sbuf, &mut zbuf);
                            let s: f64 = sbuf.iter().map(|&x| x as f64).sum();
                            let step_w = s / k as f64;
                            votes_m.push(Vote { answer: Some(ans), weight: 1.0 });
                            votes_p.push(Vote { answer: Some(ans), weight: gen.prm_score(t) });
                            votes_s.push(Vote { answer: Some(ans), weight: step_w });
                        }
                        (
                            weighted_vote(&votes_m) == Some(0),
                            weighted_vote(&votes_p) == Some(0),
                            weighted_vote(&votes_s) == Some(0),
                        )
                    });
                let (mut cm, mut cp, mut cs) = (0, 0, 0);
                for (m_ok, p_ok, s_ok) in verdicts {
                    cm += m_ok as usize;
                    cp += p_ok as usize;
                    cs += s_ok as usize;
                }
                let nq = n_questions as f64;
                acc_m += 100.0 * cm as f64 / nq;
                acc_p += 100.0 * cp as f64 / nq;
                acc_s += 100.0 * cs as f64 / nq;
            }
            let row = Table2Row {
                model,
                bench,
                majority: acc_m / n_runs as f64,
                prm_weighted: acc_p / n_runs as f64,
                step_weighted: acc_s / n_runs as f64,
            };
            let (pm, pp, ps) = paper_row(model, bench);
            println!(
                "{:<12} {:<11} | {:>8.1} {:>8.1} {:>8.1} | paper: {:>5.1} {:>5.1} {:>5.1}",
                format!("{:?}", model),
                bench.name(),
                row.majority,
                row.prm_weighted,
                row.step_weighted,
                pm,
                pp,
                ps
            );
            rows.push(row);
        }
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::Str(format!("{:?}", r.model))),
                    ("bench", Json::Str(r.bench.name().into())),
                    ("majority", Json::Num(r.majority)),
                    ("prm", Json::Num(r.prm_weighted)),
                    ("step", Json::Num(r.step_weighted)),
                ])
            })
            .collect(),
    );
    super::write_results("table2", &json)?;
    Ok(rows)
}
