//! Figure 5 — pairwise ranking accuracy (RankAcc) of the hidden-state
//! step scorer vs token-level confidence, as a function of the prefix
//! fraction of steps observed. 256 traces/question, Qwen3-4B, on
//! AIME-25 + HMMT-25.

use anyhow::Result;

use super::HarnessOpts;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::TraceGen;
use crate::util::json::Json;
use crate::util::stats::rank_acc;

pub struct Fig5 {
    pub fractions: Vec<f64>,
    pub scorer_rankacc: Vec<f64>,
    pub confidence_rankacc: Vec<f64>,
}

pub fn run(opts: &HarnessOpts) -> Result<Fig5> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let traces_per_q = 256;
    let fractions: Vec<f64> = (1..=10).map(|k| k as f64 / 10.0).collect();

    let mut sc_acc = vec![Vec::new(); fractions.len()];
    let mut cf_acc = vec![Vec::new(); fractions.len()];

    for bench in [BenchId::Aime25, BenchId::Hmmt2425] {
        let gen = TraceGen::new(ModelId::Qwen3_4B, bench, gen_params.clone(), opts.seed);
        let n_questions = opts.max_questions.unwrap_or(15).min(30);
        for qid in 0..n_questions {
            let q = gen.question(qid);
            // Pre-sample traces + full per-step signals once.
            let traces: Vec<_> = (0..traces_per_q).map(|i| gen.trace(&q, i)).collect();
            if !traces.iter().any(|t| t.label) || traces.iter().all(|t| t.label) {
                continue; // RankAcc undefined without both classes
            }
            let step_scores: Vec<Vec<f64>> = traces
                .iter()
                .map(|t| {
                    (1..=t.n_steps())
                        .map(|n| scorer.score(&gen.hidden_state(&q, t, n)) as f64)
                        .collect()
                })
                .collect();
            let step_confs: Vec<Vec<f64>> = traces
                .iter()
                .map(|t| (1..=t.n_steps()).map(|n| gen.step_confidence(t, n)).collect())
                .collect();

            for (fi, &frac) in fractions.iter().enumerate() {
                let prefix_mean = |xs: &Vec<f64>| {
                    let k = ((xs.len() as f64 * frac).ceil() as usize).clamp(1, xs.len());
                    xs[..k].iter().sum::<f64>() / k as f64
                };
                let (mut ps, mut ns, mut pc, mut nc) =
                    (Vec::new(), Vec::new(), Vec::new(), Vec::new());
                for (t, (ss, cs)) in
                    traces.iter().zip(step_scores.iter().zip(&step_confs))
                {
                    if t.label {
                        ps.push(prefix_mean(ss));
                        pc.push(prefix_mean(cs));
                    } else {
                        ns.push(prefix_mean(ss));
                        nc.push(prefix_mean(cs));
                    }
                }
                if let Some(a) = rank_acc(&ps, &ns) {
                    sc_acc[fi].push(a);
                }
                if let Some(a) = rank_acc(&pc, &nc) {
                    cf_acc[fi].push(a);
                }
            }
        }
    }

    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let scorer_rankacc: Vec<f64> = sc_acc.iter().map(avg).collect();
    let confidence_rankacc: Vec<f64> = cf_acc.iter().map(avg).collect();

    println!("## Fig 5: RankAcc vs prefix fraction (Qwen3-4B, AIME+HMMT)");
    println!("{:>7} | {:>12} | {:>12}", "prefix", "step scorer", "confidence");
    for (i, f) in fractions.iter().enumerate() {
        println!(
            "{:>6.0}% | {:>12.3} | {:>12.3}",
            f * 100.0,
            scorer_rankacc[i],
            confidence_rankacc[i]
        );
    }
    println!("(paper: scorer dominates confidence at every prefix, both rising)");

    let json = Json::obj(vec![
        ("fractions", Json::arr_f64(&fractions)),
        ("scorer", Json::arr_f64(&scorer_rankacc)),
        ("confidence", Json::arr_f64(&confidence_rankacc)),
    ]);
    super::write_results("fig5", &json)?;
    Ok(Fig5 { fractions, scorer_rankacc, confidence_rankacc })
}
