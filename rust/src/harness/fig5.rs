//! Figure 5 — pairwise ranking accuracy (RankAcc) of the hidden-state
//! step scorer vs token-level confidence, as a function of the prefix
//! fraction of steps observed. 256 traces/question, Qwen3-4B, on
//! AIME-25 + HMMT-25.

use anyhow::Result;

use super::HarnessOpts;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::TraceGen;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::stats::rank_acc;

/// Fig-5 data: ranking accuracy across prefix fractions.
pub struct Fig5 {
    /// Prefix fractions evaluated (0.1 .. 1.0).
    pub fractions: Vec<f64>,
    /// RankAcc of the hidden-state step scorer per fraction.
    pub scorer_rankacc: Vec<f64>,
    /// RankAcc of mean token confidence per fraction.
    pub confidence_rankacc: Vec<f64>,
}

/// Regenerate Fig 5: scorer vs confidence ranking accuracy.
pub fn run(opts: &HarnessOpts) -> Result<Fig5> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let traces_per_q = 256;
    let fractions: Vec<f64> = (1..=10).map(|k| k as f64 / 10.0).collect();

    let mut sc_acc = vec![Vec::new(); fractions.len()];
    let mut cf_acc = vec![Vec::new(); fractions.len()];

    for bench in [BenchId::Aime25, BenchId::Hmmt2425] {
        let gen = TraceGen::new(ModelId::Qwen3_4B, bench, gen_params.clone(), opts.seed);
        let n_questions = opts.max_questions.unwrap_or(15).min(30);
        let threads = opts.threads; // parallel_map clamps to n_questions internally
        // Questions shard across workers; each returns its RankAcc pair
        // per prefix fraction, folded below in qid order so the output
        // is identical for any thread count.
        let per_q: Vec<Vec<(Option<f64>, Option<f64>)>> =
            pool::parallel_map(threads, n_questions, |qid| {
                let q = gen.question(qid);
                // Pre-sample traces + full per-step signals once.
                let traces: Vec<_> = (0..traces_per_q).map(|i| gen.trace(&q, i)).collect();
                if !traces.iter().any(|t| t.label) || traces.iter().all(|t| t.label) {
                    return vec![(None, None); fractions.len()]; // RankAcc undefined
                }
                let (mut sbuf, mut zbuf) = (Vec::new(), Vec::new());
                let step_scores: Vec<Vec<f64>> = traces
                    .iter()
                    .map(|t| {
                        // Fused batch path: all of a trace's step hidden
                        // states scored in one tiled pass (bit-exact with
                        // per-step score_into()).
                        let hs: Vec<Vec<f32>> = (1..=t.n_steps())
                            .map(|n| gen.hidden_state(&q, t, n))
                            .collect();
                        scorer.score_batch_into(&hs, &mut sbuf, &mut zbuf);
                        sbuf.iter().map(|&s| s as f64).collect()
                    })
                    .collect();
                let step_confs: Vec<Vec<f64>> = traces
                    .iter()
                    .map(|t| (1..=t.n_steps()).map(|n| gen.step_confidence(t, n)).collect())
                    .collect();

                fractions
                    .iter()
                    .map(|&frac| {
                        let prefix_mean = |xs: &[f64]| {
                            let k = ((xs.len() as f64 * frac).ceil() as usize).clamp(1, xs.len());
                            xs[..k].iter().sum::<f64>() / k as f64
                        };
                        let (mut ps, mut ns, mut pc, mut nc) =
                            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
                        for (t, (ss, cs)) in
                            traces.iter().zip(step_scores.iter().zip(&step_confs))
                        {
                            if t.label {
                                ps.push(prefix_mean(ss));
                                pc.push(prefix_mean(cs));
                            } else {
                                ns.push(prefix_mean(ss));
                                nc.push(prefix_mean(cs));
                            }
                        }
                        (rank_acc(&ps, &ns), rank_acc(&pc, &nc))
                    })
                    .collect()
            });
        for row in per_q {
            for (fi, (s, c)) in row.into_iter().enumerate() {
                if let Some(a) = s {
                    sc_acc[fi].push(a);
                }
                if let Some(a) = c {
                    cf_acc[fi].push(a);
                }
            }
        }
    }

    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let scorer_rankacc: Vec<f64> = sc_acc.iter().map(avg).collect();
    let confidence_rankacc: Vec<f64> = cf_acc.iter().map(avg).collect();

    println!("## Fig 5: RankAcc vs prefix fraction (Qwen3-4B, AIME+HMMT)");
    println!("{:>7} | {:>12} | {:>12}", "prefix", "step scorer", "confidence");
    for (i, f) in fractions.iter().enumerate() {
        println!(
            "{:>6.0}% | {:>12.3} | {:>12.3}",
            f * 100.0,
            scorer_rankacc[i],
            confidence_rankacc[i]
        );
    }
    println!("(paper: scorer dominates confidence at every prefix, both rising)");

    let json = Json::obj(vec![
        ("fractions", Json::arr_f64(&fractions)),
        ("scorer", Json::arr_f64(&scorer_rankacc)),
        ("confidence", Json::arr_f64(&confidence_rankacc)),
    ]);
    super::write_results("fig5", &json)?;
    Ok(Fig5 { fractions, scorer_rankacc, confidence_rankacc })
}
