//! Table 3 — wait/decode time breakdown on DeepSeek-8B / HMMT-25 / N=64.
//! The paper's headline systems claim: STEP's memory-triggered pruning
//! drives waiting time to exactly zero while SC waits longer than it
//! decodes.

use anyhow::Result;

use super::cells::{run_cells, CellJob, CellOpts};
use super::{paper_ref, HarnessOpts};
use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};
use crate::util::json::Json;

/// One Table-3 row: the engine-timeline wait/decode split.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Method of the row.
    pub method: Method,
    /// Mean engine wall-clock with a non-empty waiting queue, seconds.
    pub wait_s: f64,
    /// Mean engine wall-clock with an empty waiting queue, seconds.
    pub decode_s: f64,
    /// DeepConf stage split ((warmup wait, warmup decode), (prune ...)).
    pub stages: Option<((f64, f64), (f64, f64))>,
}

/// Regenerate Table 3: wait/decode latency decomposition.
pub fn run(opts: &HarnessOpts) -> Result<Vec<Table3Row>> {
    let (gen, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let methods = [Method::Sc, Method::DeepConf, Method::SlimSc, Method::Step];
    let jobs: Vec<CellJob> = methods
        .iter()
        .map(|&method| CellJob {
            model: ModelId::DeepSeek8B,
            bench: BenchId::Hmmt2425,
            method,
            opts: CellOpts {
                n_traces: opts.n_traces,
                max_questions: opts.max_questions,
                seed: opts.seed,
                ..Default::default()
            },
        })
        .collect();
    let cells = run_cells(&jobs, &gen, &scorer, opts.threads);

    let mut rows = Vec::new();
    println!("## Table 3: wait/decode seconds (DeepSeek-8B, HMMT-25, N={})", opts.n_traces);
    println!(
        "{:<10} | {:>8} {:>8} | paper: {:>7} {:>7}",
        "method", "wait", "decode", "wait", "decode"
    );
    for (method, r) in methods.into_iter().zip(&cells) {
        let (pw, pd) = paper_ref::table3(method);
        println!(
            "{:<10} | {:>8.0} {:>8.0} | paper: {:>7.0} {:>7.0}",
            method.name(),
            r.engine_wait_s,
            r.engine_decode_s,
            pw,
            pd
        );
        if let Some(((ww, wd), (rw, rd))) = r.stage_wait_decode {
            println!(
                "  warmup  | {:>8.0} {:>8.0} | paper: {:>7.0} {:>7.0}",
                ww, wd, 69.0, 680.0
            );
            println!(
                "  prune   | {:>8.0} {:>8.0} | paper: {:>7.0} {:>7.0}",
                rw, rd, 194.0, 726.0
            );
        }
        rows.push(Table3Row {
            method,
            wait_s: r.engine_wait_s,
            decode_s: r.engine_decode_s,
            stages: r.stage_wait_decode,
        });
    }
    println!("(claim: STEP wait == 0; SC wait > SC decode)");
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("method", Json::Str(r.method.name().into())),
                    ("wait_s", Json::Num(r.wait_s)),
                    ("decode_s", Json::Num(r.decode_s)),
                ])
            })
            .collect(),
    );
    super::write_results("table3", &json)?;
    Ok(rows)
}
