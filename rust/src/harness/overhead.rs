//! Appendix D — the step scorer's computational overhead relative to one
//! LLM decode step: 2m(d+1) / (2N*t) with m = 512, d = hidden size,
//! N = non-embedding parameters, t = mean tokens/step. The paper's claim:
//! below 1e-6.

use crate::sim::profiles::{BenchId, BenchProfile, ModelId, ModelProfile};

/// One Appendix-D row: scorer FLOPs vs LLM FLOPs per step.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    /// Model of the row.
    pub model: ModelId,
    /// Step-scorer FLOPs per reasoning step.
    pub scorer_flops_per_step: f64,
    /// LLM decode FLOPs per reasoning step.
    pub llm_flops_per_step: f64,
    /// scorer / LLM FLOP ratio.
    pub relative: f64,
}

/// Non-embedding parameter counts (approx, from the model cards).
fn non_embedding_params(model: ModelId) -> f64 {
    match model {
        ModelId::Qwen3_4B => 3.6e9,
        ModelId::DeepSeek8B => 7.6e9,
        ModelId::Phi4_14B => 14.2e9,
    }
}

/// Regenerate Appendix D: the scorer's relative FLOPs overhead.
pub fn run() -> Vec<OverheadRow> {
    const M: f64 = 512.0;
    println!("## Appendix D: scorer overhead per reasoning step");
    println!(
        "{:<14} | {:>12} | {:>12} | {:>10}",
        "model", "scorer FLOPs", "LLM FLOPs", "relative"
    );
    let mut rows = Vec::new();
    for model in ModelId::ALL {
        let p = ModelProfile::get(model);
        let d = p.hidden_dim as f64;
        let t = BenchProfile::get(BenchId::Aime25).tokens_per_step;
        let scorer = 2.0 * M * (d + 1.0);
        let llm = 2.0 * non_embedding_params(model) * t;
        let relative = scorer / llm;
        println!(
            "{:<14} | {:>12.3e} | {:>12.3e} | {:>10.2e}",
            format!("{:?}", model),
            scorer,
            llm,
            relative
        );
        rows.push(OverheadRow {
            model,
            scorer_flops_per_step: scorer,
            llm_flops_per_step: llm,
            relative,
        });
    }
    println!("(paper claim: < 1e-6. Note: the paper's own formula with its");
    println!(" stated constants (m=512, d~1e3.4, N~1e9.6, t~1e2) evaluates to");
    println!(" ~2-3e-6; the <1e-6 bound holds for t >~ 330 tokens/step. Either");
    println!(" way the overhead is negligible — 5+ orders below an LLM step.)");
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_negligible() {
        for row in run() {
            // Negligible means orders of magnitude below an LLM step; the
            // paper's exact <1e-6 needs t >= ~330 tokens/step (see run()).
            assert!(row.relative < 1e-5, "{:?}: {}", row.model, row.relative);
        }
    }
}
