//! Figures 1 + 4 — accuracy/latency scaling.
//!
//! Fig 4: accuracy-vs-latency curves for N in {1, 16, 32, 64} on
//! (Qwen3-4B, DeepSeek-8B) x (AIME-25, HMMT-25) for all methods.
//! Fig 1: the N=64 DeepSeek-8B summary scatter (accuracy averaged over
//! AIME-25 / HMMT-24/25 / GPQA-D vs mean latency).

use anyhow::Result;

use super::cells::{run_cells, CellJob, CellOpts};
use super::HarnessOpts;
use crate::coordinator::method::Method;
use crate::sim::profiles::{BenchId, ModelId};
use crate::util::json::Json;

/// One point of the Fig-1/Fig-4 accuracy-latency scaling curves.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    /// Model of the point.
    pub model: ModelId,
    /// Benchmark of the point.
    pub bench: BenchId,
    /// Method of the point.
    pub method: Method,
    /// Trace budget N.
    pub n: usize,
    /// Accuracy, percent.
    pub acc: f64,
    /// Mean end-to-end latency, seconds.
    pub lat_s: f64,
}

/// Regenerate Fig 4: latency scaling across trace budgets.
pub fn run_fig4(opts: &HarnessOpts) -> Result<Vec<ScalingPoint>> {
    let (gen, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let budgets = [1usize, 16, 32, 64];
    // Build the full 64-point grid, shard it across workers, then print.
    // N=1 degenerates to plain CoT for every method, so the four method
    // rows of each (model, bench) share one simulated Cot cell instead
    // of recomputing it per method.
    let mut meta = Vec::new();
    let mut jobs = Vec::new();
    let mut job_of = Vec::new(); // meta index -> job index
    let mut cot_job: std::collections::HashMap<(ModelId, BenchId), usize> =
        std::collections::HashMap::new();
    for model in [ModelId::Qwen3_4B, ModelId::DeepSeek8B] {
        for bench in [BenchId::Aime25, BenchId::Hmmt2425] {
            for method in [Method::Sc, Method::SlimSc, Method::DeepConf, Method::Step] {
                for &n in &budgets {
                    meta.push((model, bench, method, n));
                    let cell_opts = CellOpts {
                        n_traces: n,
                        max_questions: opts.max_questions,
                        seed: opts.seed,
                        ..Default::default()
                    };
                    if n == 1 {
                        let idx = *cot_job.entry((model, bench)).or_insert_with(|| {
                            jobs.push(CellJob { model, bench, method: Method::Cot, opts: cell_opts });
                            jobs.len() - 1
                        });
                        job_of.push(idx);
                    } else {
                        jobs.push(CellJob { model, bench, method, opts: cell_opts });
                        job_of.push(jobs.len() - 1);
                    }
                }
            }
        }
    }
    let cells = run_cells(&jobs, &gen, &scorer, opts.threads);

    let mut points = Vec::new();
    println!("## Fig 4: latency scaling (N = 1, 16, 32, 64)");
    let mut last_group = None;
    for (mi, (model, bench, method, n)) in meta.into_iter().enumerate() {
        let r = &cells[job_of[mi]];
        if last_group != Some((model, bench)) {
            last_group = Some((model, bench));
            println!("\n### {:?} / {}", model, bench.name());
            println!("{:<10} {:>4} | {:>6} {:>8}", "method", "N", "acc%", "lat(s)");
        }
        println!(
            "{:<10} {:>4} | {:>6.1} {:>8.0}",
            method.name(),
            n,
            r.acc,
            r.lat_s
        );
        points.push(ScalingPoint {
            model,
            bench,
            method,
            n,
            acc: r.acc,
            lat_s: r.lat_s,
        });
    }
    let json = Json::Arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("model", Json::Str(format!("{:?}", p.model))),
                    ("bench", Json::Str(p.bench.name().into())),
                    ("method", Json::Str(p.method.name().into())),
                    ("n", Json::Num(p.n as f64)),
                    ("acc", Json::Num(p.acc)),
                    ("lat_s", Json::Num(p.lat_s)),
                ])
            })
            .collect(),
    );
    super::write_results("fig4", &json)?;
    Ok(points)
}

/// Regenerate Fig 1: accuracy-vs-latency scatter per method.
pub fn run_fig1(opts: &HarnessOpts) -> Result<Vec<(Method, f64, f64)>> {
    let (gen, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let benches = [BenchId::Aime25, BenchId::Hmmt2425, BenchId::GpqaDiamond];
    let mut jobs = Vec::new();
    for method in Method::ALL {
        for bench in benches {
            jobs.push(CellJob {
                model: ModelId::DeepSeek8B,
                bench,
                method,
                opts: CellOpts {
                    n_traces: opts.n_traces,
                    max_questions: opts.max_questions,
                    seed: opts.seed,
                    ..Default::default()
                },
            });
        }
    }
    let cells = run_cells(&jobs, &gen, &scorer, opts.threads);

    let mut points = Vec::new();
    println!("## Fig 1: accuracy vs latency scatter (DeepSeek-8B, N=64, avg of AIME/HMMT/GPQA)");
    println!("{:<10} | {:>6} {:>8}", "method", "acc%", "lat(s)");
    for (mi, method) in Method::ALL.into_iter().enumerate() {
        let group = &cells[mi * benches.len()..(mi + 1) * benches.len()];
        let acc = group.iter().map(|r| r.acc).sum::<f64>() / benches.len() as f64;
        let lat = group.iter().map(|r| r.lat_s).sum::<f64>() / benches.len() as f64;
        println!("{:<10} | {:>6.1} {:>8.0}", method.name(), acc, lat);
        points.push((method, acc, lat));
    }
    println!("(claim: STEP sits top-left — highest accuracy at a fraction of SC latency)");
    let json = Json::Arr(
        points
            .iter()
            .map(|(m, a, l)| {
                Json::obj(vec![
                    ("method", Json::Str(m.name().into())),
                    ("acc", Json::Num(*a)),
                    ("lat_s", Json::Num(*l)),
                ])
            })
            .collect(),
    );
    super::write_results("fig1", &json)?;
    Ok(points)
}
