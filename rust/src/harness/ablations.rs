//! Design-choice ablations (DESIGN.md §6 extension; quantifies §4.2's
//! "the greedy strategy is simple ... while leading to strong empirical
//! improvements" and §4.3's "average score rather than the latest step
//! score"):
//!
//!   A. pruning-victim policy: lowest-score (paper) vs random vs
//!      youngest vs an incorrect-trace oracle (upper bound);
//!   B. score aggregation: running mean (paper) vs latest-step vs EMA.

use anyhow::Result;

use super::HarnessOpts;
use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::sim::des::{DesEngine, ScoreAgg, Scratch, SimConfig, VictimPolicy};
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::tracegen::{GenParams, TraceGen};
use crate::util::json::Json;
use crate::util::pool;

/// One ablation row: a design variant's accuracy / tokens / latency.
#[derive(Debug, Clone)]
pub struct AblationRow {
    /// Variant label.
    pub name: String,
    /// Accuracy, percent.
    pub acc: f64,
    /// Mean generated tokens per question, thousands.
    pub tok_k: f64,
    /// Mean end-to-end latency, seconds.
    pub lat_s: f64,
}

fn run_variant(
    gen_params: &GenParams,
    scorer: &StepScorer,
    opts: &HarnessOpts,
    victim: VictimPolicy,
    agg: ScoreAgg,
) -> (f64, f64, f64) {
    let mut cfg = SimConfig::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::Step, opts.n_traces);
    cfg.seed = opts.seed;
    cfg.victim = victim;
    cfg.score_agg = agg;
    let gen = TraceGen::new(cfg.model, cfg.bench, gen_params.clone(), opts.seed ^ 0x5EED);
    let engine = DesEngine::new(&cfg, &gen, scorer);
    let n_questions = opts.max_questions.unwrap_or(30).min(60);
    let threads = opts.threads; // parallel_map clamps to n_questions internally
    let results = pool::parallel_map_with(threads, n_questions, Scratch::new, |scratch, qid| {
        engine.run_question_with(qid, scratch)
    });
    let (mut acc, mut tok, mut lat) = (0.0, 0.0, 0.0);
    for r in &results {
        acc += r.correct as usize as f64;
        tok += r.gen_tokens as f64;
        lat += r.latency_s;
    }
    let nq = n_questions as f64;
    (100.0 * acc / nq, tok / nq / 1000.0, lat / nq)
}

/// Regenerate the design-choice ablation grid.
pub fn run(opts: &HarnessOpts) -> Result<Vec<AblationRow>> {
    let (gen_params, scorer) = super::load_sim_bundle(&super::artifact_dir())?;
    let mut rows = Vec::new();

    println!("## Ablation A: pruning-victim policy (DeepSeek-8B, HMMT-25, N={})", opts.n_traces);
    println!("{:<28} | {:>6} {:>9} {:>8}", "victim", "acc%", "tokens(k)", "lat(s)");
    for (name, v) in [
        ("lowest-score (paper)", VictimPolicy::LowestScore),
        ("random", VictimPolicy::Random),
        ("youngest", VictimPolicy::Youngest),
        ("oracle-incorrect (bound)", VictimPolicy::OracleIncorrect),
    ] {
        let (acc, tok, lat) = run_variant(&gen_params, &scorer, opts, v, ScoreAgg::Mean);
        println!("{:<28} | {:>6.1} {:>9.1} {:>8.0}", name, acc, tok, lat);
        rows.push(AblationRow { name: format!("victim/{name}"), acc, tok_k: tok, lat_s: lat });
    }

    println!("\n## Ablation B: score aggregation (same setting)");
    println!("{:<28} | {:>6} {:>9} {:>8}", "aggregation", "acc%", "tokens(k)", "lat(s)");
    for (name, a) in [
        ("running mean (paper)", ScoreAgg::Mean),
        ("latest step only", ScoreAgg::Last),
        ("EMA (alpha=0.15)", ScoreAgg::Ema),
    ] {
        let (acc, tok, lat) =
            run_variant(&gen_params, &scorer, opts, VictimPolicy::LowestScore, a);
        println!("{:<28} | {:>6.1} {:>9.1} {:>8.0}", name, acc, tok, lat);
        rows.push(AblationRow { name: format!("agg/{name}"), acc, tok_k: tok, lat_s: lat });
    }
    println!("(expected: lowest-score ~= oracle >= random/youngest on accuracy;");
    println!(" mean >= EMA > last — averaging damps single-step variance, §4.3)");

    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("acc", Json::Num(r.acc)),
                    ("tok_k", Json::Num(r.tok_k)),
                    ("lat_s", Json::Num(r.lat_s)),
                ])
            })
            .collect(),
    );
    super::write_results("ablations", &json)?;
    Ok(rows)
}
