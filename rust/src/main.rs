//! `step` — the STEP serving/experiment CLI (leader entrypoint).
//!
//! Subcommands regenerate each paper table/figure (DESIGN.md §6), run the
//! whole evaluation, or serve the e2e model. Arg parsing is in-tree
//! (no clap in the offline vendor set).

use anyhow::{bail, Result};
use step::coordinator::signal::SignalSpec;
use step::harness::bench_gate::GateOpts;
use step::harness::{self, table5::ServingOpts, table6::ClusterOpts, HarnessOpts};
use step::sim::cluster::{parse_fleet_events, GpuProfile, MigrationPolicy};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::router::RouterKind;

const USAGE: &str = "step — Step-level Trace Evaluation and Pruning (paper reproduction)

USAGE:
    step <COMMAND> [OPTIONS]

COMMANDS (experiments; see DESIGN.md §6):
    table1      Main results grid: Acc/Tok/Lat for 5 methods x 3 models x 5 benchmarks
    table2      Voting strategies: majority vs PRM-weighted vs STEP-weighted
    table3      Wait/decode breakdown (DeepSeek-8B, HMMT-25, N=64)
    table4      GPU-memory sensitivity sweep (util 0.5..0.9)
    fig1        Accuracy-vs-latency scatter (DeepSeek-8B, N=64)
    fig2        Motivation: score distributions, token skew, time breakdown
    fig4        Latency scaling N in {1,16,32,64}
    fig5        RankAcc of step scorer vs token confidence
    fig67       Trace-level score dynamics
    overhead    Appendix-D scorer FLOPs overhead
    ablations   Design-choice ablations (victim policy, score aggregation)
    serve-sim   Multi-request serving under load (beyond the paper):
                continuous batching of concurrent requests against one
                shared KV pool; reports throughput, p50/p95/p99 latency,
                time-to-first-vote, accuracy per method
    cluster-sim Multi-GPU cluster serving (beyond the paper): R per-GPU
                engines — uniform or heterogeneous (--gpu-profile) —
                behind a router (round-robin / least-outstanding /
                kv-pressure) with admission control, closed-loop
                workloads, cross-GPU trace migration (--migrate), and
                elastic fleets under a deterministic chaos schedule
                (--fleet-events: joins, leaves, spot revocations with
                drain deadlines, plus a standby scale-up pool); reports
                goodput, shed rate, cluster-wide p50/p95/p99 per
                method, per router, per migration policy, and per
                elasticity cell (goodput lost per revocation)
    bench-gate  Compare fresh BENCH_{grid,serving,cluster}.json against
                the checked-in results/ schemas (key-set match + the
                non-null perf gates) and fail on regression; writes a
                markdown table to $GITHUB_STEP_SUMMARY when set
    trace-check Validate a --trace-out JSONL event log: re-derive the
                admission/goodput counters from events alone and check
                the per-request lifecycle + conservation laws
                (step trace-check FILE; nonzero exit on any violation)
    all         Everything above at full scale (except serve-sim and
                cluster-sim)

OPTIONS:
    --questions N    cap questions per benchmark (default: paper-faithful)
    --traces N       trace budget (default 64)
    --seed S         RNG seed (default 0)
    --threads N      worker threads for the evaluation grid (default: all
                     cores; 1 = serial). Results are bit-identical for
                     any thread count.
    --quick          shorthand for --questions 8 --traces 32

SERVE-SIM OPTIONS (plus --seed/--threads/--traces above):
    --requests N     workload size in requests (default 32)
    --rate R         mean arrival rate, requests/second (default 0.05)
    --burst B        bursty arrivals: B requests per burst (default: poisson)
    --model M        qwen3-4b | deepseek-8b | phi-4 (default deepseek-8b)
    --bench B        aime-25 | hmmt | gpqa | equibench | divlogiceval
                     (default aime-25)
    --mem-util U     gpu_memory_utilization of the shared pool (default 0.9)
    --quota-frac F   per-request KV quota as a fraction of the pool
                     (default: none — pool-bound, cross-request pruning)
    --signal NAME[:PARAM=V,...]
                     pruning signal scoring step boundaries:
                     hidden-mlp (default; the paper's MLP over hidden
                     states, byte-identical to the pre-signal engines) |
                     latent-temporal[:lambda=0.6,slope=4,window=8]
                     (EWMA + slope over the hidden-state trajectory) |
                     confidence[:gamma=1] (intrinsic token confidence) |
                     prm-oracle (PRM upper bound). Unknown names or
                     params fail at parse time naming the flag. The
                     signal is stamped into step-score/prune events, so
                     trace-check attributes prunes per signal

CLUSTER-SIM OPTIONS (plus the serve-sim options above):
    --gpus R             per-GPU engines in the cluster (default 4)
    --clients C          closed-loop client population; 0 = open loop at
                         --rate (default 12)
    --think S            mean closed-loop think time, seconds (default 60)
    --heavy-frac F       fraction of clients pinned to the longest-trace
                         questions (default 0.5)
    --router P           round-robin | least-outstanding | kv-pressure |
                         kv-sharded (default kv-pressure; the routers
                         grid always compares all four under STEP)
    --shard-size N       GPUs per shard of the kv-sharded router
                         (default 0 = auto, ~sqrt(R) with a floor of 8;
                         ignored by the flat routers)
    --queue-cap N        cluster admission-queue bound (default 64)
    --max-outstanding N  per-GPU cap on live requests (default 8)
    --slo S              SLO-aware early-reject budget, seconds
                         (default: off)
    --step-threads N     advance the per-GPU engines in parallel between
                         arrivals (0 = all cores; default 1 = serial).
                         Metric output is bit-identical for any value
    --gpu-profile U:B:S  heterogeneous pools: one GPU's mem-util, block
                         size, and timing scale (e.g. 0.9:16:1.0 =
                         baseline, 0.45:16:2.5 = small 2.5x-slower).
                         Repeatable; fewer entries than --gpus cycle.
                         Default: a uniform pool (the migration grid
                         substitutes a default mixed fleet)
    --migrate P          cross-GPU trace migration policy: never |
                         on-shed | on-pressure[:RATIO] (default never).
                         on-shed relocates work instead of shedding;
                         on-pressure also rebalances with hysteresis
                         and rescues last-survivor prunes
    --fleet-events SPEC  deterministic fleet chaos schedule: ;-separated
                         T:GPU:ACTION[:DEADLINE] entries (join | leave |
                         revoke:DEADLINE_S) or rand:SEED:N:HORIZON_S for
                         a seeded random schedule. A revocation drains
                         the victim — admission stops, residents migrate
                         out under --migrate on-shed/on-pressure before
                         the deadline, the rest are abandoned. Empty =
                         static fleet (default)
    --standby N          standby engines behind the initial fleet
                         (indices R..R+N), activated by join events or
                         the scaling controller (default 0)
    --scale-up-queue-depth N
                         admission-queue depth that triggers activating
                         a standby engine (default 0 = only when a
                         request would otherwise shed)
    --prefix-cache       share each question's full prompt blocks
                         copy-on-write through a per-GPU prefix
                         registry (default off; off is byte-identical
                         to today). Adds the affinity-weight sweep to
                         the cluster grids
    --affinity-weight W  kv-pressure routing credit: discount a GPU's
                         expected-footprint term by W x its pinned
                         prefix blocks for the request's question
                         (default 0 = placement arithmetic untouched;
                         needs --prefix-cache to matter)
    --trace-out PATH     after the grids, rerun the canonical STEP cell
                         with the event log on and write the merged
                         stream as JSON Lines (one event per line).
                         The run first proves the traced metric block
                         is byte-identical to the untraced one — the
                         recorder determinism contract
    --perfetto-out PATH  write the same traced stream as Chrome
                         trace-event JSON (open in ui.perfetto.dev or
                         chrome://tracing): per-GPU tracks, per-request
                         queued/running spans, KV-occupancy and
                         queue-depth counter tracks
    --trace-filter KINDS comma-separated event kinds kept in the JSONL
                         log, e.g. offer,place,shed,complete
                         (default: every kind). Unknown kinds fail at
                         parse time naming the flag

BENCH-GATE OPTIONS:
    --results DIR    fresh bench artifacts to check (default:
                     $STEP_RESULTS_DIR or ./results)
    --schemas DIR    checked-in schema documents (default ./results)

Artifacts are read from $STEP_ARTIFACTS_DIR (default ./artifacts); run
`make artifacts` first. Results are written to $STEP_RESULTS_DIR
(default ./results). serve-sim and cluster-sim fall back to built-in
generator defaults when artifacts are absent and write
results/BENCH_serving.json / results/BENCH_cluster.json.";

fn parse_opts(args: &[String]) -> Result<HarnessOpts> {
    let mut opts = HarnessOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                // Only the quick knobs; earlier --seed/--threads survive.
                opts.max_questions = Some(8);
                opts.n_traces = 32;
                i += 1;
            }
            "--questions" => {
                opts.max_questions = Some(parse_val(args, i)?);
                i += 2;
            }
            "--traces" => {
                opts.n_traces = parse_val(args, i)?;
                i += 2;
            }
            "--seed" => {
                opts.seed = parse_val(args, i)?;
                i += 2;
            }
            "--threads" => {
                opts.threads = parse_val(args, i)?;
                i += 2;
            }
            other => bail!("unknown option '{other}'\n\n{USAGE}"),
        }
    }
    Ok(opts)
}

fn need_val(args: &[String], i: usize) -> Result<&String> {
    args.get(i + 1)
        .ok_or_else(|| anyhow::anyhow!("option {} needs a value", args[i]))
}

/// Parse the value of the flag at `args[i]`; errors name the flag and
/// echo the offending value.
fn parse_val<T: std::str::FromStr>(args: &[String], i: usize) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    let v = need_val(args, i)?;
    v.parse()
        .map_err(|e| anyhow::anyhow!("{}: bad value '{v}': {e}", args[i]))
}

/// Parse a `--signal NAME[:PARAM=V,...]` value — the one parser both
/// serve-sim and cluster-sim share; errors name the flag.
fn parse_signal_val(args: &[String], i: usize) -> Result<SignalSpec> {
    let spec = need_val(args, i)?;
    SignalSpec::parse(spec).map_err(|e| anyhow::anyhow!("--signal: {e}"))
}

fn parse_serving_opts(args: &[String]) -> Result<ServingOpts> {
    let mut opts = ServingOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--requests" => {
                opts.n_requests = parse_val(args, i)?;
                i += 2;
            }
            "--rate" => {
                opts.rate_rps = parse_val(args, i)?;
                i += 2;
            }
            "--burst" => {
                opts.burst = Some(parse_val(args, i)?);
                i += 2;
            }
            "--traces" => {
                opts.n_traces = parse_val(args, i)?;
                i += 2;
            }
            "--seed" => {
                opts.seed = parse_val(args, i)?;
                i += 2;
            }
            "--threads" => {
                opts.threads = parse_val(args, i)?;
                i += 2;
            }
            "--model" => {
                let name = need_val(args, i)?;
                opts.model = ModelId::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--model: unknown model '{name}' (qwen3-4b | deepseek-8b | phi-4)"
                    )
                })?;
                i += 2;
            }
            "--bench" => {
                let name = need_val(args, i)?;
                opts.bench = BenchId::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--bench: unknown bench '{name}' (aime-25 | hmmt | gpqa | \
                         equibench | divlogiceval)"
                    )
                })?;
                i += 2;
            }
            "--mem-util" => {
                opts.mem_util = parse_val(args, i)?;
                i += 2;
            }
            "--quota-frac" => {
                opts.quota_frac = Some(parse_val(args, i)?);
                i += 2;
            }
            "--signal" => {
                opts.signal = parse_signal_val(args, i)?;
                i += 2;
            }
            other => bail!("unknown serve-sim option '{other}'\n\n{USAGE}"),
        }
    }
    Ok(opts)
}

fn parse_cluster_opts(args: &[String]) -> Result<ClusterOpts> {
    let mut opts = ClusterOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--gpus" => {
                opts.gpus = parse_val(args, i)?;
                i += 2;
            }
            "--clients" => {
                opts.clients = parse_val(args, i)?;
                i += 2;
            }
            "--think" => {
                opts.think_s = parse_val(args, i)?;
                i += 2;
            }
            "--heavy-frac" => {
                opts.heavy_frac = parse_val(args, i)?;
                i += 2;
            }
            "--router" => {
                let name = need_val(args, i)?;
                opts.router = RouterKind::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--router: unknown router '{name}' (round-robin | \
                         least-outstanding | kv-pressure | kv-sharded)"
                    )
                })?;
                i += 2;
            }
            "--shard-size" => {
                opts.shard_size = parse_val(args, i)?;
                i += 2;
            }
            "--queue-cap" => {
                opts.queue_cap = parse_val(args, i)?;
                i += 2;
            }
            "--max-outstanding" => {
                opts.max_outstanding = parse_val(args, i)?;
                i += 2;
            }
            "--slo" => {
                opts.slo_s = Some(parse_val(args, i)?);
                i += 2;
            }
            "--step-threads" => {
                opts.step_threads = parse_val(args, i)?;
                i += 2;
            }
            "--gpu-profile" => {
                let spec = need_val(args, i)?;
                let p = GpuProfile::parse(spec).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--gpu-profile: bad profile '{spec}' (want \
                         MEM_UTIL:BLOCK_SIZE:TIMING_SCALE, e.g. 0.9:16:1.0)"
                    )
                })?;
                opts.gpu_profiles.push(p);
                i += 2;
            }
            "--migrate" => {
                let name = need_val(args, i)?;
                opts.migrate = MigrationPolicy::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--migrate: unknown migration policy '{name}' (never | on-shed | \
                         on-pressure[:RATIO])"
                    )
                })?;
                i += 2;
            }
            "--fleet-events" => {
                opts.fleet_events = need_val(args, i)?.clone();
                i += 2;
            }
            "--standby" => {
                opts.standby = parse_val(args, i)?;
                i += 2;
            }
            "--scale-up-queue-depth" => {
                opts.scale_up_queue_depth = parse_val(args, i)?;
                i += 2;
            }
            "--prefix-cache" => {
                opts.prefix_cache = true;
                i += 1;
            }
            "--affinity-weight" => {
                opts.affinity_weight = parse_val(args, i)?;
                if !(0.0..=10.0).contains(&opts.affinity_weight) {
                    bail!(
                        "--affinity-weight: want a credit weight in [0, 10], got {}",
                        opts.affinity_weight
                    );
                }
                i += 2;
            }
            "--trace-out" => {
                opts.trace_out = Some(need_val(args, i)?.into());
                i += 2;
            }
            "--perfetto-out" => {
                opts.perfetto_out = Some(need_val(args, i)?.into());
                i += 2;
            }
            "--trace-filter" => {
                let spec = need_val(args, i)?;
                let kinds: Vec<String> = spec
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                step::obs::validate_kinds(&kinds)
                    .map_err(|e| anyhow::anyhow!("--trace-filter: {e}"))?;
                opts.trace_filter = kinds;
                i += 2;
            }
            "--requests" => {
                opts.n_requests = parse_val(args, i)?;
                i += 2;
            }
            "--rate" => {
                opts.rate_rps = parse_val(args, i)?;
                i += 2;
            }
            "--burst" => {
                opts.burst = Some(parse_val(args, i)?);
                i += 2;
            }
            "--traces" => {
                opts.n_traces = parse_val(args, i)?;
                i += 2;
            }
            "--seed" => {
                opts.seed = parse_val(args, i)?;
                i += 2;
            }
            "--threads" => {
                opts.threads = parse_val(args, i)?;
                i += 2;
            }
            "--model" => {
                let name = need_val(args, i)?;
                opts.model = ModelId::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--model: unknown model '{name}' (qwen3-4b | deepseek-8b | phi-4)"
                    )
                })?;
                i += 2;
            }
            "--bench" => {
                let name = need_val(args, i)?;
                opts.bench = BenchId::parse(name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--bench: unknown bench '{name}' (aime-25 | hmmt | gpqa | \
                         equibench | divlogiceval)"
                    )
                })?;
                i += 2;
            }
            "--mem-util" => {
                opts.mem_util = parse_val(args, i)?;
                i += 2;
            }
            "--quota-frac" => {
                opts.quota_frac = Some(parse_val(args, i)?);
                i += 2;
            }
            "--signal" => {
                opts.signal = parse_signal_val(args, i)?;
                i += 2;
            }
            other => bail!("unknown cluster-sim option '{other}'\n\n{USAGE}"),
        }
    }
    // --fleet-events can precede --gpus/--standby, so validate the spec
    // against the final fleet shape here rather than inline.
    if parse_fleet_events(&opts.fleet_events, opts.gpus, opts.standby).is_none() {
        bail!(
            "--fleet-events: bad spec '{}' (want ;-separated T:GPU:ACTION[:DEADLINE] with \
             GPU < gpus+standby, or rand:SEED:N:HORIZON_S)",
            opts.fleet_events
        );
    }
    Ok(opts)
}

fn parse_gate_opts(args: &[String]) -> Result<GateOpts> {
    let mut opts = GateOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--results" => {
                opts.results_dir = need_val(args, i)?.into();
                i += 2;
            }
            "--schemas" => {
                opts.schemas_dir = need_val(args, i)?.into();
                i += 2;
            }
            other => bail!("unknown bench-gate option '{other}'\n\n{USAGE}"),
        }
    }
    Ok(opts)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    if cmd == "serve-sim" {
        let sopts = parse_serving_opts(&args[1..])?;
        harness::table5::run(&sopts)?;
        return Ok(());
    }
    if cmd == "cluster-sim" {
        let copts = parse_cluster_opts(&args[1..])?;
        harness::table6::run(&copts)?;
        return Ok(());
    }
    if cmd == "bench-gate" {
        let gopts = parse_gate_opts(&args[1..])?;
        harness::bench_gate::run(&gopts)?;
        return Ok(());
    }
    if cmd == "trace-check" {
        let Some(path) = args.get(1) else {
            bail!("trace-check needs a FILE argument (a --trace-out JSONL log)\n\n{USAGE}");
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("trace-check: cannot read '{path}': {e}"))?;
        let events = step::obs::parse_jsonl(&text)
            .map_err(|e| anyhow::anyhow!("trace-check: {path}: {e}"))?;
        let report = step::obs::replay::check(&events);
        println!("trace-check {path}: {} events", report.events);
        println!("  replayed counters: {}", report.counters.report());
        for a in &report.attribution {
            println!(
                "  signal {}: {} step-scores, {} prunes",
                a.signal, a.step_scores, a.prunes
            );
        }
        if !report.ok() {
            for v in &report.violations {
                eprintln!("  VIOLATION: {v}");
            }
            bail!("trace-check: {} violation(s) in {path}", report.violations.len());
        }
        println!("  OK: per-request lifecycle and conservation laws hold");
        return Ok(());
    }
    let opts = parse_opts(&args[1..])?;

    match cmd.as_str() {
        "table1" => {
            harness::table1::run(&opts)?;
        }
        "table2" => {
            harness::table2::run(&opts)?;
        }
        "table3" => {
            harness::table3::run(&opts)?;
        }
        "table4" => {
            harness::table4::run(&opts)?;
        }
        "fig1" => {
            harness::fig1_fig4::run_fig1(&opts)?;
        }
        "fig2" => {
            harness::fig2::run(&opts)?;
        }
        "fig4" => {
            harness::fig1_fig4::run_fig4(&opts)?;
        }
        "fig5" => {
            harness::fig5::run(&opts)?;
        }
        "fig67" => {
            harness::fig67::run(&opts)?;
        }
        "overhead" => {
            harness::overhead::run();
        }
        "ablations" => {
            harness::ablations::run(&opts)?;
        }
        "all" => {
            harness::table1::run(&opts)?;
            harness::fig1_fig4::run_fig1(&opts)?;
            harness::fig2::run(&opts)?;
            harness::fig1_fig4::run_fig4(&opts)?;
            harness::fig5::run(&opts)?;
            harness::table2::run(&opts)?;
            harness::table3::run(&opts)?;
            harness::table4::run(&opts)?;
            harness::fig67::run(&opts)?;
            harness::ablations::run(&opts)?;
            harness::overhead::run();
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
    Ok(())
}
