//! `step` — the STEP serving/experiment CLI (leader entrypoint).
//!
//! Subcommands regenerate each paper table/figure (DESIGN.md §6), run the
//! whole evaluation, or serve the e2e model. Arg parsing is in-tree
//! (no clap in the offline vendor set).

use anyhow::{bail, Result};
use step::harness::{self, HarnessOpts};

const USAGE: &str = "step — Step-level Trace Evaluation and Pruning (paper reproduction)

USAGE:
    step <COMMAND> [OPTIONS]

COMMANDS (experiments; see DESIGN.md §6):
    table1      Main results grid: Acc/Tok/Lat for 5 methods x 3 models x 5 benchmarks
    table2      Voting strategies: majority vs PRM-weighted vs STEP-weighted
    table3      Wait/decode breakdown (DeepSeek-8B, HMMT-25, N=64)
    table4      GPU-memory sensitivity sweep (util 0.5..0.9)
    fig1        Accuracy-vs-latency scatter (DeepSeek-8B, N=64)
    fig2        Motivation: score distributions, token skew, time breakdown
    fig4        Latency scaling N in {1,16,32,64}
    fig5        RankAcc of step scorer vs token confidence
    fig67       Trace-level score dynamics
    overhead    Appendix-D scorer FLOPs overhead
    ablations   Design-choice ablations (victim policy, score aggregation)
    all         Everything above at full scale

OPTIONS:
    --questions N    cap questions per benchmark (default: paper-faithful)
    --traces N       trace budget (default 64)
    --seed S         RNG seed (default 0)
    --threads N      worker threads for the evaluation grid (default: all
                     cores; 1 = serial). Results are bit-identical for
                     any thread count.
    --quick          shorthand for --questions 8 --traces 32

Artifacts are read from $STEP_ARTIFACTS_DIR (default ./artifacts); run
`make artifacts` first. Results are written to $STEP_RESULTS_DIR
(default ./results).";

fn parse_opts(args: &[String]) -> Result<HarnessOpts> {
    let mut opts = HarnessOpts::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                // Only the quick knobs; earlier --seed/--threads survive.
                opts.max_questions = Some(8);
                opts.n_traces = 32;
                i += 1;
            }
            "--questions" => {
                opts.max_questions = Some(need_val(args, i)?.parse()?);
                i += 2;
            }
            "--traces" => {
                opts.n_traces = need_val(args, i)?.parse()?;
                i += 2;
            }
            "--seed" => {
                opts.seed = need_val(args, i)?.parse()?;
                i += 2;
            }
            "--threads" => {
                opts.threads = need_val(args, i)?.parse()?;
                i += 2;
            }
            other => bail!("unknown option '{other}'\n\n{USAGE}"),
        }
    }
    Ok(opts)
}

fn need_val(args: &[String], i: usize) -> Result<&String> {
    args.get(i + 1)
        .ok_or_else(|| anyhow::anyhow!("option {} needs a value", args[i]))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let opts = parse_opts(&args[1..])?;

    match cmd.as_str() {
        "table1" => {
            harness::table1::run(&opts)?;
        }
        "table2" => {
            harness::table2::run(&opts)?;
        }
        "table3" => {
            harness::table3::run(&opts)?;
        }
        "table4" => {
            harness::table4::run(&opts)?;
        }
        "fig1" => {
            harness::fig1_fig4::run_fig1(&opts)?;
        }
        "fig2" => {
            harness::fig2::run(&opts)?;
        }
        "fig4" => {
            harness::fig1_fig4::run_fig4(&opts)?;
        }
        "fig5" => {
            harness::fig5::run(&opts)?;
        }
        "fig67" => {
            harness::fig67::run(&opts)?;
        }
        "overhead" => {
            harness::overhead::run();
        }
        "ablations" => {
            harness::ablations::run(&opts)?;
        }
        "all" => {
            harness::table1::run(&opts)?;
            harness::fig1_fig4::run_fig1(&opts)?;
            harness::fig2::run(&opts)?;
            harness::fig1_fig4::run_fig4(&opts)?;
            harness::fig5::run(&opts)?;
            harness::table2::run(&opts)?;
            harness::table3::run(&opts)?;
            harness::table4::run(&opts)?;
            harness::fig67::run(&opts)?;
            harness::ablations::run(&opts)?;
            harness::overhead::run();
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
    Ok(())
}
