//! STEP: Step-level Trace Evaluation and Pruning for efficient test-time
//! scaling — a rust + JAX + Pallas reproduction of Liang et al. (2026).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! reproduced tables/figures.

pub mod config;
pub mod coordinator;
pub mod harness;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
