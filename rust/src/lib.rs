//! STEP: Step-level Trace Evaluation and Pruning for efficient test-time
//! scaling — a rust + JAX + Pallas reproduction of Liang et al. (2026),
//! grown into a serving-system testbed.
//!
//! Layer map (see ARCHITECTURE.md for the full tour):
//!
//! * [`sim`] — discrete-event engines: the single-question engine behind
//!   every paper table/figure, the multi-request serving simulator
//!   (`step serve-sim`) with open-loop workloads and continuous batching,
//!   and the multi-GPU cluster simulator (`step cluster-sim`) with
//!   routing policies, admission control, and closed-loop workloads —
//!   all sharing one scheduler core (`sim::sched`).
//! * [`kvcache`] — PagedAttention block accounting: allocator, per-
//!   sequence block tables, and the shared pool with per-request quotas.
//! * [`coordinator`] — the paper's contribution: step scoring, trace and
//!   request lifecycle, pruning/method policies, answer voting.
//! * [`harness`] — one module per reproduced table/figure plus the
//!   serving cell; each writes `results/*.json`.
//! * [`metrics`] — latency histograms/sketches and engine counters.
//! * [`obs`] — observability: structured sim-time event telemetry
//!   (recorder trait, JSONL + Perfetto sinks, flight-recorder rings,
//!   counters-from-events replay) threaded through all three engines.
//! * [`model`] / [`runtime`] — the e2e path: tokenizer, sampler, and the
//!   PJRT artifact registry (execution gated behind the `pjrt` feature).
//! * [`util`] — in-tree substrates forced by the offline vendor set:
//!   JSON, PRNG, stats, thread pool, bench harness.
//!
//! See DESIGN.md for the system inventory, EXPERIMENTS.md for the
//! reproduced tables/figures, and README.md for the quickstart.

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod harness;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod util;
