//! Request routing policies for the cluster serving simulator
//! ([`crate::sim::cluster`]).
//!
//! The router decides which GPU's engine serves an arriving request.
//! Its leverage at cluster scale is exactly the source paper's thesis
//! in scheduling form: a router that can see *per-GPU KV pressure* —
//! resident blocks plus the score-weighted demand of the traces that
//! will survive STEP's pruning — can place requests so that pruning is
//! never needed, while per-trace signals (token confidence, probes)
//! say nothing about where a request should go. Three policies:
//!
//! * [`RoundRobin`] — the load-oblivious baseline: GPUs in cyclic
//!   order, regardless of state.
//! * [`LeastOutstanding`] — classic load balancing on request *count*;
//!   blind to the skew in per-request KV footprints.
//! * [`KvPressure`] — pick the GPU whose free pool the projected
//!   demand — its surviving traces' score-weighted needs
//!   ([`GpuView::survivor_demand_blocks`]) plus the request's own
//!   expected footprint — would consume the smallest *fraction* of,
//!   scaled by the GPU's relative slowness
//!   ([`GpuView::timing_scale`]). Memory- **and capacity-**aware: on a
//!   heterogeneous pool the footprint is quantized by each GPU's own
//!   block size, and the timing scale keeps a slow-but-empty GPU from
//!   outbidding a fast-but-busy one (equal block pressure on a 3×
//!   slower GPU drains 3× slower).
//!
//! Policies are pure functions of their inputs (the round-robin cursor
//! is the only state), so cluster runs stay bit-deterministic.

/// Read-only scheduling view of one per-GPU engine at routing time.
///
/// Views are cheap to build per placement: every field is either an
/// O(1) engine counter or, for
/// [`survivor_demand_blocks`](GpuView::survivor_demand_blocks), served
/// from the engine's incrementally maintained router-view aggregates
/// (`ServeSimConfig::route_views`) instead of an O(live) scan-and-sort
/// over its trace table.
#[derive(Debug, Clone, Copy)]
pub struct GpuView {
    /// The GPU's index in the cluster.
    pub gpu: usize,
    /// Requests submitted to this GPU and not yet complete.
    pub outstanding: usize,
    /// Live sequences resident in the GPU's KV pool.
    pub live_traces: usize,
    /// Free blocks in the GPU's KV pool.
    pub free_blocks: usize,
    /// Physical blocks in the GPU's KV pool.
    pub pool_blocks: usize,
    /// PagedAttention block size of this GPU's pool, in tokens
    /// (heterogeneous pools may differ per GPU).
    pub block_size: usize,
    /// Relative per-token slowness of this GPU (1.0 = the calibrated
    /// baseline; 3.0 = three times slower). Capacity-aware policies
    /// scale projected pressure by it.
    pub timing_scale: f64,
    /// Estimated blocks the GPU's surviving traces still need (see
    /// [`crate::sim::serve::ServeEngine::survivor_demand_blocks`]).
    pub survivor_demand_blocks: f64,
}

/// What the router knows about an arriving request.
#[derive(Debug, Clone, Copy)]
pub struct RouteRequest {
    /// Cluster-global request id.
    pub rid: usize,
    /// Question the request asks.
    pub qid: usize,
    /// Traces the request will decode (N).
    pub n_traces: usize,
    /// Expected KV *tokens* the request (prompt + N traces) will occupy
    /// at its expected full length (benchmark-profile mean — the router
    /// cannot see the sampled trace lengths). Tokens, not blocks: on a
    /// heterogeneous pool each GPU quantizes the footprint by its own
    /// [`GpuView::block_size`].
    pub expected_tokens: f64,
}

/// A placement policy: pick one GPU for each arriving request.
///
/// The cluster's admission layer pre-filters the views to the GPUs
/// currently eligible (below their outstanding-request quota) and calls
/// [`place`](RouterPolicy::place) with a non-empty slice; the return
/// value is an *index into that slice* (map back to a GPU id through
/// [`GpuView::gpu`]).
///
/// # Examples
///
/// ```
/// use step::sim::router::{GpuView, RouteRequest, RouterPolicy, RoundRobin};
///
/// let view = |gpu: usize| GpuView {
///     gpu,
///     outstanding: 0,
///     live_traces: 0,
///     free_blocks: 100,
///     pool_blocks: 100,
///     block_size: 16,
///     timing_scale: 1.0,
///     survivor_demand_blocks: 0.0,
/// };
/// let req = RouteRequest { rid: 0, qid: 0, n_traces: 4, expected_tokens: 192.0 };
/// let gpus = [view(0), view(1), view(2)];
/// let mut rr = RoundRobin::new();
/// assert_eq!(rr.place(&req, &gpus), 0);
/// assert_eq!(rr.place(&req, &gpus), 1);
/// assert_eq!(rr.place(&req, &gpus), 2);
/// assert_eq!(rr.place(&req, &gpus), 0); // wraps
/// ```
pub trait RouterPolicy {
    /// The policy's display name.
    fn name(&self) -> &'static str;

    /// Choose a GPU for `req` among the eligible `gpus` (non-empty);
    /// returns an index into `gpus`.
    fn place(&mut self, req: &RouteRequest, gpus: &[GpuView]) -> usize;
}

/// Load-oblivious cyclic placement (the baseline every load balancer is
/// measured against).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    /// Next GPU id the cursor wants to serve.
    next: usize,
}

impl RoundRobin {
    /// A cursor starting at GPU 0.
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RouterPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&mut self, _req: &RouteRequest, gpus: &[GpuView]) -> usize {
        // The eligible set may have holes (GPUs at quota), so advance
        // the cursor to the first eligible GPU at-or-after it, wrapping.
        let max_gpu = gpus.iter().map(|g| g.gpu).max().unwrap_or(0);
        let pick = gpus
            .iter()
            .enumerate()
            .filter(|(_, g)| g.gpu >= self.next)
            .min_by_key(|(_, g)| g.gpu)
            .or_else(|| gpus.iter().enumerate().min_by_key(|(_, g)| g.gpu));
        let (idx, g) = pick.expect("place called with a non-empty view set");
        self.next = if g.gpu >= max_gpu { 0 } else { g.gpu + 1 };
        idx
    }
}

/// Place on the GPU with the fewest outstanding requests (ties: fewer
/// live traces, then lower GPU id).
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastOutstanding;

impl RouterPolicy for LeastOutstanding {
    fn name(&self) -> &'static str {
        "least-outstanding"
    }

    fn place(&mut self, _req: &RouteRequest, gpus: &[GpuView]) -> usize {
        gpus.iter()
            .enumerate()
            .min_by_key(|(_, g)| (g.outstanding, g.live_traces, g.gpu))
            .map(|(idx, _)| idx)
            .expect("place called with a non-empty view set")
    }
}

/// Place on the GPU whose free pool the projected demand would consume
/// the least, *relatively*, weighted by how slowly that GPU drains it:
/// score = timing_scale × (survivor demand + the request's expected
/// footprint in this GPU's blocks) / free blocks. The ratio is what
/// makes the request's own footprint a real input — a heavy request
/// tolerates a loaded-but-large free pool better than a
/// clean-but-small one, which an absolute `demand − free` difference
/// cannot express (any per-GPU constant cancels out of an argmin) —
/// and the timing scale is what makes the policy *capacity*-aware on a
/// heterogeneous pool: the same block pressure on a 3× slower GPU
/// represents 3× the wall-clock of queued work, so a slow-but-empty
/// GPU no longer outbids a fast-but-busy one. Deterministic
/// first-minimum tie-breaking in view order.
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPressure;

impl RouterPolicy for KvPressure {
    fn name(&self) -> &'static str {
        "kv-pressure"
    }

    fn place(&mut self, req: &RouteRequest, gpus: &[GpuView]) -> usize {
        debug_assert!(!gpus.is_empty(), "place called with a non-empty view set");
        let score = |g: &GpuView| {
            let expected_blocks = req.expected_tokens / g.block_size.max(1) as f64;
            (g.survivor_demand_blocks + expected_blocks) / g.free_blocks.max(1) as f64
                * g.timing_scale
        };
        let mut best = 0usize;
        for (idx, g) in gpus.iter().enumerate().skip(1) {
            if score(g) < score(&gpus[best]) {
                best = idx;
            }
        }
        best
    }
}

/// Selectable router policy (CLI / config surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// [`RoundRobin`].
    RoundRobin,
    /// [`LeastOutstanding`].
    LeastOutstanding,
    /// [`KvPressure`].
    KvPressure,
}

impl RouterKind {
    /// Every policy, baseline first.
    pub const ALL: [RouterKind; 3] =
        [RouterKind::RoundRobin, RouterKind::LeastOutstanding, RouterKind::KvPressure];

    /// Display name (also the CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastOutstanding => "least-outstanding",
            RouterKind::KvPressure => "kv-pressure",
        }
    }

    /// Parse a CLI router name (case-insensitive).
    pub fn parse(s: &str) -> Option<RouterKind> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(RouterKind::RoundRobin),
            "least-outstanding" | "leastoutstanding" | "lor" => {
                Some(RouterKind::LeastOutstanding)
            }
            "kv-pressure" | "kvpressure" | "kv" => Some(RouterKind::KvPressure),
            _ => None,
        }
    }

    /// Instantiate the policy.
    pub fn build(&self) -> Box<dyn RouterPolicy> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::LeastOutstanding => Box::new(LeastOutstanding),
            RouterKind::KvPressure => Box::new(KvPressure),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(gpu: usize, outstanding: usize, free: usize, demand: f64) -> GpuView {
        GpuView {
            gpu,
            outstanding,
            live_traces: outstanding * 4,
            free_blocks: free,
            pool_blocks: 1000,
            block_size: 16,
            timing_scale: 1.0,
            survivor_demand_blocks: demand,
        }
    }

    fn req() -> RouteRequest {
        // 800 tokens / 16-token blocks = 50 expected blocks at baseline.
        RouteRequest { rid: 0, qid: 0, n_traces: 4, expected_tokens: 800.0 }
    }

    #[test]
    fn round_robin_cycles_and_skips_holes() {
        let mut rr = RoundRobin::new();
        let all = [view(0, 0, 10, 0.0), view(1, 0, 10, 0.0), view(2, 0, 10, 0.0)];
        let seq: Vec<usize> = (0..6).map(|_| all[rr.place(&req(), &all)].gpu).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
        // GPU 1 drops out (quota): the cursor skips it without stalling.
        let holed = [view(0, 0, 10, 0.0), view(2, 0, 10, 0.0)];
        let seq: Vec<usize> = (0..4).map(|_| holed[rr.place(&req(), &holed)].gpu).collect();
        assert_eq!(seq, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_outstanding_picks_min_with_stable_ties() {
        let mut lo = LeastOutstanding;
        let gpus = [view(0, 3, 10, 0.0), view(1, 1, 10, 0.0), view(2, 1, 10, 0.0)];
        // 1 and 2 tie on outstanding and live traces: lower gpu id wins.
        assert_eq!(gpus[lo.place(&req(), &gpus)].gpu, 1);
        let gpus = [view(0, 0, 10, 0.0), view(1, 1, 10, 0.0)];
        assert_eq!(gpus[lo.place(&req(), &gpus)].gpu, 0);
    }

    #[test]
    fn kv_pressure_prefers_headroom_not_count() {
        let mut kv = KvPressure;
        // GPU 0 has fewer requests but its survivors want the memory;
        // GPU 1 is busier by count yet has real block headroom.
        let gpus = [view(0, 1, 100, 400.0), view(1, 3, 300, 50.0)];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
        // All else equal, more free blocks wins.
        let gpus = [view(0, 1, 100, 0.0), view(1, 1, 200, 0.0)];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
    }

    #[test]
    fn kv_pressure_footprint_drives_the_placement() {
        let mut kv = KvPressure;
        // A heavy request (3200 tok = 200 blocks) prefers the
        // loaded-but-large free pool (300 free absorbs 100 + 200 at
        // ratio 1.0; 100 free would sit at 2.0); a light request
        // (160 tok = 10 blocks) flips to the cleaner small pool
        // (0.1 vs 0.37).
        let big = RouteRequest { rid: 0, qid: 0, n_traces: 8, expected_tokens: 3200.0 };
        let gpus = [view(0, 1, 100, 0.0), view(1, 1, 300, 100.0)];
        assert_eq!(gpus[kv.place(&big, &gpus)].gpu, 1);
        let small = RouteRequest { expected_tokens: 160.0, ..big };
        assert_eq!(gpus[kv.place(&small, &gpus)].gpu, 0);
    }

    #[test]
    fn kv_pressure_weighs_timing_scale_on_heterogeneous_pools() {
        let mut kv = KvPressure;
        // Equal block pressure: the empty-but-3x-slower GPU loses to a
        // moderately loaded baseline GPU, because its queued work
        // drains three times slower.
        let mut slow = view(0, 0, 200, 0.0);
        slow.timing_scale = 3.0;
        let busy = view(1, 2, 200, 150.0);
        // slow: 3.0 * (0 + 50) / 200 = 0.75; busy: 1.0 * 200 / 200 = 1.0
        // -> still prefers the slow empty one at this gap...
        assert_eq!([slow, busy][kv.place(&req(), &[slow, busy])].gpu, 0);
        // ...but once the gap narrows the fast GPU wins even while
        // busier: slow 3.0 * 50/200 = 0.75 vs busy 1.0 * 100/200 = 0.5.
        let busy = view(1, 2, 200, 50.0);
        assert_eq!([slow, busy][kv.place(&req(), &[slow, busy])].gpu, 1);
        // A load-oblivious scale-free comparison would have picked the
        // empty GPU both times.
    }

    #[test]
    fn kv_pressure_quantizes_footprint_by_each_gpus_block_size() {
        let mut kv = KvPressure;
        // Same tokens, different block sizes: 800 tokens is 50 blocks
        // at bs=16 but 25 at bs=32, so the coarse-blocked GPU's ratio
        // halves and it wins at equal free capacity.
        let fine = view(0, 0, 100, 0.0);
        let mut coarse = view(1, 0, 100, 0.0);
        coarse.block_size = 32;
        assert_eq!([fine, coarse][kv.place(&req(), &[fine, coarse])].gpu, 1);
    }

    #[test]
    fn kind_parse_build_roundtrip() {
        for k in RouterKind::ALL {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(RouterKind::parse("nope"), None);
    }
}
