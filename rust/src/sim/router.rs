//! Request routing policies for the cluster serving simulator
//! ([`crate::sim::cluster`]).
//!
//! The router decides which GPU's engine serves an arriving request.
//! Its leverage at cluster scale is exactly the source paper's thesis
//! in scheduling form: a router that can see *per-GPU KV pressure* —
//! resident blocks plus the score-weighted demand of the traces that
//! will survive STEP's pruning — can place requests so that pruning is
//! never needed, while per-trace signals (token confidence, probes)
//! say nothing about where a request should go. Three policies:
//!
//! * [`RoundRobin`] — the load-oblivious baseline: GPUs in cyclic
//!   order, regardless of state.
//! * [`LeastOutstanding`] — classic load balancing on request *count*;
//!   blind to the skew in per-request KV footprints.
//! * [`KvPressure`] — pick the GPU whose free pool the projected
//!   demand — its surviving traces' score-weighted needs
//!   ([`GpuView::survivor_demand_blocks`]) plus the request's own
//!   expected footprint — would consume the smallest *fraction* of,
//!   scaled by the GPU's relative slowness
//!   ([`GpuView::timing_scale`]). Memory- **and capacity-**aware: on a
//!   heterogeneous pool the footprint is quantized by each GPU's own
//!   block size, and the timing scale keeps a slow-but-empty GPU from
//!   outbidding a fast-but-busy one (equal block pressure on a 3×
//!   slower GPU drains 3× slower). A *saturated* GPU (zero free
//!   blocks) is always ranked behind any GPU with headroom — the
//!   `free.max(1)` guard alone scored it identically to a GPU with a
//!   single free block, steering arrivals into guaranteed sheds.
//! * [`ShardedKvPressure`] — the fleet-scale form of the same policy:
//!   GPUs partition into fixed shards of [`shard_size`]
//!   consecutive ids, a cheap global stage picks the shard whose
//!   *request-independent* base pressure
//!   (minimum over members) is lowest, and the exact kv-pressure scan
//!   runs only within that shard — O(S + R/S) per placement instead of
//!   O(R). With a single shard it is byte-identical to [`KvPressure`];
//!   the cluster simulator maintains the per-shard aggregates
//!   incrementally and asserts against this reference implementation.
//!
//! [`shard_size`]: ShardedKvPressure::shard_size
//!
//! Policies are pure functions of their inputs (the round-robin cursor
//! is the only state), so cluster runs stay bit-deterministic.
//!
//! **Elastic fleets.** Engines can appear (standby activation, join
//! events) and disappear (leaves, spot revocations) mid-run without any
//! policy here noticing: the cluster keeps a cached view per *slot* —
//! active and standby alike — and renders every non-placeable slot
//! (standby, draining, departed) as a sentinel view with
//! `outstanding == usize::MAX`. Every eligibility filter is the same
//! `outstanding < quota` test, so sentinels fall out of the flat
//! eligible slice, the sharded router's per-shard aggregates, and the
//! debug cross-check uniformly — the dirty-shard bookkeeping needs no
//! fleet-state special cases, only a `view_version` bump on each state
//! transition to force the sentinel (re)build. Policies therefore only
//! ever see currently-placeable GPUs, exactly as with a static fleet;
//! [`RoundRobin`]'s cursor advances by absolute GPU id, so a slot
//! vanishing or reappearing between placements just looks like another
//! eligibility hole.

/// Read-only scheduling view of one per-GPU engine at routing time.
///
/// Views are cheap to build per placement: every field is either an
/// O(1) engine counter or, for
/// [`survivor_demand_blocks`](GpuView::survivor_demand_blocks), served
/// from the engine's incrementally maintained router-view aggregates
/// (`ServeSimConfig::route_views`) instead of an O(live) scan-and-sort
/// over its trace table.
#[derive(Debug, Clone, Copy)]
pub struct GpuView {
    /// The GPU's index in the cluster.
    pub gpu: usize,
    /// Requests submitted to this GPU and not yet complete.
    pub outstanding: usize,
    /// Live sequences resident in the GPU's KV pool.
    pub live_traces: usize,
    /// Free blocks in the GPU's KV pool.
    pub free_blocks: usize,
    /// Physical blocks in the GPU's KV pool.
    pub pool_blocks: usize,
    /// PagedAttention block size of this GPU's pool, in tokens
    /// (heterogeneous pools may differ per GPU).
    pub block_size: usize,
    /// Relative per-token slowness of this GPU (1.0 = the calibrated
    /// baseline; 3.0 = three times slower). Capacity-aware policies
    /// scale projected pressure by it.
    pub timing_scale: f64,
    /// Estimated blocks the GPU's surviving traces still need (see
    /// [`crate::sim::serve::ServeEngine::survivor_demand_blocks`]).
    pub survivor_demand_blocks: f64,
    /// Blocks the *arriving request's question* would reuse from this
    /// GPU's prefix registry (0 with the cache off, on a miss, or for
    /// request-independent uses of the view). Per-(request, GPU) data:
    /// the cluster stamps it into per-placement view copies, never into
    /// its version-keyed view cache.
    pub prefix_hit_blocks: f64,
    /// Affinity-credit weight `w`: [`kv_pressure_key`] subtracts
    /// `w × prefix_hit_blocks` from the request's expected footprint.
    /// At 0 (the default) the scoring arithmetic is untouched, so
    /// placements stay bit-identical to the affinity-blind router.
    pub affinity_weight: f64,
}

/// What the router knows about an arriving request.
#[derive(Debug, Clone, Copy)]
pub struct RouteRequest {
    /// Cluster-global request id.
    pub rid: usize,
    /// Question the request asks.
    pub qid: usize,
    /// Traces the request will decode (N).
    pub n_traces: usize,
    /// Expected KV *tokens* the request (prompt + N traces) will occupy
    /// at its expected full length (benchmark-profile mean — the router
    /// cannot see the sampled trace lengths). Tokens, not blocks: on a
    /// heterogeneous pool each GPU quantizes the footprint by its own
    /// [`GpuView::block_size`].
    pub expected_tokens: f64,
}

/// A placement policy: pick one GPU for each arriving request.
///
/// The cluster's admission layer pre-filters the views to the GPUs
/// currently eligible (below their outstanding-request quota) and calls
/// [`place`](RouterPolicy::place) with a non-empty slice; the return
/// value is an *index into that slice* (map back to a GPU id through
/// [`GpuView::gpu`]).
///
/// # Examples
///
/// ```
/// use step::sim::router::{GpuView, RouteRequest, RouterPolicy, RoundRobin};
///
/// let view = |gpu: usize| GpuView {
///     gpu,
///     outstanding: 0,
///     live_traces: 0,
///     free_blocks: 100,
///     pool_blocks: 100,
///     block_size: 16,
///     timing_scale: 1.0,
///     survivor_demand_blocks: 0.0,
///     prefix_hit_blocks: 0.0,
///     affinity_weight: 0.0,
/// };
/// let req = RouteRequest { rid: 0, qid: 0, n_traces: 4, expected_tokens: 192.0 };
/// let gpus = [view(0), view(1), view(2)];
/// let mut rr = RoundRobin::new();
/// assert_eq!(rr.place(&req, &gpus), 0);
/// assert_eq!(rr.place(&req, &gpus), 1);
/// assert_eq!(rr.place(&req, &gpus), 2);
/// assert_eq!(rr.place(&req, &gpus), 0); // wraps
/// ```
pub trait RouterPolicy {
    /// The policy's display name.
    fn name(&self) -> &'static str;

    /// Choose a GPU for `req` among the eligible `gpus` (non-empty);
    /// returns an index into `gpus`.
    fn place(&mut self, req: &RouteRequest, gpus: &[GpuView]) -> usize;
}

/// Load-oblivious cyclic placement (the baseline every load balancer is
/// measured against).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundRobin {
    /// Next GPU id the cursor wants to serve.
    next: usize,
}

impl RoundRobin {
    /// A cursor starting at GPU 0.
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RouterPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&mut self, _req: &RouteRequest, gpus: &[GpuView]) -> usize {
        // The eligible set may have holes (GPUs at quota), so advance
        // the cursor to the first eligible GPU at-or-after it, wrapping.
        let max_gpu = gpus.iter().map(|g| g.gpu).max().unwrap_or(0);
        let pick = gpus
            .iter()
            .enumerate()
            .filter(|(_, g)| g.gpu >= self.next)
            .min_by_key(|(_, g)| g.gpu)
            .or_else(|| gpus.iter().enumerate().min_by_key(|(_, g)| g.gpu));
        let (idx, g) = pick.expect("place called with a non-empty view set");
        self.next = if g.gpu >= max_gpu { 0 } else { g.gpu + 1 };
        idx
    }
}

/// Place on the GPU with the fewest outstanding requests (ties: fewer
/// live traces, then lower GPU id).
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastOutstanding;

impl RouterPolicy for LeastOutstanding {
    fn name(&self) -> &'static str {
        "least-outstanding"
    }

    fn place(&mut self, _req: &RouteRequest, gpus: &[GpuView]) -> usize {
        gpus.iter()
            .enumerate()
            .min_by_key(|(_, g)| (g.outstanding, g.live_traces, g.gpu))
            .map(|(idx, _)| idx)
            .expect("place called with a non-empty view set")
    }
}

/// Place on the GPU whose free pool the projected demand would consume
/// the least, *relatively*, weighted by how slowly that GPU drains it:
/// score = timing_scale × (survivor demand + the request's expected
/// footprint in this GPU's blocks) / free blocks. The ratio is what
/// makes the request's own footprint a real input — a heavy request
/// tolerates a loaded-but-large free pool better than a
/// clean-but-small one, which an absolute `demand − free` difference
/// cannot express (any per-GPU constant cancels out of an argmin) —
/// and the timing scale is what makes the policy *capacity*-aware on a
/// heterogeneous pool: the same block pressure on a 3× slower GPU
/// represents 3× the wall-clock of queued work, so a slow-but-empty
/// GPU no longer outbids a fast-but-busy one. Deterministic
/// first-minimum tie-breaking in view order.
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPressure;

/// The kv-pressure placement key for `req` on `g`, ordered
/// lexicographically: a *saturation flag* (no free blocks at all —
/// such a GPU can only shed or stall the request, so it ranks behind
/// every GPU with headroom no matter how loaded), then the
/// relative-pressure score described on [`KvPressure`]. The
/// `free.max(1)` guard alone collapsed "zero free blocks" onto "one
/// free block", which made a fully saturated GPU outbid a lightly
/// saturated one — the explicit flag restores the ordering.
///
/// Shared by [`KvPressure`], [`ShardedKvPressure`]'s within-shard scan,
/// and the cluster simulator's incremental placement path, so all three
/// agree byte-for-byte.
///
/// **Affinity credit.** When the view carries a positive
/// [`GpuView::affinity_weight`] and the GPU's prefix registry holds
/// blocks of this request's question ([`GpuView::prefix_hit_blocks`]),
/// the request's expected footprint shrinks by `w × hit_blocks`
/// (floored at zero — a cached prompt can waive the request's own
/// footprint, never turn it into anti-pressure): KV the GPU already
/// holds is KV the placement does not consume. Both guards are
/// structural, so `w == 0` (or the cache off) leaves the scoring
/// arithmetic — and hence every placement — bit-identical to the
/// affinity-blind router.
pub(crate) fn kv_pressure_key(req: &RouteRequest, g: &GpuView) -> (bool, f64) {
    let mut expected_blocks = req.expected_tokens / g.block_size.max(1) as f64;
    if g.affinity_weight > 0.0 && g.prefix_hit_blocks > 0.0 {
        expected_blocks =
            (expected_blocks - g.affinity_weight * g.prefix_hit_blocks).max(0.0);
    }
    let score = (g.survivor_demand_blocks + expected_blocks) / g.free_blocks.max(1) as f64
        * g.timing_scale;
    (g.free_blocks == 0, score)
}

/// The request-independent part of [`kv_pressure_key`]: the saturation
/// flag and the survivor-demand-to-headroom ratio, without the arriving
/// request's own footprint. This is what the sharded router's global
/// stage aggregates per shard — it must not depend on the request, or
/// the per-shard minima could not be cached between placements. The
/// affinity credit is per-(request, GPU) data and therefore lives only
/// in [`kv_pressure_key`]'s stage-two scan.
pub(crate) fn shard_base_key(g: &GpuView) -> (bool, f64) {
    let score = g.timing_scale * g.survivor_demand_blocks / g.free_blocks.max(1) as f64;
    (g.free_blocks == 0, score)
}

/// First minimum of [`kv_pressure_key`] in view order.
fn kv_pressure_scan(req: &RouteRequest, gpus: &[GpuView]) -> usize {
    debug_assert!(!gpus.is_empty(), "place called with a non-empty view set");
    let mut best = 0usize;
    let mut best_key = kv_pressure_key(req, &gpus[0]);
    for (idx, g) in gpus.iter().enumerate().skip(1) {
        let key = kv_pressure_key(req, g);
        if key < best_key {
            best = idx;
            best_key = key;
        }
    }
    best
}

impl RouterPolicy for KvPressure {
    fn name(&self) -> &'static str {
        "kv-pressure"
    }

    fn place(&mut self, req: &RouteRequest, gpus: &[GpuView]) -> usize {
        kv_pressure_scan(req, gpus)
    }
}

/// Two-stage kv-pressure placement for large fleets.
///
/// GPUs partition into fixed shards by absolute id
/// (`gpu / shard_size` — *never* by position in the eligible slice,
/// which would move shard boundaries between placements and break
/// determinism). Stage one ranks shards by the minimum
/// [`shard_base_key`] over their eligible members, picking the
/// lexicographically smallest `(key, shard_id)`; stage two runs the
/// exact [`kv_pressure_key`] scan within the winning shard only. With
/// every GPU in one shard the policy degenerates to [`KvPressure`]
/// byte-for-byte.
///
/// This struct is the O(R) *reference semantics*: it recomputes the
/// shard minima from the slice on every call. The cluster simulator
/// implements the same two stages over incrementally maintained
/// per-shard aggregates (O(S + R/S) per placement) and
/// `debug_assert!`s its pick against this reference.
#[derive(Debug, Clone, Copy)]
pub struct ShardedKvPressure {
    /// GPUs per shard (>= 1); shard of a view is `gpu / shard_size`.
    pub shard_size: usize,
}

impl ShardedKvPressure {
    /// A sharded policy with the given shard size (clamped to >= 1).
    pub fn new(shard_size: usize) -> ShardedKvPressure {
        ShardedKvPressure { shard_size: shard_size.max(1) }
    }

    /// Stage one on an eligible slice: the shard with the smallest
    /// `(min member base key, shard id)`.
    fn pick_shard(&self, gpus: &[GpuView]) -> usize {
        let mut best: Option<(usize, (bool, f64))> = None;
        for g in gpus {
            let shard = g.gpu / self.shard_size;
            let key = shard_base_key(g);
            best = Some(match best {
                None => (shard, key),
                Some((bs, bk)) => {
                    if key < bk || (key == bk && shard < bs) {
                        (shard, key)
                    } else {
                        (bs, bk)
                    }
                }
            });
        }
        best.expect("place called with a non-empty view set").0
    }
}

impl RouterPolicy for ShardedKvPressure {
    fn name(&self) -> &'static str {
        "kv-sharded"
    }

    fn place(&mut self, req: &RouteRequest, gpus: &[GpuView]) -> usize {
        let shard = self.pick_shard(gpus);
        // Stage two: exact scan restricted to the winning shard, in
        // view order (== ascending GPU id for cluster-built slices).
        let mut best: Option<(usize, (bool, f64))> = None;
        for (idx, g) in gpus.iter().enumerate() {
            if g.gpu / self.shard_size != shard {
                continue;
            }
            let key = kv_pressure_key(req, g);
            let better = match best {
                None => true,
                Some((_, bk)) => key < bk,
            };
            if better {
                best = Some((idx, key));
            }
        }
        best.expect("winning shard has at least one member").0
    }
}

/// Shard size the cluster uses when none is configured: ~sqrt(R)
/// balances the global stage (R / size shards) against the within-shard
/// scan (size GPUs), floored at 8 so small fleets collapse to a single
/// shard and stay byte-identical to the flat [`KvPressure`] policy.
pub fn auto_shard_size(n_gpus: usize) -> usize {
    ((n_gpus as f64).sqrt().ceil() as usize).max(8)
}

/// Selectable router policy (CLI / config surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// [`RoundRobin`].
    RoundRobin,
    /// [`LeastOutstanding`].
    LeastOutstanding,
    /// [`KvPressure`].
    KvPressure,
    /// [`ShardedKvPressure`].
    KvPressureSharded,
}

/// Shard size [`RouterKind::build`] falls back to when no fleet
/// geometry is known (the cluster passes an explicit size through
/// [`RouterKind::build_with`]).
pub const DEFAULT_SHARD_SIZE: usize = 8;

impl RouterKind {
    /// Every policy, baseline first.
    pub const ALL: [RouterKind; 4] = [
        RouterKind::RoundRobin,
        RouterKind::LeastOutstanding,
        RouterKind::KvPressure,
        RouterKind::KvPressureSharded,
    ];

    /// Display name (also the CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::RoundRobin => "round-robin",
            RouterKind::LeastOutstanding => "least-outstanding",
            RouterKind::KvPressure => "kv-pressure",
            RouterKind::KvPressureSharded => "kv-sharded",
        }
    }

    /// Parse a CLI router name (case-insensitive).
    pub fn parse(s: &str) -> Option<RouterKind> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(RouterKind::RoundRobin),
            "least-outstanding" | "leastoutstanding" | "lor" => {
                Some(RouterKind::LeastOutstanding)
            }
            "kv-pressure" | "kvpressure" | "kv" => Some(RouterKind::KvPressure),
            "kv-sharded" | "kvsharded" | "kvs" => Some(RouterKind::KvPressureSharded),
            _ => None,
        }
    }

    /// Instantiate the policy with [`DEFAULT_SHARD_SIZE`] for the
    /// sharded kind.
    pub fn build(&self) -> Box<dyn RouterPolicy> {
        self.build_with(DEFAULT_SHARD_SIZE)
    }

    /// Instantiate the policy with an explicit shard size (0 falls back
    /// to [`DEFAULT_SHARD_SIZE`]; ignored by the flat policies).
    pub fn build_with(&self, shard_size: usize) -> Box<dyn RouterPolicy> {
        let shard_size = if shard_size == 0 { DEFAULT_SHARD_SIZE } else { shard_size };
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::LeastOutstanding => Box::new(LeastOutstanding),
            RouterKind::KvPressure => Box::new(KvPressure),
            RouterKind::KvPressureSharded => Box::new(ShardedKvPressure::new(shard_size)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(gpu: usize, outstanding: usize, free: usize, demand: f64) -> GpuView {
        GpuView {
            gpu,
            outstanding,
            live_traces: outstanding * 4,
            free_blocks: free,
            pool_blocks: 1000,
            block_size: 16,
            timing_scale: 1.0,
            survivor_demand_blocks: demand,
            prefix_hit_blocks: 0.0,
            affinity_weight: 0.0,
        }
    }

    fn req() -> RouteRequest {
        // 800 tokens / 16-token blocks = 50 expected blocks at baseline.
        RouteRequest { rid: 0, qid: 0, n_traces: 4, expected_tokens: 800.0 }
    }

    #[test]
    fn round_robin_cycles_and_skips_holes() {
        let mut rr = RoundRobin::new();
        let all = [view(0, 0, 10, 0.0), view(1, 0, 10, 0.0), view(2, 0, 10, 0.0)];
        let seq: Vec<usize> = (0..6).map(|_| all[rr.place(&req(), &all)].gpu).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
        // GPU 1 drops out (quota): the cursor skips it without stalling.
        let holed = [view(0, 0, 10, 0.0), view(2, 0, 10, 0.0)];
        let seq: Vec<usize> = (0..4).map(|_| holed[rr.place(&req(), &holed)].gpu).collect();
        assert_eq!(seq, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_outstanding_picks_min_with_stable_ties() {
        let mut lo = LeastOutstanding;
        let gpus = [view(0, 3, 10, 0.0), view(1, 1, 10, 0.0), view(2, 1, 10, 0.0)];
        // 1 and 2 tie on outstanding and live traces: lower gpu id wins.
        assert_eq!(gpus[lo.place(&req(), &gpus)].gpu, 1);
        let gpus = [view(0, 0, 10, 0.0), view(1, 1, 10, 0.0)];
        assert_eq!(gpus[lo.place(&req(), &gpus)].gpu, 0);
    }

    #[test]
    fn kv_pressure_prefers_headroom_not_count() {
        let mut kv = KvPressure;
        // GPU 0 has fewer requests but its survivors want the memory;
        // GPU 1 is busier by count yet has real block headroom.
        let gpus = [view(0, 1, 100, 400.0), view(1, 3, 300, 50.0)];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
        // All else equal, more free blocks wins.
        let gpus = [view(0, 1, 100, 0.0), view(1, 1, 200, 0.0)];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
    }

    #[test]
    fn kv_pressure_footprint_drives_the_placement() {
        let mut kv = KvPressure;
        // A heavy request (3200 tok = 200 blocks) prefers the
        // loaded-but-large free pool (300 free absorbs 100 + 200 at
        // ratio 1.0; 100 free would sit at 2.0); a light request
        // (160 tok = 10 blocks) flips to the cleaner small pool
        // (0.1 vs 0.37).
        let big = RouteRequest { rid: 0, qid: 0, n_traces: 8, expected_tokens: 3200.0 };
        let gpus = [view(0, 1, 100, 0.0), view(1, 1, 300, 100.0)];
        assert_eq!(gpus[kv.place(&big, &gpus)].gpu, 1);
        let small = RouteRequest { expected_tokens: 160.0, ..big };
        assert_eq!(gpus[kv.place(&small, &gpus)].gpu, 0);
    }

    #[test]
    fn kv_pressure_weighs_timing_scale_on_heterogeneous_pools() {
        let mut kv = KvPressure;
        // Equal block pressure: the empty-but-3x-slower GPU loses to a
        // moderately loaded baseline GPU, because its queued work
        // drains three times slower.
        let mut slow = view(0, 0, 200, 0.0);
        slow.timing_scale = 3.0;
        let busy = view(1, 2, 200, 150.0);
        // slow: 3.0 * (0 + 50) / 200 = 0.75; busy: 1.0 * 200 / 200 = 1.0
        // -> still prefers the slow empty one at this gap...
        assert_eq!([slow, busy][kv.place(&req(), &[slow, busy])].gpu, 0);
        // ...but once the gap narrows the fast GPU wins even while
        // busier: slow 3.0 * 50/200 = 0.75 vs busy 1.0 * 100/200 = 0.5.
        let busy = view(1, 2, 200, 50.0);
        assert_eq!([slow, busy][kv.place(&req(), &[slow, busy])].gpu, 1);
        // A load-oblivious scale-free comparison would have picked the
        // empty GPU both times.
    }

    #[test]
    fn kv_pressure_quantizes_footprint_by_each_gpus_block_size() {
        let mut kv = KvPressure;
        // Same tokens, different block sizes: 800 tokens is 50 blocks
        // at bs=16 but 25 at bs=32, so the coarse-blocked GPU's ratio
        // halves and it wins at equal free capacity.
        let fine = view(0, 0, 100, 0.0);
        let mut coarse = view(1, 0, 100, 0.0);
        coarse.block_size = 32;
        assert_eq!([fine, coarse][kv.place(&req(), &[fine, coarse])].gpu, 1);
    }

    #[test]
    fn kv_pressure_never_picks_a_saturated_gpu_over_headroom() {
        let mut kv = KvPressure;
        // Regression: with only the `free.max(1)` guard, a GPU with 0
        // free blocks scored identically to one with 1 free block, so a
        // saturated fast GPU could outbid a slow one with real
        // headroom. The saturation flag ranks any headroom first.
        let saturated = view(0, 1, 0, 10.0);
        let mut slow_with_room = view(1, 3, 1, 10.0);
        slow_with_room.timing_scale = 4.0;
        let gpus = [saturated, slow_with_room];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
        // Among saturated GPUs the relative score still orders them.
        let gpus = [view(0, 1, 0, 500.0), view(1, 1, 0, 10.0)];
        assert_eq!(gpus[kv.place(&req(), &gpus)].gpu, 1);
    }

    #[test]
    fn affinity_credit_steers_toward_the_prefix_holder_only_when_weighted() {
        let mut kv = KvPressure;
        // Identical GPUs: the first minimum wins.
        let plain = [view(0, 1, 100, 50.0), view(1, 1, 100, 50.0)];
        assert_eq!(plain[kv.place(&req(), &plain)].gpu, 0);
        // GPU 1 holds 30 of the question's prompt blocks: with w > 0
        // the credit shrinks the footprint ((50 + 50 - 30)/100 = 0.7
        // vs 1.0) and GPU 1 wins.
        let mut holder = plain;
        holder[1].prefix_hit_blocks = 30.0;
        holder[1].affinity_weight = 1.0;
        assert_eq!(holder[kv.place(&req(), &holder)].gpu, 1);
        // Half weight still wins, proportionally: (100 - 15)/100 = 0.85.
        holder[1].affinity_weight = 0.5;
        assert_eq!(holder[kv.place(&req(), &holder)].gpu, 1);
        // w = 0 leaves the arithmetic untouched even with hit blocks
        // present: placement reverts to the affinity-blind pick.
        holder[1].affinity_weight = 0.0;
        assert_eq!(holder[kv.place(&req(), &holder)].gpu, 0);
        // The credit floors at zero: an enormous cached prefix waives
        // the request's own footprint but never subtracts survivor
        // demand (score stays at 50/100 = 0.5).
        holder[1].affinity_weight = 1.0;
        holder[1].prefix_hit_blocks = 1e6;
        let key = kv_pressure_key(&req(), &holder[1]);
        assert!((key.1 - 0.5).abs() < 1e-12, "floored score, got {}", key.1);
        // The request-independent shard base key never sees affinity.
        assert_eq!(shard_base_key(&holder[1]), shard_base_key(&plain[1]));
    }

    #[test]
    fn sharded_matches_flat_when_one_shard_covers_the_fleet() {
        // shard_size >= fleet: stage one is a no-op and the within-shard
        // scan is the flat policy, placement by placement.
        let mut flat = KvPressure;
        let mut sharded = ShardedKvPressure::new(64);
        let gpus: Vec<GpuView> = (0..9)
            .map(|g| view(g, g % 3, 40 + 13 * ((g * 7) % 5), (g as f64 * 37.0) % 90.0))
            .collect();
        for tok in [64.0, 800.0, 3200.0] {
            let r = RouteRequest { expected_tokens: tok, ..req() };
            assert_eq!(flat.place(&r, &gpus), sharded.place(&r, &gpus), "tok={tok}");
        }
    }

    #[test]
    fn sharded_two_stage_picks_cheapest_shard_then_exact_member() {
        let mut sharded = ShardedKvPressure::new(2);
        // Shards {0,1} and {2,3}. Base keys (demand / free): shard 0
        // min = GPU 1 at 60/1000 = 0.06; shard 1 min = GPU 2 at
        // 5/100 = 0.05 -> shard 1 wins stage one. The exact scan then
        // never considers GPU 1, even though its full kv-pressure key
        // ((60+50)/1000 = 0.11 vs GPU 2's 55/100 = 0.55) would win
        // globally once the request's own footprint is added.
        let gpus = [
            view(0, 1, 10, 90.0),
            view(1, 1, 1000, 60.0),
            view(2, 1, 100, 5.0),
            view(3, 1, 100, 80.0),
        ];
        let pick = gpus[sharded.place(&req(), &gpus)].gpu;
        assert_eq!(pick, 2, "exact scan runs only inside the cheapest shard");
        let mut flat = KvPressure;
        assert_eq!(gpus[flat.place(&req(), &gpus)].gpu, 1, "flat would have picked GPU 1");
    }

    #[test]
    fn sharded_shards_by_absolute_gpu_id_not_slice_position() {
        let mut sharded = ShardedKvPressure::new(2);
        // GPU 1 is at quota and missing from the eligible slice, so the
        // slice positions are [GPU0, GPU2, GPU3]. Absolute-id shards are
        // {0} and {2,3}; a positional partition would wrongly pair
        // {GPU0, GPU2}. Base keys: GPU0 0/10 = 0, GPU2 40/1000 = 0.04,
        // GPU3 10/50 = 0.2 -> absolute shard {0} wins and GPU 0 is
        // placed. Positional sharding would scan {GPU0, GPU2} and pick
        // GPU 2 on the exact key (90/1000 = 0.09 vs GPU0's 50/10 = 5).
        let gpus = [view(0, 1, 10, 0.0), view(2, 1, 1000, 40.0), view(3, 1, 50, 10.0)];
        assert_eq!(gpus[sharded.place(&req(), &gpus)].gpu, 0);
        // Saturation feeds stage one too: a shard whose only eligible
        // member has zero free blocks loses to any shard with headroom.
        let gpus = [view(0, 1, 10, 90.0), view(1, 1, 100, 1.0), view(3, 1, 0, 0.0)];
        assert_eq!(gpus[sharded.place(&req(), &gpus)].gpu, 1);
    }

    #[test]
    fn auto_shard_size_tracks_sqrt_with_a_floor() {
        assert_eq!(auto_shard_size(1), 8);
        assert_eq!(auto_shard_size(4), 8); // single shard at R=4
        assert_eq!(auto_shard_size(64), 8);
        assert_eq!(auto_shard_size(256), 16);
        assert_eq!(auto_shard_size(1024), 32);
    }

    #[test]
    fn kind_parse_build_roundtrip() {
        for k in RouterKind::ALL {
            assert_eq!(RouterKind::parse(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(RouterKind::parse("kvs"), Some(RouterKind::KvPressureSharded));
        assert_eq!(RouterKind::parse("nope"), None);
        assert_eq!(RouterKind::KvPressureSharded.build_with(0).name(), "kv-sharded");
    }
}
