//! GPU memory model (the paper's testbed: one 96 GB NVIDIA GH200).
//!
//! The paper's latency bottleneck is *discrete*: once the KV cache cannot
//! grow, vLLM preempts traces into a waiting queue. That behaviour depends
//! only on the memory budget arithmetic reproduced here — total HBM x
//! utilization knob (`gpu_memory_utilization`, §5.3.5 sweeps 0.5..0.9)
//! minus model weights, divided into PagedAttention blocks.

/// Physical GPU description.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    /// Total HBM in bytes.
    pub total_bytes: u64,
    /// vLLM-style `gpu_memory_utilization` (fraction of HBM usable).
    pub mem_util: f64,
}

impl GpuSpec {
    /// The paper's 96 GB GH200 at a given memory-utilization setting.
    pub fn gh200(mem_util: f64) -> Self {
        GpuSpec { total_bytes: 96 * (1 << 30), mem_util }
    }

    /// Bytes available for KV cache after weights + activation slack.
    pub fn kv_budget_bytes(&self, weight_bytes: u64, activation_bytes: u64) -> u64 {
        let usable = (self.total_bytes as f64 * self.mem_util) as u64;
        usable.saturating_sub(weight_bytes + activation_bytes)
    }

    /// KV capacity in tokens for a model with `kv_bytes_per_token`.
    pub fn kv_capacity_tokens(
        &self,
        weight_bytes: u64,
        activation_bytes: u64,
        kv_bytes_per_token: u64,
    ) -> usize {
        (self.kv_budget_bytes(weight_bytes, activation_bytes) / kv_bytes_per_token.max(1))
            as usize
    }

    /// Number of PagedAttention blocks of `block_size` tokens.
    pub fn kv_capacity_blocks(
        &self,
        weight_bytes: u64,
        activation_bytes: u64,
        kv_bytes_per_token: u64,
        block_size: usize,
    ) -> usize {
        self.kv_capacity_tokens(weight_bytes, activation_bytes, kv_bytes_per_token)
            / block_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gh200_budget() {
        let g = GpuSpec::gh200(0.9);
        let budget = g.kv_budget_bytes(16 << 30, 2 << 30);
        // 0.9*96 GiB - 18 GiB = 68.4 GiB
        assert!((budget as f64 / (1u64 << 30) as f64 - 68.4).abs() < 0.1);
    }

    #[test]
    fn capacity_scales_with_util() {
        let lo = GpuSpec::gh200(0.5).kv_capacity_tokens(16 << 30, 0, 150_000);
        let hi = GpuSpec::gh200(0.9).kv_capacity_tokens(16 << 30, 0, 150_000);
        assert!(hi > lo);
        // 0.9: (86.4-16) GiB / 150 KB ~ 503k tokens.
        assert!((450_000..560_000).contains(&hi), "hi={hi}");
    }

    #[test]
    fn weights_larger_than_budget_saturate_to_zero() {
        let g = GpuSpec::gh200(0.5);
        assert_eq!(g.kv_budget_bytes(60 << 30, 0), 0);
        assert_eq!(g.kv_capacity_tokens(60 << 30, 0, 100_000), 0);
    }

    #[test]
    fn block_quantization() {
        let g = GpuSpec::gh200(0.9);
        let tokens = g.kv_capacity_tokens(16 << 30, 0, 150_000);
        let blocks = g.kv_capacity_blocks(16 << 30, 0, 150_000, 16);
        assert_eq!(blocks, tokens / 16);
    }
}
