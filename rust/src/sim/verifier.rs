//! Rule-based answer verifier (the paper adapts Qwen2.5-Math's verifier:
//! normalization + numeric matching + symbolic equivalence for simple
//! forms). Used to label scorer training traces and to check e2e answers.
//!
//! Our answer algebra covers what the synthetic/e2e workloads emit:
//! integers, decimals, simple fractions "a/b", leading/trailing
//! whitespace, surrounding `\boxed{...}`, thousands separators, and
//! leading zeros.

/// Normalized answer value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AnswerValue {
    /// Exact rational p/q in lowest terms (q > 0).
    Rational(i64, i64),
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a.abs().max(1)
    } else {
        gcd(b, a % b)
    }
}

impl AnswerValue {
    /// Normalize p/q to lowest terms; `None` when q == 0.
    pub fn rational(p: i64, q: i64) -> Option<AnswerValue> {
        if q == 0 {
            return None;
        }
        let sign = if q < 0 { -1 } else { 1 };
        let g = gcd(p, q);
        Some(AnswerValue::Rational(sign * p / g, (q / g).abs()))
    }
}

/// Parse + normalize an answer string. Returns None when unparseable
/// (the trace then abstains from voting, like the paper's verifier
/// failing to extract an answer).
pub fn parse_answer(raw: &str) -> Option<AnswerValue> {
    let mut s = raw.trim();
    // Strip \boxed{...} (possibly with surrounding text noise).
    if let Some(start) = s.find("\\boxed{") {
        let rest = &s[start + 7..];
        let end = rest.find('}')?;
        s = rest[..end].trim();
    }
    let s = s.replace(',', ""); // thousands separators
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    // Fraction a/b.
    if let Some((num, den)) = s.split_once('/') {
        let p: i64 = num.trim().parse().ok()?;
        let q: i64 = den.trim().parse().ok()?;
        return AnswerValue::rational(p, q);
    }
    // Decimal.
    if let Some((int_part, frac_part)) = s.split_once('.') {
        let frac_digits = frac_part.len() as u32;
        if frac_digits == 0 || frac_digits > 9 {
            return None;
        }
        let negative = int_part.trim_start().starts_with('-');
        let int_val: i64 = if int_part == "-" { 0 } else { int_part.parse().ok()? };
        let frac_val: i64 = frac_part.parse().ok()?;
        let scale = 10i64.pow(frac_digits);
        let p = int_val.abs() * scale + frac_val;
        let p = if negative || int_val < 0 { -p } else { p };
        return AnswerValue::rational(p, scale);
    }
    // Integer (handles leading zeros via parse).
    let p: i64 = s.parse().ok()?;
    AnswerValue::rational(p, 1)
}

/// The verifier: does the candidate match ground truth?
pub fn verify(candidate: &str, ground_truth: &str) -> bool {
    match (parse_answer(candidate), parse_answer(ground_truth)) {
        (Some(a), Some(b)) => a == b,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integers_and_leading_zeros() {
        assert!(verify("007", "7"));
        assert!(verify(" 42 ", "42"));
        assert!(!verify("41", "42"));
        assert!(verify("-3", "-3"));
    }

    #[test]
    fn boxed_extraction() {
        assert!(verify("the answer is \\boxed{128}", "128"));
        assert!(verify("\\boxed{1/2}", "0.5"));
        assert!(!verify("\\boxed{", "128"));
    }

    #[test]
    fn fractions_reduce() {
        assert!(verify("6/4", "3/2"));
        assert!(verify("6/2", "3"));
        assert!(verify("-6/4", "3/-2"));
        assert!(!verify("1/3", "0.3333"));
        assert!(parse_answer("1/0").is_none());
    }

    #[test]
    fn decimals() {
        assert!(verify("2.50", "5/2"));
        assert!(verify("-0.5", "-1/2"));
        assert!(verify("1000.0", "1,000"));
    }

    #[test]
    fn unparseable_rejected() {
        assert!(parse_answer("").is_none());
        assert!(parse_answer("banana").is_none());
        assert!(!verify("banana", "42"));
        assert!(!verify("42", "banana"));
    }
}
