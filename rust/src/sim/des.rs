//! Discrete-event serving engine: the paper-scale experiment driver.
//!
//! This is a faithful discrete-event rendering of the vLLM-V1 scheduler
//! the paper modifies: N traces of one question decode in lockstep
//! continuous batching (one token per running trace per iteration);
//! PagedAttention blocks are allocated as traces grow; when the next
//! iteration's blocks cannot be allocated the engine takes a *memory
//! event* — the SC-family baselines preempt a trace into a waiting queue
//! (recompute-on-resume), STEP prunes the lowest-scored trace (paper
//! §4.2, Algorithm 1).
//!
//! Between events the engine jumps time analytically
//! (`TimingModel::decode_interval`), so a 64-trace x 45k-token question
//! costs O(#step-boundaries), not O(#tokens). Policies (scoring, voting,
//! pruning, confidence thresholds) are the same modules the e2e engine
//! uses; only the token source differs (synthetic `TraceGen` vs PJRT).

use crate::coordinator::method::{Method, MethodParams};
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::signal::{SignalScratch, SignalSpec, StepCtx, TraceSignal};
use crate::coordinator::trace::{TraceState, TraceStatus};
use crate::coordinator::voting::{weighted_vote, Vote};
use crate::kvcache::KvCacheManager;
use crate::obs::{EventKind, Recorder, SimEvent};
use crate::sim::gpu::GpuSpec;
use crate::sim::sched::{self, WaitQueue};
use crate::sim::profiles::{BenchId, ModelId, ModelProfile};
use crate::sim::tracegen::{Question, TraceGen, TraceSpec};
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Which trace the memory event removes (ablation of the paper's
/// lowest-mean-score choice; §4.2 calls the greedy choice "simple to
/// implement and easy to interpret" — the ablation quantifies it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Paper: argmin aggregated step score.
    LowestScore,
    /// Uniform random running trace.
    Random,
    /// Fewest generated tokens (cheapest to lose).
    Youngest,
    /// Oracle: prune a known-incorrect trace if any (upper bound).
    OracleIncorrect,
}

/// How step scores aggregate into score_t (§4.3 ablation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScoreAgg {
    /// Paper: running mean over all scored steps.
    Mean,
    /// Latest step score only.
    Last,
    /// Exponential moving average (alpha = 0.15).
    Ema,
}

/// Simulation configuration for one (model, bench, method) cell.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Simulated model (KV geometry + timing coefficients).
    pub model: ModelId,
    /// Benchmark the questions come from.
    pub bench: BenchId,
    /// Test-time-scaling method driving the scheduler.
    pub method: Method,
    /// Trace budget N per question.
    pub n_traces: usize,
    /// Method hyper-parameters (paper Appendix B.3).
    pub params: MethodParams,
    /// vLLM gpu_memory_utilization (paper default 0.9; Table 4 sweeps).
    pub mem_util: f64,
    /// PagedAttention block size in tokens.
    pub block_size: usize,
    /// Master seed; every RNG stream derives from `(seed, qid)`.
    pub seed: u64,
    /// Score every trace regardless of method (Table 2 / Fig 6-7 need
    /// scores on SC traces).
    pub score_all: bool,
    /// Record (token, score) trajectories (Fig 6-7).
    pub record_dynamics: bool,
    /// Ablation knob: which trace the memory event removes.
    pub victim: VictimPolicy,
    /// Ablation knob: how step scores aggregate into score_t.
    pub score_agg: ScoreAgg,
    /// The pruning signal scoring step boundaries (`--signal`; default
    /// `hidden-mlp`, the paper's MLP over hidden states — byte-identical
    /// to the pre-trait scorer path).
    pub signal: SignalSpec,
}

impl SimConfig {
    /// Paper-default configuration for one cell.
    pub fn new(model: ModelId, bench: BenchId, method: Method, n_traces: usize) -> Self {
        SimConfig {
            model,
            bench,
            method,
            n_traces,
            params: MethodParams::default(),
            mem_util: 0.9,
            block_size: 16,
            seed: 0,
            score_all: false,
            record_dynamics: false,
            victim: VictimPolicy::LowestScore,
            score_agg: ScoreAgg::Mean,
            signal: SignalSpec::default(),
        }
    }
}

/// Outcome of one trace.
#[derive(Debug, Clone)]
pub struct TraceOutcome {
    /// Ground-truth correctness of the trace's reasoning.
    pub label: bool,
    /// Final answer (None = truncated / no parseable answer).
    pub answer: Option<u32>,
    /// Terminal lifecycle state.
    pub status: TraceStatus,
    /// Mean step score at termination.
    pub final_score: f64,
    /// Whole-trace mean token confidence.
    pub mean_confidence: f64,
    /// Tokens generated (excludes prompt).
    pub generated: u64,
    /// Seconds spent waiting (preempted / recompute).
    pub wait_s: f64,
    /// Seconds spent decoding.
    pub decode_s: f64,
    /// Times this trace was preempted.
    pub preemptions: usize,
    /// (token index, running mean score) at each scored boundary.
    pub dynamics: Vec<(u64, f64)>,
}

/// Outcome of one question (the row unit of every table).
#[derive(Debug, Clone)]
pub struct QuestionResult {
    /// Question index within the benchmark.
    pub qid: usize,
    /// Did the voted answer match ground truth?
    pub correct: bool,
    /// Voted answer (None = every trace abstained).
    pub chosen: Option<u32>,
    /// End-to-end latency of the question, seconds.
    pub latency_s: f64,
    /// Initial prefill time, seconds (folded into `latency_s`).
    pub prefill_s: f64,
    /// Total generated tokens across all traces (Table 1's Tok column).
    pub gen_tokens: u64,
    /// Mean per-trace wait seconds (Fig 2c's per-trace view).
    pub mean_wait_s: f64,
    /// Mean per-trace decode seconds.
    pub mean_decode_s: f64,
    /// Engine-timeline decomposition (Table 3's view): wall-clock during
    /// which the waiting queue was non-empty vs empty.
    pub engine_wait_s: f64,
    /// Wall-clock with an empty waiting queue (see `engine_wait_s`).
    pub engine_decode_s: f64,
    /// Total preemption events.
    pub n_preemptions: usize,
    /// Traces removed by pruning policies.
    pub n_pruned: usize,
    /// Traces stopped early by DeepConf's confidence check.
    pub n_early_stopped: usize,
    /// DeepConf stage split: (warmup latency, prune-stage latency).
    pub stage_latency: Option<(f64, f64)>,
    /// DeepConf stage wait/decode means: ((w_wait, w_dec), (p_wait, p_dec)).
    pub stage_wait_decode: Option<((f64, f64), (f64, f64))>,
    /// Per-trace outcomes, in trace-index order.
    pub traces: Vec<TraceOutcome>,
}

struct SimTrace {
    spec: TraceSpec,
    st: TraceState,
    /// DeepConf online stage: subject to early termination.
    monitored: bool,
    dynamics: Vec<(u64, f64)>,
}

/// Reusable hot-path state for [`DesEngine::run_question_with`]: the
/// incremental [`sched::EventIndex`] over the running set, the per-event
/// running-set snapshot, cached next-boundary lookups, lazy-accrual
/// settle marks, and scorer activations. The event loop allocates
/// nothing once these are warm; keep one `Scratch` per worker thread and
/// reuse it across questions (`util::pool::parallel_map_with`).
#[derive(Debug, Default)]
pub struct Scratch {
    /// Incremental index over the running set: O(1) `d_event` /
    /// context-size peeks and closed-form memory-horizon probes,
    /// updated only at admissions, crossings, and removals.
    index: sched::EventIndex,
    /// Snapshot of the index's running set for the current event (so
    /// boundary processing can mutate the index while iterating).
    /// `u32` trace ids, matching the index's arena layout.
    running: Vec<u32>,
    /// Next step boundary per trace index (mirror of
    /// `spec.step_ends[st.next_step]`, updated at crossings).
    next_end: Vec<u64>,
    /// Lazy-accrual marks: wall-clock up to which each trace's wait /
    /// decode time has been settled ([`sched::settle`]).
    last_settle: Vec<f64>,
    /// Per-worker signal scratch (hidden-state / activation buffers) —
    /// the only mutable state a [`TraceSignal`] may touch.
    sig: SignalScratch,
    /// Attached event recorder (`None` — the default — is the zero-cost
    /// disabled path: one branch per emission site, no event
    /// construction). Recorders observe; they never influence
    /// scheduling, and results are bit-identical with one attached.
    pub rec: Option<Box<dyn Recorder>>,
    /// External request id stamped on emitted events (the qid of the
    /// question currently running).
    rid: usize,
}

impl Scratch {
    /// Empty scratch; buffers warm up on first use.
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Emit one event if a recorder is attached. The builder receives
    /// the current question's external rid; it runs only on the enabled
    /// path.
    #[inline]
    fn emit(&mut self, build: impl FnOnce(usize) -> SimEvent) {
        if let Some(rec) = self.rec.as_mut() {
            rec.record(build(self.rid));
        }
    }
}

/// The engine.
pub struct DesEngine<'a> {
    cfg: &'a SimConfig,
    gen: &'a TraceGen,
    scorer: &'a StepScorer,
    /// The pruning signal built from `cfg.signal` (owned, so engines
    /// shared across worker threads need no synchronization beyond
    /// `TraceSignal: Send + Sync`).
    signal: Box<dyn TraceSignal>,
    profile: ModelProfile,
}

impl<'a> DesEngine<'a> {
    /// Bind a configuration to a trace generator and step scorer.
    pub fn new(cfg: &'a SimConfig, gen: &'a TraceGen, scorer: &'a StepScorer) -> Self {
        DesEngine {
            cfg,
            gen,
            scorer,
            signal: cfg.signal.build(scorer),
            profile: ModelProfile::get(cfg.model),
        }
    }

    fn kv_manager(&self) -> KvCacheManager {
        let gpu = GpuSpec::gh200(self.cfg.mem_util);
        let blocks = gpu.kv_capacity_blocks(
            self.profile.weight_bytes,
            self.profile.activation_bytes,
            self.profile.kv_bytes_per_token,
            self.cfg.block_size,
        );
        // This question's share of the pool under whole-benchmark
        // submission (profiles::BenchProfile::eval_concurrency).
        let share = (blocks as f64 / self.gen.bench.eval_concurrency) as usize;
        KvCacheManager::new(share.max(1), self.cfg.block_size)
    }

    /// Simulate one question end to end.
    pub fn run_question(&self, qid: usize) -> QuestionResult {
        let mut scratch = Scratch::new();
        self.run_question_with(qid, &mut scratch)
    }

    /// Like [`run_question`](Self::run_question) with caller-owned
    /// scratch, so batch drivers reuse the hot-path buffers across
    /// questions. Results are identical either way.
    pub fn run_question_with(&self, qid: usize, scratch: &mut Scratch) -> QuestionResult {
        scratch.rid = qid;
        let q = self.gen.question(qid);
        let n = if self.cfg.method == Method::Cot { 1 } else { self.cfg.n_traces };
        let mut rng = Rng::new(self.cfg.seed ^ (qid as u64).wrapping_mul(0x2545F4914F6CDD1D));

        let mut traces: Vec<SimTrace> = (0..n)
            .map(|i| SimTrace {
                spec: self.gen.trace(&q, i),
                st: TraceState::new(i as u64, self.cfg.params.deepconf_window),
                monitored: false,
                dynamics: Vec::new(),
            })
            .collect();

        let mut kv = self.kv_manager();
        let mut clock = 0.0;
        let mut stage_latency = None;
        let mut stage_wait_decode = None;
        let mut engine_split = (0.0, 0.0);

        if self.cfg.method == Method::DeepConf {
            let n_init = self.cfg.params.deepconf_warmup_for_budget(n);
            // Stage 1: warmup traces to completion (SC mechanics).
            let warm: Vec<usize> = (0..n_init).collect();
            let mut warm_split = (0.0, 0.0);
            self.run_phase(&q, &mut traces, &warm, &mut kv, &mut clock, None, &mut rng, &mut warm_split, scratch);
            let warm_latency = clock;
            let (w_wait, w_dec) = warm_split;
            // Threshold from the warmup set's *lowest group confidence*
            // statistic (the same statistic the online check uses):
            // DeepConf-low keeps only traces above the top-10% level.
            let confs: Vec<f64> = traces[..n_init]
                .iter()
                .map(|t| {
                    t.st.min_window_confidence()
                        .unwrap_or_else(|| t.st.mean_confidence(self.cfg.params.default_score))
                })
                .collect();
            let threshold = percentile(&confs, 100.0 * (1.0 - self.cfg.params.deepconf_keep_top));
            // Stage 2: remaining traces with online early termination.
            let online: Vec<usize> = (n_init..n).collect();
            for &i in &online {
                traces[i].monitored = true;
            }
            let t0 = clock;
            let mut prune_split = (0.0, 0.0);
            self.run_phase(&q, &mut traces, &online, &mut kv, &mut clock, Some(threshold), &mut rng, &mut prune_split, scratch);
            stage_latency = Some((warm_latency, clock - t0));
            let (p_wait, p_dec) = prune_split;
            stage_wait_decode = Some(((w_wait, w_dec), (p_wait, p_dec)));
            engine_split = (warm_split.0 + prune_split.0, warm_split.1 + prune_split.1);
        } else {
            let all: Vec<usize> = (0..n).collect();
            self.run_phase(&q, &mut traces, &all, &mut kv, &mut clock, None, &mut rng, &mut engine_split, scratch);
        }

        self.finish(qid, &q, traces, clock, engine_split, stage_latency, stage_wait_decode)
    }

    /// score_t under the configured aggregation (paper: running mean).
    fn agg_score(&self, st: &TraceState) -> f64 {
        let d = self.cfg.params.default_score;
        match self.cfg.score_agg {
            ScoreAgg::Mean => st.mean_score(d),
            ScoreAgg::Last => st.last_score(d),
            ScoreAgg::Ema => st.ema_score(d),
        }
    }

    /// Should this run compute step scores / confidences?
    fn needs_scores(&self) -> bool {
        self.cfg.score_all || self.cfg.method == Method::Step
    }

    fn needs_conf(&self) -> bool {
        self.cfg.score_all || self.cfg.method == Method::DeepConf
    }

    /// Run one generation phase over `phase` (indices into `traces`).
    #[allow(clippy::too_many_arguments)]
    fn run_phase(
        &self,
        q: &Question,
        traces: &mut [SimTrace],
        phase: &[usize],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        conf_threshold: Option<f64>,
        rng: &mut Rng,
        engine_split: &mut (f64, f64),
        scratch: &mut Scratch,
    ) {
        let tm = self.profile.timing;
        let params = &self.cfg.params;
        macro_rules! engine_accrue {
            ($wq:expr, $dt:expr) => {
                if $wq.is_empty() {
                    engine_split.1 += $dt;
                } else {
                    engine_split.0 += $dt;
                }
            };
        }

        // Warm the reusable hot-path state (no per-event allocations).
        scratch.sig.h.resize(self.gen.gen.d, 0.0);
        scratch.sig.z.resize(self.scorer.hidden, 0.0);
        scratch.next_end.resize(traces.len(), 0);
        scratch.last_settle.resize(traces.len(), 0.0);
        for &i in phase {
            scratch.next_end[i] = traces[i].spec.step_ends[traces[i].st.next_step];
        }
        // No quotas in the single-question regime: pool-wide demand only.
        scratch.index.reset(self.cfg.block_size, false);

        // --- admission: prefill prompts (waiting queue if memory-bound;
        // FIFO resume via the shared scheduler core).
        let mut wait_q = WaitQueue::new();
        let mut admitted = 0usize;
        for &i in phase {
            let need = kv.blocks_needed_for_new(q.prompt_tokens);
            if kv.can_allocate(need) {
                kv.allocate_seq(traces[i].st.id, q.prompt_tokens);
                traces[i].st.status = TraceStatus::Running;
                scratch.index.insert(
                    i as u32,
                    0,
                    q.prompt_tokens as u64,
                    scratch.next_end[i] - traces[i].st.generated,
                );
                admitted += 1;
            } else {
                traces[i].st.status = TraceStatus::Preempted;
                wait_q.push_back(i);
            }
        }
        let prefill_dt = tm.prefill(q.prompt_tokens * admitted.max(1));
        *clock += prefill_dt;
        engine_accrue!(wait_q, prefill_dt);
        // Lazy accrual: the phase's traces start their settle windows
        // after the admission prefill (queued ones begin waiting now).
        for &i in phase {
            scratch.last_settle[i] = *clock;
        }
        let t_admit = *clock;
        scratch.emit(|rid| {
            SimEvent::new(t_admit, EventKind::Admit { traces: admitted }).rid(rid)
        });
        let mut boundaries_crossed: usize = 0;
        let mut next_slim_check: usize = params.slim_check_interval_steps * phase.len().max(1);

        loop {
            if scratch.index.running() == 0 {
                if wait_q.is_empty() {
                    break;
                }
                // Everything is parked: resume the first queued trace (in
                // FIFO order) whose prefix fits. Only when *no* queued
                // trace can ever fit again is the head dropped — it
                // counts as pruned like any other non-voluntary removal.
                let resumed = self
                    .resume_first_fit(q, traces, kv, clock, &mut wait_q, scratch, engine_split);
                if !resumed {
                    let head = wait_q.pop_front().unwrap();
                    let t = &mut traces[head];
                    sched::settle(&mut t.st, &mut scratch.last_settle[head], *clock);
                    t.st.status = TraceStatus::Pruned;
                    t.st.finish_clock = *clock;
                    let t_now = *clock;
                    scratch.emit(|rid| {
                        SimEvent::new(t_now, EventKind::Prune)
                            .rid(rid)
                            .trace(head)
                            .cause("stall-drop")
                    });
                }
                continue;
            }
            // Snapshot the maintained running set (ascending trace
            // order, the historical scan order) so boundary processing
            // can mutate the index while iterating.
            scratch.running.clear();
            scratch.running.extend_from_slice(scratch.index.tids());

            let b = scratch.running.len();

            // ---- event horizon: O(1) peek at the maintained min.
            let d_event = scratch.index.d_event().expect("running traces are indexed");
            debug_assert!(d_event >= 1);

            // ---- memory horizon: largest d with block demand <= free,
            // every probe a closed-form histogram fold.
            let free = kv.free_blocks() as u64;
            let index = &scratch.index;
            let d_mem = sched::max_fitting(d_event, |d| index.pool_demand(d) <= free);
            if d_mem == 0 {
                self.memory_event(traces, kv, clock, &mut wait_q, rng, scratch);
                continue;
            }
            let d = d_event.min(d_mem);

            // ---- advance time + tokens (lazy accrual: the open settle
            // windows absorb `dt`).
            let k0 = scratch.index.resident_tokens() as usize;
            let dt = tm.decode_interval(b, k0, d);
            *clock += dt;
            engine_accrue!(wait_q, dt);
            for &i in &scratch.running {
                let t = &mut traces[i as usize];
                t.st.generated += d;
                let ok = kv.append_tokens(t.st.id, d as usize);
                debug_assert!(ok, "memory horizon must guarantee the append");
            }
            scratch.index.advance(d);

            // ---- boundary / completion events.
            let mut freed_any = false;
            for &i in &scratch.running {
                let iu = i as usize;
                let t = &mut traces[iu];
                if t.st.generated != scratch.next_end[iu] {
                    continue;
                }
                let step_n = t.st.next_step + 1;
                t.st.next_step += 1;
                boundaries_crossed += 1;
                if t.st.generated < t.spec.total_tokens {
                    scratch.next_end[iu] = t.spec.step_ends[t.st.next_step];
                }

                if self.needs_scores() {
                    let ctx = StepCtx { gen: self.gen, q, spec: &t.spec, step_n };
                    let s = self.signal.score_step(&ctx, &mut scratch.sig) as f64;
                    t.st.push_score(s);
                    if self.cfg.record_dynamics {
                        t.dynamics.push((t.st.generated, t.st.mean_score(params.default_score)));
                    }
                    let t_now = *clock;
                    let sig = self.signal.name();
                    scratch.emit(|rid| {
                        SimEvent::new(t_now, EventKind::StepScore { score: s })
                            .rid(rid)
                            .trace(iu)
                            .signal(sig)
                    });
                }
                let mut completed_group = None;
                if self.needs_conf() {
                    let c = self.gen.step_confidence(&t.spec, step_n);
                    completed_group = t.st.push_confidence(c);
                }

                if t.st.generated == t.spec.total_tokens {
                    sched::settle(&mut t.st, &mut scratch.last_settle[iu], *clock);
                    t.st.status = TraceStatus::Finished;
                    t.st.finish_clock = *clock;
                    kv.free_seq(t.st.id);
                    scratch.index.remove(i);
                    freed_any = true;
                } else if t.monitored {
                    // DeepConf online check fires when a confidence group
                    // completes (the ~2k-token group granularity).
                    let mut stopped = false;
                    if let (Some(th), Some(wc)) = (conf_threshold, completed_group) {
                        if wc < th {
                            sched::settle(&mut t.st, &mut scratch.last_settle[iu], *clock);
                            t.st.status = TraceStatus::EarlyStopped;
                            t.st.finish_clock = *clock;
                            kv.free_seq(t.st.id);
                            scratch.index.remove(i);
                            freed_any = true;
                            stopped = true;
                        }
                    }
                    if !stopped {
                        scratch
                            .index
                            .set_boundary(i, scratch.next_end[iu] - traces[iu].st.generated);
                    }
                } else {
                    scratch.index.set_boundary(i, scratch.next_end[iu] - traces[iu].st.generated);
                }
            }

            // ---- Slim-SC periodic similarity pruning.
            if self.cfg.method == Method::SlimSc && boundaries_crossed >= next_slim_check {
                next_slim_check += params.slim_check_interval_steps
                    * phase.iter().filter(|&&i| traces[i].st.status == TraceStatus::Running).count().max(1);
                freed_any |= self.slim_check(traces, phase, kv, clock, rng, scratch);
            }

            if freed_any {
                while self.try_resume(q, traces, kv, clock, &mut wait_q, scratch, engine_split) {}
            }
        }
    }

    /// Memory saturated: prune (STEP) or preempt (vLLM default). Victim
    /// selection goes through the shared scheduler core so the serving
    /// engines apply the identical rules; the victim set is the
    /// snapshot in `scratch.running`.
    fn memory_event(
        &self,
        traces: &mut [SimTrace],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        wait_q: &mut WaitQueue,
        _rng: &mut Rng,
        scratch: &mut Scratch,
    ) {
        let free_now = kv.free_blocks();
        let t_now = *clock;
        scratch.emit(|rid| {
            SimEvent::new(t_now, EventKind::MemoryEvent { free_blocks: free_now }).rid(rid)
        });
        let running: &[u32] = &scratch.running;
        match self.cfg.method {
            Method::Step => {
                // Algorithm 1: prune argmin score_t, release KV at once.
                // (VictimPolicy ablates the argmin choice.)
                let victim = match self.cfg.victim {
                    VictimPolicy::LowestScore => sched::lowest_score_victim(
                        running,
                        |_| true,
                        |i| self.agg_score(&traces[i as usize].st),
                    )
                    .expect("memory event with empty running set"),
                    VictimPolicy::Random => running[_rng.below(running.len())],
                    VictimPolicy::Youngest => {
                        sched::youngest_victim(running, |_| true, |i| {
                            traces[i as usize].st.generated
                        })
                        .expect("memory event with empty running set")
                    }
                    VictimPolicy::OracleIncorrect => running
                        .iter()
                        .copied()
                        .find(|&i| !traces[i as usize].spec.label)
                        .unwrap_or_else(|| {
                            sched::youngest_victim(running, |_| true, |i| {
                                traces[i as usize].st.generated
                            })
                            .unwrap()
                        }),
                };
                let t = &mut traces[victim as usize];
                sched::settle(&mut t.st, &mut scratch.last_settle[victim as usize], *clock);
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = *clock;
                kv.free_seq(t.st.id);
                scratch.index.remove(victim);
                // Memory prunes are the signal-driven removals: stamp
                // the signal whose scores selected the victim.
                let sig = self.signal.name();
                scratch.emit(|rid| {
                    SimEvent::new(t_now, EventKind::Prune)
                        .rid(rid)
                        .trace(victim as usize)
                        .cause("memory")
                        .signal(sig)
                });
            }
            _ => {
                // vLLM preemption: evict the youngest running trace
                // (cheapest recompute), FIFO resume.
                let victim = sched::youngest_victim(running, |_| true, |i| {
                    traces[i as usize].st.generated
                })
                .expect("memory event with empty running set");
                let t = &mut traces[victim as usize];
                sched::settle(&mut t.st, &mut scratch.last_settle[victim as usize], *clock);
                t.st.status = TraceStatus::Preempted;
                t.st.preemptions += 1;
                kv.free_seq(t.st.id);
                scratch.index.remove(victim);
                wait_q.push_back(victim as usize);
                scratch.emit(|rid| {
                    SimEvent::new(t_now, EventKind::Preempt)
                        .rid(rid)
                        .trace(victim as usize)
                        .cause("memory")
                });
            }
        }
    }

    /// Resume the waiting-queue head if its whole prefix fits (plus one
    /// block of headroom) — vLLM's FCFS resume rule for the normal path
    /// where running traces free memory as they finish
    /// ([`WaitQueue::pop_head_if`]).
    #[allow(clippy::too_many_arguments)]
    fn try_resume(
        &self,
        q: &Question,
        traces: &mut [SimTrace],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        wait_q: &mut WaitQueue,
        scratch: &mut Scratch,
        engine_split: &mut (f64, f64),
    ) -> bool {
        let Some(head) = wait_q.pop_head_if(|idx| self.resume_fits(q, traces, kv, idx))
        else {
            return false;
        };
        self.admit_resumed(q, traces, kv, clock, wait_q, scratch, engine_split, head);
        true
    }

    /// Stalled-engine resume: nothing is running, so strict head-of-line
    /// FCFS would wedge on an oversized head while shorter queued traces
    /// could still make progress. Resume the *first queued trace in FIFO
    /// order* whose prefix fits ([`WaitQueue::pop_first_fit`]); false
    /// only when none fits (the caller then drops the head as pruned).
    #[allow(clippy::too_many_arguments)]
    fn resume_first_fit(
        &self,
        q: &Question,
        traces: &mut [SimTrace],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        wait_q: &mut WaitQueue,
        scratch: &mut Scratch,
        engine_split: &mut (f64, f64),
    ) -> bool {
        let Some(idx) = wait_q.pop_first_fit(|idx| self.resume_fits(q, traces, kv, idx))
        else {
            return false;
        };
        self.admit_resumed(q, traces, kv, clock, wait_q, scratch, engine_split, idx);
        true
    }

    /// Would resuming trace `idx` fit right now (+1 block of headroom)?
    fn resume_fits(&self, q: &Question, traces: &[SimTrace], kv: &KvCacheManager, idx: usize) -> bool {
        let prefix = q.prompt_tokens + traces[idx].st.generated as usize;
        kv.can_allocate(kv.blocks_needed_for_new(prefix) + 1)
    }

    /// Re-admit a dequeued trace. Recompute-on-resume: the prefix KV is
    /// rebuilt by a prefill pass that stalls the engine. The resumed
    /// trace's own reconstruction counts as waiting ([`sched::settle`]
    /// closes its wait window at the post-prefill clock); other live
    /// traces' open windows absorb the stall under their statuses.
    #[allow(clippy::too_many_arguments)]
    fn admit_resumed(
        &self,
        q: &Question,
        traces: &mut [SimTrace],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        wait_q: &WaitQueue,
        scratch: &mut Scratch,
        engine_split: &mut (f64, f64),
        idx: usize,
    ) {
        let prefix = q.prompt_tokens + traces[idx].st.generated as usize;
        kv.allocate_seq(traces[idx].st.id, prefix);
        // Recompute cost: a prefill over the generated prefix.
        let dt = self.profile.timing.prefill(prefix);
        *clock += dt;
        // Recompute happens while (other) traces may still be queued.
        if wait_q.is_empty() {
            engine_split.1 += dt;
        } else {
            engine_split.0 += dt;
        }
        let t = &mut traces[idx];
        sched::settle(&mut t.st, &mut scratch.last_settle[idx], *clock);
        t.st.status = TraceStatus::Running;
        scratch.index.insert(
            idx as u32,
            0,
            prefix as u64,
            scratch.next_end[idx] - t.st.generated,
        );
        let t_now = *clock;
        scratch.emit(|rid| SimEvent::new(t_now, EventKind::Resume).rid(rid).trace(idx));
    }

    /// Slim-SC similarity check (thought level): pair up the active
    /// traces disjointly at random, prune one member of each pair whose
    /// similarity crosses the 0.95 threshold. Similarity is modelled from
    /// answer agreement (chains converging to the same answer read alike)
    /// + gaussian noise, calibrated so a full run prunes a modest
    /// fraction of chains — the paper's Slim-SC saves ~12% of tokens on
    /// DeepSeek/HMMT, not half the pool (DESIGN.md §3).
    fn slim_check(
        &self,
        traces: &mut [SimTrace],
        phase: &[usize],
        kv: &mut KvCacheManager,
        clock: &mut f64,
        rng: &mut Rng,
        scratch: &mut Scratch,
    ) -> bool {
        let threshold = self.cfg.params.slim_similarity_threshold;
        let mut active: Vec<usize> = phase
            .iter()
            .copied()
            .filter(|&i| traces[i].st.status == TraceStatus::Running)
            .collect();
        rng.shuffle(&mut active);
        let mut pruned_any = false;
        for pair in active.chunks_exact(2) {
            let (i, j) = (pair[0], pair[1]);
            let same = traces[i].spec.answer.is_some()
                && traces[i].spec.answer == traces[j].spec.answer;
            let sim = if same {
                rng.normal_with(0.905, 0.025)
            } else {
                rng.normal_with(0.80, 0.03)
            };
            if sim > threshold {
                // Random-pruning variant: drop one of the pair.
                let victim = if rng.bernoulli(0.5) { i } else { j };
                let t = &mut traces[victim];
                sched::settle(&mut t.st, &mut scratch.last_settle[victim], *clock);
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = *clock;
                kv.free_seq(t.st.id);
                scratch.index.remove(victim as u32);
                pruned_any = true;
                let t_now = *clock;
                scratch.emit(|rid| {
                    SimEvent::new(t_now, EventKind::Prune)
                        .rid(rid)
                        .trace(victim)
                        .cause("slim-sc")
                });
            }
        }
        pruned_any
    }

    /// Final aggregation: voting + metrics.
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &self,
        qid: usize,
        _q: &Question,
        traces: Vec<SimTrace>,
        clock: f64,
        engine_split: (f64, f64),
        stage_latency: Option<(f64, f64)>,
        stage_wait_decode: Option<((f64, f64), (f64, f64))>,
    ) -> QuestionResult {
        let default = self.cfg.params.default_score;
        let votes: Vec<Vote> = traces
            .iter()
            .filter_map(|t| {
                let answer = match t.st.status {
                    TraceStatus::Finished => t.spec.answer,
                    _ => None, // pruned / early-stopped traces abstain
                };
                answer?;
                let weight = match self.cfg.method {
                    Method::Step => self.agg_score(&t.st),
                    Method::DeepConf => t.st.mean_confidence(default),
                    _ => 1.0,
                };
                Some(Vote { answer, weight })
            })
            .collect();
        let chosen = weighted_vote(&votes);
        let correct = chosen == Some(0);

        let outcomes: Vec<TraceOutcome> = traces
            .into_iter()
            .map(|t| TraceOutcome {
                label: t.spec.label,
                answer: t.spec.answer,
                status: t.st.status,
                final_score: t.st.mean_score(default),
                mean_confidence: t.st.mean_confidence(default),
                generated: t.st.generated,
                wait_s: t.st.wait_time,
                decode_s: t.st.decode_time,
                preemptions: t.st.preemptions,
                dynamics: t.dynamics,
            })
            .collect();

        let gen_tokens = outcomes.iter().map(|t| t.generated).sum();
        let n = outcomes.len().max(1) as f64;
        QuestionResult {
            qid,
            correct,
            chosen,
            latency_s: clock,
            prefill_s: 0.0,
            gen_tokens,
            mean_wait_s: outcomes.iter().map(|t| t.wait_s).sum::<f64>() / n,
            mean_decode_s: outcomes.iter().map(|t| t.decode_s).sum::<f64>() / n,
            engine_wait_s: engine_split.0,
            engine_decode_s: engine_split.1,
            n_preemptions: outcomes.iter().map(|t| t.preemptions).sum(),
            n_pruned: outcomes
                .iter()
                .filter(|t| t.status == TraceStatus::Pruned)
                .count(),
            n_early_stopped: outcomes
                .iter()
                .filter(|t| t.status == TraceStatus::EarlyStopped)
                .count(),
            stage_latency,
            stage_wait_decode,
            traces: outcomes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::GenParams;

    fn engine_cfg(method: Method) -> SimConfig {
        let mut c = SimConfig::new(ModelId::Qwen3_4B, BenchId::Aime25, method, 16);
        c.seed = 11;
        c
    }

    fn dummy_scorer() -> StepScorer {
        // Scorer that projects onto the signal direction (dim 0 for the
        // default GenParams) — a stand-in for the trained MLP.
        let d = 64;
        let hidden = 2;
        let mut w1 = vec![0.0f32; d * hidden];
        w1[0] = 1.0; // h[0] -> z[0]
        w1[1] = -1.0; // h[0] -> z[1]
        StepScorer::new(d, hidden, w1, vec![0.0; 2], vec![1.0, -1.0], 0.0).unwrap()
    }

    fn run(method: Method) -> QuestionResult {
        let cfg = engine_cfg(method);
        let gen = TraceGen::new(cfg.model, cfg.bench, GenParams::default_d64(), 3);
        let scorer = dummy_scorer();
        DesEngine::new(&cfg, &gen, &scorer).run_question(0)
    }

    #[test]
    fn cot_single_trace() {
        let r = run(Method::Cot);
        assert_eq!(r.traces.len(), 1);
        assert_eq!(r.n_preemptions, 0);
        assert!(r.latency_s > 0.0);
        assert!(r.gen_tokens > 0);
    }

    #[test]
    fn sc_runs_all_traces_to_completion() {
        let r = run(Method::Sc);
        assert_eq!(r.traces.len(), 16);
        for t in &r.traces {
            assert!(matches!(t.status, TraceStatus::Finished));
            assert!(t.generated > 0);
        }
        assert_eq!(r.n_pruned, 0);
    }

    #[test]
    fn step_never_preempts() {
        let r = run(Method::Step);
        assert_eq!(r.n_preemptions, 0, "STEP must eliminate the waiting queue");
        for t in &r.traces {
            assert_eq!(t.wait_s, 0.0);
        }
    }

    #[test]
    fn deepconf_two_stages() {
        let r = run(Method::DeepConf);
        assert!(r.stage_latency.is_some());
        let (warm, prune) = r.stage_latency.unwrap();
        assert!(warm > 0.0 && prune > 0.0);
        assert!((warm + prune - r.latency_s).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Method::Step);
        let b = run(Method::Step);
        assert_eq!(a.latency_s, b.latency_s);
        assert_eq!(a.gen_tokens, b.gen_tokens);
        assert_eq!(a.chosen, b.chosen);
    }

    /// Memory pressure test: tiny memory budget forces events.
    fn pressured(method: Method) -> QuestionResult {
        let mut cfg = engine_cfg(method);
        cfg.mem_util = 0.5;
        cfg.n_traces = 32;
        // Shrink capacity brutally via a fake profile? Easier: use the
        // Phi model (biggest kv/token) + low util on HMMT (long traces).
        cfg.model = ModelId::Phi4_14B;
        cfg.bench = BenchId::Hmmt2425;
        let gen = TraceGen::new(cfg.model, cfg.bench, GenParams::default_d64(), 5);
        let scorer = dummy_scorer();
        DesEngine::new(&cfg, &gen, &scorer).run_question(1)
    }

    #[test]
    fn sc_preempts_under_pressure() {
        let r = pressured(Method::Sc);
        assert!(r.n_preemptions > 0, "expected preemption under 0.5 util");
        assert!(r.mean_wait_s > 0.0);
    }

    #[test]
    fn step_prunes_under_pressure() {
        let r = pressured(Method::Step);
        assert!(r.n_pruned > 0, "expected pruning under 0.5 util");
        assert_eq!(r.n_preemptions, 0);
        assert!(r.mean_wait_s == 0.0);
        // Pruning must save tokens vs SC.
        let sc = pressured(Method::Sc);
        assert!(r.gen_tokens < sc.gen_tokens);
        assert!(r.latency_s < sc.latency_s, "STEP {} vs SC {}", r.latency_s, sc.latency_s);
    }

    #[test]
    fn step_prunes_lower_quality_traces() {
        let r = pressured(Method::Step);
        // Pruned traces should skew incorrect: compare label rate.
        let pruned: Vec<_> = r.traces.iter().filter(|t| t.status == TraceStatus::Pruned).collect();
        let kept: Vec<_> = r.traces.iter().filter(|t| t.status == TraceStatus::Finished).collect();
        if pruned.len() >= 5 && kept.len() >= 5 {
            let pr = pruned.iter().filter(|t| t.label).count() as f64 / pruned.len() as f64;
            let kr = kept.iter().filter(|t| t.label).count() as f64 / kept.len() as f64;
            assert!(kr >= pr, "kept label rate {kr} < pruned {pr}");
        }
    }

    #[test]
    fn slim_sc_prunes_similar() {
        let r = pressured(Method::SlimSc);
        assert!(r.n_pruned > 0, "slim-sc should prune similar traces");
    }

    #[test]
    fn wait_plus_decode_bounded_by_latency() {
        for m in [Method::Sc, Method::Step, Method::SlimSc] {
            let r = pressured(m);
            for t in &r.traces {
                assert!(
                    t.wait_s + t.decode_s <= r.latency_s + 1e-6,
                    "{m:?}: trace lifetime exceeds latency"
                );
            }
        }
    }

    #[test]
    fn tokens_accounted() {
        let r = run(Method::Sc);
        let sum: u64 = r.traces.iter().map(|t| t.generated).sum();
        assert_eq!(sum, r.gen_tokens);
    }

    /// The stalled-resume path must never wedge or leave traces parked:
    /// every trace ends in a terminal state even when the queue's head
    /// cannot fit (the pre-fix code dropped fittable traces instead of
    /// scanning the rest of the queue).
    #[test]
    fn all_traces_reach_terminal_states_under_pressure() {
        for m in [Method::Sc, Method::SlimSc, Method::DeepConf, Method::Step] {
            let r = pressured(m);
            for t in &r.traces {
                assert!(
                    !matches!(t.status, TraceStatus::Running | TraceStatus::Preempted),
                    "{m:?}: trace left non-terminal ({:?})",
                    t.status
                );
            }
        }
    }

    /// Determinism contract: an attached recorder observes the run
    /// without changing a single result bit, and under pressure the
    /// stream carries the memory / prune / step-score kinds.
    #[test]
    fn recorder_is_invisible_to_results_and_sees_pressure() {
        let mut cfg = engine_cfg(Method::Step);
        cfg.mem_util = 0.5;
        cfg.n_traces = 32;
        cfg.model = ModelId::Phi4_14B;
        cfg.bench = BenchId::Hmmt2425;
        let gen = TraceGen::new(cfg.model, cfg.bench, GenParams::default_d64(), 5);
        let scorer = dummy_scorer();
        let engine = DesEngine::new(&cfg, &gen, &scorer);
        let untraced = engine.run_question(1);
        let mut scratch = Scratch::new();
        scratch.rec = Some(Box::new(crate::obs::EventBuf::unbounded()));
        let traced = engine.run_question_with(1, &mut scratch);
        assert_eq!(untraced.latency_s, traced.latency_s);
        assert_eq!(untraced.gen_tokens, traced.gen_tokens);
        assert_eq!(untraced.chosen, traced.chosen);
        assert_eq!(untraced.n_pruned, traced.n_pruned);
        let mut rec = scratch.rec.take().unwrap();
        let events = rec.drain();
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::MemoryEvent { .. })));
        assert!(events.iter().any(|e| matches!(e.kind, EventKind::Prune)));
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::StepScore { .. })));
        assert!(events.iter().all(|e| e.rid == Some(1)), "rid stamps the qid");
    }

    /// Reusing one Scratch across questions must not change any result.
    #[test]
    fn scratch_reuse_is_bit_identical() {
        for method in [Method::Sc, Method::Step, Method::DeepConf] {
            let cfg = engine_cfg(method);
            let gen = TraceGen::new(cfg.model, cfg.bench, GenParams::default_d64(), 3);
            let scorer = dummy_scorer();
            let engine = DesEngine::new(&cfg, &gen, &scorer);
            let mut scratch = Scratch::new();
            for qid in 0..3 {
                let fresh = engine.run_question(qid);
                let reused = engine.run_question_with(qid, &mut scratch);
                assert_eq!(fresh.latency_s, reused.latency_s);
                assert_eq!(fresh.gen_tokens, reused.gen_tokens);
                assert_eq!(fresh.chosen, reused.chosen);
                assert_eq!(fresh.n_pruned, reused.n_pruned);
            }
        }
    }
}
