//! Latency model for the simulated serving engine.
//!
//! One continuous-batching decode iteration (every running trace emits one
//! token) costs
//!
//! ```text
//! T_iter(B, K) = c0 + c1 * B + c2 * K          (seconds)
//! ```
//!
//! where `B` is the running batch and `K` the total resident KV tokens:
//! `c0` captures fixed per-iteration overhead (kernel launches, sampler),
//! `c1` per-sequence compute (MLP/QKV GEMM rows), and `c2` the KV-cache
//! bandwidth term (attention reads the whole resident cache each
//! iteration). Prefill / recompute-on-resume costs `p0 + p1 * tokens`.
//!
//! Over an interval of `d` iterations with a fixed live set, K grows by B
//! per iteration, so the total time has the closed form used by
//! [`TimingModel::decode_interval`] — this is what lets the discrete-event
//! simulator jump between events in O(1) instead of iterating tokens.
//! Coefficients per model are calibrated against Table 1's CoT/SC rows
//! (see `sim::profiles`).

/// Per-model latency coefficients (seconds).
#[derive(Debug, Clone, Copy)]
pub struct TimingModel {
    /// Fixed per-iteration overhead (kernel launches, sampler).
    pub c0: f64,
    /// Per-sequence compute term (MLP/QKV GEMM rows).
    pub c1: f64,
    /// KV-bandwidth term per resident token.
    pub c2: f64,
    /// Fixed prefill overhead.
    pub p0: f64,
    /// Per-token prefill cost.
    pub p1: f64,
}

impl TimingModel {
    /// One decode iteration with batch `b` and `k` resident KV tokens.
    pub fn decode_iter(&self, b: usize, k: usize) -> f64 {
        self.c0 + self.c1 * b as f64 + self.c2 * k as f64
    }

    /// Total wall-clock for `d` iterations starting at `k0` resident
    /// tokens with a fixed running batch `b` (K grows by b per iter):
    /// sum_{i=0..d-1} [c0 + c1 b + c2 (k0 + i b)].
    pub fn decode_interval(&self, b: usize, k0: usize, d: u64) -> f64 {
        if d == 0 || b == 0 {
            return 0.0;
        }
        let df = d as f64;
        let bf = b as f64;
        df * (self.c0 + self.c1 * bf + self.c2 * k0 as f64)
            + self.c2 * bf * df * (df - 1.0) / 2.0
    }

    /// Prefill (or recompute-on-resume) of `tokens` prompt tokens.
    pub fn prefill(&self, tokens: usize) -> f64 {
        self.p0 + self.p1 * tokens as f64
    }

    /// The same model on hardware `scale`× slower than the calibrated
    /// baseline: every coefficient multiplies by `scale` (> 1 = slower
    /// GPU, < 1 = faster). `scale == 1.0` is bit-exact identity —
    /// multiplying a finite f64 by 1.0 never changes its bits — which
    /// is what keeps uniform heterogeneous-pool configurations
    /// byte-identical to the unscaled path.
    pub fn scaled(&self, scale: f64) -> TimingModel {
        TimingModel {
            c0: self.c0 * scale,
            c1: self.c1 * scale,
            c2: self.c2 * scale,
            p0: self.p0 * scale,
            p1: self.p1 * scale,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TM: TimingModel =
        TimingModel { c0: 0.005, c1: 1e-4, c2: 3e-8, p0: 0.01, p1: 1e-5 };

    #[test]
    fn interval_matches_iterated_sum() {
        for &(b, k0, d) in &[(1usize, 0usize, 10u64), (64, 400_000, 137), (8, 1000, 1)] {
            let mut total = 0.0;
            let mut k = k0;
            for _ in 0..d {
                total += TM.decode_iter(b, k);
                k += b;
            }
            let closed = TM.decode_interval(b, k0, d);
            assert!(
                (total - closed).abs() < 1e-9 * total.max(1.0),
                "b={b} k0={k0} d={d}: {total} vs {closed}"
            );
        }
    }

    #[test]
    fn zero_cases() {
        assert_eq!(TM.decode_interval(0, 100, 10), 0.0);
        assert_eq!(TM.decode_interval(4, 100, 0), 0.0);
    }

    #[test]
    fn monotonic_in_batch_and_kv() {
        assert!(TM.decode_iter(2, 100) > TM.decode_iter(1, 100));
        assert!(TM.decode_iter(1, 200) > TM.decode_iter(1, 100));
    }

    #[test]
    fn prefill_linear() {
        assert!((TM.prefill(100) - (0.01 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn scaled_multiplies_every_coefficient() {
        let s = TM.scaled(2.5);
        let (a, b) = (s.decode_iter(4, 100), 2.5 * TM.decode_iter(4, 100));
        assert!((a - b).abs() < 1e-15, "{a} vs {b}");
        let (a, b) = (s.prefill(64), 2.5 * TM.prefill(64));
        assert!((a - b).abs() < 1e-15, "{a} vs {b}");
        // scale 1.0 is a bit-exact identity (the uniform-pool contract).
        let id = TM.scaled(1.0);
        assert_eq!(id.c0.to_bits(), TM.c0.to_bits());
        assert_eq!(id.c2.to_bits(), TM.c2.to_bits());
        assert_eq!(id.p1.to_bits(), TM.p1.to_bits());
    }
}
