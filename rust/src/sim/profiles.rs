//! Model + benchmark profiles: the calibration constants behind the
//! paper-scale experiments (DESIGN.md §3 substitution table).
//!
//! Model profiles carry real architecture numbers (KV bytes/token, weight
//! bytes) for the three paper models, plus timing coefficients calibrated
//! so the CoT and SC rows of Table 1 land near the paper's latencies.
//! Benchmark profiles carry per-(model, benchmark) difficulty/length
//! targets taken from Table 1's CoT rows; everything else (SC gains,
//! method orderings, wait/decode splits) must *emerge* from the engine
//! mechanics rather than being set directly.

use super::timing::TimingModel;

/// The three reasoning models of §5.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    /// Qwen3-4B-Thinking-2507.
    Qwen3_4B,
    /// DeepSeek-R1-0528-Qwen3-8B.
    DeepSeek8B,
    /// Phi-4-reasoning-plus (14B).
    Phi4_14B,
}

impl ModelId {
    /// Every model, in the paper's column order.
    pub const ALL: [ModelId; 3] = [ModelId::Qwen3_4B, ModelId::DeepSeek8B, ModelId::Phi4_14B];

    /// Parse a CLI/config model name (case-insensitive, with aliases).
    pub fn parse(s: &str) -> Option<ModelId> {
        match s.to_ascii_lowercase().as_str() {
            "qwen3-4b" | "qwen" | "qwen3-4b-thinking-2507" => Some(ModelId::Qwen3_4B),
            "deepseek-8b" | "deepseek" | "deepseek-r1-0528-qwen3-8b" => Some(ModelId::DeepSeek8B),
            "phi-4" | "phi" | "phi-4-reasoning-plus" => Some(ModelId::Phi4_14B),
            _ => None,
        }
    }
}

/// Serving-relevant description of a reasoning LLM.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    /// Which model this profile describes.
    pub id: ModelId,
    /// Full model name as published.
    pub name: &'static str,
    /// Last-layer hidden size (the step scorer's input dim in the paper).
    pub hidden_dim: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// bf16 weights resident in HBM.
    pub weight_bytes: u64,
    /// KV bytes per token: layers * 2 * kv_heads * head_dim * 2 (bf16).
    pub kv_bytes_per_token: u64,
    /// Activation/workspace slack subtracted from the KV budget.
    pub activation_bytes: u64,
    /// Calibrated serving-latency coefficients.
    pub timing: TimingModel,
    /// Generation cap (Appendix B: 64k Qwen/DeepSeek, 32k Phi).
    pub max_gen_tokens: usize,
    /// Appendix-B sampling temperature (metadata; sampling itself happens
    /// in the e2e backend, the simulator consumes outcome distributions).
    pub temperature: f64,
    /// Appendix-B nucleus (top-p) threshold.
    pub top_p: f64,
    /// Appendix-B top-k cutoff.
    pub top_k: usize,
}

impl ModelProfile {
    /// The calibrated profile of a model.
    pub fn get(id: ModelId) -> ModelProfile {
        match id {
            // Qwen3-4B-Thinking-2507: 36 layers, GQA 8 kv-heads x 128.
            ModelId::Qwen3_4B => ModelProfile {
                id,
                name: "Qwen3-4B-Thinking-2507",
                hidden_dim: 2560,
                n_layers: 36,
                weight_bytes: 8 << 30,
                kv_bytes_per_token: 36 * 2 * 8 * 128 * 2, // 147 KB
                activation_bytes: 10 << 30,
                timing: TimingModel {
                    c0: 0.0052,
                    c1: 4.0e-5,
                    c2: 5.4e-8,
                    p0: 0.015,
                    p1: 6.0e-5,
                },
                max_gen_tokens: 64_000,
                temperature: 0.6,
                top_p: 0.95,
                top_k: 20,
            },
            // DeepSeek-R1-0528-Qwen3-8B: Qwen3-8B base, 36 layers, 8x128 kv.
            ModelId::DeepSeek8B => ModelProfile {
                id,
                name: "DeepSeek-R1-0528-Qwen3-8B",
                hidden_dim: 4096,
                n_layers: 36,
                weight_bytes: 16 << 30,
                kv_bytes_per_token: 36 * 2 * 8 * 128 * 2, // 147 KB
                activation_bytes: 10 << 30,
                timing: TimingModel {
                    c0: 0.0062,
                    c1: 6.0e-5,
                    c2: 5.5e-8,
                    p0: 0.02,
                    p1: 1.0e-4,
                },
                max_gen_tokens: 64_000,
                temperature: 0.6,
                top_p: 0.95,
                top_k: 20,
            },
            // Phi-4-reasoning-plus: 14B dense, 40 layers, 10x128 kv.
            ModelId::Phi4_14B => ModelProfile {
                id,
                name: "Phi-4-reasoning-plus",
                hidden_dim: 5120,
                n_layers: 40,
                weight_bytes: 28 << 30,
                kv_bytes_per_token: 40 * 2 * 10 * 128 * 2, // 205 KB
                activation_bytes: 10 << 30,
                timing: TimingModel {
                    c0: 0.0095,
                    c1: 9.0e-5,
                    c2: 8.0e-8,
                    p0: 0.03,
                    p1: 1.5e-4,
                },
                max_gen_tokens: 32_000,
                temperature: 0.8,
                top_p: 0.95,
                top_k: 50,
            },
        }
    }
}

/// The six evaluation benchmarks of §5.1 (HMMT-24/25 reported jointly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BenchId {
    /// AIME 2025 (30 competition-math questions).
    Aime25,
    /// HMMT February 2024 + 2025 (60 questions, reported jointly).
    Hmmt2425,
    /// GPQA-Diamond (198 graduate-level MCQs).
    GpqaDiamond,
    /// EquiBench (program-equivalence, binary choice).
    EquiBench,
    /// DivLogicEval (diverse logic MCQs).
    DivLogicEval,
}

impl BenchId {
    /// Every benchmark, in the paper's column order.
    pub const ALL: [BenchId; 5] = [
        BenchId::Aime25,
        BenchId::Hmmt2425,
        BenchId::GpqaDiamond,
        BenchId::EquiBench,
        BenchId::DivLogicEval,
    ];

    /// Parse a CLI/config benchmark name (case-insensitive, aliases).
    pub fn parse(s: &str) -> Option<BenchId> {
        match s.to_ascii_lowercase().as_str() {
            "aime-25" | "aime25" | "aime" => Some(BenchId::Aime25),
            "hmmt" | "hmmt-24/25" | "hmmt2425" | "hmmt-25" => Some(BenchId::Hmmt2425),
            "gpqa" | "gpqa-d" | "gpqa-diamond" => Some(BenchId::GpqaDiamond),
            "equibench" | "equi" => Some(BenchId::EquiBench),
            "divlogiceval" | "divlogic" => Some(BenchId::DivLogicEval),
            _ => None,
        }
    }

    /// Display name (the paper's column label).
    pub fn name(&self) -> &'static str {
        match self {
            BenchId::Aime25 => "AIME-25",
            BenchId::Hmmt2425 => "HMMT-24/25",
            BenchId::GpqaDiamond => "GPQA-D",
            BenchId::EquiBench => "EquiBench",
            BenchId::DivLogicEval => "DivLogicEval",
        }
    }
}

/// Benchmark-level workload description.
#[derive(Debug, Clone)]
pub struct BenchProfile {
    /// Which benchmark this profile describes.
    pub id: BenchId,
    /// Question pool size.
    pub n_questions: usize,
    /// 0 = open numeric answer (competition math); else MCQ choice count.
    pub n_choices: usize,
    /// Zipf exponent of the wrong-answer distribution (higher = more
    /// concentrated wrong answers = harder for majority voting).
    pub wrong_answer_zipf: f64,
    /// Number of distinct wrong-answer candidates.
    pub wrong_answer_pool: usize,
    /// Mean prompt length in tokens.
    pub prompt_tokens: usize,
    /// Beta concentration for per-question solve rates. Lower = more
    /// bimodal question difficulty = larger SC-over-CoT gains.
    pub difficulty_kappa: f64,
    /// Mean generated tokens per reasoning step (paper App. D: ~1e2).
    pub tokens_per_step: f64,
    /// Evaluation-harness concurrency: how many questions' trace groups
    /// share the GPU at once. The paper submits whole benchmarks to
    /// vLLM, so on short-trace benchmarks (GPQA/EquiBench/DivLogicEval)
    /// neighbouring questions keep the KV pool saturated even though a
    /// single question would fit — without this the memory trigger never
    /// fires there and STEP degenerates to SC, contradicting Table 1.
    pub eval_concurrency: f64,
}

impl BenchProfile {
    /// The calibrated profile of a benchmark.
    pub fn get(id: BenchId) -> BenchProfile {
        match id {
            BenchId::Aime25 => BenchProfile {
                id,
                n_questions: 30,
                n_choices: 0,
                wrong_answer_zipf: 1.1,
                wrong_answer_pool: 40,
                prompt_tokens: 120,
                difficulty_kappa: 1.1,
                tokens_per_step: 115.0,
                eval_concurrency: 1.0,
            },
            BenchId::Hmmt2425 => BenchProfile {
                id,
                n_questions: 60, // HMMT-24 + HMMT-25, 30 each
                n_choices: 0,
                wrong_answer_zipf: 1.1,
                wrong_answer_pool: 40,
                prompt_tokens: 130,
                difficulty_kappa: 1.0,
                tokens_per_step: 115.0,
                eval_concurrency: 1.0,
            },
            BenchId::GpqaDiamond => BenchProfile {
                id,
                n_questions: 198,
                n_choices: 4,
                wrong_answer_zipf: 1.4,
                wrong_answer_pool: 3,
                prompt_tokens: 600,
                difficulty_kappa: 1.6,
                tokens_per_step: 100.0,
                eval_concurrency: 2.0,
            },
            BenchId::EquiBench => BenchProfile {
                id,
                n_questions: 200,
                n_choices: 2,
                wrong_answer_zipf: 1.0,
                wrong_answer_pool: 1,
                prompt_tokens: 800,
                difficulty_kappa: 1.6,
                tokens_per_step: 95.0,
                eval_concurrency: 2.0,
            },
            BenchId::DivLogicEval => BenchProfile {
                id,
                n_questions: 200,
                n_choices: 6,
                wrong_answer_zipf: 1.3,
                wrong_answer_pool: 5,
                prompt_tokens: 300,
                difficulty_kappa: 1.4,
                tokens_per_step: 100.0,
                eval_concurrency: 2.0,
            },
        }
    }
}

/// Per-(model, benchmark) calibration targets, from Table 1's CoT rows:
/// (mean solve rate, mean generated tokens in thousands).
pub fn cot_calibration(model: ModelId, bench: BenchId) -> (f64, f64) {
    use BenchId::*;
    use ModelId::*;
    match (model, bench) {
        (Qwen3_4B, Aime25) => (0.813, 22.7),
        (Qwen3_4B, Hmmt2425) => (0.517, 28.3),
        (Qwen3_4B, GpqaDiamond) => (0.658, 8.9),
        (Qwen3_4B, EquiBench) => (0.672, 7.8),
        (Qwen3_4B, DivLogicEval) => (0.510, 8.7),
        (DeepSeek8B, Aime25) => (0.775, 26.4),
        (DeepSeek8B, Hmmt2425) => (0.552, 31.5),
        (DeepSeek8B, GpqaDiamond) => (0.623, 11.4),
        (DeepSeek8B, EquiBench) => (0.695, 5.3),
        (DeepSeek8B, DivLogicEval) => (0.390, 5.7),
        (Phi4_14B, Aime25) => (0.783, 16.0),
        (Phi4_14B, Hmmt2425) => (0.552, 21.5),
        (Phi4_14B, GpqaDiamond) => (0.695, 11.9),
        (Phi4_14B, EquiBench) => (0.620, 12.1),
        (Phi4_14B, DivLogicEval) => (0.423, 8.2),
    }
}

/// Length ratio incorrect/correct traces (Fig. 2b: 42.5k vs 35.3k).
pub const INCORRECT_LEN_RATIO: f64 = 1.204;

/// Lognormal sigma of per-trace total lengths.
pub const TRACE_LEN_SIGMA: f64 = 0.30;

/// Lognormal sigma of per-step token counts.
pub const STEP_TOKENS_SIGMA: f64 = 0.45;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_exist_for_all_models() {
        for id in ModelId::ALL {
            let p = ModelProfile::get(id);
            assert!(p.kv_bytes_per_token > 100_000);
            assert!(p.weight_bytes > 1 << 30);
            assert!(p.timing.c0 > 0.0);
        }
    }

    #[test]
    fn kv_bytes_match_arch() {
        // 36 layers * 2 (K,V) * 8 heads * 128 dim * 2 bytes = 147456.
        assert_eq!(ModelProfile::get(ModelId::Qwen3_4B).kv_bytes_per_token, 147_456);
        assert_eq!(ModelProfile::get(ModelId::Phi4_14B).kv_bytes_per_token, 204_800);
    }

    #[test]
    fn calibration_covers_grid() {
        for m in ModelId::ALL {
            for b in BenchId::ALL {
                let (acc, tok) = cot_calibration(m, b);
                assert!((0.0..=1.0).contains(&acc));
                assert!(tok > 1.0 && tok < 50.0);
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(ModelId::parse("qwen3-4b"), Some(ModelId::Qwen3_4B));
        assert_eq!(BenchId::parse("aime-25"), Some(BenchId::Aime25));
        assert_eq!(BenchId::parse("nope"), None);
    }

    #[test]
    fn phi_shorter_cap() {
        assert_eq!(ModelProfile::get(ModelId::Phi4_14B).max_gen_tokens, 32_000);
        assert_eq!(ModelProfile::get(ModelId::Qwen3_4B).max_gen_tokens, 64_000);
    }
}
