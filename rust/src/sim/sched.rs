//! Shared scheduler core of the discrete-event serving engines.
//!
//! The single-question engine ([`crate::sim::des`]), the multi-request
//! serving engine ([`crate::sim::serve`]), and the cluster simulator's
//! per-GPU engines ([`crate::sim::cluster`]) all implement the same
//! vLLM-V1 scheduling mechanics. This module holds the pieces they
//! share, so §4.2 policy fixes land once (the PR-2 debt the ROADMAP
//! records):
//!
//! * [`WaitQueue`] — the FIFO queue of preempted traces with both
//!   resume disciplines: head-of-line FCFS resume for the normal path
//!   where finishing traces free memory, and a first-fit scan for the
//!   stalled-engine path (strict FCFS would wedge on an oversized head
//!   while shorter queued traces could still make progress);
//! * victim selection for memory events — [`lowest_score_victim`]
//!   (STEP, Algorithm 1: argmin aggregated step score) and
//!   [`youngest_victim`] (vLLM preemption: cheapest recompute), both
//!   preserving first-minimum tie-breaking so results are deterministic;
//! * [`max_fitting`] — the monotone binary search behind every memory
//!   and arrival horizon ("largest d that still fits");
//! * recompute accounting — [`accrue`] (engine busy time lands as
//!   decode on running traces and as wait on preempted ones) and
//!   [`charge_resume`] (the resumed trace's own reconstruction counts
//!   as waiting, paper: "resumed with KV cache reconstructed").
//!
//! Everything here is pure bookkeeping over indices and
//! [`TraceState`]s; the engines keep ownership of their trace vectors,
//! pools, and clocks.

use std::collections::VecDeque;

use crate::coordinator::trace::{TraceState, TraceStatus};

/// FIFO waiting queue of preempted trace indices with the two resume
/// disciplines the engines share.
#[derive(Debug, Clone, Default)]
pub struct WaitQueue {
    q: VecDeque<usize>,
}

impl WaitQueue {
    /// An empty queue.
    pub fn new() -> WaitQueue {
        WaitQueue::default()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Queued trace count.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// Enqueue a preempted trace (FIFO order).
    pub fn push_back(&mut self, tid: usize) {
        self.q.push_back(tid);
    }

    /// Dequeue the head unconditionally (the stalled-engine drop path:
    /// nothing fits, the head is removed as pruned).
    pub fn pop_front(&mut self) -> Option<usize> {
        self.q.pop_front()
    }

    /// Head-of-line FCFS resume: pop the head iff `fits(head)` — vLLM's
    /// resume rule for the normal path where finishing traces free
    /// memory. Returns the popped trace index.
    pub fn pop_head_if(&mut self, mut fits: impl FnMut(usize) -> bool) -> Option<usize> {
        let &head = self.q.front()?;
        if fits(head) {
            self.q.pop_front()
        } else {
            None
        }
    }

    /// Stalled-engine resume: pop the *first queued trace in FIFO
    /// order* whose prefix fits. Returns `None` only when nothing fits
    /// (the caller then drops the head as pruned).
    pub fn pop_first_fit(&mut self, mut fits: impl FnMut(usize) -> bool) -> Option<usize> {
        let pos = (0..self.q.len()).find(|&p| fits(self.q[p]))?;
        self.q.remove(pos)
    }
}

/// Largest `d` in `[0, cap]` such that `fits(d)` holds, by binary
/// search over a monotone predicate (`fits(0)` must hold; if `fits(d)`
/// then `fits(d')` for all `d' <= d`). This is the search every memory
/// horizon ("largest token advance whose block demand fits the free
/// pool") and arrival horizon ("largest iteration count within the
/// wall-clock gap") reduces to.
pub fn max_fitting(cap: u64, fits: impl Fn(u64) -> bool) -> u64 {
    if fits(cap) {
        return cap;
    }
    let (mut lo, mut hi) = (0u64, cap); // fits(lo), !fits(hi)
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// STEP's memory-event victim (Algorithm 1): the candidate in
/// `running` passing `in_set` with the lowest aggregated step score.
/// Ties keep the *first* minimum (iteration order), matching the
/// engines' historical `min_by` semantics, so runs stay deterministic.
pub fn lowest_score_victim(
    running: &[usize],
    in_set: impl Fn(usize) -> bool,
    score: impl Fn(usize) -> f64,
) -> Option<usize> {
    running
        .iter()
        .copied()
        .filter(|&i| in_set(i))
        .min_by(|&a, &b| score(a).partial_cmp(&score(b)).unwrap())
}

/// vLLM's preemption victim: the candidate in `running` passing
/// `in_set` with the fewest generated tokens (cheapest recompute).
/// First-minimum tie-breaking, as with [`lowest_score_victim`].
pub fn youngest_victim(
    running: &[usize],
    in_set: impl Fn(usize) -> bool,
    generated: impl Fn(usize) -> u64,
) -> Option<usize> {
    running.iter().copied().filter(|&i| in_set(i)).min_by_key(|&i| generated(i))
}

/// Accrue `dt` seconds of engine busy time (a decode interval, or a
/// prefill stall from admission / recompute-on-resume) onto one trace:
/// running traces accrue decode time (the engine is busy on their
/// behalf), preempted traces accrue wait time, terminal traces nothing.
/// Engines apply this over every live trace whenever the clock moves.
pub fn accrue(st: &mut TraceState, dt: f64) {
    match st.status {
        TraceStatus::Running => st.decode_time += dt,
        TraceStatus::Preempted => st.wait_time += dt,
        _ => {}
    }
}

/// Recompute-on-resume accounting for the resumed trace itself: its KV
/// reconstruction counts as waiting, not decoding (the paper's
/// "resumed with KV cache reconstructed"). The caller has already run
/// [`accrue`] over every trace (which charged this one `dt` of decode
/// as a then-running trace); this moves the charge to waiting.
pub fn charge_resume(st: &mut TraceState, dt: f64) {
    st.decode_time -= dt;
    st.wait_time += dt;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_queue_fifo_and_first_fit() {
        let mut q = WaitQueue::new();
        assert!(q.is_empty());
        q.push_back(3);
        q.push_back(7);
        q.push_back(5);
        assert_eq!(q.len(), 3);
        // Head-of-line resume refuses when the head does not fit.
        assert_eq!(q.pop_head_if(|t| t != 3), None);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_head_if(|t| t == 3), Some(3));
        // First-fit scans past a non-fitting head in FIFO order.
        assert_eq!(q.pop_first_fit(|t| t == 5), Some(5));
        assert_eq!(q.pop_first_fit(|_| false), None);
        assert_eq!(q.pop_front(), Some(7));
        assert!(q.is_empty());
    }

    #[test]
    fn max_fitting_matches_linear_scan() {
        for cap in [1u64, 2, 7, 64, 1000] {
            for cut in 0..=cap {
                let fits = |d: u64| d <= cut;
                assert_eq!(max_fitting(cap, fits), cut.min(cap), "cap={cap} cut={cut}");
            }
        }
        assert_eq!(max_fitting(100, |_| true), 100);
        assert_eq!(max_fitting(100, |d| d == 0), 0);
    }

    #[test]
    fn victims_take_first_minimum() {
        let running = [4usize, 2, 9, 7];
        let scores = |i: usize| match i {
            2 | 9 => 0.25,
            _ => 0.5,
        };
        // Both 2 and 9 tie at the minimum; the first in iteration order
        // wins.
        assert_eq!(lowest_score_victim(&running, |_| true, scores), Some(2));
        assert_eq!(lowest_score_victim(&running, |i| i > 2, scores), Some(9));
        assert_eq!(lowest_score_victim(&running, |_| false, scores), None);

        let gens = |i: usize| if i == 9 || i == 7 { 10 } else { 20 };
        assert_eq!(youngest_victim(&running, |_| true, gens), Some(9));
        assert_eq!(youngest_victim(&running, |i| i != 9, gens), Some(7));
    }

    #[test]
    fn stall_accrual_splits_by_status() {
        let mut sts: Vec<TraceState> = (0..3).map(|i| TraceState::new(i, 4)).collect();
        sts[1].status = TraceStatus::Preempted;
        sts[2].status = TraceStatus::Finished;
        for st in sts.iter_mut() {
            accrue(st, 2.0);
        }
        assert_eq!(sts[0].decode_time, 2.0);
        assert_eq!(sts[1].wait_time, 2.0);
        assert_eq!(sts[2].decode_time + sts[2].wait_time, 0.0);
        // Resume charge moves decode to wait for the resumed trace.
        charge_resume(&mut sts[0], 2.0);
        assert_eq!(sts[0].decode_time, 0.0);
        assert_eq!(sts[0].wait_time, 2.0);
    }
}
