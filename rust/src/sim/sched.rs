//! Shared scheduler core of the discrete-event serving engines.
//!
//! The single-question engine ([`crate::sim::des`]), the multi-request
//! serving engine ([`crate::sim::serve`]), and the cluster simulator's
//! per-GPU engines ([`crate::sim::cluster`]) all implement the same
//! vLLM-V1 scheduling mechanics. This module holds the pieces they
//! share, so §4.2 policy fixes land once (the PR-2 debt the ROADMAP
//! records):
//!
//! * [`WaitQueue`] — the FIFO queue of preempted traces with both
//!   resume disciplines: head-of-line FCFS resume for the normal path
//!   where finishing traces free memory, and a first-fit scan for the
//!   stalled-engine path (strict FCFS would wedge on an oversized head
//!   while shorter queued traces could still make progress);
//! * victim selection for memory events — [`lowest_score_victim`]
//!   (STEP, Algorithm 1: argmin aggregated step score) and
//!   [`youngest_victim`] (vLLM preemption: cheapest recompute), both
//!   preserving first-minimum tie-breaking so results are deterministic;
//! * [`max_fitting`] — the monotone binary search behind every memory
//!   and arrival horizon ("largest d that still fits");
//! * [`EventIndex`] — the incremental index over the *running* trace
//!   set that turns the per-event O(live) scans (running-set rebuild,
//!   `d_event` min fold, per-probe block-demand regather, per-owner
//!   resident sort) into O(log) or O(1) maintained aggregates, updated
//!   only at the points where the state actually changes: boundary
//!   crossings, prune/preempt/finish, and admit/resume;
//! * recompute accounting — [`settle`] (lazy accrual: a trace's
//!   decode/wait time is settled from its `last_settle` timestamp only
//!   when its status changes, instead of accruing every live trace on
//!   every event), plus the eager reference pair [`accrue`] /
//!   [`charge_resume`] that documents the per-event semantics the lazy
//!   form replaces.
//!
//! Everything here is pure bookkeeping over indices and
//! [`TraceState`]s; the engines keep ownership of their trace vectors,
//! pools, and clocks.
//!
//! The scheduler core is deliberately *fleet-agnostic*: joins, drains,
//! and revocations ([`crate::sim::cluster`]'s elastic-fleet layer) are
//! engine-**external** lifecycle transitions. A draining engine keeps
//! scheduling its residents with the unchanged mechanics here (that is
//! what lets drained work complete or migrate instead of being thrown
//! away), and a departed engine simply stops being stepped — no state
//! in this module spans engines, so nothing here needs to know the
//! fleet changed shape.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::trace::{TraceState, TraceStatus};

/// FIFO waiting queue of preempted trace indices with the two resume
/// disciplines the engines share.
#[derive(Debug, Clone, Default)]
pub struct WaitQueue {
    q: VecDeque<usize>,
}

impl WaitQueue {
    /// An empty queue.
    pub fn new() -> WaitQueue {
        WaitQueue::default()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Queued trace count.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// Enqueue a preempted trace (FIFO order).
    pub fn push_back(&mut self, tid: usize) {
        self.q.push_back(tid);
    }

    /// Dequeue the head unconditionally (the stalled-engine drop path:
    /// nothing fits, the head is removed as pruned).
    pub fn pop_front(&mut self) -> Option<usize> {
        self.q.pop_front()
    }

    /// Head-of-line FCFS resume: pop the head iff `fits(head)` — vLLM's
    /// resume rule for the normal path where finishing traces free
    /// memory. Returns the popped trace index.
    pub fn pop_head_if(&mut self, mut fits: impl FnMut(usize) -> bool) -> Option<usize> {
        let &head = self.q.front()?;
        if fits(head) {
            self.q.pop_front()
        } else {
            None
        }
    }

    /// Stalled-engine resume: pop the *first queued trace in FIFO
    /// order* whose prefix fits. Returns `None` only when nothing fits
    /// (the caller then drops the head as pruned).
    pub fn pop_first_fit(&mut self, mut fits: impl FnMut(usize) -> bool) -> Option<usize> {
        let pos = (0..self.q.len()).find(|&p| fits(self.q[p]))?;
        self.q.remove(pos)
    }

    /// Remove a specific queued trace (the cross-GPU migration path
    /// pulls a request's preempted traces out of its source engine's
    /// queue), preserving FIFO order of the rest. Returns whether the
    /// trace was queued.
    pub fn remove(&mut self, tid: usize) -> bool {
        match (0..self.q.len()).find(|&p| self.q[p] == tid) {
            Some(pos) => {
                self.q.remove(pos);
                true
            }
            None => false,
        }
    }
}

/// Largest `d` in `[0, cap]` such that `fits(d)` holds, by binary
/// search over a monotone predicate (`fits(0)` must hold; if `fits(d)`
/// then `fits(d')` for all `d' <= d`). This is the search every memory
/// horizon ("largest token advance whose block demand fits the free
/// pool") and arrival horizon ("largest iteration count within the
/// wall-clock gap") reduces to.
pub fn max_fitting(cap: u64, fits: impl Fn(u64) -> bool) -> u64 {
    if fits(cap) {
        return cap;
    }
    let (mut lo, mut hi) = (0u64, cap); // fits(lo), !fits(hi)
    while lo + 1 < hi {
        // Overflow-safe midpoint: `(lo + hi) / 2` wraps once the caller
        // passes a cap in the top half of u64 (e.g. an "unbounded"
        // horizon of u64::MAX iterations).
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Incremental index over an engine's *running* trace set.
///
/// Every engine event used to pay O(live) scans: rebuild the running
/// set, fold the `d_event` min over tokens-to-next-boundary, regather
/// every trace's resident tokens on each probe of the memory-horizon
/// binary search, and (under quotas) sort an `(owner, resident)` pair
/// list. All of that state changes only at *crossings* — a boundary is
/// reached, a trace is admitted/resumed, pruned, preempted, or
/// finishes — so this index maintains it incrementally:
///
/// * the running set itself ([`tids`](EventIndex::tids), kept in
///   ascending trace order so victim selection and boundary iteration
///   match the engines' historical scan order);
/// * a lazy min-heap over *absolute boundary keys* (`iterations at
///   insert + distance to boundary`), making
///   [`d_event`](EventIndex::d_event) an O(1) amortized peek — keys
///   stay valid under [`advance`](EventIndex::advance) because every
///   running trace advances in lockstep;
/// * the resident-token sum ([`resident_tokens`](EventIndex::resident_tokens),
///   the scheduler's `K0` context size) and running count, both O(1);
/// * a block-offset histogram: traces are binned by the *phase* of
///   their resident token count modulo the block size, expressed in
///   advance-invariant coordinates (`free slots + iterations mod bs`),
///   so the total block demand of advancing every running trace `d`
///   tokens ([`pool_demand`](EventIndex::pool_demand)) is a
///   closed-form O(block size) fold instead of an O(live) regather per
///   binary-search probe;
/// * the same histogram per owner plus the sorted active-owner list
///   ([`active_owners`](EventIndex::active_owners)), replacing the
///   per-event owner-pair sort in the quota path
///   ([`owner_demand`](EventIndex::owner_demand)); per-owner rows live
///   in compact recycled slots, so their memory tracks the *peak
///   concurrently active* owner count, not the monotonically growing
///   owner-id space.
///
/// All aggregates are integer arithmetic over exactly the quantities
/// the scan-based code folded, so every derived horizon is
/// bit-identical to the naive reference — the differential property
/// test in `tests/prop_invariants.rs` locks that in.
///
/// Trace ids are `u32` throughout (an engine's trace table is bounded
/// by requests × N, far below 2^32): the running set, the boundary
/// heap, and the per-owner rows are dense index-keyed arenas of 4-byte
/// ids, so a fleet of 1024 engines stepping concurrently keeps its hot
/// scheduler state cache-resident instead of chasing per-engine map
/// nodes.
#[derive(Debug, Default)]
pub struct EventIndex {
    /// PagedAttention block size in tokens.
    bs: u64,
    /// Total decode iterations advanced since [`reset`](Self::reset).
    iters: u64,
    /// Running trace ids, ascending.
    tids: Vec<u32>,
    /// Per-tid valid absolute boundary key (`u64::MAX` = not running).
    key_of: Vec<u64>,
    /// Lazy min-heap of `(absolute boundary key, tid)`.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    /// Per-tid resident tokens at insert, and the iteration counter at
    /// insert (current residency = base + iters - base_iters).
    base_resident: Vec<u64>,
    base_iters: Vec<u64>,
    /// Σ resident tokens over running traces (the scheduler's K0).
    resident_sum: u64,
    /// Tokens pinned in shared prompt-prefix blocks on this engine's
    /// pool. Counted *once* toward K0 regardless of how many running
    /// traces share them (each trace inserts only its private
    /// residency), and never in the phase histograms — pinned blocks
    /// are full by construction, so they contribute no future block
    /// demand. Zero whenever the prefix cache is off.
    pinned_tokens: u64,
    /// Histogram over advance-invariant block phases (len = bs).
    hist: Vec<u64>,
    /// Whether per-owner aggregates are maintained (quota engines).
    track_owners: bool,
    /// External owner id → compact slot + 1 (0 = no slot). Owner ids
    /// grow monotonically with the request count, so the per-slot
    /// aggregates below are keyed by *compact slots* recycled through
    /// `free_slots` — memory stays proportional to the peak number of
    /// concurrently active owners, not the total ever seen (only this
    /// 4-byte-per-owner map grows with the run).
    owner_slot: Vec<u32>,
    /// Retired compact slots available for reuse (their histogram rows
    /// are all-zero by construction when freed).
    free_slots: Vec<u32>,
    /// Per-slot running-trace count.
    owner_count: Vec<u64>,
    /// Flat per-slot block-phase histograms (`slot * bs + phase`).
    owner_hist: Vec<u64>,
    /// Owners with at least one running trace, ascending (external
    /// ids).
    active_owners: Vec<u32>,
    /// Per-tid owner (only meaningful while running).
    owner_of: Vec<u32>,
}

impl EventIndex {
    /// A fresh index for `block_size`-token blocks; `track_owners`
    /// enables the per-owner aggregates the quota path needs.
    pub fn new(block_size: usize, track_owners: bool) -> EventIndex {
        let mut idx = EventIndex::default();
        idx.reset(block_size, track_owners);
        idx
    }

    /// Clear the index and rebind it to `block_size` / `track_owners`,
    /// keeping allocated capacity (the DES engine reuses one index
    /// across phases and questions via its `Scratch`).
    pub fn reset(&mut self, block_size: usize, track_owners: bool) {
        assert!(block_size > 0, "block size must be positive");
        self.bs = block_size as u64;
        self.iters = 0;
        self.tids.clear();
        self.key_of.clear();
        self.heap.clear();
        self.base_resident.clear();
        self.base_iters.clear();
        self.resident_sum = 0;
        self.pinned_tokens = 0;
        self.hist.clear();
        self.hist.resize(block_size, 0);
        self.track_owners = track_owners;
        self.owner_slot.clear();
        self.free_slots.clear();
        self.owner_count.clear();
        self.owner_hist.clear();
        self.active_owners.clear();
        self.owner_of.clear();
    }

    /// Number of running traces.
    pub fn running(&self) -> usize {
        self.tids.len()
    }

    /// The running trace ids in ascending order (the engines' historical
    /// scan order, so victim selection and boundary iteration are
    /// unchanged).
    pub fn tids(&self) -> &[u32] {
        &self.tids
    }

    /// Σ resident tokens over the running set — the scheduler's batch
    /// context size `K0`, previously an O(live) fold per event — plus
    /// the tokens pinned in shared prefixes, counted exactly once.
    pub fn resident_tokens(&self) -> u64 {
        self.resident_sum + self.pinned_tokens
    }

    /// Account tokens newly pinned in a shared prompt prefix: they
    /// enter K0 once, here, instead of once per sharing trace.
    pub fn add_pinned_tokens(&mut self, tokens: u64) {
        self.pinned_tokens += tokens;
    }

    /// Release pinned-prefix tokens (registry eviction).
    pub fn sub_pinned_tokens(&mut self, tokens: u64) {
        debug_assert!(self.pinned_tokens >= tokens, "pinned-token underflow");
        self.pinned_tokens -= tokens;
    }

    /// Owners with at least one running trace, ascending (empty unless
    /// owner tracking is enabled). Same iteration order as the retired
    /// sorted owner-pair scan.
    pub fn active_owners(&self) -> &[u32] {
        &self.active_owners
    }

    fn ensure_tid(&mut self, tid: u32) {
        let tid = tid as usize;
        if self.key_of.len() <= tid {
            self.key_of.resize(tid + 1, u64::MAX);
            self.base_resident.resize(tid + 1, 0);
            self.base_iters.resize(tid + 1, 0);
            if self.track_owners {
                self.owner_of.resize(tid + 1, 0);
            }
        }
    }

    /// Advance-invariant block phase of a trace with `resident` tokens
    /// right now: `(free slots in its last block + iters) mod bs`.
    /// Advancing d tokens decreases the free-slot count by d (mod bs)
    /// while `iters` grows by d, so the phase never moves while the
    /// trace runs — [`advance`](Self::advance) is O(1).
    fn phase(&self, resident: u64) -> usize {
        let free = (self.bs - resident % self.bs) % self.bs;
        ((free + self.iters) % self.bs) as usize
    }

    /// Register a trace entering the running set with `resident` tokens
    /// (prompt + generated) and `dist` iterations to its next step
    /// boundary. Called at admission and resume.
    pub fn insert(&mut self, tid: u32, owner: u32, resident: u64, dist: u64) {
        debug_assert!(dist >= 1, "a running trace is strictly before its boundary");
        self.ensure_tid(tid);
        let ti = tid as usize;
        debug_assert_eq!(self.key_of[ti], u64::MAX, "trace already running");
        let pos = self.tids.partition_point(|&t| t < tid);
        self.tids.insert(pos, tid);
        let key = self.iters + dist;
        self.key_of[ti] = key;
        self.heap.push(Reverse((key, tid)));
        self.base_resident[ti] = resident;
        self.base_iters[ti] = self.iters;
        self.resident_sum += resident;
        let p = self.phase(resident);
        self.hist[p] += 1;
        if self.track_owners {
            self.owner_of[ti] = owner;
            let o = owner as usize;
            if self.owner_slot.len() <= o {
                self.owner_slot.resize(o + 1, 0);
            }
            let slot = if self.owner_slot[o] == 0 {
                // First running trace of this owner: bind a recycled (or
                // fresh) compact slot.
                let slot = self.free_slots.pop().unwrap_or_else(|| {
                    let s = self.owner_count.len() as u32;
                    self.owner_count.push(0);
                    self.owner_hist.resize(self.owner_hist.len() + self.bs as usize, 0);
                    s
                }) as usize;
                self.owner_slot[o] = slot as u32 + 1;
                let op = self.active_owners.partition_point(|&x| x < owner);
                self.active_owners.insert(op, owner);
                slot
            } else {
                (self.owner_slot[o] - 1) as usize
            };
            self.owner_count[slot] += 1;
            self.owner_hist[slot * self.bs as usize + p] += 1;
        }
    }

    /// Remove a trace from the running set (prune / preempt / finish).
    pub fn remove(&mut self, tid: u32) {
        let ti = tid as usize;
        debug_assert_ne!(self.key_of[ti], u64::MAX, "removing a non-running trace");
        let resident = self.base_resident[ti] + (self.iters - self.base_iters[ti]);
        let p = self.phase(resident);
        self.hist[p] -= 1;
        self.resident_sum -= resident;
        self.key_of[ti] = u64::MAX;
        let pos = self.tids.partition_point(|&t| t < tid);
        debug_assert_eq!(self.tids[pos], tid);
        self.tids.remove(pos);
        if self.track_owners {
            let owner = self.owner_of[ti];
            let slot = (self.owner_slot[owner as usize] - 1) as usize;
            self.owner_count[slot] -= 1;
            self.owner_hist[slot * self.bs as usize + p] -= 1;
            if self.owner_count[slot] == 0 {
                // Last running trace of this owner: its histogram row is
                // all-zero again, so the slot recycles cleanly.
                self.owner_slot[owner as usize] = 0;
                self.free_slots.push(slot as u32);
                let op = self.active_owners.partition_point(|&x| x < owner);
                debug_assert_eq!(self.active_owners[op], owner);
                self.active_owners.remove(op);
            }
        }
    }

    /// Advance every running trace by `d` decode iterations (`d` tokens
    /// each). O(1): the resident sum shifts by `d × running`, and the
    /// block-phase histograms are advance-invariant by construction.
    pub fn advance(&mut self, d: u64) {
        self.iters += d;
        self.resident_sum += d * self.tids.len() as u64;
    }

    /// Re-key a trace that just crossed a step boundary: `dist`
    /// iterations to its next boundary.
    pub fn set_boundary(&mut self, tid: u32, dist: u64) {
        debug_assert!(dist >= 1);
        debug_assert_ne!(self.key_of[tid as usize], u64::MAX, "re-keying a non-running trace");
        let key = self.iters + dist;
        self.key_of[tid as usize] = key;
        self.heap.push(Reverse((key, tid)));
    }

    /// Iterations until the nearest step boundary of any running trace
    /// (`None` when nothing runs). Amortized O(1): stale heap entries
    /// (crossed boundaries, removed traces) are popped lazily.
    pub fn d_event(&mut self) -> Option<u64> {
        while let Some(&Reverse((key, tid))) = self.heap.peek() {
            if self.key_of.get(tid as usize) == Some(&key) {
                return Some(key - self.iters);
            }
            self.heap.pop();
        }
        None
    }

    /// Blocks the whole running set needs to advance `d` tokens each —
    /// the memory-horizon probe, closed-form over the block-phase
    /// histogram (O(block size), independent of the live-trace count).
    /// Bit-identical to folding `(c + d).div_ceil(bs) - c.div_ceil(bs)`
    /// over every running trace's residency `c`.
    pub fn pool_demand(&self, d: u64) -> u64 {
        Self::hist_demand(&self.hist, d, self.bs, self.iters)
    }

    /// Blocks `owner`'s running traces need to advance `d` tokens each
    /// (0 for owners with nothing running). Requires owner tracking.
    pub fn owner_demand(&self, owner: u32, d: u64) -> u64 {
        debug_assert!(self.track_owners, "owner demand needs owner tracking");
        let Some(&slot1) = self.owner_slot.get(owner as usize) else {
            return 0;
        };
        if slot1 == 0 {
            return 0;
        }
        let (slot, bs) = ((slot1 - 1) as usize, self.bs as usize);
        Self::hist_demand(&self.owner_hist[slot * bs..(slot + 1) * bs], d, self.bs, self.iters)
    }

    fn hist_demand(hist: &[u64], d: u64, bs: u64, iters: u64) -> u64 {
        let mut demand = 0u64;
        for (p, &cnt) in hist.iter().enumerate() {
            if cnt == 0 {
                continue;
            }
            let free = (p as u64 + bs - iters % bs) % bs;
            if d > free {
                demand += cnt * (d - free).div_ceil(bs);
            }
        }
        demand
    }
}

/// STEP's memory-event victim (Algorithm 1): the candidate in
/// `running` passing `in_set` with the lowest aggregated step score.
/// Ties keep the *first* minimum (iteration order), matching the
/// engines' historical `min_by` semantics, so runs stay deterministic.
/// Generic over the id width so both the `usize`-indexed DES engine
/// and the `u32`-arena serving engines share one implementation.
pub fn lowest_score_victim<I: Copy>(
    running: &[I],
    in_set: impl Fn(I) -> bool,
    score: impl Fn(I) -> f64,
) -> Option<I> {
    running
        .iter()
        .copied()
        .filter(|&i| in_set(i))
        .min_by(|&a, &b| score(a).partial_cmp(&score(b)).unwrap())
}

/// vLLM's preemption victim: the candidate in `running` passing
/// `in_set` with the fewest generated tokens (cheapest recompute).
/// First-minimum tie-breaking, as with [`lowest_score_victim`].
pub fn youngest_victim<I: Copy>(
    running: &[I],
    in_set: impl Fn(I) -> bool,
    generated: impl Fn(I) -> u64,
) -> Option<I> {
    running.iter().copied().filter(|&i| in_set(i)).min_by_key(|&i| generated(i))
}

/// Lazy time accrual: charge the window since `last_settle` onto one
/// trace according to its *current* status — running time lands as
/// decode, preempted time as wait, terminal states nothing — and move
/// the settle mark to `clock`.
///
/// This replaces the eager accrue-every-live-trace-on-every-event loop
/// ([`accrue`]): because a trace's rate class only changes when its
/// status changes, engines need to settle only at status transitions
/// (admit, preempt, resume, prune, finish) instead of on every clock
/// move. Totals are equal to the eager form's up to floating-point
/// summation order (one subtraction per status window vs. one addition
/// per event); neither feeds back into scheduling decisions.
pub fn settle(st: &mut TraceState, last_settle: &mut f64, clock: f64) {
    let dt = clock - *last_settle;
    match st.status {
        TraceStatus::Running => st.decode_time += dt,
        TraceStatus::Preempted => st.wait_time += dt,
        _ => {}
    }
    *last_settle = clock;
}

/// Accrue `dt` seconds of engine busy time (a decode interval, or a
/// prefill stall from admission / recompute-on-resume) onto one trace:
/// running traces accrue decode time (the engine is busy on their
/// behalf), preempted traces accrue wait time, terminal traces nothing.
///
/// This is the eager per-event reference semantics; the engines now use
/// the lazy [`settle`] form, which charges the same windows at status
/// transitions only.
pub fn accrue(st: &mut TraceState, dt: f64) {
    match st.status {
        TraceStatus::Running => st.decode_time += dt,
        TraceStatus::Preempted => st.wait_time += dt,
        _ => {}
    }
}

/// Recompute-on-resume accounting for the resumed trace itself: its KV
/// reconstruction counts as waiting, not decoding (the paper's
/// "resumed with KV cache reconstructed"). The caller has already run
/// [`accrue`] over every trace (which charged this one `dt` of decode
/// as a then-running trace); this moves the charge to waiting.
pub fn charge_resume(st: &mut TraceState, dt: f64) {
    st.decode_time -= dt;
    st.wait_time += dt;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_queue_fifo_and_first_fit() {
        let mut q = WaitQueue::new();
        assert!(q.is_empty());
        q.push_back(3);
        q.push_back(7);
        q.push_back(5);
        assert_eq!(q.len(), 3);
        // Head-of-line resume refuses when the head does not fit.
        assert_eq!(q.pop_head_if(|t| t != 3), None);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_head_if(|t| t == 3), Some(3));
        // First-fit scans past a non-fitting head in FIFO order.
        assert_eq!(q.pop_first_fit(|t| t == 5), Some(5));
        assert_eq!(q.pop_first_fit(|_| false), None);
        assert_eq!(q.pop_front(), Some(7));
        assert!(q.is_empty());
    }

    #[test]
    fn wait_queue_removes_by_tid() {
        let mut q = WaitQueue::new();
        q.push_back(3);
        q.push_back(7);
        q.push_back(5);
        assert!(q.remove(7), "middle element leaves");
        assert!(!q.remove(7), "already gone");
        assert!(!q.remove(99), "never queued");
        // FIFO order of the rest is preserved.
        assert_eq!(q.pop_front(), Some(3));
        assert_eq!(q.pop_front(), Some(5));
        assert!(q.is_empty());
    }

    #[test]
    fn max_fitting_matches_linear_scan() {
        for cap in [1u64, 2, 7, 64, 1000] {
            for cut in 0..=cap {
                let fits = |d: u64| d <= cut;
                assert_eq!(max_fitting(cap, fits), cut.min(cap), "cap={cap} cut={cut}");
            }
        }
        assert_eq!(max_fitting(100, |_| true), 100);
        assert_eq!(max_fitting(100, |d| d == 0), 0);
    }

    /// Regression: `(lo + hi) / 2` overflowed for caps in the top half
    /// of u64 (an "unbounded" horizon), wrapping the midpoint to ~0 and
    /// either looping forever or returning garbage.
    #[test]
    fn max_fitting_survives_huge_caps() {
        for cut in [0u64, 1, 5, 1 << 40, u64::MAX - 1] {
            assert_eq!(max_fitting(u64::MAX, |d| d <= cut), cut, "cut={cut}");
        }
        assert_eq!(max_fitting(u64::MAX, |_| true), u64::MAX);
        assert_eq!(max_fitting(u64::MAX - 1, |d| d <= 3), 3);
    }

    #[test]
    fn event_index_tracks_running_set_and_horizons() {
        let mut idx = EventIndex::new(16, false);
        assert_eq!(idx.running(), 0);
        assert_eq!(idx.d_event(), None);
        // Two traces: residents 20 (12 free slots) and 32 (0 free).
        idx.insert(3, 0, 20, 5);
        idx.insert(1, 0, 32, 2);
        assert_eq!(idx.tids(), &[1, 3], "ascending trace order");
        assert_eq!(idx.resident_tokens(), 52);
        assert_eq!(idx.d_event(), Some(2));
        // demand(d): trace 20 needs ceil((d-12)+/16), trace 32 ceil(d/16).
        assert_eq!(idx.pool_demand(1), 1);
        assert_eq!(idx.pool_demand(12), 1);
        assert_eq!(idx.pool_demand(13), 2);
        assert_eq!(idx.pool_demand(16), 2);
        assert_eq!(idx.pool_demand(17), 3);
        // Advance to trace 1's boundary and re-key it (the engine
        // protocol: crossings are re-keyed before the next peek).
        idx.advance(2);
        assert_eq!(idx.resident_tokens(), 56);
        idx.set_boundary(1, 10);
        assert_eq!(idx.d_event(), Some(3), "trace 3's boundary is next");
        // Residents are now 22 and 34 (10 and 14 free slots): demand
        // stays 0 through d = 10 and crosses at d = 11.
        assert_eq!(idx.pool_demand(1), 0);
        assert_eq!(idx.pool_demand(10), 0);
        assert_eq!(idx.pool_demand(11), 1);
        idx.remove(3);
        assert_eq!(idx.tids(), &[1]);
        assert_eq!(idx.resident_tokens(), 34);
        assert_eq!(idx.d_event(), Some(10), "stale heap entries are skipped");
        idx.remove(1);
        assert_eq!(idx.d_event(), None);
        assert_eq!(idx.pool_demand(100), 0);
    }

    #[test]
    fn pinned_tokens_enter_k0_once_and_never_the_histograms() {
        let mut idx = EventIndex::new(16, false);
        // Two sharers of a 32-token pinned prefix insert only their
        // private residency (8 tokens each); the prefix enters once.
        idx.add_pinned_tokens(32);
        idx.insert(0, 0, 8, 4);
        idx.insert(1, 0, 8, 4);
        assert_eq!(idx.resident_tokens(), 32 + 16);
        // Block demand sees only the private phases: 8 free slots each.
        assert_eq!(idx.pool_demand(8), 0);
        assert_eq!(idx.pool_demand(9), 2);
        idx.advance(4);
        assert_eq!(idx.resident_tokens(), 32 + 24, "advance never scales pins");
        idx.remove(0);
        idx.remove(1);
        assert_eq!(idx.resident_tokens(), 32, "pins outlive their sharers");
        idx.sub_pinned_tokens(32);
        assert_eq!(idx.resident_tokens(), 0);
        idx.reset(16, false);
        assert_eq!(idx.resident_tokens(), 0, "reset clears pins");
    }

    #[test]
    fn event_index_owner_aggregates() {
        let mut idx = EventIndex::new(16, true);
        idx.insert(0, 7, 16, 4);
        idx.insert(1, 2, 8, 4);
        idx.insert(2, 7, 24, 4);
        assert_eq!(idx.active_owners(), &[2, 7], "ascending owners");
        // Owner 7: residents 16 (0 free) + 24 (8 free).
        assert_eq!(idx.owner_demand(7, 1), 1);
        assert_eq!(idx.owner_demand(7, 9), 2);
        assert_eq!(idx.owner_demand(2, 8), 0);
        assert_eq!(idx.owner_demand(2, 9), 1);
        assert_eq!(idx.owner_demand(99, 5), 0, "unknown owner has no demand");
        assert_eq!(idx.pool_demand(9), idx.owner_demand(7, 9) + idx.owner_demand(2, 9));
        idx.remove(0);
        idx.remove(2);
        assert_eq!(idx.active_owners(), &[2], "owner 7 left the active set");
        assert_eq!(idx.owner_demand(7, 9), 0, "freed owner has no demand");
        // A new owner recycles the freed compact slot with clean rows.
        idx.insert(3, 4, 40, 6);
        assert_eq!(idx.active_owners(), &[2, 4]);
        assert_eq!(idx.owner_demand(4, 8), 0, "40 resident → 8 free slots");
        assert_eq!(idx.owner_demand(4, 9), 1);
        assert_eq!(idx.owner_demand(2, 9), 1, "other owners unaffected by reuse");
        // Reset keeps nothing.
        idx.reset(16, true);
        assert_eq!(idx.running(), 0);
        assert_eq!(idx.active_owners(), &[] as &[u32]);
    }

    #[test]
    fn event_index_reinsert_after_preemption() {
        let mut idx = EventIndex::new(16, false);
        idx.insert(0, 0, 10, 6);
        idx.advance(3);
        // Preempt and later resume with the grown residency.
        idx.remove(0);
        assert_eq!(idx.resident_tokens(), 0);
        idx.insert(0, 0, 13, 3);
        assert_eq!(idx.d_event(), Some(3));
        assert_eq!(idx.resident_tokens(), 13);
        // 3 free slots in the last block: demand(4) crosses.
        assert_eq!(idx.pool_demand(3), 0);
        assert_eq!(idx.pool_demand(4), 1);
    }

    #[test]
    fn lazy_settle_matches_status_windows() {
        let mut st = TraceState::new(0, 4);
        let mut ls = 1.5f64;
        // Running window [1.5, 4.0).
        settle(&mut st, &mut ls, 4.0);
        assert_eq!(st.decode_time, 2.5);
        st.status = TraceStatus::Preempted;
        // Waiting window [4.0, 9.0).
        settle(&mut st, &mut ls, 9.0);
        assert_eq!(st.wait_time, 5.0);
        st.status = TraceStatus::Finished;
        settle(&mut st, &mut ls, 12.0);
        assert_eq!(st.decode_time, 2.5, "terminal traces accrue nothing");
        assert_eq!(st.wait_time, 5.0);
        assert_eq!(ls, 12.0);
    }

    #[test]
    fn victims_take_first_minimum() {
        let running = [4usize, 2, 9, 7];
        let scores = |i: usize| match i {
            2 | 9 => 0.25,
            _ => 0.5,
        };
        // Both 2 and 9 tie at the minimum; the first in iteration order
        // wins.
        assert_eq!(lowest_score_victim(&running, |_| true, scores), Some(2));
        assert_eq!(lowest_score_victim(&running, |i| i > 2, scores), Some(9));
        assert_eq!(lowest_score_victim(&running, |_| false, scores), None);

        let gens = |i: usize| if i == 9 || i == 7 { 10 } else { 20 };
        assert_eq!(youngest_victim(&running, |_| true, gens), Some(9));
        assert_eq!(youngest_victim(&running, |i| i != 9, gens), Some(7));
    }

    #[test]
    fn stall_accrual_splits_by_status() {
        let mut sts: Vec<TraceState> = (0..3).map(|i| TraceState::new(i, 4)).collect();
        sts[1].status = TraceStatus::Preempted;
        sts[2].status = TraceStatus::Finished;
        for st in sts.iter_mut() {
            accrue(st, 2.0);
        }
        assert_eq!(sts[0].decode_time, 2.0);
        assert_eq!(sts[1].wait_time, 2.0);
        assert_eq!(sts[2].decode_time + sts[2].wait_time, 0.0);
        // Resume charge moves decode to wait for the resumed trace.
        charge_resume(&mut sts[0], 2.0);
        assert_eq!(sts[0].decode_time, 0.0);
        assert_eq!(sts[0].wait_time, 2.0);
    }
}
