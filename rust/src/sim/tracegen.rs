//! Synthetic reasoning-trace generator — the data substrate standing in
//! for sampling real reasoning LLMs (DESIGN.md §3).
//!
//! Mirrors the generative process `python/compile/scorer.py` trains the
//! step scorer on (parameters are loaded from the exported
//! `artifacts/scorer_sim.json`, keeping the two sides in sync):
//!
//!   question q:  solve rate p_q ~ Beta(k*mu, k*(1-mu)),
//!                nuisance direction w_q ~ N(0, I) * c_q / sqrt(d)
//!   trace t:     label y ~ Bern(p_q), latent quality g = (2y-1) + nu
//!   step n:      h_n = s0 * rho(n) * g * u + w_q + sigma_h * eps,
//!                rho(n) = n / (n + n0)
//!
//! plus everything the serving engine additionally needs: per-step token
//! counts (App. D: ~1e2 tokens/step), trace lengths with the Fig.-2b
//! incorrect-longer skew, per-step token confidences (the DeepConf
//! baseline's weaker signal), and final answers over a wrong-answer
//! distribution (controls when majority voting fails).

use crate::util::rng::Rng;

use super::profiles::{
    cot_calibration, BenchId, BenchProfile, ModelId, ModelProfile,
    INCORRECT_LEN_RATIO, STEP_TOKENS_SIGMA, TRACE_LEN_SIGMA,
};

/// Hidden-state generator parameters (mirror of python GenParams; loaded
/// from artifacts/scorer_sim.json `gen` + `signal_dir`).
#[derive(Debug, Clone)]
pub struct GenParams {
    /// Hidden-state dimension.
    pub d: usize,
    /// Signal amplitude along the signal direction.
    pub s0: f64,
    /// Progress-ramp half-saturation step (rho(n) = n / (n + n0)).
    pub n0: f64,
    /// Per-step isotropic noise sigma.
    pub sigma_h: f64,
    /// Per-trace latent-quality noise sigma.
    pub sigma_t: f64,
    /// Per-question nuisance-direction magnitude.
    pub c_q: f64,
    /// Transient early-trace offset along the signal direction (the
    /// model's "exploration" phase before committing): amplitude ~
    /// N(0, sigma_a) per trace, decaying as exp(-n/tau). This is what
    /// keeps early-prefix ranking below the late-prefix plateau (Fig 5).
    pub sigma_a: f64,
    /// Decay constant (in steps) of the early-trace transient.
    pub tau: f64,
    /// Unit signal direction (length d).
    pub signal_dir: Vec<f32>,
}

impl GenParams {
    /// Parse the `gen` + `signal_dir` fields of a scorer bundle JSON
    /// (artifacts/scorer_sim.json) so the rust generator and the
    /// python-trained scorer share one distribution.
    pub fn from_json(blob: &crate::util::json::Json) -> anyhow::Result<GenParams> {
        use anyhow::Context;
        let g = blob.get("gen");
        let signal_dir = blob.get("signal_dir").as_f32_vec().context("signal_dir")?;
        let gp = GenParams {
            d: g.get("d").as_usize().context("gen.d")?,
            s0: g.get("s0").as_f64().context("gen.s0")?,
            n0: g.get("n0").as_f64().context("gen.n0")?,
            sigma_h: g.get("sigma_h").as_f64().context("gen.sigma_h")?,
            sigma_t: g.get("sigma_t").as_f64().context("gen.sigma_t")?,
            c_q: g.get("c_q").as_f64().context("gen.c_q")?,
            sigma_a: g.get("sigma_a").as_f64().unwrap_or(0.0),
            tau: g.get("tau").as_f64().unwrap_or(45.0),
            signal_dir,
        };
        anyhow::ensure!(gp.signal_dir.len() == gp.d, "signal_dir/d mismatch");
        Ok(gp)
    }

    /// Built-in defaults matching python `GenParams()` — used by tests
    /// that must run without artifacts. The signal direction here is a
    /// basis vector; real runs load the trained direction from JSON.
    pub fn default_d64() -> GenParams {
        let mut dir = vec![0.0f32; 64];
        dir[0] = 1.0;
        GenParams {
            d: 64,
            s0: 2.2,
            n0: 60.0,
            sigma_h: 1.0,
            sigma_t: 1.15,
            c_q: 0.6,
            sigma_a: 1.3,
            tau: 45.0,
            signal_dir: dir,
        }
    }
}

/// Token-confidence model for the DeepConf baseline: a scalar per step
/// correlated with trace quality, but with lower SNR than the hidden
/// state (the paper's miscalibration argument, §2.1/Fig. 5).
#[derive(Debug, Clone, Copy)]
pub struct ConfidenceParams {
    /// Baseline mean token confidence.
    pub base: f64,
    /// Quality-to-confidence coupling strength.
    pub signal: f64,
    /// Per-step noise (averages out over a long trace).
    pub noise: f64,
    /// Per-trace *miscalibration* bias (does NOT average out): some
    /// traces are confidently wrong / diffidently right, which is why
    /// trace-level confidence never becomes a clean correctness signal
    /// (Chhikara 2025; the paper's §2.1 critique, Fig. 5's plateau).
    pub trace_bias: f64,
}

impl Default for ConfidenceParams {
    fn default() -> Self {
        ConfidenceParams { base: 0.82, signal: 0.045, noise: 0.10, trace_bias: 0.055 }
    }
}

/// One benchmark question instance.
#[derive(Debug, Clone)]
pub struct Question {
    /// Question index within the benchmark.
    pub qid: usize,
    /// Per-question solve probability (difficulty).
    pub p_solve: f64,
    /// Per-question trace-length multiplier: harder questions produce
    /// longer traces (the paper's Fig-2b Q28 averages 35-42k tokens vs
    /// the 22.7k benchmark mean).
    pub len_mult: f64,
    /// Nuisance direction added to every hidden state of this question.
    pub w_q: Vec<f32>,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    seed: u64,
}

/// Fully-sampled synthetic trace (token stream metadata; hidden states
/// are generated lazily and deterministically per step).
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Ground-truth correctness of the trace's reasoning.
    pub label: bool,
    /// Final answer: 0 = ground truth; >0 = specific wrong answer;
    /// None = truncated at the generation cap (no parseable answer).
    pub answer: Option<u32>,
    /// Latent quality g (drives hidden states + confidence).
    pub quality: f64,
    /// Cumulative token index (within the generation) of each step
    /// boundary; last entry == total generated tokens.
    pub step_ends: Vec<u64>,
    /// Total tokens the trace generates.
    pub total_tokens: u64,
    /// Hit the model's generation cap (answer unparseable).
    pub truncated: bool,
    seed: u64,
}

impl TraceSpec {
    /// Number of reasoning steps (= step boundaries).
    pub fn n_steps(&self) -> usize {
        self.step_ends.len()
    }
}

/// Generator bound to one (model, benchmark) pair.
#[derive(Debug, Clone)]
pub struct TraceGen {
    /// The simulated model's profile.
    pub model: ModelProfile,
    /// The benchmark's workload profile.
    pub bench: BenchProfile,
    /// Hidden-state generator parameters.
    pub gen: GenParams,
    /// Token-confidence model parameters.
    pub conf: ConfidenceParams,
    /// Mean total tokens of correct traces.
    pub mean_len_correct: f64,
    /// Mean total tokens of incorrect traces (Fig-2b skew).
    pub mean_len_incorrect: f64,
    /// Benchmark-mean solve rate (Table 1 CoT calibration).
    pub mean_solve: f64,
    base_seed: u64,
}

impl TraceGen {
    /// Bind a generator to one (model, benchmark) pair and a seed.
    pub fn new(model: ModelId, bench: BenchId, gen: GenParams, seed: u64) -> TraceGen {
        let mp = ModelProfile::get(model);
        let bp = BenchProfile::get(bench);
        let (acc, tokens_k) = cot_calibration(model, bench);
        // Split the benchmark's mean trace length into correct/incorrect
        // components with the Fig-2b ratio, preserving the overall mean.
        let denom = acc + (1.0 - acc) * INCORRECT_LEN_RATIO;
        let mean_len_correct = tokens_k * 1000.0 / denom;
        let mean_len_incorrect = mean_len_correct * INCORRECT_LEN_RATIO;
        TraceGen {
            model: mp,
            bench: bp,
            gen,
            conf: ConfidenceParams::default(),
            mean_len_correct,
            mean_len_incorrect,
            mean_solve: acc,
            base_seed: seed,
        }
    }

    /// Sample question `qid` (deterministic in (seed, qid)).
    pub fn question(&self, qid: usize) -> Question {
        let mut rng = Rng::new(self.base_seed ^ (qid as u64).wrapping_mul(0xA24BAED4963EE407));
        let mu = self.mean_solve;
        let kappa = self.bench.difficulty_kappa;
        let p_solve = rng.beta(kappa * mu, kappa * (1.0 - mu)).clamp(0.005, 0.995);
        let scale = self.gen.c_q / (self.gen.d as f64).sqrt();
        let w_q: Vec<f32> = (0..self.gen.d)
            .map(|_| (rng.normal() * scale) as f32)
            .collect();
        let prompt_tokens = ((self.bench.prompt_tokens as f64)
            * rng.lognormal(-0.02, 0.2))
        .round()
        .max(8.0) as usize;
        // E[len_mult] ~ 1 at the benchmark's mean solve rate.
        let base = (1.30 - 0.45 * p_solve) / (1.30 - 0.45 * self.mean_solve);
        let len_mult = base * rng.lognormal(-0.015, 0.17);
        Question { qid, p_solve, len_mult, w_q, prompt_tokens, seed: rng.next_u64() }
    }

    /// Scheduler-visible expectation of one trace's generated length for
    /// question `q` (tokens): the benchmark's label-weighted mean scaled
    /// by the question's difficulty/length multiplier. Routers and
    /// admission control consume this — sampled lengths stay hidden from
    /// the scheduler.
    pub fn expected_trace_tokens(&self, q: &Question) -> f64 {
        let mean_total = self.mean_solve * self.mean_len_correct
            + (1.0 - self.mean_solve) * self.mean_len_incorrect;
        q.len_mult * mean_total
    }

    /// Sample trace `idx` of a question (deterministic).
    pub fn trace(&self, q: &Question, idx: usize) -> TraceSpec {
        let seed = q.seed ^ (idx as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let label = rng.bernoulli(q.p_solve);
        let quality = if label { 1.0 } else { -1.0 } + rng.normal() * self.gen.sigma_t;

        let mean_len = q.len_mult
            * if label { self.mean_len_correct } else { self.mean_len_incorrect };
        // Mean-preserving lognormal: E[X] = mean_len.
        let mu_log = mean_len.ln() - TRACE_LEN_SIGMA * TRACE_LEN_SIGMA / 2.0;
        let mut total = rng.lognormal(mu_log, TRACE_LEN_SIGMA).round() as u64;
        total = total.max(200);

        let cap = self.model.max_gen_tokens as u64;
        let truncated = total > cap;
        if truncated {
            total = cap;
        }

        // Step boundaries: per-step token counts ~ lognormal around the
        // benchmark's tokens/step.
        let tps = self.bench.tokens_per_step;
        let step_mu = tps.ln() - STEP_TOKENS_SIGMA * STEP_TOKENS_SIGMA / 2.0;
        let mut step_ends = Vec::with_capacity((total as f64 / tps) as usize + 2);
        let mut pos = 0u64;
        while pos < total {
            let st = rng.lognormal(step_mu, STEP_TOKENS_SIGMA).round().max(8.0) as u64;
            pos = (pos + st).min(total);
            step_ends.push(pos);
        }

        let answer = if truncated {
            None
        } else if label {
            Some(0)
        } else {
            Some(1 + self.sample_wrong_answer(&mut rng))
        };

        TraceSpec { label, answer, quality, step_ends, total_tokens: total, truncated, seed }
    }

    fn sample_wrong_answer(&self, rng: &mut Rng) -> u32 {
        let pool = self.bench.wrong_answer_pool.max(1);
        let s = self.bench.wrong_answer_zipf;
        let weights: Vec<f64> = (1..=pool).map(|i| (i as f64).powf(-s)).collect();
        rng.categorical(&weights) as u32
    }

    /// Hidden state at step boundary `n` (1-based), deterministic in
    /// (trace, n). Mirrors python `sample_trace_hiddens`.
    pub fn hidden_state(&self, q: &Question, t: &TraceSpec, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.gen.d];
        self.hidden_state_into(q, t, n, &mut out);
        out
    }

    /// Allocation-free variant (DES hot path).
    pub fn hidden_state_into(&self, q: &Question, t: &TraceSpec, n: usize, out: &mut [f32]) {
        debug_assert!(n >= 1 && n <= t.n_steps());
        debug_assert_eq!(out.len(), self.gen.d);
        let mut rng = Rng::new(t.seed ^ (n as u64).wrapping_mul(0xD6E8FEB86659FD93));
        let mut a_rng = Rng::new(t.seed ^ 0xE7037ED1A0B428DB);
        let transient = self.gen.sigma_a * a_rng.normal() * (-(n as f64) / self.gen.tau).exp();
        let rho = n as f64 / (n as f64 + self.gen.n0);
        let coef = (self.gen.s0 * rho * t.quality + transient) as f32;
        let sig = self.gen.sigma_h as f32;
        for i in 0..self.gen.d {
            out[i] = coef * self.gen.signal_dir[i] + q.w_q[i] + sig * rng.normal() as f32;
        }
    }

    /// Simulated process-reward-model score for a completed trace
    /// (Table 2's Qwen2.5-Math-PRM-7B baseline): a full-trace verifier
    /// with ranking quality between token confidence and the hidden-state
    /// scorer — the ordering Fig. 5 / Table 2 establish.
    pub fn prm_score(&self, t: &TraceSpec) -> f64 {
        let mut rng = Rng::new(t.seed ^ 0x94D049BB133111EB);
        crate::coordinator::scorer::sigmoid((1.1 * t.quality + 0.9 * rng.normal()) as f32)
            as f64
    }

    /// Mean token confidence over step `n` (DeepConf's signal). The
    /// progress ramp is flatter than the hidden-state signal's rho(n):
    /// token log-probs carry weak quality information from the start but
    /// never match the hidden state's late-trace discriminability
    /// (Fig. 5's gap).
    pub fn step_confidence(&self, t: &TraceSpec, n: usize) -> f64 {
        let mut rng = Rng::new(t.seed ^ (n as u64).wrapping_mul(0xC2B2AE3D27D4EB4F));
        let mut bias_rng = Rng::new(t.seed ^ 0xA0761D6478BD642F);
        let rho = n as f64 / (n as f64 + self.gen.n0);
        (self.conf.base + self.conf.signal * t.quality * (0.35 + 0.65 * rho)
            + self.conf.trace_bias * bias_rng.normal()
            + self.conf.noise * rng.normal())
        .clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TraceGen {
        TraceGen::new(ModelId::Qwen3_4B, BenchId::Aime25, GenParams::default_d64(), 42)
    }

    #[test]
    fn deterministic() {
        let g = gen();
        let q1 = g.question(3);
        let q2 = g.question(3);
        assert_eq!(q1.p_solve, q2.p_solve);
        let t1 = g.trace(&q1, 5);
        let t2 = g.trace(&q2, 5);
        assert_eq!(t1.total_tokens, t2.total_tokens);
        assert_eq!(g.hidden_state(&q1, &t1, 3), g.hidden_state(&q2, &t2, 3));
        // Different trace index -> different stream.
        let t3 = g.trace(&q1, 6);
        assert!(t3.seed != t1.seed);
    }

    #[test]
    fn step_ends_monotone_and_end_at_total() {
        let g = gen();
        let q = g.question(0);
        for i in 0..8 {
            let t = g.trace(&q, i);
            assert!(!t.step_ends.is_empty());
            let mut prev = 0;
            for &e in &t.step_ends {
                assert!(e > prev || e == t.total_tokens, "non-monotone");
                prev = e;
            }
            assert_eq!(*t.step_ends.last().unwrap(), t.total_tokens);
        }
    }

    #[test]
    fn label_rate_tracks_p_solve() {
        let g = gen();
        let q = g.question(1);
        let n = 2000;
        let correct = (0..n).filter(|&i| g.trace(&q, i).label).count();
        let rate = correct as f64 / n as f64;
        assert!((rate - q.p_solve).abs() < 0.04, "rate={rate} p={}", q.p_solve);
    }

    #[test]
    fn incorrect_traces_longer_on_average() {
        let g = gen();
        let (mut lc, mut li, mut nc, mut ni) = (0.0, 0.0, 0, 0);
        for qid in 0..20 {
            let q = g.question(qid);
            for i in 0..64 {
                let t = g.trace(&q, i);
                if t.label {
                    lc += t.total_tokens as f64;
                    nc += 1;
                } else {
                    li += t.total_tokens as f64;
                    ni += 1;
                }
            }
        }
        let (mc, mi) = (lc / nc as f64, li / ni as f64);
        assert!(mi > mc * 1.1, "incorrect {mi} vs correct {mc}");
    }

    #[test]
    fn mean_length_matches_calibration() {
        let g = gen();
        let mut total = 0.0;
        let mut n = 0;
        for qid in 0..30 {
            let q = g.question(qid);
            for i in 0..32 {
                total += g.trace(&q, i).total_tokens as f64;
                n += 1;
            }
        }
        let mean_k = total / n as f64 / 1000.0;
        // Table-1 CoT row: 22.7k tokens for Qwen3-4B on AIME.
        assert!((mean_k - 22.7).abs() < 2.5, "mean {mean_k}k");
    }

    #[test]
    fn hidden_state_signal_separates_labels() {
        let g = gen();
        let q = g.question(2);
        let u = &g.gen.signal_dir;
        let (mut sp, mut sn, mut np_, mut nn) = (0.0, 0.0, 0, 0);
        for i in 0..400 {
            let t = g.trace(&q, i);
            let n_steps = t.n_steps();
            let h = g.hidden_state(&q, &t, n_steps.min(30));
            let proj: f32 = h.iter().zip(u).map(|(a, b)| a * b).sum();
            if t.label {
                sp += proj as f64;
                np_ += 1;
            } else {
                sn += proj as f64;
                nn += 1;
            }
        }
        if np_ > 10 && nn > 10 {
            assert!(sp / np_ as f64 > sn / nn as f64 + 0.5);
        }
    }

    #[test]
    fn confidence_correlates_weakly_with_label() {
        let g = gen();
        let q = g.question(4);
        let (mut cp, mut cn, mut np_, mut nn) = (0.0, 0.0, 0, 0);
        for i in 0..600 {
            let t = g.trace(&q, i);
            let c = g.step_confidence(&t, t.n_steps().min(25));
            if t.label {
                cp += c;
                np_ += 1;
            } else {
                cn += c;
                nn += 1;
            }
        }
        if np_ > 10 && nn > 10 {
            let gap = cp / np_ as f64 - cn / nn as f64;
            assert!(gap > 0.01 && gap < 0.3, "gap={gap}");
        }
    }

    #[test]
    fn truncation_at_cap() {
        // Force a benchmark/model combo with long traces: DeepSeek on
        // HMMT (31.5k mean) rarely truncates at 64k; use many samples.
        let g = TraceGen::new(ModelId::Phi4_14B, BenchId::Hmmt2425,
                              GenParams::default_d64(), 7);
        let mut saw_trunc = false;
        for qid in 0..10 {
            let q = g.question(qid);
            for i in 0..64 {
                let t = g.trace(&q, i);
                assert!(t.total_tokens <= 32_000);
                if t.truncated {
                    saw_trunc = true;
                    assert!(t.answer.is_none());
                }
            }
        }
        // Phi caps at 32k with mean 21.5k*1.2 for incorrect: truncation
        // must occur in 640 samples.
        assert!(saw_trunc);
    }

    #[test]
    fn wrong_answers_spread() {
        let g = gen();
        let q = g.question(6);
        let mut seen = std::collections::HashSet::new();
        for i in 0..500 {
            let t = g.trace(&q, i);
            if let Some(a) = t.answer {
                if a > 0 {
                    seen.insert(a);
                }
            }
        }
        if seen.len() > 1 {
            assert!(seen.len() >= 3, "wrong answers too concentrated: {seen:?}");
        }
    }
}
