//! Workload generators for the serving simulators
//! ([`crate::sim::serve`], [`crate::sim::cluster`]).
//!
//! A workload is a deterministic sequence of request arrivals over a
//! benchmark's question pool: each arrival carries a request id, the
//! question it asks, and its wall-clock arrival time. Two regimes:
//!
//! **Open loop** ([`WorkloadSpec`]) — clients do not wait for responses,
//! so the offered rate is fixed regardless of server state (the regime
//! where continuous batching and the paper's §4.2 memory-triggered
//! pruning actually matter):
//!
//! * [`ArrivalProcess::Poisson`] — i.i.d. exponential inter-arrival gaps
//!   at a target request rate, the standard serving-benchmark model.
//! * [`ArrivalProcess::Bursty`] — bursts of back-to-back arrivals with
//!   exponential gaps *between* bursts, preserving the same long-run
//!   rate; stresses admission and the shared KV pool much harder.
//!
//! **Closed loop** ([`ClosedLoopSpec`]) — a fixed client population;
//! each client issues one request, waits for its completion, thinks for
//! an exponential time, and issues the next. Offered load self-throttles
//! with server latency, which is what makes *saturation* observable: an
//! open loop past capacity just grows its queue without bound, a closed
//! loop settles at the concurrency the cluster can actually sustain.
//! The arrival stream is completion-driven, so the generator is
//! interactive ([`ClosedLoopClients::next_arrival`]) rather than
//! pregenerated.
//!
//! Generation is a pure function of `(spec, seed)` — for the closed
//! loop, of `(spec, seed, completion history)` — with no global state
//! and no threading, so arrival sequences are bit-identical across runs
//! and trivially invariant to the harness `--threads` setting
//! (`tests/parallel_determinism.rs` locks this in).

use crate::util::rng::Rng;

/// Shape of the request inter-arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals: exponential gaps with mean `1 / rate_rps`.
    Poisson {
        /// Mean request rate in requests per second.
        rate_rps: f64,
    },
    /// Bursts of `burst` simultaneous requests; exponential gaps between
    /// bursts sized so the long-run mean rate is still `rate_rps`.
    Bursty {
        /// Long-run mean request rate in requests per second.
        rate_rps: f64,
        /// Requests per burst (>= 1).
        burst: usize,
    },
}

/// One request arrival produced by [`WorkloadSpec::generate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Dense request id in arrival order (0, 1, 2, ...).
    pub rid: usize,
    /// Question index into the benchmark's question pool.
    pub qid: usize,
    /// Arrival wall-clock time in seconds from simulation start.
    pub t_arrive: f64,
}

/// A complete open-loop workload description.
///
/// # Examples
///
/// Generation is deterministic per seed:
///
/// ```
/// use step::sim::workload::WorkloadSpec;
///
/// let spec = WorkloadSpec::poisson(2.0, 8);
/// let a = spec.generate(30, 7);
/// let b = spec.generate(30, 7);
/// assert_eq!(a.len(), 8);
/// assert_eq!(a, b);
/// assert!(a.windows(2).all(|w| w[0].t_arrive <= w[1].t_arrive));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    /// The inter-arrival process.
    pub arrivals: ArrivalProcess,
    /// Total number of requests to generate.
    pub n_requests: usize,
}

impl WorkloadSpec {
    /// Poisson workload at `rate_rps` requests/second.
    pub fn poisson(rate_rps: f64, n_requests: usize) -> WorkloadSpec {
        WorkloadSpec { arrivals: ArrivalProcess::Poisson { rate_rps }, n_requests }
    }

    /// Bursty workload: bursts of `burst` requests, long-run `rate_rps`.
    pub fn bursty(rate_rps: f64, burst: usize, n_requests: usize) -> WorkloadSpec {
        WorkloadSpec {
            arrivals: ArrivalProcess::Bursty { rate_rps, burst: burst.max(1) },
            n_requests,
        }
    }

    /// Long-run mean request rate of the process, requests/second.
    pub fn rate_rps(&self) -> f64 {
        match self.arrivals {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Bursty { rate_rps, .. } => rate_rps,
        }
    }

    /// Generate the arrival sequence over a pool of `n_questions`
    /// benchmark questions. Deterministic in `(self, seed)`: the whole
    /// sequence derives from one seeded RNG stream, arrival times are
    /// non-decreasing, and question ids are drawn uniformly from the
    /// pool (so heavy pools repeat questions, like real traffic).
    pub fn generate(&self, n_questions: usize, seed: u64) -> Vec<Arrival> {
        let rate = self.rate_rps();
        assert!(rate > 0.0, "workload rate must be positive");
        let n_questions = n_questions.max(1);
        let mut rng = Rng::new(seed ^ 0x57A3_10AD_0A61_77E5);
        let mut out = Vec::with_capacity(self.n_requests);
        let mut clock = 0.0f64;
        match self.arrivals {
            ArrivalProcess::Poisson { .. } => {
                for rid in 0..self.n_requests {
                    clock += exp_gap(&mut rng, rate);
                    out.push(Arrival { rid, qid: rng.below(n_questions), t_arrive: clock });
                }
            }
            ArrivalProcess::Bursty { burst, .. } => {
                // Gap between bursts carries `burst` requests' worth of
                // inter-arrival budget, keeping the long-run rate fixed.
                let mut rid = 0;
                while rid < self.n_requests {
                    clock += exp_gap(&mut rng, rate / burst as f64);
                    let k = burst.min(self.n_requests - rid);
                    for _ in 0..k {
                        out.push(Arrival { rid, qid: rng.below(n_questions), t_arrive: clock });
                        rid += 1;
                    }
                }
            }
        }
        out
    }
}

/// One exponential inter-arrival gap at `rate` events/second.
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    // f64() is in [0, 1), so 1 - u is in (0, 1] and ln() is finite. The
    // max(0.0) normalizes the u = 0 draw's -0.0 to +0.0: arrival times
    // must stay non-negative *by bit pattern* too, because the cluster's
    // event heap orders times by their IEEE-754 bits.
    (-(1.0 - rng.f64()).ln() / rate).max(0.0)
}

/// A closed-loop client population: `clients` concurrent users, each
/// cycling request → wait for completion → think → next request, until
/// a global budget of `n_requests` has been issued.
///
/// The `heavy_frac` knob pins a leading fraction of the clients to a
/// caller-supplied "heavy" question subset (e.g. the benchmark's
/// longest-trace questions), producing the skewed per-request KV
/// footprints that separate load-aware routing from round-robin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClosedLoopSpec {
    /// Concurrent client population size (>= 1).
    pub clients: usize,
    /// Mean exponential think time between a completion and the
    /// client's next request, seconds (> 0).
    pub think_mean_s: f64,
    /// Total requests issued across all clients before the run drains.
    pub n_requests: usize,
    /// Fraction of the client population pinned to the heavy question
    /// subset (0.0 = every client draws uniformly).
    pub heavy_frac: f64,
}

impl ClosedLoopSpec {
    /// A uniform closed loop: `clients` users, `think_mean_s` mean think
    /// time, `n_requests` total budget, no skew.
    pub fn new(clients: usize, think_mean_s: f64, n_requests: usize) -> ClosedLoopSpec {
        ClosedLoopSpec {
            clients: clients.max(1),
            think_mean_s,
            n_requests,
            heavy_frac: 0.0,
        }
    }

    /// Same population with the leading `heavy_frac` of clients pinned
    /// to the heavy question subset.
    pub fn skewed(
        clients: usize,
        think_mean_s: f64,
        n_requests: usize,
        heavy_frac: f64,
    ) -> ClosedLoopSpec {
        ClosedLoopSpec {
            clients: clients.max(1),
            think_mean_s,
            n_requests,
            heavy_frac: heavy_frac.clamp(0.0, 1.0),
        }
    }

    /// Instantiate the client population. `heavy_qids` is the heavy
    /// question subset skewed clients draw from (callers typically pass
    /// the top trace-length quartile; ignored when empty or when
    /// `heavy_frac` is 0). Deterministic in `(self, seed)`: every
    /// client owns an independent RNG stream derived from the seed.
    pub fn clients(
        &self,
        n_questions: usize,
        heavy_qids: Vec<usize>,
        seed: u64,
    ) -> ClosedLoopClients {
        assert!(self.think_mean_s > 0.0, "think time must be positive");
        let n_heavy = if heavy_qids.is_empty() {
            0
        } else {
            ((self.clients as f64 * self.heavy_frac).round() as usize).min(self.clients)
        };
        let streams = (0..self.clients)
            .map(|c| {
                Rng::new(
                    seed ^ 0xC105_ED10_0BAD_C0DE
                        ^ (c as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            })
            .collect();
        ClosedLoopClients {
            spec: *self,
            n_questions: n_questions.max(1),
            heavy_qids,
            n_heavy,
            streams,
            issued: 0,
            client_of: Vec::new(),
        }
    }
}

/// Live state of a [`ClosedLoopSpec`] population: per-client RNG
/// streams and the global request budget.
///
/// # Examples
///
/// The stream is deterministic given the seed and the completion
/// history:
///
/// ```
/// use step::sim::workload::ClosedLoopSpec;
///
/// let spec = ClosedLoopSpec::new(2, 30.0, 4);
/// let mut a = spec.clients(10, Vec::new(), 7);
/// let mut b = spec.clients(10, Vec::new(), 7);
/// let first_a = a.initial_arrivals();
/// let first_b = b.initial_arrivals();
/// assert_eq!(first_a, first_b);
/// assert_eq!(first_a.len(), 2);
/// // Client 0's request completes at t = 100: its next arrival is
/// // reproducible and strictly later.
/// let next_a = a.next_arrival(0, 100.0).unwrap();
/// let next_b = b.next_arrival(0, 100.0).unwrap();
/// assert_eq!(next_a, next_b);
/// assert!(next_a.t_arrive > 100.0);
/// ```
#[derive(Debug, Clone)]
pub struct ClosedLoopClients {
    spec: ClosedLoopSpec,
    n_questions: usize,
    heavy_qids: Vec<usize>,
    /// Clients `0..n_heavy` draw from `heavy_qids`; the rest uniform.
    n_heavy: usize,
    streams: Vec<Rng>,
    issued: usize,
    /// Issuing client per request id (dense, issue order).
    client_of: Vec<usize>,
}

impl ClosedLoopClients {
    /// Total requests issued so far (request ids are `0..issued`).
    pub fn issued(&self) -> usize {
        self.issued
    }

    /// Requests still available under the global budget.
    pub fn remaining(&self) -> usize {
        self.spec.n_requests.saturating_sub(self.issued)
    }

    /// The client that issued request `rid`.
    pub fn client_of(&self, rid: usize) -> usize {
        self.client_of[rid]
    }

    /// Draw one request for `client` arriving at `t`.
    fn issue(&mut self, client: usize, t: f64) -> Arrival {
        let rid = self.issued;
        self.issued += 1;
        self.client_of.push(client);
        let rng = &mut self.streams[client];
        let qid = if client < self.n_heavy {
            self.heavy_qids[rng.below(self.heavy_qids.len())]
        } else {
            rng.below(self.n_questions)
        };
        Arrival { rid, qid, t_arrive: t }
    }

    /// The initial wave: one request per client at an exponential think
    /// offset from t = 0 (clients do not all arrive at one instant).
    /// Stops early if the budget is smaller than the population. Call
    /// exactly once, before any [`next_arrival`](Self::next_arrival).
    pub fn initial_arrivals(&mut self) -> Vec<Arrival> {
        assert_eq!(self.issued, 0, "initial_arrivals must be the first issue");
        let n = self.spec.clients.min(self.spec.n_requests);
        (0..n)
            .map(|c| {
                let gap = exp_gap(&mut self.streams[c], 1.0 / self.spec.think_mean_s);
                self.issue(c, gap)
            })
            .collect()
    }

    /// The next request of the client whose previous request completed
    /// at `t_done`: it thinks for an exponential gap, then arrives.
    /// `None` once the global budget is spent.
    pub fn next_arrival(&mut self, client: usize, t_done: f64) -> Option<Arrival> {
        if self.remaining() == 0 {
            return None;
        }
        let gap = exp_gap(&mut self.streams[client], 1.0 / self.spec.think_mean_s);
        Some(self.issue(client, t_done + gap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::poisson(1.5, 32);
        assert_eq!(spec.generate(30, 7), spec.generate(30, 7));
        assert_ne!(spec.generate(30, 7), spec.generate(30, 8));
    }

    #[test]
    fn times_non_decreasing_and_ids_dense() {
        for spec in [WorkloadSpec::poisson(2.0, 50), WorkloadSpec::bursty(2.0, 4, 50)] {
            let arr = spec.generate(10, 3);
            assert_eq!(arr.len(), 50);
            for (i, a) in arr.iter().enumerate() {
                assert_eq!(a.rid, i);
                assert!(a.qid < 10);
                assert!(a.t_arrive > 0.0);
            }
            assert!(arr.windows(2).all(|w| w[0].t_arrive <= w[1].t_arrive));
        }
    }

    #[test]
    fn poisson_rate_approximately_matches() {
        let spec = WorkloadSpec::poisson(4.0, 4000);
        let arr = spec.generate(30, 11);
        let span = arr.last().unwrap().t_arrive;
        let rate = arr.len() as f64 / span;
        assert!((rate - 4.0).abs() < 0.4, "empirical rate {rate}");
    }

    #[test]
    fn bursty_matches_long_run_rate_and_groups() {
        let spec = WorkloadSpec::bursty(4.0, 8, 4000);
        let arr = spec.generate(30, 11);
        let span = arr.last().unwrap().t_arrive;
        let rate = arr.len() as f64 / span;
        assert!((rate - 4.0).abs() < 0.5, "empirical rate {rate}");
        // All members of a burst share one arrival instant.
        assert_eq!(arr[0].t_arrive, arr[7].t_arrive);
        assert!(arr[8].t_arrive > arr[7].t_arrive);
    }

    #[test]
    fn questions_cover_the_pool() {
        let arr = WorkloadSpec::poisson(1.0, 400).generate(5, 1);
        let mut seen = [false; 5];
        for a in &arr {
            seen[a.qid] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn closed_loop_budget_and_rid_density() {
        let spec = ClosedLoopSpec::new(3, 10.0, 7);
        let mut cl = spec.clients(10, Vec::new(), 5);
        let first = cl.initial_arrivals();
        assert_eq!(first.len(), 3);
        for (i, a) in first.iter().enumerate() {
            assert_eq!(a.rid, i);
            assert!(a.t_arrive > 0.0);
            assert!(a.qid < 10);
            assert_eq!(cl.client_of(a.rid), i);
        }
        // Cycle completions round-robin until the budget runs dry.
        let mut t = 100.0;
        let mut client = 0;
        let mut rids = Vec::new();
        while let Some(a) = cl.next_arrival(client, t) {
            assert!(a.t_arrive > t);
            rids.push(a.rid);
            t += 50.0;
            client = (client + 1) % 3;
        }
        assert_eq!(cl.issued(), 7);
        assert_eq!(rids, vec![3, 4, 5, 6]);
        assert_eq!(cl.remaining(), 0);
    }

    #[test]
    fn closed_loop_budget_smaller_than_population() {
        let spec = ClosedLoopSpec::new(8, 10.0, 3);
        let mut cl = spec.clients(5, Vec::new(), 1);
        assert_eq!(cl.initial_arrivals().len(), 3);
        assert_eq!(cl.next_arrival(0, 1.0), None);
    }

    #[test]
    fn closed_loop_heavy_clients_draw_from_heavy_set() {
        let spec = ClosedLoopSpec::skewed(4, 10.0, 40, 0.5);
        let heavy = vec![7usize, 9];
        let mut cl = spec.clients(10, heavy.clone(), 3);
        let first = cl.initial_arrivals();
        // Clients 0 and 1 (the leading 50%) are pinned to the heavy set.
        for a in &first[..2] {
            assert!(heavy.contains(&a.qid), "heavy client drew {}", a.qid);
        }
        let mut t = 0.0;
        for _ in 0..10 {
            let a = cl.next_arrival(0, t).unwrap();
            assert!(heavy.contains(&a.qid));
            t = a.t_arrive;
        }
        // Uniform clients can reach the whole pool.
        let mut seen = [false; 10];
        let mut t = 0.0;
        while let Some(a) = cl.next_arrival(3, t) {
            seen[a.qid] = true;
            t = a.t_arrive;
        }
        assert!(seen.iter().filter(|&&s| s).count() > 2);
    }

    #[test]
    fn closed_loop_deterministic_per_seed() {
        let spec = ClosedLoopSpec::skewed(3, 20.0, 12, 0.34);
        let drive = |seed: u64| -> Vec<Arrival> {
            let mut cl = spec.clients(10, vec![1, 2], seed);
            let mut out = cl.initial_arrivals();
            let mut t = 10.0;
            let mut c = 0;
            while let Some(a) = cl.next_arrival(c, t) {
                t = a.t_arrive + 5.0;
                c = (c + 1) % 3;
                out.push(a);
            }
            out
        };
        assert_eq!(drive(11), drive(11));
        assert_ne!(drive(11), drive(12));
    }
}
