//! Open-loop workload generator for the multi-request serving simulator
//! ([`crate::sim::serve`]).
//!
//! A workload is a deterministic sequence of request arrivals over a
//! benchmark's question pool: each arrival carries a request id, the
//! question it asks, and its wall-clock arrival time. Arrival times come
//! from an open-loop process (the client does not wait for responses —
//! the regime where continuous batching and the paper's §4.2
//! memory-triggered pruning actually matter):
//!
//! * [`ArrivalProcess::Poisson`] — i.i.d. exponential inter-arrival gaps
//!   at a target request rate, the standard serving-benchmark model.
//! * [`ArrivalProcess::Bursty`] — bursts of back-to-back arrivals with
//!   exponential gaps *between* bursts, preserving the same long-run
//!   rate; stresses admission and the shared KV pool much harder.
//!
//! Generation is a pure function of `(spec, seed)` — no global state, no
//! threading — so arrival sequences are bit-identical across runs and
//! trivially invariant to the harness `--threads` setting
//! (`tests/parallel_determinism.rs` locks this in).

use crate::util::rng::Rng;

/// Shape of the request inter-arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals: exponential gaps with mean `1 / rate_rps`.
    Poisson {
        /// Mean request rate in requests per second.
        rate_rps: f64,
    },
    /// Bursts of `burst` simultaneous requests; exponential gaps between
    /// bursts sized so the long-run mean rate is still `rate_rps`.
    Bursty {
        /// Long-run mean request rate in requests per second.
        rate_rps: f64,
        /// Requests per burst (>= 1).
        burst: usize,
    },
}

/// One request arrival produced by [`WorkloadSpec::generate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Dense request id in arrival order (0, 1, 2, ...).
    pub rid: usize,
    /// Question index into the benchmark's question pool.
    pub qid: usize,
    /// Arrival wall-clock time in seconds from simulation start.
    pub t_arrive: f64,
}

/// A complete open-loop workload description.
///
/// # Examples
///
/// Generation is deterministic per seed:
///
/// ```
/// use step::sim::workload::WorkloadSpec;
///
/// let spec = WorkloadSpec::poisson(2.0, 8);
/// let a = spec.generate(30, 7);
/// let b = spec.generate(30, 7);
/// assert_eq!(a.len(), 8);
/// assert_eq!(a, b);
/// assert!(a.windows(2).all(|w| w[0].t_arrive <= w[1].t_arrive));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadSpec {
    /// The inter-arrival process.
    pub arrivals: ArrivalProcess,
    /// Total number of requests to generate.
    pub n_requests: usize,
}

impl WorkloadSpec {
    /// Poisson workload at `rate_rps` requests/second.
    pub fn poisson(rate_rps: f64, n_requests: usize) -> WorkloadSpec {
        WorkloadSpec { arrivals: ArrivalProcess::Poisson { rate_rps }, n_requests }
    }

    /// Bursty workload: bursts of `burst` requests, long-run `rate_rps`.
    pub fn bursty(rate_rps: f64, burst: usize, n_requests: usize) -> WorkloadSpec {
        WorkloadSpec {
            arrivals: ArrivalProcess::Bursty { rate_rps, burst: burst.max(1) },
            n_requests,
        }
    }

    /// Long-run mean request rate of the process, requests/second.
    pub fn rate_rps(&self) -> f64 {
        match self.arrivals {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Bursty { rate_rps, .. } => rate_rps,
        }
    }

    /// Generate the arrival sequence over a pool of `n_questions`
    /// benchmark questions. Deterministic in `(self, seed)`: the whole
    /// sequence derives from one seeded RNG stream, arrival times are
    /// non-decreasing, and question ids are drawn uniformly from the
    /// pool (so heavy pools repeat questions, like real traffic).
    pub fn generate(&self, n_questions: usize, seed: u64) -> Vec<Arrival> {
        let rate = self.rate_rps();
        assert!(rate > 0.0, "workload rate must be positive");
        let n_questions = n_questions.max(1);
        let mut rng = Rng::new(seed ^ 0x57A3_10AD_0A61_77E5);
        let mut out = Vec::with_capacity(self.n_requests);
        let mut clock = 0.0f64;
        match self.arrivals {
            ArrivalProcess::Poisson { .. } => {
                for rid in 0..self.n_requests {
                    clock += exp_gap(&mut rng, rate);
                    out.push(Arrival { rid, qid: rng.below(n_questions), t_arrive: clock });
                }
            }
            ArrivalProcess::Bursty { burst, .. } => {
                // Gap between bursts carries `burst` requests' worth of
                // inter-arrival budget, keeping the long-run rate fixed.
                let mut rid = 0;
                while rid < self.n_requests {
                    clock += exp_gap(&mut rng, rate / burst as f64);
                    let k = burst.min(self.n_requests - rid);
                    for _ in 0..k {
                        out.push(Arrival { rid, qid: rng.below(n_questions), t_arrive: clock });
                        rid += 1;
                    }
                }
            }
        }
        out
    }
}

/// One exponential inter-arrival gap at `rate` events/second.
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    // f64() is in [0, 1), so 1 - u is in (0, 1] and ln() is finite.
    -(1.0 - rng.f64()).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::poisson(1.5, 32);
        assert_eq!(spec.generate(30, 7), spec.generate(30, 7));
        assert_ne!(spec.generate(30, 7), spec.generate(30, 8));
    }

    #[test]
    fn times_non_decreasing_and_ids_dense() {
        for spec in [WorkloadSpec::poisson(2.0, 50), WorkloadSpec::bursty(2.0, 4, 50)] {
            let arr = spec.generate(10, 3);
            assert_eq!(arr.len(), 50);
            for (i, a) in arr.iter().enumerate() {
                assert_eq!(a.rid, i);
                assert!(a.qid < 10);
                assert!(a.t_arrive > 0.0);
            }
            assert!(arr.windows(2).all(|w| w[0].t_arrive <= w[1].t_arrive));
        }
    }

    #[test]
    fn poisson_rate_approximately_matches() {
        let spec = WorkloadSpec::poisson(4.0, 4000);
        let arr = spec.generate(30, 11);
        let span = arr.last().unwrap().t_arrive;
        let rate = arr.len() as f64 / span;
        assert!((rate - 4.0).abs() < 0.4, "empirical rate {rate}");
    }

    #[test]
    fn bursty_matches_long_run_rate_and_groups() {
        let spec = WorkloadSpec::bursty(4.0, 8, 4000);
        let arr = spec.generate(30, 11);
        let span = arr.last().unwrap().t_arrive;
        let rate = arr.len() as f64 / span;
        assert!((rate - 4.0).abs() < 0.5, "empirical rate {rate}");
        // All members of a burst share one arrival instant.
        assert_eq!(arr[0].t_arrive, arr[7].t_arrive);
        assert!(arr[8].t_arrive > arr[7].t_arrive);
    }

    #[test]
    fn questions_cover_the_pool() {
        let arr = WorkloadSpec::poisson(1.0, 400).generate(5, 1);
        let mut seen = [false; 5];
        for a in &arr {
            seen[a.qid] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
