//! Multi-request serving simulator: continuous batching of many
//! concurrent N-trace jobs against one shared KV pool.
//!
//! [`crate::sim::des`] simulates one question's trace set at a time — the
//! figure-reproduction regime. This module generalizes that event loop to
//! *request-level* serving: an open-loop workload
//! ([`crate::sim::workload`]) delivers questions at wall-clock arrival
//! times, a continuous-batching scheduler admits, preempts, and resumes
//! whole requests' traces against a single [`SharedKvPool`], and the
//! paper's §4.2 memory trigger becomes **cross-request**: when the pool
//! saturates, STEP prunes the trace with the lowest step score across
//! *all* running requests, regardless of which request owns it — exactly
//! the multi-tenant regime confidence-based baselines never model.
//!
//! Mechanics shared with the single-question engine (via the
//! [`crate::sim::sched`] scheduler core):
//! * lockstep continuous batching (one token per running trace per
//!   iteration) with analytic time jumps between events
//!   (`TimingModel::decode_interval`), so cost is O(#events) not
//!   O(#tokens);
//! * vLLM-style recompute-on-resume preemption for the SC family, FIFO
//!   resume, first-fit resume when the engine fully stalls;
//! * the same scoring / voting / method-policy modules.
//!
//! New here: request lifecycle tracking
//! ([`crate::coordinator::request`]), per-request KV quotas (optional —
//! a quota-bound owner triggers a memory event for that owner even while
//! the pool has room), and SLO metrics (queue delay, time-to-first-vote,
//! end-to-end latency) per request.
//!
//! The engine itself is the *steppable* [`ServeEngine`]: callers submit
//! arrivals and advance it event by event or up to a wall-clock limit,
//! which is what lets the cluster simulator ([`crate::sim::cluster`])
//! drive R of them under one global clock. [`ServeSim::run`] is the
//! single-GPU driver: it feeds one open-loop workload through one engine
//! to completion.
//!
//! The event loop is O(running + log) per event, not O(live): the
//! shared [`sched::EventIndex`] maintains the running set, the
//! `d_event` boundary horizon, the batch context size, and the
//! block-demand histograms (pool-wide and per-owner) incrementally at
//! status transitions, so the per-event scans and the per-probe
//! regather of the memory-horizon search are gone; per-trace wait and
//! decode time settle lazily from `last_settle` timestamps at status
//! changes ([`sched::settle`]) instead of accruing every live trace on
//! every clock move; and the KV-pressure router view
//! ([`survivor_demand_blocks`](ServeEngine::survivor_demand_blocks)) is
//! served from an incrementally maintained sorted score multiset when
//! [`ServeSimConfig::route_views`] is on, instead of sorting the live
//! set on every placement.
//!
//! Everything derives from `(config, seed)`: one run is bit-identical
//! across processes and thread counts.

use crate::coordinator::method::{Method, MethodParams};
use crate::coordinator::request::RequestState;
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::signal::{SignalScratch, SignalSpec, StepCtx, TraceSignal};
use crate::coordinator::trace::{TraceState, TraceStatus};
use crate::coordinator::voting::{weighted_vote, Vote};
use crate::kvcache::{OwnerId, PrefixShare, SharedKvPool};
use crate::metrics::EngineCounters;
use crate::obs::{EventKind, Recorder, SimEvent};
use crate::sim::des::ScoreAgg;
use crate::sim::gpu::GpuSpec;
use crate::sim::profiles::{BenchId, ModelId, ModelProfile};
use crate::sim::sched::{self, EventIndex, WaitQueue};
use crate::sim::tracegen::{Question, TraceGen, TraceSpec};
use crate::sim::workload::{Arrival, WorkloadSpec};
use crate::util::rng::Rng;

/// Configuration of one serving simulation (a method under a workload).
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Served model (sets KV geometry and timing coefficients).
    pub model: ModelId,
    /// Benchmark whose question pool the workload draws from.
    pub bench: BenchId,
    /// Test-time-scaling method driving the scheduler. `DeepConf` is not
    /// supported here: its two-stage warmup is a per-question protocol
    /// that has no continuous-batching rendering.
    pub method: Method,
    /// Traces per request (N); CoT forces 1.
    pub n_traces: usize,
    /// Method hyper-parameters (paper Appendix B.3).
    pub params: MethodParams,
    /// vLLM-style gpu_memory_utilization for the shared pool.
    pub mem_util: f64,
    /// PagedAttention block size in tokens.
    pub block_size: usize,
    /// Master seed; every stream (workload, questions, traces) derives
    /// from it.
    pub seed: u64,
    /// Step-score aggregation for pruning/voting (paper: running mean).
    pub score_agg: ScoreAgg,
    /// The open-loop arrival process ([`ServeSim::run`]'s driver; the
    /// cluster simulator submits arrivals itself and ignores this).
    pub workload: WorkloadSpec,
    /// Optional per-request KV quota as a fraction of the pool. `None`
    /// (default) = pool-bound only: one tenant may fill the pool and
    /// cross-request pruning arbitrates.
    pub quota_frac: Option<f64>,
    /// Maintain the incremental router-view aggregates (the sorted
    /// score multiset behind
    /// [`ServeEngine::survivor_demand_blocks`]). The cluster simulator
    /// turns this on — it queries the view on every placement; the
    /// single-GPU drivers leave it off and the view (if ever asked)
    /// falls back to an identical-result scan.
    pub route_views: bool,
    /// Hardware speed multiplier on every timing coefficient (1.0 =
    /// the calibrated baseline GPU; > 1 = proportionally slower). The
    /// cluster's heterogeneous pools set this per engine from each
    /// GPU's profile; `1.0` is bit-exact identity
    /// ([`crate::sim::timing::TimingModel::scaled`]).
    pub timing_scale: f64,
    /// Allow a memory event that would prune the *last surviving*
    /// trace of a request to instead evict the whole request into the
    /// migration outbox ([`ServeEngine::drain_migrations_into`]) so a
    /// cluster driver can relocate it to a less-pressured GPU. Off
    /// (default) the event prunes as always; single-GPU drivers have
    /// nowhere to relocate to and leave this off.
    pub migrate_rescue: bool,
    /// Share prompt-prefix KV copy-on-write: admissions pin a
    /// question's full prompt blocks once in the pool's prefix registry
    /// ([`crate::kvcache::SharedKvPool::allocate_seq_shared`]) and each
    /// trace holds only its private suffix, so repeated questions —
    /// and sibling traces of one request — stop paying prompt KV (and
    /// prompt prefill) per trace. Off (default) the engine's arithmetic
    /// is byte-identical to the pre-registry code.
    pub prefix_cache: bool,
    /// The pruning signal scoring step boundaries (`--signal`; default
    /// `hidden-mlp`, the paper's MLP over hidden states — byte-identical
    /// to the pre-trait scorer path).
    pub signal: SignalSpec,
}

impl ServeSimConfig {
    /// Paper-default serving configuration for a (model, bench, method)
    /// under `workload`.
    pub fn new(
        model: ModelId,
        bench: BenchId,
        method: Method,
        n_traces: usize,
        workload: WorkloadSpec,
    ) -> ServeSimConfig {
        ServeSimConfig {
            model,
            bench,
            method,
            n_traces,
            params: MethodParams::default(),
            mem_util: 0.9,
            block_size: 16,
            seed: 0,
            score_agg: ScoreAgg::Mean,
            workload,
            quota_frac: None,
            route_views: false,
            timing_scale: 1.0,
            migrate_rescue: false,
            prefix_cache: false,
            signal: SignalSpec::default(),
        }
    }

    /// Builder-style construction: the paper defaults of [`Self::new`]
    /// plus chainable field setters, so adding a config field is not a
    /// breaking change at every call site.
    pub fn builder(
        model: ModelId,
        bench: BenchId,
        method: Method,
        n_traces: usize,
        workload: WorkloadSpec,
    ) -> ServeSimConfigBuilder {
        ServeSimConfigBuilder { cfg: ServeSimConfig::new(model, bench, method, n_traces, workload) }
    }
}

/// Chainable builder over [`ServeSimConfig`]
/// ([`ServeSimConfig::builder`]).
#[derive(Debug, Clone)]
pub struct ServeSimConfigBuilder {
    cfg: ServeSimConfig,
}

impl ServeSimConfigBuilder {
    /// Set the master seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Set gpu_memory_utilization for the shared pool.
    pub fn mem_util(mut self, mem_util: f64) -> Self {
        self.cfg.mem_util = mem_util;
        self
    }

    /// Set the per-request KV quota fraction.
    pub fn quota_frac(mut self, quota_frac: Option<f64>) -> Self {
        self.cfg.quota_frac = quota_frac;
        self
    }

    /// Maintain the incremental router-view aggregates.
    pub fn route_views(mut self, on: bool) -> Self {
        self.cfg.route_views = on;
        self
    }

    /// Set the hardware speed multiplier.
    pub fn timing_scale(mut self, scale: f64) -> Self {
        self.cfg.timing_scale = scale;
        self
    }

    /// Allow last-survivor memory events to evict into the migration
    /// outbox.
    pub fn migrate_rescue(mut self, on: bool) -> Self {
        self.cfg.migrate_rescue = on;
        self
    }

    /// Share prompt-prefix KV copy-on-write.
    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.cfg.prefix_cache = on;
        self
    }

    /// Set the pruning signal.
    pub fn signal(mut self, signal: SignalSpec) -> Self {
        self.cfg.signal = signal;
        self
    }

    /// Set the step-score aggregation.
    pub fn score_agg(mut self, agg: ScoreAgg) -> Self {
        self.cfg.score_agg = agg;
        self
    }

    /// Finish: the configured [`ServeSimConfig`].
    pub fn build(self) -> ServeSimConfig {
        self.cfg
    }
}

/// A whole request extracted from one engine for relocation to another
/// ([`ServeEngine::extract_request`] →
/// [`ServeEngine::submit_migrated`]). Terminal traces travel with their
/// votes; surviving traces travel as preempted state and re-enter
/// through the target's wait queue, so the recompute cost of the moved
/// KV is charged by the same `sched` resume accounting every
/// preemption uses.
#[derive(Debug, Clone)]
pub struct MigratedRequest {
    /// Cluster-global request id.
    pub rid: usize,
    /// Question the request asks.
    pub qid: usize,
    /// Prompt tokens of the question (each surviving trace's resume
    /// prefill covers `prompt + generated` tokens).
    pub prompt_tokens: usize,
    /// Request lifecycle marks, carried so end-to-end latency spans
    /// hops.
    pub st: RequestState,
    /// Per-slot trace runtime state (scores, generated tokens, status,
    /// accrued wait/decode time), in slot order. Surviving traces leave
    /// the source as [`TraceStatus::Preempted`] — their KV is freed
    /// there and rebuilt by the target's recompute-on-resume path. The
    /// synthetic [`TraceSpec`]s are *not* carried: each is a pure
    /// function of `(question, global rid, slot)` through the shared
    /// [`TraceGen`], so the target regenerates them bit-identically.
    pub traces: Vec<TraceState>,
    /// Step boundaries the request crossed so far (Slim-SC cadence).
    pub boundaries: usize,
    /// Next Slim-SC check threshold.
    pub next_slim: usize,
    /// The request's similarity-check RNG, mid-stream.
    pub slim_rng: Rng,
    /// Non-terminal traces at extraction (always ≥ 1).
    pub live: usize,
    /// Source engine's clock at extraction.
    pub t_evict: f64,
}

impl MigratedRequest {
    /// Prefix tokens (prompt + generated) the target must recompute to
    /// resume every surviving trace — the migration's recompute bill.
    pub fn recompute_tokens(&self) -> u64 {
        self.traces
            .iter()
            .filter(|st| st.status.is_active())
            .map(|st| self.prompt_tokens as u64 + st.generated)
            .sum()
    }
}

/// Per-request outcome and SLO metrics of one serving run.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// Request id (the id the arrival carried; engine-local runs use
    /// arrival order, cluster runs use the cluster-global id).
    pub rid: usize,
    /// Question the request asked.
    pub qid: usize,
    /// Did the voted answer match ground truth?
    pub correct: bool,
    /// Voted answer (None = every trace abstained).
    pub chosen: Option<u32>,
    /// Arrival wall-clock, seconds.
    pub t_arrive: f64,
    /// Arrival -> first admission (queue delay), seconds.
    pub queue_s: f64,
    /// Arrival -> completion (end-to-end latency), seconds.
    pub latency_s: f64,
    /// Arrival -> first finished trace (time-to-first-vote), seconds.
    pub ttfv_s: f64,
    /// Tokens generated across the request's traces.
    pub gen_tokens: u64,
    /// Mean per-trace seconds spent waiting (admission queue, preemption,
    /// resume recompute) — the serving analog of Fig 2c's per-trace view.
    pub mean_wait_s: f64,
    /// Mean per-trace seconds spent decoding.
    pub mean_decode_s: f64,
    /// Traces that finished naturally.
    pub n_finished: usize,
    /// Traces removed by pruning (STEP / Slim-SC / stalled-queue drops).
    pub n_pruned: usize,
    /// Preemption events suffered by the request's traces.
    pub n_preemptions: usize,
}

/// Aggregate result of one serving simulation.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// One outcome per request, in submission order.
    pub outcomes: Vec<RequestOutcome>,
    /// Wall-clock from the first arrival's epoch to the last
    /// completion, seconds (the idle lead-in before traffic starts is
    /// excluded).
    pub makespan_s: f64,
    /// Engine-level event counters.
    pub counters: EngineCounters,
    /// Physical blocks in the shared pool.
    pub pool_blocks: usize,
    /// Peak blocks in use across the run.
    pub peak_used_blocks: usize,
}

impl ServeResult {
    /// Completed requests per second of simulated wall-clock.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.outcomes.len() as f64 / self.makespan_s
        }
    }
}

/// One live trace: owning request (engine-local index), synthetic spec,
/// runtime state.
struct ServeTrace {
    rid: usize,
    spec: TraceSpec,
    st: TraceState,
    /// Lazy-accrual mark: wall-clock up to which this trace's wait /
    /// decode time has been settled ([`sched::settle`]).
    last_settle: f64,
}

/// Per-request scheduling bookkeeping.
struct Req {
    st: RequestState,
    q: Question,
    /// Cached [`TraceGen::expected_trace_tokens`] of `q` (pure function
    /// of the question — computed once at submission for the router
    /// view instead of per trace per placement).
    expected_tokens: f64,
    /// Trace slot range `[lo, lo + n)` in the global trace vector.
    lo: usize,
    n: usize,
    /// Non-terminal traces remaining.
    live: usize,
    /// Step boundaries crossed (Slim-SC check cadence).
    boundaries: usize,
    next_slim: usize,
    slim_rng: Rng,
    /// Migrated out to another engine: this engine must neither report
    /// an outcome nor a completion for it.
    gone: bool,
}

/// Decrement a request's live-trace count; on the transition to zero,
/// mark it complete and report the completion to the engine's driver.
fn request_done(rq: &mut Req, clock: f64, completions: &mut Vec<(usize, f64)>) {
    rq.live -= 1;
    if rq.live == 0 {
        rq.st.completed(clock);
        completions.push((rq.st.rid, clock));
    }
}

/// The multi-request serving simulation: a configuration bound to a
/// trace generator and step scorer, plus the single-GPU workload driver
/// ([`ServeSim::run`]). The event-loop state lives in [`ServeEngine`].
pub struct ServeSim<'a> {
    cfg: &'a ServeSimConfig,
    gen: &'a TraceGen,
    scorer: &'a StepScorer,
    profile: ModelProfile,
}

/// What one engine event accomplished (see [`ServeEngine::run_until`]).
enum Step {
    /// State advanced: a decode interval, memory event, or resume/drop.
    Advanced,
    /// Nothing to do: no running traces and an empty waiting queue.
    Idle,
}

/// The steppable per-GPU serving engine: owns the shared KV pool, the
/// trace/request tables, and the clock. Drivers ([`ServeSim::run`] for
/// one GPU, [`crate::sim::cluster::ClusterSim`] for R of them) submit
/// arrivals with [`submit`](ServeEngine::submit) and advance the engine
/// with [`run_until`](ServeEngine::run_until) /
/// [`run_one_event`](ServeEngine::run_one_event), harvesting request
/// completions via
/// [`drain_completions_into`](ServeEngine::drain_completions_into).
pub struct ServeEngine<'a> {
    sim: ServeSim<'a>,
    n_per: usize,
    pool: SharedKvPool,
    pool_blocks: usize,
    reqs: Vec<Req>,
    traces: Vec<ServeTrace>,
    next_end: Vec<u64>,
    wait_q: WaitQueue,
    counters: EngineCounters,
    clock: f64,
    /// First submission's arrival time (the makespan epoch).
    epoch: Option<f64>,
    submitted: usize,
    drained: usize,
    /// Requests migrated out to other engines (they complete elsewhere).
    migrated_out: usize,
    /// Undrained completions: (external request id, completion clock).
    completions: Vec<(usize, f64)>,
    /// Migration outbox: whole requests a memory event evicted instead
    /// of pruning their last survivor ([`ServeSimConfig::migrate_rescue`]),
    /// awaiting relocation by the cluster driver.
    migrations: Vec<MigratedRequest>,
    /// Local indices of possibly-live requests, compacted lazily by
    /// [`migration_victim`](Self::migration_victim) — keeps the victim
    /// scan O(outstanding), not O(every request ever submitted).
    live_locals: Vec<usize>,
    /// Incremental index over the running set: O(1) `d_event` peek and
    /// batch context size, closed-form block-demand probes (pool-wide
    /// and per-owner), running-set snapshots without a live scan.
    index: EventIndex,
    /// Sorted multiset of the running traces' aggregated step scores,
    /// maintained at boundary crossings / status changes — the
    /// incremental backing of the KV-pressure router view (only kept
    /// when [`ServeSimConfig::route_views`] is on).
    scores_sorted: Vec<f64>,
    /// Monotone state-change counter: bumped by every mutation that can
    /// change the engine's router view (events, submissions, migrations).
    /// Cluster drivers cache `GpuView`s keyed by this and skip the
    /// refresh for engines that have not moved.
    version: u64,
    // Reusable hot-path buffers. `running` snapshots the index's u32
    // arena ids (ascending trace order).
    running: Vec<u32>,
    /// The pruning signal built from `cfg.signal` (owned per engine, so
    /// per-GPU engines stepped on different threads share nothing
    /// mutable).
    signal: Box<dyn TraceSignal>,
    /// Signal scratch (hidden-state / activation buffers) — the only
    /// mutable state the signal may touch.
    sig: SignalScratch,
    /// Attached event recorder (`None` — the default — is the zero-cost
    /// disabled path: one branch per emission site, no event
    /// construction). Recorders observe; they never influence
    /// scheduling.
    rec: Option<Box<dyn Recorder>>,
}

impl<'a> ServeSim<'a> {
    /// Bind a configuration to a trace generator and step scorer.
    ///
    /// Panics if `cfg.method` is [`Method::DeepConf`] (unsupported, see
    /// [`ServeSimConfig::method`]).
    pub fn new(cfg: &'a ServeSimConfig, gen: &'a TraceGen, scorer: &'a StepScorer) -> Self {
        assert!(
            cfg.method != Method::DeepConf,
            "serve-sim supports CoT/SC/Slim-SC/STEP; DeepConf's two-stage \
             warmup is a per-question protocol"
        );
        assert!(cfg.n_traces > 0, "n_traces must be positive");
        assert!(
            cfg.timing_scale.is_finite() && cfg.timing_scale > 0.0,
            "timing_scale must be a positive finite multiplier"
        );
        let mut profile = ModelProfile::get(cfg.model);
        profile.timing = profile.timing.scaled(cfg.timing_scale);
        ServeSim { cfg, gen, scorer, profile }
    }

    /// score_t under the configured aggregation (paper: running mean).
    fn agg_score(&self, st: &TraceState) -> f64 {
        let d = self.cfg.params.default_score;
        match self.cfg.score_agg {
            ScoreAgg::Mean => st.mean_score(d),
            ScoreAgg::Last => st.last_score(d),
            ScoreAgg::Ema => st.ema_score(d),
        }
    }

    /// Run the whole open-loop workload to completion on one engine.
    pub fn run(&self) -> ServeResult {
        let arrivals = self
            .cfg
            .workload
            .generate(self.gen.bench.n_questions, self.cfg.seed ^ 0xA331_4A11_D00D_FEED);
        let mut eng = ServeEngine::new(self.cfg, self.gen, self.scorer);
        let mut next_arr = 0usize;
        loop {
            // Admit every arrival due by now (admission prefills advance
            // the clock, which can make more arrivals due).
            while next_arr < arrivals.len() && arrivals[next_arr].t_arrive <= eng.clock() {
                eng.submit(&arrivals[next_arr]);
                next_arr += 1;
            }
            if next_arr < arrivals.len() {
                let t = arrivals[next_arr].t_arrive;
                if eng.is_idle() {
                    // Idle: jump to the next arrival.
                    eng.advance_idle_to(t);
                    continue;
                }
                eng.run_until(t);
            } else {
                eng.run_to_completion();
                break;
            }
        }
        eng.finish()
    }

    /// Largest iteration count `d <= gap`'s worth of decode time (binary
    /// search over the monotone closed-form interval cost).
    fn iters_within(&self, b: usize, k0: usize, cap: u64, gap: f64) -> u64 {
        let tm = self.profile.timing;
        sched::max_fitting(cap, |d| tm.decode_interval(b, k0, d) <= gap)
    }

    /// Would resuming trace `tid` fit right now (+1 block of headroom),
    /// pool and quota included?
    fn resume_fits(
        &self,
        traces: &[ServeTrace],
        reqs: &[Req],
        pool: &SharedKvPool,
        tid: usize,
    ) -> bool {
        let rid = traces[tid].rid;
        let prompt = reqs[rid].q.prompt_tokens;
        let generated = traces[tid].st.generated as usize;
        if self.cfg.prefix_cache {
            // Shared resume: a registry hit pays only the private
            // suffix; feasibility counts evictable cold prefixes. The
            // strict `>` keeps the plain path's +1 block of headroom.
            let qid = reqs[rid].st.qid;
            return pool.can_admit_shared(rid as OwnerId, qid, prompt, generated)
                && pool.available_blocks()
                    > pool.shared_blocks_needed(qid, prompt, generated);
        }
        let prefix = prompt + generated;
        pool.can_admit(rid as OwnerId, pool.blocks_needed_for_new(prefix) + 1)
    }
}

impl<'a> ServeEngine<'a> {
    /// A fresh engine over its own full-GPU [`SharedKvPool`]. The
    /// `workload` field of `cfg` is ignored — drivers submit arrivals.
    ///
    /// Panics if `cfg.method` is [`Method::DeepConf`] (see
    /// [`ServeSim::new`]).
    pub fn new(cfg: &'a ServeSimConfig, gen: &'a TraceGen, scorer: &'a StepScorer) -> Self {
        let sim = ServeSim::new(cfg, gen, scorer);
        let n_per = if cfg.method == Method::Cot { 1 } else { cfg.n_traces };
        let gpu = GpuSpec::gh200(cfg.mem_util);
        let pool_blocks = gpu
            .kv_capacity_blocks(
                sim.profile.weight_bytes,
                sim.profile.activation_bytes,
                sim.profile.kv_bytes_per_token,
                cfg.block_size,
            )
            .max(1);
        let quota = cfg.quota_frac.map(|f| ((pool_blocks as f64 * f) as usize).max(1));
        let pool = SharedKvPool::new(pool_blocks, cfg.block_size, quota);
        let mut sig = SignalScratch::new();
        sig.h.resize(gen.gen.d, 0.0);
        sig.z.resize(scorer.hidden, 0.0);
        // Per-owner demand aggregates are only needed when quotas can
        // bind the memory horizon.
        let index = EventIndex::new(cfg.block_size, quota.is_some());
        ServeEngine {
            sim,
            n_per,
            pool,
            pool_blocks,
            reqs: Vec::new(),
            traces: Vec::new(),
            next_end: Vec::new(),
            wait_q: WaitQueue::new(),
            counters: EngineCounters::default(),
            clock: 0.0,
            epoch: None,
            submitted: 0,
            drained: 0,
            migrated_out: 0,
            completions: Vec::new(),
            migrations: Vec::new(),
            live_locals: Vec::new(),
            index,
            scores_sorted: Vec::new(),
            version: 0,
            running: Vec::new(),
            signal: cfg.signal.build(scorer),
            sig,
            rec: None,
        }
    }

    /// Attach an event recorder; emission sites start constructing
    /// [`SimEvent`]s into it. Replaces any previous recorder.
    pub fn set_recorder(&mut self, rec: Box<dyn Recorder>) {
        self.rec = Some(rec);
    }

    /// Detach and return the recorder (drivers drain it before
    /// [`finish`](Self::finish) consumes the engine).
    pub fn take_recorder(&mut self) -> Option<Box<dyn Recorder>> {
        self.rec.take()
    }

    /// Record one event if a recorder is attached. The builder closure
    /// receives the engine's load stamp (live sequences, KV blocks in
    /// use) and runs only on the enabled path.
    #[inline]
    fn emit<F: FnOnce(usize, usize) -> SimEvent>(&mut self, build: F) {
        if let Some(rec) = self.rec.as_mut() {
            let live = self.pool.num_seqs();
            let kv = self.pool.used_blocks();
            rec.record(build(live, kv));
        }
    }

    /// Monotone state-change counter: increases whenever the engine's
    /// observable scheduling state (and hence its router view) may have
    /// changed — any advanced event, submission, or migration in/out.
    /// Equal versions guarantee an identical [`GpuView`] snapshot, so
    /// cluster drivers refresh views only for engines whose version
    /// moved since the last placement.
    ///
    /// [`GpuView`]: crate::sim::router::GpuView
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Current engine wall-clock, seconds.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Requests submitted and not yet complete (requests migrated out
    /// stopped being this engine's responsibility).
    pub fn outstanding(&self) -> usize {
        self.submitted - self.drained - self.completions.len() - self.migrated_out
    }

    /// No submitted request is still in flight.
    pub fn is_idle(&self) -> bool {
        self.outstanding() == 0
    }

    /// Live sequences resident in the engine's KV pool.
    pub fn live_traces(&self) -> usize {
        self.pool.num_seqs()
    }

    /// Free blocks in the engine's KV pool (hard free; see
    /// [`available_blocks`](Self::available_blocks)).
    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Hard-free plus reclaimable (zero-ref cached prefix) blocks —
    /// the capacity an admission willing to evict cold prefixes can
    /// reach. Equal to [`free_blocks`](Self::free_blocks) with the
    /// prefix cache off.
    pub fn available_blocks(&self) -> usize {
        self.pool.available_blocks()
    }

    /// Blocks a shared admission of question `qid` would reuse from
    /// the engine's prefix registry right now (0 on a miss or with the
    /// cache off) — the router's affinity signal, served from the
    /// pool's O(1) digest.
    pub fn prefix_hit_blocks(&self, qid: usize) -> usize {
        self.pool.prefix_hit_blocks(qid)
    }

    /// Physical blocks in the engine's KV pool.
    pub fn pool_blocks(&self) -> usize {
        self.pool_blocks
    }

    /// Jump an idle engine's clock forward to `t` (never backward).
    pub fn advance_idle_to(&mut self, t: f64) {
        debug_assert!(self.is_idle(), "only an idle engine may jump its clock");
        self.clock = self.clock.max(t);
    }

    /// Move all pending request completions `(request id, completion
    /// clock)` into `out`, in completion order.
    pub fn drain_completions_into(&mut self, out: &mut Vec<(usize, f64)>) {
        self.drained += self.completions.len();
        out.append(&mut self.completions);
    }

    /// Move all requests the engine evicted for relocation (memory
    /// events under [`ServeSimConfig::migrate_rescue`]) into `out`, in
    /// eviction order. The driver re-places them with
    /// [`submit_migrated`](Self::submit_migrated) on some engine.
    pub fn drain_migrations_into(&mut self, out: &mut Vec<MigratedRequest>) {
        out.append(&mut self.migrations);
    }

    /// The cheapest outstanding request to relocate: minimal surviving
    /// resident prefix (prompt + generated over its non-terminal
    /// traces — exactly the recompute the target will pay), tie-broken
    /// by lower external request id. `None` when nothing migratable is
    /// outstanding (every request complete, gone, or mid-drain).
    ///
    /// Scans the lazily compacted live-request index, so the cost is
    /// O(outstanding) — retired requests are dropped from the index the
    /// first time a scan visits them, not revisited forever. The victim
    /// is a minimum over a set, so the index's (compaction-dependent)
    /// iteration order cannot change the result.
    pub fn migration_victim(&mut self) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        let mut i = 0;
        while i < self.live_locals.len() {
            let rq = &self.reqs[self.live_locals[i]];
            if rq.gone || rq.live == 0 {
                self.live_locals.swap_remove(i);
                continue;
            }
            let cost: u64 = self.traces[rq.lo..rq.lo + rq.n]
                .iter()
                .filter(|t| t.st.status.is_active())
                .map(|t| rq.q.prompt_tokens as u64 + t.st.generated)
                .sum();
            let key = (cost, rq.st.rid);
            let better = match best {
                None => true,
                Some(b) => key < b,
            };
            if better {
                best = Some(key);
            }
            i += 1;
        }
        best.map(|(_, rid)| rid)
    }

    /// Extract a whole request for relocation to another engine: its
    /// running traces leave the index and free their KV (settled as
    /// decode through now, then marked preempted for transport), its
    /// queued traces leave the wait queue, and the request stops
    /// counting toward this engine's [`outstanding`](Self::outstanding).
    /// Returns `None` when `external_rid` is unknown, already gone, or
    /// already complete. O(outstanding): the lookup goes through the
    /// live-request index, not the full historical request table.
    pub fn extract_request(&mut self, external_rid: usize) -> Option<MigratedRequest> {
        let local = self.live_locals.iter().copied().find(|&l| {
            let rq = &self.reqs[l];
            rq.st.rid == external_rid && !rq.gone && rq.live > 0
        })?;
        Some(self.extract_local(local))
    }

    /// [`extract_request`](Self::extract_request) by local request
    /// index (the in-engine rescue path already holds it).
    fn extract_local(&mut self, local: usize) -> MigratedRequest {
        debug_assert!(!self.reqs[local].gone && self.reqs[local].live > 0);
        let (lo, n) = (self.reqs[local].lo, self.reqs[local].n);
        let clock = self.clock;
        for tid in lo..lo + n {
            match self.traces[tid].st.status {
                TraceStatus::Running => {
                    self.index_remove(tid);
                    let t = &mut self.traces[tid];
                    sched::settle(&mut t.st, &mut t.last_settle, clock);
                    t.st.status = TraceStatus::Preempted;
                    self.pool.free_seq(tid as u64);
                }
                TraceStatus::Preempted => {
                    let removed = self.wait_q.remove(tid);
                    debug_assert!(removed, "a preempted trace is queued");
                    let t = &mut self.traces[tid];
                    sched::settle(&mut t.st, &mut t.last_settle, clock);
                }
                _ => {}
            }
        }
        self.debug_check_pool();
        let traces = self.traces[lo..lo + n].iter().map(|t| t.st.clone()).collect();
        let rq = &mut self.reqs[local];
        let live = rq.live;
        rq.live = 0;
        rq.gone = true;
        self.migrated_out += 1;
        self.version += 1;
        MigratedRequest {
            rid: rq.st.rid,
            qid: rq.st.qid,
            prompt_tokens: rq.q.prompt_tokens,
            st: rq.st.clone(),
            traces,
            boundaries: rq.boundaries,
            next_slim: rq.next_slim,
            slim_rng: rq.slim_rng.clone(),
            live,
            t_evict: clock,
        }
    }

    /// Admit a migrated request extracted from another engine. Terminal
    /// traces keep their votes; surviving traces join the wait queue as
    /// preempted and are rebuilt by the normal recompute-on-resume path
    /// (prefill over prompt + generated — the `sched` recompute
    /// accounting the migration is charged through). Trace specs are
    /// regenerated from the shared [`TraceGen`], bit-identical to the
    /// source's. An idle engine's clock first jumps to the eviction
    /// instant (the request cannot arrive before it left).
    pub fn submit_migrated(&mut self, m: MigratedRequest) {
        debug_assert_eq!(m.traces.len(), self.n_per, "engines share the cluster's N");
        debug_assert!(m.live > 0, "migrating a completed request");
        if self.is_idle() {
            self.clock = self.clock.max(m.t_evict);
        }
        if self.epoch.is_none() {
            self.epoch = Some(m.t_evict);
        }
        self.submitted += 1;
        let local = self.reqs.len();
        let q = self.sim.gen.question(m.qid);
        let expected_tokens = self.sim.gen.expected_trace_tokens(&q);
        let lo = self.traces.len();
        let clock = self.clock;
        let mut live = 0usize;
        for (i, mut st) in m.traces.into_iter().enumerate() {
            let tid = lo + i;
            let spec = self.sim.gen.trace(&q, m.rid * self.n_per + i);
            st.id = tid as u64;
            if st.status.is_active() {
                st.status = TraceStatus::Preempted;
                // The source settled this trace through `t_evict` on
                // its own clock, but accrual resumes from this engine's
                // clock — a busy target may trail (or lead) the
                // eviction instant. Pre-charging the signed gap makes
                // the trace's total wait over the hybrid timeline come
                // out to exactly `resume clock − t_evict`, instead of
                // double- or under-counting the skew window. Scheduling
                // never reads these sums.
                st.wait_time += clock - m.t_evict;
                live += 1;
                debug_assert!(
                    st.generated < spec.step_ends[st.next_step],
                    "a surviving trace sits strictly before its next boundary"
                );
                self.next_end.push(spec.step_ends[st.next_step]);
                self.wait_q.push_back(tid);
            } else {
                self.next_end.push(st.generated);
            }
            self.traces.push(ServeTrace { rid: local, spec, st, last_settle: clock });
        }
        debug_assert_eq!(live, m.live);
        self.version += 1;
        self.live_locals.push(local);
        self.reqs.push(Req {
            st: m.st,
            q,
            expected_tokens,
            lo,
            n: self.n_per,
            live,
            boundaries: m.boundaries,
            next_slim: m.next_slim,
            slim_rng: m.slim_rng,
            gone: false,
        });
    }

    /// Estimated KV blocks the engine's *surviving* traces still need to
    /// finish — the KV-pressure signal the cluster router consumes.
    ///
    /// Per running trace the expected remaining generation is the
    /// question's expected trace length
    /// ([`TraceGen::expected_trace_tokens`] — the scheduler cannot see
    /// sampled lengths) minus what the trace already generated, floored
    /// at one step. Under STEP the demand is weighted by the trace's
    /// survival odds — its score's rank fraction among the running set,
    /// since the lowest-scored trace is the next prune victim — which is
    /// exactly the signal per-trace confidence baselines cannot provide.
    ///
    /// With [`ServeSimConfig::route_views`] on, the score ranks come
    /// from the incrementally maintained sorted multiset (no sort, no
    /// allocation per placement); otherwise this falls back to
    /// [`survivor_demand_blocks_scan`](Self::survivor_demand_blocks_scan).
    /// Both paths produce bit-identical values — the differential
    /// property suite locks that in.
    pub fn survivor_demand_blocks(&self) -> f64 {
        if self.sim.cfg.route_views {
            debug_assert_eq!(self.scores_sorted.len(), self.index.running());
            self.survivor_fold(&self.scores_sorted)
        } else {
            self.survivor_demand_blocks_scan()
        }
    }

    /// Scan-based reference for
    /// [`survivor_demand_blocks`](Self::survivor_demand_blocks): gather
    /// and sort the running traces' scores on every call. Kept public as
    /// the differential baseline for the property tests and the
    /// `router/pressure_*` microbenchmarks.
    pub fn survivor_demand_blocks_scan(&self) -> f64 {
        let mut sorted: Vec<f64> = self
            .index
            .tids()
            .iter()
            .map(|&i| self.sim.agg_score(&self.traces[i as usize].st))
            .collect();
        sorted.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        self.survivor_fold(&sorted)
    }

    /// The demand fold shared by both router-view paths; `sorted` is
    /// the ascending multiset of the running traces' aggregated scores.
    /// `below` (the count of strictly lower scores) is the first index
    /// of the score's equal-run in the sorted order, so ties share a
    /// weight.
    fn survivor_fold(&self, sorted: &[f64]) -> f64 {
        let n_run = self.index.running();
        if n_run == 0 {
            return 0.0;
        }
        let floor = self.sim.gen.bench.tokens_per_step;
        let weighted = self.sim.cfg.method == Method::Step && n_run > 1;
        let n = n_run as f64;
        let bs = self.sim.cfg.block_size as f64;
        let mut demand = 0.0;
        for &i in self.index.tids() {
            let t = &self.traces[i as usize];
            let s = self.sim.agg_score(&t.st);
            let remaining = (self.reqs[t.rid].expected_tokens - t.st.generated as f64).max(floor);
            let w = if weighted {
                let below = sorted.partition_point(|&x| x < s) as f64;
                0.5 + 0.5 * below / (n - 1.0)
            } else {
                1.0
            };
            demand += w * remaining / bs;
        }
        demand
    }

    /// Register a trace entering the running set: index it (with its
    /// `resident` prefix tokens) and, when router views are maintained,
    /// add its aggregated score to the sorted multiset.
    fn index_insert(&mut self, tid: usize, resident: usize) {
        let dist = self.next_end[tid] - self.traces[tid].st.generated;
        let owner = self.traces[tid].rid as OwnerId;
        self.index.insert(tid as u32, owner, resident as u64, dist);
        if self.sim.cfg.route_views {
            let s = self.sim.agg_score(&self.traces[tid].st);
            let p = self.scores_sorted.partition_point(|&x| x < s);
            self.scores_sorted.insert(p, s);
        }
    }

    /// Remove a trace from the running set (prune / preempt / finish):
    /// drop it from the index and (when maintained) its current
    /// aggregated score from the sorted multiset.
    fn index_remove(&mut self, tid: usize) {
        self.index.remove(tid as u32);
        if self.sim.cfg.route_views {
            let s = self.sim.agg_score(&self.traces[tid].st);
            let p = self.scores_sorted.partition_point(|&x| x < s);
            debug_assert_eq!(self.scores_sorted.get(p), Some(&s), "score multiset drift");
            self.scores_sorted.remove(p);
        }
    }

    /// Replace one score in the sorted multiset (a boundary crossing
    /// moved a running trace's aggregate from `old` to `new`).
    fn scores_replace(&mut self, old: f64, new: f64) {
        let p = self.scores_sorted.partition_point(|&x| x < old);
        debug_assert_eq!(self.scores_sorted.get(p), Some(&old), "score multiset drift");
        self.scores_sorted.remove(p);
        let p = self.scores_sorted.partition_point(|&x| x < new);
        self.scores_sorted.insert(p, new);
    }

    /// Submit one arrival: create its request's traces and admit
    /// whatever fits; the rest joins the FIFO wait queue. One batched
    /// prefill covers everything admitted here. An idle engine's clock
    /// first jumps to the arrival instant (service cannot start before
    /// the request exists); a busy engine admits at its current clock.
    pub fn submit(&mut self, arr: &Arrival) {
        if self.is_idle() {
            self.clock = self.clock.max(arr.t_arrive);
        }
        if self.epoch.is_none() {
            self.epoch = Some(arr.t_arrive);
        }
        self.submitted += 1;
        self.counters.requests += 1;
        let local = self.reqs.len();
        let n_per = self.n_per;
        let q = self.sim.gen.question(arr.qid);
        let expected_tokens = self.sim.gen.expected_trace_tokens(&q);
        let lo = self.traces.len();
        let mut rq = Req {
            st: RequestState::new(arr.rid, arr.qid, arr.t_arrive),
            q,
            expected_tokens,
            lo,
            n: n_per,
            live: n_per,
            boundaries: 0,
            next_slim: self.sim.cfg.params.slim_check_interval_steps * n_per,
            slim_rng: Rng::new(
                self.sim.cfg.seed
                    ^ (arr.rid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ 0x0051_1A5C,
            ),
            gone: false,
        };
        let mut admitted = 0usize;
        let mut prefill_tokens = 0usize;
        let prefix_cache = self.sim.cfg.prefix_cache;
        for i in 0..n_per {
            let tid = lo + i;
            // Trace streams offset by rid so repeated questions still
            // decode distinct samples (cluster-wide: rid is global).
            let spec = self.sim.gen.trace(&rq.q, arr.rid * n_per + i);
            let mut st = TraceState::new(tid as u64, self.sim.cfg.params.deepconf_window);
            let prompt = rq.q.prompt_tokens;
            // `resident` is what enters the index: the full prompt on
            // the plain path, only the private suffix on the shared one
            // (the pinned span enters K0 once, not per sharer).
            let (fits, resident) = if prefix_cache {
                match self
                    .pool
                    .allocate_seq_shared(local as OwnerId, tid as u64, arr.qid, prompt, 0)
                {
                    Some(share) => {
                        let span = share.shared_blocks * self.sim.cfg.block_size;
                        prefill_tokens += if share.hit { prompt - span } else { prompt };
                        self.note_prefix_share(arr.qid, share);
                        (true, prompt - span)
                    }
                    None => (false, 0),
                }
            } else {
                let need = self.pool.blocks_needed_for_new(prompt);
                let fits = self.pool.can_admit(local as OwnerId, need);
                if fits {
                    let ok = self.pool.allocate_seq(local as OwnerId, tid as u64, prompt);
                    debug_assert!(ok, "can_admit guaranteed the admission");
                    prefill_tokens += prompt;
                }
                (fits, prompt)
            };
            if fits {
                admitted += 1;
            } else {
                st.status = TraceStatus::Preempted;
                self.wait_q.push_back(tid);
            }
            self.next_end.push(spec.step_ends[0]);
            self.traces.push(ServeTrace { rid: local, spec, st, last_settle: 0.0 });
            if fits {
                self.index_insert(tid, resident);
            }
        }
        self.drain_prefix_evictions();
        if admitted > 0 {
            rq.st.admitted(self.clock);
            let dt = self.sim.profile.timing.prefill(prefill_tokens);
            // The engine stalls for the prefill; earlier requests' live
            // traces need no bookkeeping here — their open settle
            // windows span the stall and classify it by status when
            // they next change state ([`sched::settle`]).
            self.clock += dt;
        }
        // The new request's traces start accruing after their own
        // admission prefill (queued ones begin waiting now).
        let clock = self.clock;
        for t in self.traces[lo..].iter_mut() {
            t.last_settle = clock;
        }
        self.live_locals.push(local);
        self.reqs.push(rq);
        self.version += 1;
        let rid = arr.rid;
        self.emit(|live, kv| {
            SimEvent::new(clock, EventKind::Admit { traces: n_per })
                .rid(rid)
                .load(live, kv)
        });
        self.debug_check_pool();
    }

    /// Account one copy-on-write admission: counters, the pinned-token
    /// K0 term (a fresh pin enters once; hits and resurrections add
    /// nothing — their tokens are already counted), and the
    /// `PrefixShare` / `PrefixHit` event.
    fn note_prefix_share(&mut self, qid: usize, share: PrefixShare) {
        let blocks = share.shared_blocks;
        if share.hit {
            self.counters.prefix_hits += 1;
            self.counters.prefix_saved_blocks += blocks as u64;
        } else {
            self.counters.prefix_misses += 1;
            if blocks > 0 {
                self.index
                    .add_pinned_tokens((blocks * self.sim.cfg.block_size) as u64);
            }
        }
        if blocks > 0 && self.rec.is_some() {
            let clock = self.clock;
            let kind = if share.hit {
                EventKind::PrefixHit { qid, blocks }
            } else {
                EventKind::PrefixShare { qid, blocks }
            };
            self.emit(|live, kv| SimEvent::new(clock, kind).load(live, kv));
        }
    }

    /// Drain registry evictions the pool performed since the last call:
    /// retire their tokens from the K0 pinned term and emit
    /// `PrefixEvict` — each pin's blocks are freed exactly once, the
    /// conservation law `obs::replay` checks. No-op with the prefix
    /// cache off (the pool never evicts then).
    fn drain_prefix_evictions(&mut self) {
        if !self.sim.cfg.prefix_cache {
            return;
        }
        let bs = self.sim.cfg.block_size;
        let clock = self.clock;
        for (qid, blocks) in self.pool.take_prefix_evictions() {
            self.index.sub_pinned_tokens((blocks as usize * bs) as u64);
            self.counters.prefix_evictions += 1;
            let (qid, blocks) = (qid as usize, blocks as usize);
            self.emit(|live, kv| {
                SimEvent::new(clock, EventKind::PrefixEvict { qid, blocks })
                    .cause("pressure")
                    .load(live, kv)
            });
        }
    }

    /// Debug-build pool invariant sweep (per-owner charges, registry
    /// refcounts and pins, the O(1) digest): every mutation class on
    /// the serving hot path funnels through here, so CoW bugs fail
    /// loudly in the property suites, not just the pool unit tests.
    /// Compiled out in release builds.
    #[inline]
    fn debug_check_pool(&self) {
        #[cfg(debug_assertions)]
        self.pool.check_invariants();
    }

    /// Advance until the clock reaches `t_limit` or the engine runs out
    /// of work. On return either `clock() >= t_limit`, or
    /// [`is_idle`](Self::is_idle) holds (possibly with undrained
    /// completions).
    pub fn run_until(&mut self, t_limit: f64) {
        while self.clock < t_limit {
            if matches!(self.step_event(t_limit), Step::Idle) {
                return;
            }
        }
    }

    /// Advance until no work remains.
    pub fn run_to_completion(&mut self) {
        self.run_until(f64::INFINITY);
    }

    /// Process exactly one event (decode interval, memory event, or
    /// resume/drop). Returns false when the engine had nothing to do.
    pub fn run_one_event(&mut self) -> bool {
        matches!(self.step_event(f64::INFINITY), Step::Advanced)
    }

    /// One iteration of the event loop, bounded by `t_limit`: runs
    /// [`step_event_inner`](Self::step_event_inner) and, when state
    /// advanced, bumps the engine's [`version`](Self::version) and the
    /// `events` counter (the events/sec numerator).
    fn step_event(&mut self, t_limit: f64) -> Step {
        let s = self.step_event_inner(t_limit);
        if matches!(s, Step::Advanced) {
            self.version += 1;
            self.counters.events += 1;
        }
        s
    }

    /// The event-loop body: decode interval, memory event, or
    /// resume/drop pass.
    fn step_event_inner(&mut self, t_limit: f64) -> Step {
        if self.index.running() == 0 {
            if !self.wait_q.is_empty() {
                self.resume_or_drop();
                return Step::Advanced;
            }
            return Step::Idle;
        }
        // Snapshot the maintained running set (ascending trace order —
        // the historical scan order) so boundary processing can mutate
        // the index while iterating.
        let mut running = std::mem::take(&mut self.running);
        running.clear();
        running.extend_from_slice(self.index.tids());

        let b = running.len();

        // ---- event horizon: O(1) peek at the maintained boundary min.
        let d_event = self.index.d_event().expect("running traces are indexed");
        debug_assert!(d_event >= 1);

        // ---- limit horizon: do not decode past the driver's limit
        // (the next arrival, for the single-GPU driver). K0 is the
        // index's maintained resident-token sum.
        let k0 = self.index.resident_tokens() as usize;
        let mut d_cap = d_event;
        if t_limit.is_finite() {
            let gap = t_limit - self.clock;
            d_cap = d_cap.min(self.sim.iters_within(b, k0, d_event, gap).max(1));
        }

        // ---- memory horizon over the shared pool (+ quotas).
        let d_mem = self.memory_horizon(d_cap);
        if d_mem == 0 {
            self.memory_event(&running);
            self.running = running;
            return Step::Advanced;
        }
        let d = d_cap.min(d_mem);

        // ---- advance time + tokens (lazy accrual: the open settle
        // windows absorb `dt`; nothing per-trace to touch here).
        let dt = self.sim.profile.timing.decode_interval(b, k0, d);
        self.clock += dt;
        self.counters.decode_iterations += d;
        self.counters.generated_tokens += d * b as u64;
        for &i in &running {
            self.traces[i as usize].st.generated += d;
            let ok = self.pool.append_tokens(i as u64, d as usize);
            debug_assert!(ok, "memory horizon must guarantee the append");
        }
        // Appends may have reclaimed cold prefixes (the horizon counts
        // them as capacity).
        self.drain_prefix_evictions();
        self.index.advance(d);

        // ---- boundary / completion events.
        let mut freed_any = false;
        let needs_scores = self.sim.cfg.method == Method::Step;
        let route_views = self.sim.cfg.route_views;
        let clock = self.clock;
        for &ti in &running {
            let i = ti as usize;
            if self.traces[i].st.generated != self.next_end[i] {
                continue;
            }
            let t = &mut self.traces[i];
            let step_n = t.st.next_step + 1;
            t.st.next_step += 1;
            let rid = t.rid;
            self.reqs[rid].boundaries += 1;
            if t.st.generated < t.spec.total_tokens {
                self.next_end[i] = t.spec.step_ends[t.st.next_step];
            }
            if needs_scores {
                let old = self.sim.agg_score(&self.traces[i].st);
                let t = &mut self.traces[i];
                let ctx = StepCtx {
                    gen: self.sim.gen,
                    q: &self.reqs[rid].q,
                    spec: &t.spec,
                    step_n,
                };
                let s = self.signal.score_step(&ctx, &mut self.sig) as f64;
                t.st.push_score(s);
                self.counters.step_scores += 1;
                if route_views {
                    let new = self.sim.agg_score(&self.traces[i].st);
                    self.scores_replace(old, new);
                }
                if self.rec.is_some() {
                    let ext = self.reqs[rid].st.rid;
                    let sig = self.signal.name();
                    self.emit(|live, kv| {
                        SimEvent::new(clock, EventKind::StepScore { score: s })
                            .rid(ext)
                            .trace(i)
                            .load(live, kv)
                            .signal(sig)
                    });
                }
            }
            if self.traces[i].st.generated == self.traces[i].spec.total_tokens {
                self.index_remove(i);
                let t = &mut self.traces[i];
                sched::settle(&mut t.st, &mut t.last_settle, clock);
                t.st.status = TraceStatus::Finished;
                t.st.finish_clock = clock;
                self.pool.free_seq(i as u64);
                freed_any = true;
                let rq = &mut self.reqs[rid];
                rq.st.first_vote(clock);
                request_done(rq, clock, &mut self.completions);
            } else {
                let dist = self.next_end[i] - self.traces[i].st.generated;
                self.index.set_boundary(ti, dist);
            }
        }

        // ---- Slim-SC periodic similarity pruning (per request).
        if self.sim.cfg.method == Method::SlimSc {
            for rid in 0..self.reqs.len() {
                if self.reqs[rid].live == 0
                    || self.reqs[rid].boundaries < self.reqs[rid].next_slim
                {
                    continue;
                }
                let (lo, n) = (self.reqs[rid].lo, self.reqs[rid].n);
                let active = self.traces[lo..lo + n]
                    .iter()
                    .filter(|t| t.st.status == TraceStatus::Running)
                    .count();
                self.reqs[rid].next_slim +=
                    self.sim.cfg.params.slim_check_interval_steps * active.max(1);
                freed_any |= self.slim_check_request(rid, clock);
            }
        }

        if freed_any {
            while self.try_resume_head() {}
            self.debug_check_pool();
        }
        self.running = running;
        Step::Advanced
    }

    /// Largest d (capped at `cap`) such that advancing every running
    /// trace d tokens fits the free pool *and* every owner's quota.
    /// Every probe of the binary search is a closed-form fold over the
    /// index's block-offset histograms — O(block size + active owners)
    /// instead of an O(live) regather per probe.
    fn memory_horizon(&self, cap: u64) -> u64 {
        // Reclaimable (zero-ref cached prefix) blocks count as free:
        // the append path evicts them on demand. Identical to hard
        // free with the prefix cache off.
        let free = self.pool.available_blocks() as u64;
        let quota = self.pool.quota_blocks();
        let (index, pool) = (&self.index, &self.pool);
        sched::max_fitting(cap, |d| {
            if index.pool_demand(d) > free {
                return false;
            }
            if quota.is_some() {
                for &o in index.active_owners() {
                    if let Some(hr) = pool.owner_headroom(o) {
                        if index.owner_demand(o, d) > hr as u64 {
                            return false;
                        }
                    }
                }
            }
            true
        })
    }

    /// Memory saturated at d = 1: prune (STEP) or preempt (vLLM default).
    /// If the *pool* binds, the victim set is every running trace —
    /// cross-request. If only one owner's *quota* binds, the victim set
    /// is that owner's running traces (found through the index's
    /// per-owner demand aggregates, ascending owner order — the same
    /// first-binding-owner the retired sorted-pair scan produced).
    fn memory_event(&mut self, running: &[u32]) {
        debug_assert!(!running.is_empty());
        let free_now = self.pool.free_blocks();
        let t_now = self.clock;
        self.emit(|live, kv| {
            SimEvent::new(t_now, EventKind::MemoryEvent { free_blocks: free_now })
                .load(live, kv)
        });
        let pool_bound = self.index.pool_demand(1) > self.pool.available_blocks() as u64;
        let binding: Option<OwnerId> = if pool_bound || self.pool.quota_blocks().is_none() {
            None
        } else {
            self.index.active_owners().iter().copied().find(|&o| {
                matches!(self.pool.owner_headroom(o),
                         Some(h) if self.index.owner_demand(o, 1) > h as u64)
            })
        };
        let traces = &self.traces;
        let in_set = |i: u32| match binding {
            Some(o) => traces[i as usize].rid as OwnerId == o,
            None => true,
        };
        let clock = self.clock;
        match self.sim.cfg.method {
            Method::Step => {
                // Algorithm 1, serving form: argmin aggregated step score
                // over the victim set, release KV at once.
                let victim =
                    sched::lowest_score_victim(running, in_set, |i: u32| {
                        self.sim.agg_score(&traces[i as usize].st)
                    })
                    .expect("memory event with empty victim set");
                let victim = victim as usize;
                let rid = self.traces[victim].rid;
                let rescue = self.sim.cfg.migrate_rescue
                    && self.reqs[rid].live == 1
                    && running.len() > 1;
                if rescue {
                    // Pruning the request's last survivor would complete
                    // it with every trace abstaining — all its work lost.
                    // Evict the whole request into the migration outbox
                    // instead; the victim's KV is freed either way, so
                    // the memory event still unblocks the pool, and the
                    // cluster driver relocates the request to the
                    // least-pressured GPU. When the victim is the *only*
                    // running trace, other traces' pressure cannot be
                    // the cause — the trace simply outgrew this pool —
                    // so prune as always rather than bouncing a request
                    // no pool may ever hold.
                    let m = self.extract_local(rid);
                    self.migrations.push(m);
                    return;
                }
                self.index_remove(victim);
                let t = &mut self.traces[victim];
                sched::settle(&mut t.st, &mut t.last_settle, clock);
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = clock;
                self.pool.free_seq(victim as u64);
                self.counters.pruned += 1;
                request_done(&mut self.reqs[rid], clock, &mut self.completions);
                let ext = self.reqs[rid].st.rid;
                // Memory prunes are the signal-driven removals: stamp
                // the signal whose scores selected the victim.
                let sig = self.signal.name();
                self.emit(|live, kv| {
                    SimEvent::new(clock, EventKind::Prune)
                        .rid(ext)
                        .trace(victim)
                        .cause("memory")
                        .load(live, kv)
                        .signal(sig)
                });
            }
            _ => {
                // vLLM preemption: evict the youngest running trace in
                // the victim set (cheapest recompute), FIFO resume.
                let victim =
                    sched::youngest_victim(running, in_set, |i: u32| {
                        traces[i as usize].st.generated
                    })
                    .expect("memory event with empty victim set");
                let victim = victim as usize;
                self.index_remove(victim);
                let t = &mut self.traces[victim];
                sched::settle(&mut t.st, &mut t.last_settle, clock);
                t.st.status = TraceStatus::Preempted;
                t.st.preemptions += 1;
                self.pool.free_seq(victim as u64);
                self.counters.preemptions += 1;
                self.wait_q.push_back(victim);
                let ext = self.reqs[self.traces[victim].rid].st.rid;
                self.emit(|live, kv| {
                    SimEvent::new(clock, EventKind::Preempt)
                        .rid(ext)
                        .trace(victim)
                        .cause("memory")
                        .load(live, kv)
                });
            }
        }
        self.debug_check_pool();
    }

    /// Slim-SC similarity check within one request (thought level): pair
    /// up its active traces at random, prune one member of each pair
    /// whose modelled similarity crosses the threshold. Same calibration
    /// as the single-question engine.
    fn slim_check_request(&mut self, rid: usize, clock: f64) -> bool {
        let threshold = self.sim.cfg.params.slim_similarity_threshold;
        let (lo, n) = (self.reqs[rid].lo, self.reqs[rid].n);
        let mut active: Vec<usize> = (lo..lo + n)
            .filter(|&i| self.traces[i].st.status == TraceStatus::Running)
            .collect();
        self.reqs[rid].slim_rng.shuffle(&mut active);
        let mut pruned_any = false;
        for pair in active.chunks_exact(2) {
            let (i, j) = (pair[0], pair[1]);
            let same = self.traces[i].spec.answer.is_some()
                && self.traces[i].spec.answer == self.traces[j].spec.answer;
            let rq = &mut self.reqs[rid];
            let sim = if same {
                rq.slim_rng.normal_with(0.905, 0.025)
            } else {
                rq.slim_rng.normal_with(0.80, 0.03)
            };
            if sim > threshold {
                let victim = if rq.slim_rng.bernoulli(0.5) { i } else { j };
                self.index_remove(victim);
                let t = &mut self.traces[victim];
                sched::settle(&mut t.st, &mut t.last_settle, clock);
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = clock;
                self.pool.free_seq(victim as u64);
                self.counters.pruned += 1;
                request_done(&mut self.reqs[rid], clock, &mut self.completions);
                pruned_any = true;
                let ext = self.reqs[rid].st.rid;
                self.emit(|live, kv| {
                    SimEvent::new(clock, EventKind::Prune)
                        .rid(ext)
                        .trace(victim)
                        .cause("slim-sc")
                        .load(live, kv)
                });
            }
        }
        pruned_any
    }

    /// Fully stalled: resume the first queued trace (FIFO) whose prefix
    /// fits; only when none can ever fit is the head dropped (counted as
    /// pruned).
    fn resume_or_drop(&mut self) {
        let (sim, traces, reqs, pool) = (&self.sim, &self.traces, &self.reqs, &self.pool);
        let fitting = self.wait_q.pop_first_fit(|tid| sim.resume_fits(traces, reqs, pool, tid));
        if let Some(tid) = fitting {
            self.admit_resumed(tid);
            return;
        }
        let head = self.wait_q.pop_front().expect("caller checked non-empty");
        let clock = self.clock;
        let t = &mut self.traces[head];
        sched::settle(&mut t.st, &mut t.last_settle, clock);
        t.st.status = TraceStatus::Pruned;
        t.st.finish_clock = clock;
        let rid = t.rid;
        self.counters.pruned += 1;
        request_done(&mut self.reqs[rid], clock, &mut self.completions);
        let ext = self.reqs[rid].st.rid;
        self.emit(|live, kv| {
            SimEvent::new(clock, EventKind::Prune)
                .rid(ext)
                .trace(head)
                .cause("stall-drop")
                .load(live, kv)
        });
    }

    /// Resume the wait-queue head if its whole prefix fits — vLLM's FCFS
    /// resume rule for the normal path where finishing traces free memory.
    fn try_resume_head(&mut self) -> bool {
        let (sim, traces, reqs, pool) = (&self.sim, &self.traces, &self.reqs, &self.pool);
        let head = self.wait_q.pop_head_if(|tid| sim.resume_fits(traces, reqs, pool, tid));
        let Some(tid) = head else {
            return false;
        };
        self.admit_resumed(tid);
        true
    }

    /// Re-admit a dequeued trace: recompute-on-resume rebuilds the prefix
    /// KV with a prefill pass that stalls the engine.
    fn admit_resumed(&mut self, tid: usize) {
        let rid = self.traces[tid].rid;
        let prompt = self.reqs[rid].q.prompt_tokens;
        let generated = self.traces[tid].st.generated as usize;
        let prefix = prompt + generated;
        // Shared resume: a registry hit restores the pinned span for
        // free, so the recompute prefill covers only the private suffix
        // (tail + generated); a miss re-pins and pays the full prefix.
        // The plain path recomputes everything, as before.
        let (prefill, resident) = if self.sim.cfg.prefix_cache {
            let qid = self.reqs[rid].st.qid;
            let share = self
                .pool
                .allocate_seq_shared(rid as OwnerId, tid as u64, qid, prompt, generated)
                .expect("resume_fits guaranteed the admission");
            let span = share.shared_blocks * self.sim.cfg.block_size;
            self.note_prefix_share(qid, share);
            self.drain_prefix_evictions();
            (if share.hit { prefix - span } else { prefix }, prefix - span)
        } else {
            let ok = self.pool.allocate_seq(rid as OwnerId, tid as u64, prefix);
            debug_assert!(ok, "resume_fits guaranteed the admission");
            (prefix, prefix)
        };
        self.reqs[rid].st.admitted(self.clock);
        self.counters.resumes += 1;
        let dt = self.sim.profile.timing.prefill(prefill);
        self.clock += dt;
        // The resumed trace's own KV reconstruction counts as waiting
        // (paper: "resumed with KV cache reconstructed"): settle its
        // wait through the post-prefill clock, then open its running
        // window. Other live traces' open windows absorb the stall
        // under their own statuses.
        let clock = self.clock;
        let t = &mut self.traces[tid];
        sched::settle(&mut t.st, &mut t.last_settle, clock);
        t.st.status = TraceStatus::Running;
        self.index_insert(tid, resident);
        let ext = self.reqs[rid].st.rid;
        self.emit(|live, kv| {
            SimEvent::new(clock, EventKind::Resume).rid(ext).trace(tid).load(live, kv)
        });
        self.debug_check_pool();
    }

    /// Final aggregation: voting + per-request SLO metrics, in
    /// submission order.
    pub fn finish(mut self) -> ServeResult {
        debug_assert!(self.wait_q.is_empty());
        let cfg = self.sim.cfg;
        let clock = self.clock;
        // Settle any still-open accrual windows (a no-op on a fully
        // drained engine, where every trace is terminal).
        for t in self.traces.iter_mut() {
            sched::settle(&mut t.st, &mut t.last_settle, clock);
        }
        let outcomes: Vec<RequestOutcome> = self
            .reqs
            .iter()
            // Requests migrated out complete (and report) elsewhere.
            .filter(|rq| !rq.gone)
            .map(|rq| {
                let slice = &self.traces[rq.lo..rq.lo + rq.n];
                let votes: Vec<Vote> = slice
                    .iter()
                    .filter_map(|t| {
                        let answer = match t.st.status {
                            TraceStatus::Finished => t.spec.answer,
                            _ => None, // pruned / preempted traces abstain
                        };
                        answer?;
                        let weight = if cfg.method == Method::Step {
                            self.sim.agg_score(&t.st)
                        } else {
                            1.0
                        };
                        Some(Vote { answer, weight })
                    })
                    .collect();
                let chosen = weighted_vote(&votes);
                let t_done = rq.st.t_done.unwrap_or(clock);
                RequestOutcome {
                    rid: rq.st.rid,
                    qid: rq.st.qid,
                    correct: chosen == Some(0),
                    chosen,
                    t_arrive: rq.st.t_arrive,
                    queue_s: rq.st.queue_s().unwrap_or(t_done - rq.st.t_arrive),
                    latency_s: t_done - rq.st.t_arrive,
                    ttfv_s: rq.st.ttfv_s().unwrap_or(t_done - rq.st.t_arrive),
                    gen_tokens: slice.iter().map(|t| t.st.generated).sum(),
                    mean_wait_s: slice.iter().map(|t| t.st.wait_time).sum::<f64>()
                        / slice.len().max(1) as f64,
                    mean_decode_s: slice.iter().map(|t| t.st.decode_time).sum::<f64>()
                        / slice.len().max(1) as f64,
                    n_finished: slice
                        .iter()
                        .filter(|t| t.st.status == TraceStatus::Finished)
                        .count(),
                    n_pruned: slice
                        .iter()
                        .filter(|t| t.st.status == TraceStatus::Pruned)
                        .count(),
                    n_preemptions: slice.iter().map(|t| t.st.preemptions).sum(),
                }
            })
            .collect();

        ServeResult {
            outcomes,
            makespan_s: clock - self.epoch.unwrap_or(clock),
            counters: self.counters,
            pool_blocks: self.pool_blocks,
            peak_used_blocks: self.pool.peak_used_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::cells::projection_scorer;
    use crate::sim::tracegen::GenParams;

    /// Short-trace benchmark + full pool: demand stays far below
    /// capacity, so no memory event can fire.
    fn light_cfg(method: Method) -> ServeSimConfig {
        let mut c = ServeSimConfig::new(
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            method,
            4,
            WorkloadSpec::poisson(0.01, 3),
        );
        c.seed = 11;
        c
    }

    fn pressured_cfg(method: Method) -> ServeSimConfig {
        let mut c = ServeSimConfig::new(
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            method,
            6,
            WorkloadSpec::poisson(0.1, 3),
        );
        c.mem_util = 0.45;
        c.seed = 13;
        c
    }

    fn run(cfg: &ServeSimConfig) -> ServeResult {
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        ServeSim::new(cfg, &gen, &scorer).run()
    }

    #[test]
    fn all_requests_complete_for_every_method() {
        for method in [Method::Cot, Method::Sc, Method::SlimSc, Method::Step] {
            for cfg in [light_cfg(method), pressured_cfg(method)] {
                let r = run(&cfg);
                assert_eq!(r.outcomes.len(), cfg.workload.n_requests, "{method:?}");
                for o in &r.outcomes {
                    assert!(o.latency_s > 0.0, "{method:?}: rid {} zero latency", o.rid);
                    assert!(o.ttfv_s <= o.latency_s + 1e-9, "{method:?}");
                    assert!(o.queue_s >= 0.0, "{method:?}");
                    let expected = if method == Method::Cot { 1 } else { cfg.n_traces };
                    assert!(o.n_finished + o.n_pruned <= expected, "{method:?}");
                }
                assert!(r.makespan_s > 0.0);
                assert!(r.throughput_rps() > 0.0);
            }
        }
    }

    #[test]
    fn light_load_never_triggers_memory_events() {
        for method in [Method::Sc, Method::Step] {
            let r = run(&light_cfg(method));
            assert_eq!(r.counters.preemptions, 0, "{method:?}");
            // STEP never preempts by design; under light load it also
            // never needs to prune.
            if method == Method::Step {
                assert_eq!(r.counters.pruned, 0);
            }
            for o in &r.outcomes {
                assert_eq!(o.n_finished, 4, "{method:?}: all traces finish");
            }
        }
    }

    #[test]
    fn sc_preempts_under_pressure() {
        let r = run(&pressured_cfg(Method::Sc));
        assert!(r.counters.preemptions > 0, "expected preemption at 0.45 util");
    }

    #[test]
    fn step_prunes_cross_request_and_never_preempts() {
        let r = run(&pressured_cfg(Method::Step));
        assert_eq!(r.counters.preemptions, 0, "STEP must eliminate the waiting queue");
        assert!(r.counters.pruned > 0, "expected pruning at 0.45 util");
    }

    #[test]
    fn step_beats_sc_latency_under_pressure() {
        let step = run(&pressured_cfg(Method::Step));
        let sc = run(&pressured_cfg(Method::Sc));
        let max_lat = |r: &ServeResult| {
            r.outcomes.iter().map(|o| o.latency_s).fold(0.0f64, f64::max)
        };
        assert!(
            max_lat(&step) < max_lat(&sc),
            "STEP tail {} vs SC tail {}",
            max_lat(&step),
            max_lat(&sc)
        );
        assert!(step.makespan_s < sc.makespan_s);
        assert!(
            step.counters.generated_tokens < sc.counters.generated_tokens,
            "pruning must save tokens"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        for method in [Method::Sc, Method::Step] {
            let a = run(&pressured_cfg(method));
            let b = run(&pressured_cfg(method));
            assert_eq!(a.makespan_s, b.makespan_s, "{method:?}");
            assert_eq!(a.counters.generated_tokens, b.counters.generated_tokens);
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.latency_s, y.latency_s, "{method:?}");
                assert_eq!(x.chosen, y.chosen);
            }
        }
    }

    fn prefix_cfg(method: Method) -> ServeSimConfig {
        let mut c = pressured_cfg(method);
        c.prefix_cache = true;
        c
    }

    #[test]
    fn prefix_cache_shares_prompts_and_completes() {
        for method in [Method::Sc, Method::Step] {
            let r = run(&prefix_cfg(method));
            assert_eq!(r.outcomes.len(), 3, "{method:?}");
            // The N traces of each request share one prompt: the first
            // admission pins it, the rest hit the registry.
            assert!(r.counters.prefix_misses > 0, "{method:?}: someone pins");
            assert!(r.counters.prefix_hits > 0, "{method:?}: siblings hit");
            assert!(r.counters.prefix_saved_blocks > 0, "{method:?}");
            for o in &r.outcomes {
                assert!(o.latency_s > 0.0, "{method:?}");
            }
        }
    }

    #[test]
    fn prefix_cache_prunes_no_more_than_the_baseline() {
        let base = run(&pressured_cfg(Method::Step));
        let shared = run(&prefix_cfg(Method::Step));
        // Shared prompts raise effective KV capacity, so memory events
        // fire later and prune at most as much as the private baseline.
        assert!(
            shared.counters.pruned <= base.counters.pruned,
            "shared {} > private {}",
            shared.counters.pruned,
            base.counters.pruned
        );
        assert!(base.counters.pruned > 0, "the baseline must be pressured");
    }

    #[test]
    fn prefix_cache_off_leaves_counters_untouched() {
        let a = run(&pressured_cfg(Method::Step));
        assert_eq!(a.counters.prefix_hits, 0);
        assert_eq!(a.counters.prefix_misses, 0);
        assert_eq!(a.counters.prefix_saved_blocks, 0);
        assert_eq!(a.counters.prefix_evictions, 0);
    }

    #[test]
    fn prefix_cache_is_deterministic_given_seed() {
        for method in [Method::Sc, Method::Step] {
            let a = run(&prefix_cfg(method));
            let b = run(&prefix_cfg(method));
            assert_eq!(a.makespan_s, b.makespan_s, "{method:?}");
            assert_eq!(a.counters.generated_tokens, b.counters.generated_tokens);
            assert_eq!(a.counters.prefix_hits, b.counters.prefix_hits);
            assert_eq!(a.counters.prefix_evictions, b.counters.prefix_evictions);
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.latency_s, y.latency_s, "{method:?}");
                assert_eq!(x.chosen, y.chosen);
            }
        }
    }

    #[test]
    fn prefix_cache_respects_quotas() {
        let mut cfg = prefix_cfg(Method::Step);
        cfg.quota_frac = Some(0.4);
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 3);
        assert!(r.peak_used_blocks <= r.pool_blocks);
        assert!(r.counters.prefix_hits > 0);
    }

    /// Drive a traced prefix-cache run and hold its event stream to the
    /// pin conservation law: every `(qid)` pin alternates share → evict
    /// with matching block counts, and hits only land on live pins —
    /// shared blocks are freed exactly once.
    #[test]
    fn prefix_events_satisfy_the_pin_conservation_law() {
        let cfg = prefix_cfg(Method::Step);
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        let arrivals = cfg
            .workload
            .generate(gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);
        let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
        eng.set_recorder(Box::new(crate::obs::EventBuf::unbounded()));
        let mut next = 0usize;
        loop {
            while next < arrivals.len() && arrivals[next].t_arrive <= eng.clock() {
                eng.submit(&arrivals[next]);
                next += 1;
            }
            if next < arrivals.len() {
                if eng.is_idle() {
                    eng.advance_idle_to(arrivals[next].t_arrive);
                    continue;
                }
                eng.run_until(arrivals[next].t_arrive);
            } else if !eng.run_one_event() {
                break;
            }
        }
        let events = eng.take_recorder().unwrap().drain();
        let shares = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::PrefixShare { .. }))
            .count();
        let hits = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::PrefixHit { .. }))
            .count();
        assert!(shares > 0, "a pressured run must pin prompts");
        assert!(hits > 0, "sibling traces must hit");
        let report = crate::obs::replay::check(&events);
        assert!(report.ok(), "pin law violated: {:?}", report.violations);
    }

    #[test]
    fn quota_bounds_every_owner() {
        let mut cfg = pressured_cfg(Method::Sc);
        cfg.quota_frac = Some(0.4);
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 3);
        // Quota of 40% of the pool: peak usage can fill the pool across
        // owners, but the run must still complete with every trace
        // terminal (the per-owner memory events keep it live).
        assert!(r.peak_used_blocks <= r.pool_blocks);
        let mut cfg_step = pressured_cfg(Method::Step);
        cfg_step.quota_frac = Some(0.4);
        let rs = run(&cfg_step);
        assert_eq!(rs.counters.preemptions, 0);
        assert!(rs.counters.pruned > 0);
    }

    #[test]
    fn bursty_workload_completes() {
        let mut cfg = pressured_cfg(Method::Step);
        cfg.workload = WorkloadSpec::bursty(0.1, 3, 3);
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 3);
        // A burst of 3 requests lands at one instant: queueing shows up.
        assert!(r.outcomes.iter().all(|o| o.latency_s > 0.0));
    }

    #[test]
    fn slim_sc_prunes_similar_traces() {
        let r = run(&pressured_cfg(Method::SlimSc));
        assert!(r.counters.pruned > 0, "slim-sc should prune similar traces");
    }

    #[test]
    fn request_lifecycle_marks_are_consistent() {
        let r = run(&pressured_cfg(Method::Sc));
        for o in &r.outcomes {
            assert!(o.queue_s <= o.latency_s + 1e-9);
            assert!(o.t_arrive >= 0.0);
        }
    }

    #[test]
    fn wait_decode_split_is_populated() {
        let sc = run(&pressured_cfg(Method::Sc));
        assert!(
            sc.outcomes.iter().any(|o| o.mean_wait_s > 0.0),
            "SC under pressure must accrue waiting time"
        );
        for o in &sc.outcomes {
            assert!(o.mean_decode_s >= 0.0 && o.mean_wait_s >= 0.0);
        }
        // Light load: nothing ever waits.
        let light = run(&light_cfg(Method::Sc));
        for o in &light.outcomes {
            assert_eq!(o.mean_wait_s, 0.0, "no queueing under light load");
            assert!(o.mean_decode_s > 0.0);
        }
    }

    /// Driving the engine stepwise (one event at a time after the last
    /// arrival) reproduces the batch driver exactly — the contract the
    /// cluster simulator relies on.
    #[test]
    fn stepwise_driver_matches_batch_run() {
        for method in [Method::Sc, Method::Step] {
            let cfg = pressured_cfg(method);
            let gp = GenParams::default_d64();
            let scorer = projection_scorer(&gp);
            let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
            let batch = ServeSim::new(&cfg, &gen, &scorer).run();

            let arrivals = cfg
                .workload
                .generate(gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);
            let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
            let mut next = 0usize;
            let mut done: Vec<(usize, f64)> = Vec::new();
            loop {
                while next < arrivals.len() && arrivals[next].t_arrive <= eng.clock() {
                    eng.submit(&arrivals[next]);
                    next += 1;
                }
                if next < arrivals.len() {
                    if eng.is_idle() {
                        eng.advance_idle_to(arrivals[next].t_arrive);
                        continue;
                    }
                    eng.run_until(arrivals[next].t_arrive);
                } else if !eng.run_one_event() {
                    break;
                }
                eng.drain_completions_into(&mut done);
            }
            eng.drain_completions_into(&mut done);
            assert_eq!(done.len(), arrivals.len(), "{method:?}: all requests complete");
            assert!(eng.is_idle());
            let step = eng.finish();
            assert_eq!(batch.makespan_s, step.makespan_s, "{method:?}");
            assert_eq!(
                batch.counters.generated_tokens,
                step.counters.generated_tokens,
                "{method:?}"
            );
            for (x, y) in batch.outcomes.iter().zip(&step.outcomes) {
                assert_eq!(x.latency_s, y.latency_s, "{method:?}");
                assert_eq!(x.chosen, y.chosen, "{method:?}");
            }
        }
    }

    /// Completion notifications carry the external rid and a clock
    /// consistent with the outcome's latency.
    #[test]
    fn completions_match_outcomes() {
        let cfg = pressured_cfg(Method::Step);
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp.clone(), cfg.seed ^ 0x5EED);
        let arrivals = cfg
            .workload
            .generate(gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);
        let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
        for a in &arrivals {
            if eng.is_idle() {
                eng.advance_idle_to(a.t_arrive);
            }
            eng.run_until(a.t_arrive);
            eng.submit(a);
        }
        eng.run_to_completion();
        let mut done: Vec<(usize, f64)> = Vec::new();
        eng.drain_completions_into(&mut done);
        let r = eng.finish();
        assert_eq!(done.len(), r.outcomes.len());
        for (rid, t_done) in done {
            let o = r.outcomes.iter().find(|o| o.rid == rid).expect("rid known");
            assert!((o.t_arrive + o.latency_s - t_done).abs() < 1e-9);
        }
    }

    /// The incremental router view (maintained sorted score multiset)
    /// is bit-identical to the sort-per-call scan at every step of a
    /// pressured run — the contract the cluster router relies on.
    #[test]
    fn survivor_demand_incremental_matches_scan() {
        for method in [Method::Sc, Method::Step] {
            let mut cfg = pressured_cfg(method);
            cfg.route_views = true;
            cfg.quota_frac = Some(0.4);
            let gp = GenParams::default_d64();
            let scorer = projection_scorer(&gp);
            let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
            let arrivals = cfg
                .workload
                .generate(gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);
            let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
            for a in &arrivals {
                if eng.is_idle() {
                    eng.advance_idle_to(a.t_arrive);
                }
                eng.run_until(a.t_arrive);
                eng.submit(a);
                assert_eq!(
                    eng.survivor_demand_blocks(),
                    eng.survivor_demand_blocks_scan(),
                    "{method:?}: incremental view diverged after submit"
                );
            }
            let mut steps = 0usize;
            while eng.run_one_event() {
                steps += 1;
                assert_eq!(
                    eng.survivor_demand_blocks(),
                    eng.survivor_demand_blocks_scan(),
                    "{method:?}: incremental view diverged at event {steps}"
                );
            }
            assert!(steps > 10, "{method:?}: the pressured run should do real work");
            assert_eq!(eng.survivor_demand_blocks(), 0.0);
        }
    }

    /// A slower GPU profile (timing_scale > 1) stretches the same
    /// deterministic workload's wall-clock; scale 1.0 is bit-identical
    /// to the unscaled config.
    #[test]
    fn timing_scale_stretches_wall_clock() {
        let base = pressured_cfg(Method::Sc);
        let mut unit = base.clone();
        unit.timing_scale = 1.0;
        let mut slow = base.clone();
        slow.timing_scale = 3.0;
        let r_base = run(&base);
        let r_unit = run(&unit);
        let r_slow = run(&slow);
        assert_eq!(r_base.makespan_s, r_unit.makespan_s, "scale 1.0 is identity");
        for (a, b) in r_base.outcomes.iter().zip(&r_unit.outcomes) {
            assert_eq!(a.latency_s, b.latency_s);
        }
        assert!(
            r_slow.makespan_s > r_base.makespan_s,
            "a 3x slower GPU must take longer ({} vs {})",
            r_slow.makespan_s,
            r_base.makespan_s
        );
    }

    /// The migration transport: extract a mid-flight request from one
    /// engine and re-admit it on another — the source reports no
    /// outcome, the target completes it exactly once under the same
    /// global rid, and no trace is lost or duplicated.
    #[test]
    fn extract_and_resubmit_moves_a_request_across_engines() {
        for method in [Method::Sc, Method::Step] {
            let cfg = pressured_cfg(method);
            let gp = GenParams::default_d64();
            let scorer = projection_scorer(&gp);
            let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
            let mut a = ServeEngine::new(&cfg, &gen, &scorer);
            let mut b = ServeEngine::new(&cfg, &gen, &scorer);

            a.submit(&Arrival { rid: 7, qid: 1, t_arrive: 0.0 });
            // Decode a few events so the request is genuinely mid-flight.
            for _ in 0..3 {
                a.run_one_event();
            }
            assert_eq!(a.outstanding(), 1);
            assert_eq!(a.migration_victim(), Some(7), "the only request is the victim");

            let m = a.extract_request(7).expect("mid-flight request extracts");
            assert_eq!(m.rid, 7);
            assert!(m.live >= 1);
            assert!(m.recompute_tokens() > 0, "surviving prefixes cost recompute");
            assert_eq!(a.outstanding(), 0, "the source drops responsibility");
            assert!(a.is_idle());
            assert!(a.extract_request(7).is_none(), "a request extracts once");
            assert_eq!(a.migration_victim(), None);

            b.submit_migrated(m);
            assert_eq!(b.outstanding(), 1);
            b.run_to_completion();
            let mut done = Vec::new();
            b.drain_completions_into(&mut done);
            assert_eq!(done.len(), 1, "{method:?}: exactly one completion");
            assert_eq!(done[0].0, 7, "{method:?}: under the global rid");

            let ra = a.finish();
            assert!(ra.outcomes.is_empty(), "{method:?}: source reports nothing");
            let rb = b.finish();
            assert_eq!(rb.outcomes.len(), 1);
            let o = &rb.outcomes[0];
            assert_eq!(o.rid, 7);
            assert!(o.latency_s > 0.0);
            assert!(
                o.n_finished + o.n_pruned <= cfg.n_traces,
                "{method:?}: no trace duplicated across the hop"
            );
        }
    }

    /// Extraction returns the wait queue and KV pool to a clean state
    /// on the source: all blocks free, nothing queued.
    #[test]
    fn extract_request_releases_all_source_resources() {
        let cfg = pressured_cfg(Method::Sc);
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        let mut a = ServeEngine::new(&cfg, &gen, &scorer);
        a.submit(&Arrival { rid: 0, qid: 0, t_arrive: 0.0 });
        for _ in 0..5 {
            a.run_one_event();
        }
        let free_before_full = a.free_blocks() < a.pool_blocks();
        assert!(free_before_full, "the request must hold KV before extraction");
        a.extract_request(0).expect("extracts");
        assert_eq!(a.free_blocks(), a.pool_blocks(), "every block returns");
        assert_eq!(a.live_traces(), 0);
        assert!(!a.run_one_event(), "nothing left to do");
    }

    /// The KV-pressure view is zero when idle and positive under load.
    #[test]
    fn survivor_demand_tracks_load() {
        let cfg = light_cfg(Method::Step);
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
        assert_eq!(eng.survivor_demand_blocks(), 0.0);
        eng.submit(&Arrival { rid: 0, qid: 0, t_arrive: 0.0 });
        assert!(eng.survivor_demand_blocks() > 0.0);
        assert_eq!(eng.outstanding(), 1);
        eng.run_to_completion();
        assert_eq!(eng.survivor_demand_blocks(), 0.0);
        assert!(eng.is_idle());
    }
}
