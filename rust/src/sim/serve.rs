//! Multi-request serving simulator: continuous batching of many
//! concurrent N-trace jobs against one shared KV pool.
//!
//! [`crate::sim::des`] simulates one question's trace set at a time — the
//! figure-reproduction regime. This module generalizes that event loop to
//! *request-level* serving: an open-loop workload
//! ([`crate::sim::workload`]) delivers questions at wall-clock arrival
//! times, a continuous-batching scheduler admits, preempts, and resumes
//! whole requests' traces against a single [`SharedKvPool`], and the
//! paper's §4.2 memory trigger becomes **cross-request**: when the pool
//! saturates, STEP prunes the trace with the lowest step score across
//! *all* running requests, regardless of which request owns it — exactly
//! the multi-tenant regime confidence-based baselines never model.
//!
//! Mechanics shared with the single-question engine:
//! * lockstep continuous batching (one token per running trace per
//!   iteration) with analytic time jumps between events
//!   (`TimingModel::decode_interval`), so cost is O(#events) not
//!   O(#tokens);
//! * vLLM-style recompute-on-resume preemption for the SC family, FIFO
//!   resume, first-fit resume when the engine fully stalls;
//! * the same scoring / voting / method-policy modules.
//!
//! New here: request lifecycle tracking
//! ([`crate::coordinator::request`]), per-request KV quotas (optional —
//! a quota-bound owner triggers a memory event for that owner even while
//! the pool has room), and SLO metrics (queue delay, time-to-first-vote,
//! end-to-end latency) per request.
//!
//! Everything derives from `(config, seed)`: one run is bit-identical
//! across processes and thread counts.

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::method::{Method, MethodParams};
use crate::coordinator::request::RequestState;
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::trace::{TraceState, TraceStatus};
use crate::coordinator::voting::{weighted_vote, Vote};
use crate::kvcache::{OwnerId, SharedKvPool};
use crate::metrics::EngineCounters;
use crate::sim::des::ScoreAgg;
use crate::sim::gpu::GpuSpec;
use crate::sim::profiles::{BenchId, ModelId, ModelProfile};
use crate::sim::tracegen::{Question, TraceGen, TraceSpec};
use crate::sim::workload::{Arrival, WorkloadSpec};
use crate::util::rng::Rng;

/// Configuration of one serving simulation (a method under a workload).
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Served model (sets KV geometry and timing coefficients).
    pub model: ModelId,
    /// Benchmark whose question pool the workload draws from.
    pub bench: BenchId,
    /// Test-time-scaling method driving the scheduler. `DeepConf` is not
    /// supported here: its two-stage warmup is a per-question protocol
    /// that has no continuous-batching rendering.
    pub method: Method,
    /// Traces per request (N); CoT forces 1.
    pub n_traces: usize,
    /// Method hyper-parameters (paper Appendix B.3).
    pub params: MethodParams,
    /// vLLM-style gpu_memory_utilization for the shared pool.
    pub mem_util: f64,
    /// PagedAttention block size in tokens.
    pub block_size: usize,
    /// Master seed; every stream (workload, questions, traces) derives
    /// from it.
    pub seed: u64,
    /// Step-score aggregation for pruning/voting (paper: running mean).
    pub score_agg: ScoreAgg,
    /// The open-loop arrival process.
    pub workload: WorkloadSpec,
    /// Optional per-request KV quota as a fraction of the pool. `None`
    /// (default) = pool-bound only: one tenant may fill the pool and
    /// cross-request pruning arbitrates.
    pub quota_frac: Option<f64>,
}

impl ServeSimConfig {
    /// Paper-default serving configuration for a (model, bench, method)
    /// under `workload`.
    pub fn new(
        model: ModelId,
        bench: BenchId,
        method: Method,
        n_traces: usize,
        workload: WorkloadSpec,
    ) -> ServeSimConfig {
        ServeSimConfig {
            model,
            bench,
            method,
            n_traces,
            params: MethodParams::default(),
            mem_util: 0.9,
            block_size: 16,
            seed: 0,
            score_agg: ScoreAgg::Mean,
            workload,
            quota_frac: None,
        }
    }
}

/// Per-request outcome and SLO metrics of one serving run.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// Request id (arrival order).
    pub rid: usize,
    /// Question the request asked.
    pub qid: usize,
    /// Did the voted answer match ground truth?
    pub correct: bool,
    /// Voted answer (None = every trace abstained).
    pub chosen: Option<u32>,
    /// Arrival wall-clock, seconds.
    pub t_arrive: f64,
    /// Arrival -> first admission (queue delay), seconds.
    pub queue_s: f64,
    /// Arrival -> completion (end-to-end latency), seconds.
    pub latency_s: f64,
    /// Arrival -> first finished trace (time-to-first-vote), seconds.
    pub ttfv_s: f64,
    /// Tokens generated across the request's traces.
    pub gen_tokens: u64,
    /// Mean per-trace seconds spent waiting (admission queue, preemption,
    /// resume recompute) — the serving analog of Fig 2c's per-trace view.
    pub mean_wait_s: f64,
    /// Mean per-trace seconds spent decoding.
    pub mean_decode_s: f64,
    /// Traces that finished naturally.
    pub n_finished: usize,
    /// Traces removed by pruning (STEP / Slim-SC / stalled-queue drops).
    pub n_pruned: usize,
    /// Preemption events suffered by the request's traces.
    pub n_preemptions: usize,
}

/// Aggregate result of one serving simulation.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// One outcome per request, in arrival order.
    pub outcomes: Vec<RequestOutcome>,
    /// Wall-clock from the first arrival's epoch to the last
    /// completion, seconds (the idle lead-in before traffic starts is
    /// excluded).
    pub makespan_s: f64,
    /// Engine-level event counters.
    pub counters: EngineCounters,
    /// Physical blocks in the shared pool.
    pub pool_blocks: usize,
    /// Peak blocks in use across the run.
    pub peak_used_blocks: usize,
}

impl ServeResult {
    /// Completed requests per second of simulated wall-clock.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.outcomes.len() as f64 / self.makespan_s
        }
    }
}

/// One live trace: owning request, synthetic spec, runtime state.
struct ServeTrace {
    rid: usize,
    spec: TraceSpec,
    st: TraceState,
}

/// Per-request scheduling bookkeeping.
struct Req {
    st: RequestState,
    q: Question,
    /// Trace slot range `[lo, lo + n)` in the global trace vector.
    lo: usize,
    n: usize,
    /// Non-terminal traces remaining.
    live: usize,
    /// Step boundaries crossed (Slim-SC check cadence).
    boundaries: usize,
    next_slim: usize,
    slim_rng: Rng,
}

/// The multi-request serving engine.
pub struct ServeSim<'a> {
    cfg: &'a ServeSimConfig,
    gen: &'a TraceGen,
    scorer: &'a StepScorer,
    profile: ModelProfile,
}

impl<'a> ServeSim<'a> {
    /// Bind a configuration to a trace generator and step scorer.
    ///
    /// Panics if `cfg.method` is [`Method::DeepConf`] (unsupported, see
    /// [`ServeSimConfig::method`]).
    pub fn new(cfg: &'a ServeSimConfig, gen: &'a TraceGen, scorer: &'a StepScorer) -> Self {
        assert!(
            cfg.method != Method::DeepConf,
            "serve-sim supports CoT/SC/Slim-SC/STEP; DeepConf's two-stage \
             warmup is a per-question protocol"
        );
        assert!(cfg.n_traces > 0, "n_traces must be positive");
        ServeSim { cfg, gen, scorer, profile: ModelProfile::get(cfg.model) }
    }

    /// score_t under the configured aggregation (paper: running mean).
    fn agg_score(&self, st: &TraceState) -> f64 {
        let d = self.cfg.params.default_score;
        match self.cfg.score_agg {
            ScoreAgg::Mean => st.mean_score(d),
            ScoreAgg::Last => st.last_score(d),
            ScoreAgg::Ema => st.ema_score(d),
        }
    }

    /// Run the whole workload to completion.
    pub fn run(&self) -> ServeResult {
        let cfg = self.cfg;
        let n_per = if cfg.method == Method::Cot { 1 } else { cfg.n_traces };
        let arrivals = cfg
            .workload
            .generate(self.gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);

        let gpu = GpuSpec::gh200(cfg.mem_util);
        let pool_blocks = gpu
            .kv_capacity_blocks(
                self.profile.weight_bytes,
                self.profile.activation_bytes,
                self.profile.kv_bytes_per_token,
                cfg.block_size,
            )
            .max(1);
        let quota = cfg.quota_frac.map(|f| ((pool_blocks as f64 * f) as usize).max(1));
        let mut pool = SharedKvPool::new(pool_blocks, cfg.block_size, quota);

        let tm = self.profile.timing;
        let needs_scores = cfg.method == Method::Step;
        let mut reqs: Vec<Req> = Vec::with_capacity(arrivals.len());
        let mut traces: Vec<ServeTrace> = Vec::new();
        let mut next_end: Vec<u64> = Vec::new();
        let mut wait_q: VecDeque<usize> = VecDeque::new();
        let mut counters =
            EngineCounters { requests: arrivals.len() as u64, ..Default::default() };
        let mut clock = 0.0f64;
        let mut next_arr = 0usize;
        // Makespan is measured from the first arrival's epoch; the idle
        // lead-in before it is not service time.
        let epoch = arrivals.first().map(|a| a.t_arrive).unwrap_or(0.0);

        // Terminal-prefix watermark: traces below this index are all
        // terminal, so per-event scans skip them. Requests complete
        // roughly in arrival order, which keeps the scans proportional
        // to the *live* trace count instead of every trace ever created.
        let mut first_live = 0usize;
        // Reusable hot-path buffers.
        let mut running: Vec<usize> = Vec::new();
        let mut cur_tokens: Vec<u64> = Vec::new();
        let mut owner_pairs: Vec<(OwnerId, u64)> = Vec::new();
        let mut h = vec![0.0f32; self.gen.gen.d];
        let mut z = vec![0.0f32; self.scorer.hidden];

        loop {
            // ---- admit every arrival due by now (admission prefills
            // advance the clock, which can make more arrivals due).
            while next_arr < arrivals.len() && arrivals[next_arr].t_arrive <= clock {
                let arr = arrivals[next_arr];
                next_arr += 1;
                self.admit_arrival(
                    &arr,
                    n_per,
                    &mut reqs,
                    &mut traces,
                    &mut next_end,
                    &mut pool,
                    &mut wait_q,
                    &mut clock,
                );
            }

            while first_live < traces.len() && !traces[first_live].st.status.is_active() {
                first_live += 1;
            }
            running.clear();
            for (i, t) in traces.iter().enumerate().skip(first_live) {
                if t.st.status == TraceStatus::Running {
                    running.push(i);
                }
            }

            if running.is_empty() {
                if !wait_q.is_empty() {
                    // Fully stalled: resume the first queued trace (FIFO)
                    // whose prefix fits; only when none can ever fit is
                    // the head dropped (counted as pruned).
                    if !self.resume_first_fit(
                        first_live,
                        &mut traces,
                        &mut reqs,
                        &mut pool,
                        &mut wait_q,
                        &mut clock,
                        &mut counters,
                    ) {
                        let head = wait_q.pop_front().unwrap();
                        let t = &mut traces[head];
                        t.st.status = TraceStatus::Pruned;
                        t.st.finish_clock = clock;
                        let rid = t.rid;
                        counters.pruned += 1;
                        let rq = &mut reqs[rid];
                        rq.live -= 1;
                        if rq.live == 0 {
                            rq.st.completed(clock);
                        }
                    }
                    continue;
                }
                if next_arr < arrivals.len() {
                    // Idle: jump to the next arrival.
                    clock = clock.max(arrivals[next_arr].t_arrive);
                    continue;
                }
                break;
            }

            let b = running.len();

            // ---- event horizon: iterations until any step boundary.
            let mut d_event = u64::MAX;
            for &i in &running {
                d_event = d_event.min(next_end[i] - traces[i].st.generated);
            }
            debug_assert!(d_event >= 1);

            // ---- arrival horizon: do not decode past the next arrival.
            let k0: usize = running
                .iter()
                .map(|&i| reqs[traces[i].rid].q.prompt_tokens + traces[i].st.generated as usize)
                .sum();
            let mut d_cap = d_event;
            if next_arr < arrivals.len() {
                let gap = arrivals[next_arr].t_arrive - clock;
                d_cap = d_cap.min(self.iters_within(b, k0, d_event, gap).max(1));
            }

            // ---- memory horizon over the shared pool (+ quotas).
            let d_mem = self.memory_horizon(
                &traces,
                &pool,
                &running,
                d_cap,
                &mut cur_tokens,
                &mut owner_pairs,
            );
            if d_mem == 0 {
                self.memory_event(
                    &running,
                    &mut traces,
                    &mut reqs,
                    &mut pool,
                    &mut wait_q,
                    &mut counters,
                    clock,
                );
                continue;
            }
            let d = d_cap.min(d_mem);

            // ---- advance time + tokens.
            let dt = tm.decode_interval(b, k0, d);
            clock += dt;
            counters.decode_iterations += d;
            counters.generated_tokens += d * b as u64;
            for t in traces[first_live..].iter_mut() {
                match t.st.status {
                    TraceStatus::Running => t.st.decode_time += dt,
                    TraceStatus::Preempted => t.st.wait_time += dt,
                    _ => {}
                }
            }
            for &i in &running {
                traces[i].st.generated += d;
                let ok = pool.append_tokens(i as u64, d as usize);
                debug_assert!(ok, "memory horizon must guarantee the append");
            }

            // ---- boundary / completion events.
            let mut freed_any = false;
            for &i in &running {
                let t = &mut traces[i];
                if t.st.generated != next_end[i] {
                    continue;
                }
                let step_n = t.st.next_step + 1;
                t.st.next_step += 1;
                let rid = t.rid;
                reqs[rid].boundaries += 1;
                if t.st.generated < t.spec.total_tokens {
                    next_end[i] = t.spec.step_ends[t.st.next_step];
                }
                if needs_scores {
                    self.gen.hidden_state_into(&reqs[rid].q, &t.spec, step_n, &mut h);
                    let s = self.scorer.score_into(&h, &mut z) as f64;
                    t.st.push_score(s);
                    counters.step_scores += 1;
                }
                if t.st.generated == t.spec.total_tokens {
                    t.st.status = TraceStatus::Finished;
                    t.st.finish_clock = clock;
                    pool.free_seq(i as u64);
                    freed_any = true;
                    let rq = &mut reqs[rid];
                    rq.live -= 1;
                    rq.st.first_vote(clock);
                    if rq.live == 0 {
                        rq.st.completed(clock);
                    }
                }
            }

            // ---- Slim-SC periodic similarity pruning (per request).
            if cfg.method == Method::SlimSc {
                for rid in 0..reqs.len() {
                    if reqs[rid].live == 0 || reqs[rid].boundaries < reqs[rid].next_slim {
                        continue;
                    }
                    let (lo, n) = (reqs[rid].lo, reqs[rid].n);
                    let active = traces[lo..lo + n]
                        .iter()
                        .filter(|t| t.st.status == TraceStatus::Running)
                        .count();
                    reqs[rid].next_slim += cfg.params.slim_check_interval_steps * active.max(1);
                    freed_any |= self.slim_check_request(
                        rid,
                        &mut reqs,
                        &mut traces,
                        &mut pool,
                        &mut counters,
                        clock,
                    );
                }
            }

            if freed_any {
                while self.try_resume(
                    first_live,
                    &mut traces,
                    &mut reqs,
                    &mut pool,
                    &mut wait_q,
                    &mut clock,
                    &mut counters,
                ) {}
            }
        }

        debug_assert!(wait_q.is_empty());
        let outcomes: Vec<RequestOutcome> = reqs
            .iter()
            .map(|rq| {
                let slice = &traces[rq.lo..rq.lo + rq.n];
                let votes: Vec<Vote> = slice
                    .iter()
                    .filter_map(|t| {
                        let answer = match t.st.status {
                            TraceStatus::Finished => t.spec.answer,
                            _ => None, // pruned / preempted traces abstain
                        };
                        answer?;
                        let weight = if cfg.method == Method::Step {
                            self.agg_score(&t.st)
                        } else {
                            1.0
                        };
                        Some(Vote { answer, weight })
                    })
                    .collect();
                let chosen = weighted_vote(&votes);
                let t_done = rq.st.t_done.unwrap_or(clock);
                RequestOutcome {
                    rid: rq.st.rid,
                    qid: rq.st.qid,
                    correct: chosen == Some(0),
                    chosen,
                    t_arrive: rq.st.t_arrive,
                    queue_s: rq.st.queue_s().unwrap_or(t_done - rq.st.t_arrive),
                    latency_s: t_done - rq.st.t_arrive,
                    ttfv_s: rq.st.ttfv_s().unwrap_or(t_done - rq.st.t_arrive),
                    gen_tokens: slice.iter().map(|t| t.st.generated).sum(),
                    mean_wait_s: slice.iter().map(|t| t.st.wait_time).sum::<f64>()
                        / slice.len().max(1) as f64,
                    mean_decode_s: slice.iter().map(|t| t.st.decode_time).sum::<f64>()
                        / slice.len().max(1) as f64,
                    n_finished: slice
                        .iter()
                        .filter(|t| t.st.status == TraceStatus::Finished)
                        .count(),
                    n_pruned: slice
                        .iter()
                        .filter(|t| t.st.status == TraceStatus::Pruned)
                        .count(),
                    n_preemptions: slice.iter().map(|t| t.st.preemptions).sum(),
                }
            })
            .collect();

        ServeResult {
            outcomes,
            makespan_s: clock - epoch,
            counters,
            pool_blocks,
            peak_used_blocks: pool.peak_used_blocks(),
        }
    }

    /// Create a request's traces and admit whatever fits; the rest joins
    /// the global FIFO wait queue. One batched prefill covers everything
    /// admitted here.
    #[allow(clippy::too_many_arguments)]
    fn admit_arrival(
        &self,
        arr: &Arrival,
        n_per: usize,
        reqs: &mut Vec<Req>,
        traces: &mut Vec<ServeTrace>,
        next_end: &mut Vec<u64>,
        pool: &mut SharedKvPool,
        wait_q: &mut VecDeque<usize>,
        clock: &mut f64,
    ) {
        debug_assert_eq!(arr.rid, reqs.len(), "arrivals admit in rid order");
        let q = self.gen.question(arr.qid);
        let lo = traces.len();
        let mut rq = Req {
            st: RequestState::new(arr.rid, arr.qid, arr.t_arrive),
            q,
            lo,
            n: n_per,
            live: n_per,
            boundaries: 0,
            next_slim: self.cfg.params.slim_check_interval_steps * n_per,
            slim_rng: Rng::new(
                self.cfg.seed
                    ^ (arr.rid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ 0x0051_1A5C,
            ),
        };
        let mut admitted = 0usize;
        for i in 0..n_per {
            let tid = lo + i;
            // Trace streams offset by rid so repeated questions still
            // decode distinct samples.
            let spec = self.gen.trace(&rq.q, arr.rid * n_per + i);
            let mut st = TraceState::new(tid as u64, self.cfg.params.deepconf_window);
            let need = pool.blocks_needed_for_new(rq.q.prompt_tokens);
            if pool.can_admit(arr.rid as OwnerId, need) {
                let ok = pool.allocate_seq(arr.rid as OwnerId, tid as u64, rq.q.prompt_tokens);
                debug_assert!(ok, "can_admit guaranteed the admission");
                admitted += 1;
            } else {
                st.status = TraceStatus::Preempted;
                wait_q.push_back(tid);
            }
            next_end.push(spec.step_ends[0]);
            traces.push(ServeTrace { rid: arr.rid, spec, st });
        }
        if admitted > 0 {
            rq.st.admitted(*clock);
            let dt = self.profile.timing.prefill(rq.q.prompt_tokens * admitted);
            *clock += dt;
            // The engine stalls for the prefill: earlier requests' traces
            // accrue decode (running) / wait (preempted) time.
            for t in traces[..lo].iter_mut() {
                match t.st.status {
                    TraceStatus::Running => t.st.decode_time += dt,
                    TraceStatus::Preempted => t.st.wait_time += dt,
                    _ => {}
                }
            }
        }
        reqs.push(rq);
    }

    /// Largest iteration count `d <= gap`'s worth of decode time (binary
    /// search over the monotone closed-form interval cost).
    fn iters_within(&self, b: usize, k0: usize, cap: u64, gap: f64) -> u64 {
        let tm = self.profile.timing;
        if tm.decode_interval(b, k0, cap) <= gap {
            return cap;
        }
        let (mut lo, mut hi) = (0u64, cap); // lo fits, hi doesn't
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if tm.decode_interval(b, k0, mid) <= gap {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Largest d (capped at `cap`) such that advancing every running
    /// trace d tokens fits the free pool *and* every owner's quota.
    /// `cur` and `pairs` are caller-owned scratch buffers reused across
    /// events (the loop allocates nothing at steady state).
    fn memory_horizon(
        &self,
        traces: &[ServeTrace],
        pool: &SharedKvPool,
        running: &[usize],
        cap: u64,
        cur: &mut Vec<u64>,
        pairs: &mut Vec<(OwnerId, u64)>,
    ) -> u64 {
        let free = pool.free_blocks() as u64;
        let bs = self.cfg.block_size as u64;
        cur.clear();
        cur.extend(running.iter().map(|&i| pool.seq_tokens(i as u64) as u64));
        let cur: &[u64] = cur;
        let quota = pool.quota_blocks();
        // (owner, resident tokens) sorted by owner, so per-owner demand
        // is a run scan. Only filled when quotas are in force.
        pairs.clear();
        if quota.is_some() {
            pairs.extend(
                running.iter().zip(cur).map(|(&i, &c)| (traces[i].rid as OwnerId, c)),
            );
            pairs.sort_unstable();
        }
        let pairs: &[(OwnerId, u64)] = pairs;
        let demand = |c: u64, d: u64| (c + d).div_ceil(bs) - c.div_ceil(bs);
        let fits = |d: u64| -> bool {
            let total: u64 = cur.iter().map(|&c| demand(c, d)).sum();
            if total > free {
                return false;
            }
            if quota.is_some() {
                let mut idx = 0;
                while idx < pairs.len() {
                    let owner = pairs[idx].0;
                    let mut need = 0u64;
                    while idx < pairs.len() && pairs[idx].0 == owner {
                        need += demand(pairs[idx].1, d);
                        idx += 1;
                    }
                    if let Some(hr) = pool.owner_headroom(owner) {
                        if need > hr as u64 {
                            return false;
                        }
                    }
                }
            }
            true
        };
        if fits(cap) {
            return cap;
        }
        let (mut lo, mut hi) = (0u64, cap); // fits(lo), !fits(hi)
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Memory saturated at d = 1: prune (STEP) or preempt (vLLM default).
    /// If the *pool* binds, the victim set is every running trace —
    /// cross-request. If only one owner's *quota* binds, the victim set
    /// is that owner's running traces.
    #[allow(clippy::too_many_arguments)]
    fn memory_event(
        &self,
        running: &[usize],
        traces: &mut [ServeTrace],
        reqs: &mut [Req],
        pool: &mut SharedKvPool,
        wait_q: &mut VecDeque<usize>,
        counters: &mut EngineCounters,
        clock: f64,
    ) {
        debug_assert!(!running.is_empty());
        let mut total_need = 0usize;
        for &i in running {
            total_need += pool.blocks_needed_for_append(i as u64, 1);
        }
        let pool_bound = total_need > pool.free_blocks();
        let binding: Option<OwnerId> = if pool_bound {
            None
        } else {
            let mut need_by: BTreeMap<OwnerId, usize> = BTreeMap::new();
            for &i in running {
                *need_by.entry(traces[i].rid as OwnerId).or_insert(0) +=
                    pool.blocks_needed_for_append(i as u64, 1);
            }
            need_by
                .into_iter()
                .find(|&(o, need)| matches!(pool.owner_headroom(o), Some(h) if need > h))
                .map(|(o, _)| o)
        };
        let in_set = |traces: &[ServeTrace], i: usize| match binding {
            Some(o) => traces[i].rid as OwnerId == o,
            None => true,
        };
        match self.cfg.method {
            Method::Step => {
                // Algorithm 1, serving form: argmin aggregated step score
                // over the victim set, release KV at once.
                let victim = running
                    .iter()
                    .copied()
                    .filter(|&i| in_set(traces, i))
                    .min_by(|&a, &b| {
                        self.agg_score(&traces[a].st)
                            .partial_cmp(&self.agg_score(&traces[b].st))
                            .unwrap()
                    })
                    .expect("memory event with empty victim set");
                let t = &mut traces[victim];
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = clock;
                let rid = t.rid;
                pool.free_seq(victim as u64);
                counters.pruned += 1;
                let rq = &mut reqs[rid];
                rq.live -= 1;
                if rq.live == 0 {
                    rq.st.completed(clock);
                }
            }
            _ => {
                // vLLM preemption: evict the youngest running trace in
                // the victim set (cheapest recompute), FIFO resume.
                let victim = running
                    .iter()
                    .copied()
                    .filter(|&i| in_set(traces, i))
                    .min_by_key(|&i| traces[i].st.generated)
                    .expect("memory event with empty victim set");
                let t = &mut traces[victim];
                t.st.status = TraceStatus::Preempted;
                t.st.preemptions += 1;
                pool.free_seq(victim as u64);
                counters.preemptions += 1;
                wait_q.push_back(victim);
            }
        }
    }

    /// Would resuming trace `tid` fit right now (+1 block of headroom),
    /// pool and quota included?
    fn resume_fits(
        &self,
        traces: &[ServeTrace],
        reqs: &[Req],
        pool: &SharedKvPool,
        tid: usize,
    ) -> bool {
        let rid = traces[tid].rid;
        let prefix = reqs[rid].q.prompt_tokens + traces[tid].st.generated as usize;
        pool.can_admit(rid as OwnerId, pool.blocks_needed_for_new(prefix) + 1)
    }

    /// Resume the wait-queue head if its whole prefix fits — vLLM's FCFS
    /// resume rule for the normal path where finishing traces free memory.
    #[allow(clippy::too_many_arguments)]
    fn try_resume(
        &self,
        first_live: usize,
        traces: &mut [ServeTrace],
        reqs: &mut [Req],
        pool: &mut SharedKvPool,
        wait_q: &mut VecDeque<usize>,
        clock: &mut f64,
        counters: &mut EngineCounters,
    ) -> bool {
        let Some(&head) = wait_q.front() else { return false };
        if !self.resume_fits(traces, reqs, pool, head) {
            return false;
        }
        wait_q.pop_front();
        self.admit_resumed(first_live, head, traces, reqs, pool, clock, counters);
        true
    }

    /// Stalled-engine resume: first queued trace (FIFO order) whose
    /// prefix fits; false only when none fits.
    #[allow(clippy::too_many_arguments)]
    fn resume_first_fit(
        &self,
        first_live: usize,
        traces: &mut [ServeTrace],
        reqs: &mut [Req],
        pool: &mut SharedKvPool,
        wait_q: &mut VecDeque<usize>,
        clock: &mut f64,
        counters: &mut EngineCounters,
    ) -> bool {
        let Some(pos) =
            (0..wait_q.len()).find(|&p| self.resume_fits(traces, reqs, pool, wait_q[p]))
        else {
            return false;
        };
        let tid = wait_q.remove(pos).expect("position came from the queue");
        self.admit_resumed(first_live, tid, traces, reqs, pool, clock, counters);
        true
    }

    /// Re-admit a dequeued trace: recompute-on-resume rebuilds the prefix
    /// KV with a prefill pass that stalls the engine. `first_live` is the
    /// caller's terminal-prefix watermark (accrual skips terminal traces).
    #[allow(clippy::too_many_arguments)]
    fn admit_resumed(
        &self,
        first_live: usize,
        tid: usize,
        traces: &mut [ServeTrace],
        reqs: &mut [Req],
        pool: &mut SharedKvPool,
        clock: &mut f64,
        counters: &mut EngineCounters,
    ) {
        let rid = traces[tid].rid;
        let prefix = reqs[rid].q.prompt_tokens + traces[tid].st.generated as usize;
        let ok = pool.allocate_seq(rid as OwnerId, tid as u64, prefix);
        debug_assert!(ok, "resume_fits guaranteed the admission");
        traces[tid].st.status = TraceStatus::Running;
        reqs[rid].st.admitted(*clock);
        counters.resumes += 1;
        let dt = self.profile.timing.prefill(prefix);
        *clock += dt;
        for t in traces[first_live..].iter_mut() {
            match t.st.status {
                TraceStatus::Running => t.st.decode_time += dt,
                TraceStatus::Preempted => t.st.wait_time += dt,
                _ => {}
            }
        }
        // The resumed trace itself: reconstruction counts as waiting.
        let t = &mut traces[tid].st;
        t.decode_time -= dt;
        t.wait_time += dt;
    }

    /// Slim-SC similarity check within one request (thought level): pair
    /// up its active traces at random, prune one member of each pair
    /// whose modelled similarity crosses the threshold. Same calibration
    /// as the single-question engine.
    fn slim_check_request(
        &self,
        rid: usize,
        reqs: &mut [Req],
        traces: &mut [ServeTrace],
        pool: &mut SharedKvPool,
        counters: &mut EngineCounters,
        clock: f64,
    ) -> bool {
        let threshold = self.cfg.params.slim_similarity_threshold;
        let (lo, n) = (reqs[rid].lo, reqs[rid].n);
        let mut active: Vec<usize> = (lo..lo + n)
            .filter(|&i| traces[i].st.status == TraceStatus::Running)
            .collect();
        let rq = &mut reqs[rid];
        rq.slim_rng.shuffle(&mut active);
        let mut pruned_any = false;
        for pair in active.chunks_exact(2) {
            let (i, j) = (pair[0], pair[1]);
            let same = traces[i].spec.answer.is_some()
                && traces[i].spec.answer == traces[j].spec.answer;
            let sim = if same {
                rq.slim_rng.normal_with(0.905, 0.025)
            } else {
                rq.slim_rng.normal_with(0.80, 0.03)
            };
            if sim > threshold {
                let victim = if rq.slim_rng.bernoulli(0.5) { i } else { j };
                let t = &mut traces[victim];
                t.st.status = TraceStatus::Pruned;
                t.st.finish_clock = clock;
                pool.free_seq(victim as u64);
                counters.pruned += 1;
                rq.live -= 1;
                pruned_any = true;
            }
        }
        if rq.live == 0 {
            rq.st.completed(clock);
        }
        pruned_any
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::cells::projection_scorer;
    use crate::sim::tracegen::GenParams;

    /// Short-trace benchmark + full pool: demand stays far below
    /// capacity, so no memory event can fire.
    fn light_cfg(method: Method) -> ServeSimConfig {
        let mut c = ServeSimConfig::new(
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            method,
            4,
            WorkloadSpec::poisson(0.01, 3),
        );
        c.seed = 11;
        c
    }

    fn pressured_cfg(method: Method) -> ServeSimConfig {
        let mut c = ServeSimConfig::new(
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            method,
            6,
            WorkloadSpec::poisson(0.1, 3),
        );
        c.mem_util = 0.45;
        c.seed = 13;
        c
    }

    fn run(cfg: &ServeSimConfig) -> ServeResult {
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        ServeSim::new(cfg, &gen, &scorer).run()
    }

    #[test]
    fn all_requests_complete_for_every_method() {
        for method in [Method::Cot, Method::Sc, Method::SlimSc, Method::Step] {
            for cfg in [light_cfg(method), pressured_cfg(method)] {
                let r = run(&cfg);
                assert_eq!(r.outcomes.len(), cfg.workload.n_requests, "{method:?}");
                for o in &r.outcomes {
                    assert!(o.latency_s > 0.0, "{method:?}: rid {} zero latency", o.rid);
                    assert!(o.ttfv_s <= o.latency_s + 1e-9, "{method:?}");
                    assert!(o.queue_s >= 0.0, "{method:?}");
                    let expected = if method == Method::Cot { 1 } else { cfg.n_traces };
                    assert!(o.n_finished + o.n_pruned <= expected, "{method:?}");
                }
                assert!(r.makespan_s > 0.0);
                assert!(r.throughput_rps() > 0.0);
            }
        }
    }

    #[test]
    fn light_load_never_triggers_memory_events() {
        for method in [Method::Sc, Method::Step] {
            let r = run(&light_cfg(method));
            assert_eq!(r.counters.preemptions, 0, "{method:?}");
            // STEP never preempts by design; under light load it also
            // never needs to prune.
            if method == Method::Step {
                assert_eq!(r.counters.pruned, 0);
            }
            for o in &r.outcomes {
                assert_eq!(o.n_finished, 4, "{method:?}: all traces finish");
            }
        }
    }

    #[test]
    fn sc_preempts_under_pressure() {
        let r = run(&pressured_cfg(Method::Sc));
        assert!(r.counters.preemptions > 0, "expected preemption at 0.45 util");
    }

    #[test]
    fn step_prunes_cross_request_and_never_preempts() {
        let r = run(&pressured_cfg(Method::Step));
        assert_eq!(r.counters.preemptions, 0, "STEP must eliminate the waiting queue");
        assert!(r.counters.pruned > 0, "expected pruning at 0.45 util");
    }

    #[test]
    fn step_beats_sc_latency_under_pressure() {
        let step = run(&pressured_cfg(Method::Step));
        let sc = run(&pressured_cfg(Method::Sc));
        let max_lat = |r: &ServeResult| {
            r.outcomes.iter().map(|o| o.latency_s).fold(0.0f64, f64::max)
        };
        assert!(
            max_lat(&step) < max_lat(&sc),
            "STEP tail {} vs SC tail {}",
            max_lat(&step),
            max_lat(&sc)
        );
        assert!(step.makespan_s < sc.makespan_s);
        assert!(
            step.counters.generated_tokens < sc.counters.generated_tokens,
            "pruning must save tokens"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        for method in [Method::Sc, Method::Step] {
            let a = run(&pressured_cfg(method));
            let b = run(&pressured_cfg(method));
            assert_eq!(a.makespan_s, b.makespan_s, "{method:?}");
            assert_eq!(a.counters.generated_tokens, b.counters.generated_tokens);
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.latency_s, y.latency_s, "{method:?}");
                assert_eq!(x.chosen, y.chosen);
            }
        }
    }

    #[test]
    fn quota_bounds_every_owner() {
        let mut cfg = pressured_cfg(Method::Sc);
        cfg.quota_frac = Some(0.4);
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 3);
        // Quota of 40% of the pool: peak usage can fill the pool across
        // owners, but the run must still complete with every trace
        // terminal (the per-owner memory events keep it live).
        assert!(r.peak_used_blocks <= r.pool_blocks);
        let mut cfg_step = pressured_cfg(Method::Step);
        cfg_step.quota_frac = Some(0.4);
        let rs = run(&cfg_step);
        assert_eq!(rs.counters.preemptions, 0);
        assert!(rs.counters.pruned > 0);
    }

    #[test]
    fn bursty_workload_completes() {
        let mut cfg = pressured_cfg(Method::Step);
        cfg.workload = WorkloadSpec::bursty(0.1, 3, 3);
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 3);
        // A burst of 3 requests lands at one instant: queueing shows up.
        assert!(r.outcomes.iter().all(|o| o.latency_s > 0.0));
    }

    #[test]
    fn slim_sc_prunes_similar_traces() {
        let r = run(&pressured_cfg(Method::SlimSc));
        assert!(r.counters.pruned > 0, "slim-sc should prune similar traces");
    }

    #[test]
    fn request_lifecycle_marks_are_consistent() {
        let r = run(&pressured_cfg(Method::Sc));
        for o in &r.outcomes {
            assert!(o.queue_s <= o.latency_s + 1e-9);
            assert!(o.t_arrive >= 0.0);
        }
    }

    #[test]
    fn wait_decode_split_is_populated() {
        let sc = run(&pressured_cfg(Method::Sc));
        assert!(
            sc.outcomes.iter().any(|o| o.mean_wait_s > 0.0),
            "SC under pressure must accrue waiting time"
        );
        for o in &sc.outcomes {
            assert!(o.mean_decode_s >= 0.0 && o.mean_wait_s >= 0.0);
        }
        // Light load: nothing ever waits.
        let light = run(&light_cfg(Method::Sc));
        for o in &light.outcomes {
            assert_eq!(o.mean_wait_s, 0.0, "no queueing under light load");
            assert!(o.mean_decode_s > 0.0);
        }
    }
}
