//! Multi-GPU cluster serving simulator: R per-GPU engines (uniform or
//! heterogeneous), a routing layer, admission control, and
//! work-preserving cross-GPU trace migration under one global clock.
//!
//! The serving layer ([`crate::sim::serve`]) models one GPU; this
//! module scales it out. A [`ClusterSim`] drives `R` independent
//! [`ServeEngine`]s — each with its own [`crate::kvcache::SharedKvPool`]
//! sized and clocked by its [`GpuProfile`] — and a cluster front door:
//!
//! ```text
//!  arrivals ──▶ admission ──▶ router ──▶ engine[g].submit(...)
//!  (open /      (bounded      (round-robin /
//!   closed       queue, SLO    least-outstanding /
//!   loop)        early-       kv-pressure)
//!                reject)
//!    ▲              │ would shed?
//!    │              ▼
//!    │        MIGRATION (policy-gated): relocate one request's
//!    │        surviving traces hottest → coolest GPU; the freed
//!    └─◀──    quota slot absorbs the queue head / the arrival
//! ```
//!
//! **Heterogeneous pools.** [`ClusterConfig::gpu_profiles`] gives each
//! GPU its own memory utilization, block size, and per-token timing
//! scale; the kv-pressure router normalizes projected demand by each
//! GPU's free blocks *and* its timing scale, so a slow-but-empty GPU
//! is not preferred over a fast-but-busy one. An empty profile list is
//! the uniform pool, bit-identical to the profile-free cluster.
//!
//! **Cross-GPU migration.** Under a [`MigrationPolicy`] other than
//! `Never`, shedding stops being the only relief valve: a request's
//! surviving traces can relocate to the least-pressured engine —
//! terminal traces keep their votes, survivors re-enter through the
//! target's wait queue and pay the standard recompute-on-resume bill
//! (counted in [`ClusterCounters::migration_recompute_tokens`]). The
//! on-pressure policy additionally rebalances proactively (with
//! hysteresis) and rescues requests whose *last* surviving trace a
//! memory event would prune.
//!
//! **Elastic fleets.** A deterministic [`FleetEvent`] schedule
//! (explicit, or seeded-random via [`random_fleet_events`]) makes R
//! dynamic: GPUs join from a standby pool, leave gracefully, or get
//! spot-revoked with a drain deadline. A revocation stops admission to
//! the victim (its cached router view reads as permanently at-quota,
//! so every placement filter excludes it), and the drain controller
//! relocates its residents through the same migration hop onto active
//! below-quota GPUs; whatever is still resident when the deadline
//! fires is abandoned and counted as
//! [`ClusterCounters::shed_on_revoke`]. A scaling controller activates
//! standby GPUs when admission runs hot (an imminent shed, or the
//! queue reaching [`ClusterConfig::scale_up_queue_depth`]). Control
//! events run on the same global clock as arrivals — ties go to the
//! control event — so every chaos schedule is byte-identical across
//! `--threads` and `--step-threads`.
//!
//! **Event order.** Arrivals (open-loop pregenerated, or closed-loop
//! completion-driven) live in one global min-heap keyed by
//! `(time, issue sequence)`. Before each arrival is offered, every
//! engine runs forward to the arrival instant; completions harvested on
//! the way spawn the closed-loop clients' next requests and unblock the
//! admission queue. Between interaction points the engines are
//! *independent* — that is what makes R of them cheap, and what lets
//! [`ClusterConfig::step_threads`] advance them **in parallel**
//! (completions are still merged in GPU order, so the parallel-stepped
//! run is bit-identical to the serial one). The same quantization the
//! single-GPU driver applies to arrivals holds here: a request is
//! admitted at the first engine event at-or-after its arrival instant.
//! After the last scheduled arrival the loop steps the busy engine with
//! the smallest local clock one event at a time — picked from a lazy
//! min-heap over engine clocks instead of an O(R) argmin per event — so
//! completion-driven interactions (queue drains, closed-loop spawns)
//! stay in near-global time order.
//!
//! **Admission control.** A bounded cluster-wide FIFO queue holds
//! requests no eligible GPU can take (every GPU at its
//! outstanding-request quota). Arrivals beyond the queue bound are shed;
//! with an SLO configured, an arrival that would queue is shed early
//! when the queued-ahead KV footprint over the cluster's measured drain
//! rate already exceeds the SLO — the *expected trace footprint* of a
//! request (N × the benchmark's expected trace length, scaled by the
//! question's difficulty multiplier) is what both the estimate and the
//! kv-pressure router consult. A shed closed-loop client re-enters its
//! think state and issues fresh work later, so the configured request
//! budget is always fully offered.
//!
//! Determinism: engines are advanced and harvested in fixed GPU order,
//! the heap's tie-break is the issue sequence, and every random draw
//! derives from the config seed — one run is bit-identical across
//! processes and `--threads` values (threads only shard whole cluster
//! cells in the harness).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::method::{Method, MethodParams};
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::signal::SignalSpec;
use crate::metrics::{ClusterCounters, EngineCounters, LatencySketch};
use crate::obs::{dump_tail, merge_streams, EventBuf, EventKind, Recorder, SimEvent};
use crate::sim::des::ScoreAgg;
use crate::sim::profiles::{BenchId, ModelId};
use crate::sim::router::{
    kv_pressure_key, shard_base_key, GpuView, RouteRequest, RouterKind, RouterPolicy,
};
use crate::sim::serve::{MigratedRequest, RequestOutcome, ServeEngine, ServeSimConfig};
use crate::sim::tracegen::TraceGen;
use crate::sim::workload::{Arrival, ClosedLoopClients, ClosedLoopSpec, WorkloadSpec};
use crate::util::pool;
use crate::util::rng::Rng;

/// Capacity/speed profile of one GPU in a heterogeneous pool.
///
/// The uniform cluster clones one engine configuration R times; with
/// profiles, each engine derives its KV pool size, block size, and
/// timing from its own entry, so mixed fleets (one big fast GPU next to
/// small slow ones) are first-class. A profile of
/// `{mem_util, block_size, timing_scale: 1.0}` matching the cluster
/// defaults is bit-identical to the profile-free path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuProfile {
    /// vLLM-style gpu_memory_utilization of this GPU's pool.
    pub mem_util: f64,
    /// PagedAttention block size of this GPU's pool, in tokens.
    pub block_size: usize,
    /// Per-token timing multiplier vs the calibrated baseline GPU
    /// (1.0 = baseline, 3.0 = three times slower).
    pub timing_scale: f64,
}

impl GpuProfile {
    /// Parse the CLI spelling `MEM_UTIL:BLOCK_SIZE:TIMING_SCALE`
    /// (e.g. `0.9:16:1.0`).
    pub fn parse(s: &str) -> Option<GpuProfile> {
        let mut it = s.split(':');
        let mem_util: f64 = it.next()?.trim().parse().ok()?;
        let block_size: usize = it.next()?.trim().parse().ok()?;
        let timing_scale: f64 = it.next()?.trim().parse().ok()?;
        let util_ok = mem_util > 0.0 && mem_util <= 1.0;
        if it.next().is_some()
            || !util_ok
            || block_size == 0
            || !timing_scale.is_finite()
            || timing_scale <= 0.0
        {
            return None;
        }
        Some(GpuProfile { mem_util, block_size, timing_scale })
    }

    /// The CLI spelling of this profile (round-trips through
    /// [`parse`](Self::parse)).
    pub fn spec(&self) -> String {
        format!("{}:{}:{}", self.mem_util, self.block_size, self.timing_scale)
    }

    /// A default heterogeneous fleet for demonstrations and the
    /// migration grid: GPU 0 is the calibrated baseline at 0.9
    /// utilization; every other GPU is small (0.45 utilization) and
    /// 2.5× slower. Cycled over `gpus` entries.
    pub fn default_hetero(gpus: usize) -> Vec<GpuProfile> {
        (0..gpus.max(1))
            .map(|g| {
                if g == 0 {
                    GpuProfile { mem_util: 0.9, block_size: 16, timing_scale: 1.0 }
                } else {
                    GpuProfile { mem_util: 0.45, block_size: 16, timing_scale: 2.5 }
                }
            })
            .collect()
    }
}

/// When the cluster may relocate a request's surviving traces to
/// another GPU instead of losing work.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MigrationPolicy {
    /// Never migrate — admission sheds and memory events prune exactly
    /// as before (byte-identical to the migration-free cluster).
    Never,
    /// Migrate only when admission is about to shed an arrival: one
    /// request moves off the highest-pressure *at-quota* GPU (so an
    /// admission slot actually opens) onto the lowest-pressure other
    /// GPU — over quota if need be, since it was already admitted
    /// once. The freed slot absorbs the queue head or the arrival
    /// itself, so the shed becomes a deferral instead of lost work.
    OnShed,
    /// Everything [`OnShed`](MigrationPolicy::OnShed) does, plus (a)
    /// proactive rebalancing with hysteresis — before each admission
    /// decision, if the highest projected pressure exceeds `ratio` ×
    /// the lowest, one request moves (quota-respecting) — and (b)
    /// last-survivor rescue: a memory event that would prune the final
    /// surviving trace of a request evicts the whole request for
    /// relocation instead ([`crate::sim::serve::ServeSimConfig::migrate_rescue`]).
    OnPressure {
        /// Hysteresis threshold: migrate only while max pressure >
        /// `ratio` × min pressure (ratio > 1 keeps near-balanced pools
        /// still).
        ratio: f64,
    },
}

impl MigrationPolicy {
    /// Default hysteresis of the on-pressure policy.
    pub const DEFAULT_PRESSURE_RATIO: f64 = 2.0;

    /// Parse the CLI spelling: `never`, `on-shed`, `on-pressure`, or
    /// `on-pressure:RATIO`.
    pub fn parse(s: &str) -> Option<MigrationPolicy> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "never" | "off" => Some(MigrationPolicy::Never),
            "on-shed" | "onshed" | "shed" => Some(MigrationPolicy::OnShed),
            "on-pressure" | "onpressure" | "pressure" => Some(MigrationPolicy::OnPressure {
                ratio: MigrationPolicy::DEFAULT_PRESSURE_RATIO,
            }),
            _ => {
                let ratio: f64 = s.strip_prefix("on-pressure:")?.parse().ok()?;
                if ratio.is_finite() && ratio >= 1.0 {
                    Some(MigrationPolicy::OnPressure { ratio })
                } else {
                    None
                }
            }
        }
    }

    /// Display/row-label name (the CLI spelling without the ratio).
    pub fn name(&self) -> &'static str {
        match self {
            MigrationPolicy::Never => "never",
            MigrationPolicy::OnShed => "on-shed",
            MigrationPolicy::OnPressure { .. } => "on-pressure",
        }
    }

    /// The full CLI spelling (round-trips through [`parse`](Self::parse)).
    pub fn spec(&self) -> String {
        match self {
            MigrationPolicy::OnPressure { ratio } => format!("on-pressure:{ratio}"),
            other => other.name().to_string(),
        }
    }

    /// Does this policy fire at admission-shed points?
    fn on_shed(&self) -> bool {
        !matches!(self, MigrationPolicy::Never)
    }
}

/// What a scheduled fleet-lifecycle event does to its target GPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FleetAction {
    /// Activate a standby (or previously departed) GPU: it becomes
    /// placeable immediately.
    Join,
    /// Graceful departure: admission stops, residents run to natural
    /// completion (no force-clear), and the GPU departs once empty.
    Leave,
    /// Spot revocation: admission stops and the drain controller has
    /// `deadline_s` seconds to relocate residents before the
    /// force-clear abandons whatever is left.
    Revoke {
        /// Seconds between the revocation notice and the force-clear.
        deadline_s: f64,
    },
}

/// One deterministic fleet-lifecycle event: at simulation time
/// [`t_s`](Self::t_s), apply [`action`](Self::action) to GPU
/// [`gpu`](Self::gpu). Events targeting a GPU in an incompatible state
/// (joining an active GPU, revoking a standby or already-draining one)
/// are no-ops, so arbitrary schedules are safe to run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    /// Simulation time the event fires (seconds, non-negative finite).
    pub t_s: f64,
    /// Target GPU id (dense over active + standby slots).
    pub gpu: usize,
    /// What happens to the target.
    pub action: FleetAction,
}

impl FleetEvent {
    /// Parse one explicit event spec — `T:GPU:join`, `T:GPU:leave`, or
    /// `T:GPU:revoke:DEADLINE_S` — with `gpu < total_gpus`.
    pub fn parse(s: &str, total_gpus: usize) -> Option<FleetEvent> {
        let mut it = s.split(':');
        let t_s: f64 = it.next()?.trim().parse().ok()?;
        let gpu: usize = it.next()?.trim().parse().ok()?;
        let action = match it.next()?.trim() {
            "join" => FleetAction::Join,
            "leave" => FleetAction::Leave,
            "revoke" => {
                let deadline_s: f64 = it.next()?.trim().parse().ok()?;
                if !deadline_s.is_finite() || deadline_s < 0.0 {
                    return None;
                }
                FleetAction::Revoke { deadline_s }
            }
            _ => return None,
        };
        if it.next().is_some() || !t_s.is_finite() || t_s < 0.0 || gpu >= total_gpus {
            return None;
        }
        Some(FleetEvent { t_s, gpu, action })
    }

    /// The CLI spelling (round-trips through [`parse`](Self::parse)).
    pub fn spec(&self) -> String {
        match self.action {
            FleetAction::Join => format!("{}:{}:join", self.t_s, self.gpu),
            FleetAction::Leave => format!("{}:{}:leave", self.t_s, self.gpu),
            FleetAction::Revoke { deadline_s } => {
                format!("{}:{}:revoke:{}", self.t_s, self.gpu, deadline_s)
            }
        }
    }
}

/// Parse the CLI `--fleet-events` spelling: either
/// `rand:SEED:N_EVENTS:HORIZON_S` (the seeded chaos generator,
/// [`random_fleet_events`]) or a `;`-separated list of explicit
/// events, each `T:GPU:join`, `T:GPU:leave`, or
/// `T:GPU:revoke:DEADLINE_S`. GPU ids must be below `gpus + standby`.
/// An empty spec is the empty schedule — the static fleet.
pub fn parse_fleet_events(
    spec: &str,
    gpus: usize,
    standby: usize,
) -> Option<Vec<FleetEvent>> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Some(Vec::new());
    }
    if let Some(rest) = spec.strip_prefix("rand:") {
        let mut it = rest.split(':');
        let seed: u64 = it.next()?.trim().parse().ok()?;
        let n_events: usize = it.next()?.trim().parse().ok()?;
        let horizon_s: f64 = it.next()?.trim().parse().ok()?;
        if it.next().is_some() || !horizon_s.is_finite() || horizon_s <= 0.0 {
            return None;
        }
        return Some(random_fleet_events(seed, gpus, standby, n_events, horizon_s));
    }
    let total = gpus + standby;
    let mut out = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(FleetEvent::parse(part, total)?);
    }
    Some(out)
}

/// The shared deterministic chaos driver: generate `n_events` fleet
/// events over `[0, horizon_s]` from `seed`. A shadow fleet state
/// keeps the schedule sensible — at least one GPU stays
/// (shadow-)active, departures are preferred over joins when both are
/// possible (p = 0.6), a departure is a spot revocation with
/// p = 0.75 (deadline uniform in 5–25 % of the horizon) and a
/// graceful leave otherwise, and joins reactivate standby or departed
/// slots. Times come out sorted ascending. The same
/// `(seed, gpus, standby, n_events, horizon_s)` always yields the same
/// schedule — the chaos tests, the CLI, and the bench all share it.
pub fn random_fleet_events(
    seed: u64,
    gpus: usize,
    standby: usize,
    n_events: usize,
    horizon_s: f64,
) -> Vec<FleetEvent> {
    let total = gpus + standby;
    let mut rng = Rng::new(seed ^ 0xF1EE_7E4E_A75C_11A0);
    let mut times: Vec<f64> =
        (0..n_events).map(|_| rng.range_f64(0.0, horizon_s)).collect();
    times.sort_by_key(|t| t.to_bits());
    let mut active: Vec<bool> = (0..total).map(|g| g < gpus).collect();
    let mut out = Vec::with_capacity(n_events);
    for t_s in times {
        let on: Vec<usize> = (0..total).filter(|&g| active[g]).collect();
        let off: Vec<usize> = (0..total).filter(|&g| !active[g]).collect();
        let can_remove = on.len() > 1;
        let can_add = !off.is_empty();
        let remove = match (can_remove, can_add) {
            (true, true) => rng.bernoulli(0.6),
            (true, false) => true,
            (false, true) => false,
            (false, false) => break,
        };
        if remove {
            let gpu = on[rng.below(on.len())];
            let action = if rng.bernoulli(0.75) {
                FleetAction::Revoke {
                    deadline_s: rng.range_f64(0.05 * horizon_s, 0.25 * horizon_s),
                }
            } else {
                FleetAction::Leave
            };
            active[gpu] = false;
            out.push(FleetEvent { t_s, gpu, action });
        } else {
            let gpu = off[rng.below(off.len())];
            active[gpu] = true;
            out.push(FleetEvent { t_s, gpu, action: FleetAction::Join });
        }
    }
    out
}

/// Lifecycle state of one GPU slot in the elastic fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
enum GpuState {
    /// In the standby pool: holds no work and steps no events until a
    /// join event or the scaling controller activates it.
    Standby,
    /// Serving: placeable and stepped by the event loop.
    Active,
    /// Admission stopped; residents drain (relocate or complete) until
    /// the absolute deadline (`f64::INFINITY` = graceful leave, no
    /// force-clear). Still stepped so in-flight work makes progress.
    Draining {
        /// Absolute force-clear instant (simulation seconds).
        deadline_s: f64,
    },
    /// Departed: empty, unstepped, invisible to the router. A later
    /// join event may reactivate the slot.
    Revoked,
}

impl GpuState {
    /// May the router place new work here?
    fn placeable(self) -> bool {
        matches!(self, GpuState::Active)
    }

    /// Does the event loop advance this engine?
    fn steppable(self) -> bool {
        matches!(self, GpuState::Active | GpuState::Draining { .. })
    }
}

/// What a fleet-log entry records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetLogKind {
    /// The GPU became active (standby activation or rejoin).
    Joined,
    /// Admission to the GPU stopped (graceful leave or revocation
    /// notice).
    DrainStarted,
    /// The GPU left the fleet holding zero residents.
    Departed,
}

/// One entry of the fleet-lifecycle audit log
/// ([`ClusterResult::fleet_log`]). The chaos suite asserts on it: a
/// [`Departed`](FleetLogKind::Departed) entry always shows zero
/// residents, and lands at or before the revocation deadline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetLogEntry {
    /// Simulation time of the transition (seconds).
    pub t_s: f64,
    /// The GPU that transitioned.
    pub gpu: usize,
    /// Which transition happened.
    pub kind: FleetLogKind,
    /// Outstanding residents immediately after the transition (always
    /// zero for [`FleetLogKind::Departed`]).
    pub residents_after: usize,
}

/// The arrival regime driving a cluster run.
#[derive(Debug, Clone)]
pub enum ClusterWorkload {
    /// Open loop: rate-driven arrivals, pregenerated from the spec.
    Open(WorkloadSpec),
    /// Closed loop: a fixed client population whose next arrivals are
    /// completion-driven (saturation self-throttles).
    Closed(ClosedLoopSpec),
}

impl ClusterWorkload {
    /// Total requests the workload will offer.
    pub fn n_requests(&self) -> usize {
        match self {
            ClusterWorkload::Open(w) => w.n_requests,
            ClusterWorkload::Closed(c) => c.n_requests,
        }
    }
}

/// Admission-control policy of the cluster front door.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Bound on the cluster-wide admission queue; arrivals that would
    /// push past it are shed.
    pub queue_cap: usize,
    /// Per-GPU cap on outstanding (incomplete) requests; a GPU at the
    /// cap is ineligible for placement until a request completes.
    pub max_outstanding_per_gpu: usize,
    /// SLO-aware early reject: an arrival that would queue is shed when
    /// the queued-ahead footprint over the measured drain rate exceeds
    /// this budget (seconds). `None` disables the early reject.
    pub slo_s: Option<f64>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { queue_cap: 64, max_outstanding_per_gpu: 8, slo_s: None }
    }
}

/// Configuration of one cluster serving simulation.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of per-GPU engines (R).
    pub gpus: usize,
    /// Served model (every GPU runs the same model).
    pub model: ModelId,
    /// Benchmark whose question pool the workload draws from.
    pub bench: BenchId,
    /// Test-time-scaling method driving every engine's scheduler.
    pub method: Method,
    /// Traces per request (N); CoT forces 1.
    pub n_traces: usize,
    /// Method hyper-parameters (paper Appendix B.3).
    pub params: MethodParams,
    /// vLLM-style gpu_memory_utilization of each GPU's pool (the
    /// uniform default; per-GPU [`gpu_profiles`](Self::gpu_profiles)
    /// override it).
    pub mem_util: f64,
    /// PagedAttention block size in tokens (uniform default; per-GPU
    /// profiles override it). Also the reference unit for the
    /// admission layer's expected-footprint accounting.
    pub block_size: usize,
    /// Master seed; every stream derives from it.
    pub seed: u64,
    /// Step-score aggregation for pruning/voting (paper: running mean).
    pub score_agg: ScoreAgg,
    /// Optional per-request KV quota as a fraction of each GPU's pool.
    pub quota_frac: Option<f64>,
    /// The arrival regime.
    pub workload: ClusterWorkload,
    /// Placement policy.
    pub router: RouterKind,
    /// Admission-control policy.
    pub admission: AdmissionConfig,
    /// Per-GPU capacity/speed profiles. Empty (default) = a uniform
    /// pool of [`mem_util`](Self::mem_util) /
    /// [`block_size`](Self::block_size) baseline GPUs — bit-identical
    /// to the pre-profile cluster. Fewer entries than GPUs cycle.
    pub gpu_profiles: Vec<GpuProfile>,
    /// Cross-GPU trace-migration policy ([`MigrationPolicy::Never`] by
    /// default — byte-identical to the migration-free cluster).
    pub migration: MigrationPolicy,
    /// GPU-shard size of the two-stage [`RouterKind::KvPressureSharded`]
    /// router (stage one picks a shard by cached aggregate, stage two
    /// scans only that shard). `0` (default) = automatic: ≈√R with a
    /// floor ([`crate::sim::router::auto_shard_size`]). Ignored by the
    /// flat routers.
    pub shard_size: usize,
    /// Worker threads advancing the per-GPU engines *in parallel*
    /// between interaction points (0 = all cores, 1 = serial). The
    /// engines share no state between arrivals and completions are
    /// always harvested in GPU order, so results are bit-identical for
    /// any value — this is intra-simulation parallelism the determinism
    /// contract already permits. Default 1: the harness shards whole
    /// cluster cells across threads, and nesting both oversubscribes.
    pub step_threads: usize,
    /// Deterministic fleet-lifecycle schedule. Empty (default) = the
    /// static fleet, byte-identical to the schedule-free cluster.
    /// Entries are sorted by time before the run; events targeting a
    /// GPU in an incompatible state are no-ops.
    pub fleet_events: Vec<FleetEvent>,
    /// Extra engines in the standby pool behind the active
    /// [`gpus`](Self::gpus) (dense ids `gpus..gpus + standby`). They
    /// hold no work and step no events until a join event or the
    /// scaling controller activates them.
    pub standby: usize,
    /// Queue-depth trigger of the scaling controller: an arrival about
    /// to shed always tries to activate a standby GPU first; with this
    /// set above 0, the admission queue reaching this depth does too.
    /// Standby exhaustion falls back to the usual queue/shed path.
    pub scale_up_queue_depth: usize,
    /// Attach per-lane event recorders (front door + one per engine)
    /// and return the merged stream in [`ClusterResult::events`]:
    /// `Some(cap)` bounds each lane to its last `cap` events (a
    /// flight-recorder ring; `0` = unbounded log). `None` (default) is
    /// the zero-cost disabled path; recorders observe but never
    /// influence scheduling, so every metric byte is identical either
    /// way.
    pub event_log: Option<usize>,
    /// Share each question's full prompt blocks copy-on-write through
    /// every engine's per-GPU prefix registry. `false` (default) is
    /// byte-identical to the registry-free cluster.
    pub prefix_cache: bool,
    /// Affinity credit `w` of the kv-pressure routers: a candidate
    /// GPU's expected-footprint term is discounted by `w ×` its
    /// registry's pinned blocks for the request's question. `0.0`
    /// (default) leaves placement arithmetic untouched; only the
    /// kv-pressure stage-two scan reads it (shard aggregates stay
    /// request-independent).
    pub affinity_weight: f64,
    /// The pruning signal every engine scores step boundaries with
    /// (`--signal`; default `hidden-mlp`, byte-identical to the
    /// pre-trait scorer path).
    pub signal: SignalSpec,
}

impl ClusterConfig {
    /// Paper-default cluster configuration for a (model, bench, method)
    /// under `workload` on `gpus` GPUs with the kv-pressure router.
    pub fn new(
        gpus: usize,
        model: ModelId,
        bench: BenchId,
        method: Method,
        n_traces: usize,
        workload: ClusterWorkload,
    ) -> ClusterConfig {
        ClusterConfig {
            gpus: gpus.max(1),
            model,
            bench,
            method,
            n_traces,
            params: MethodParams::default(),
            mem_util: 0.9,
            block_size: 16,
            seed: 0,
            score_agg: ScoreAgg::Mean,
            quota_frac: None,
            workload,
            router: RouterKind::KvPressure,
            admission: AdmissionConfig::default(),
            gpu_profiles: Vec::new(),
            migration: MigrationPolicy::Never,
            shard_size: 0,
            step_threads: 1,
            fleet_events: Vec::new(),
            standby: 0,
            scale_up_queue_depth: 0,
            event_log: None,
            prefix_cache: false,
            affinity_weight: 0.0,
            signal: SignalSpec::default(),
        }
    }

    /// Builder-style construction: the paper defaults of [`Self::new`]
    /// plus chainable field setters, so adding a config field is not a
    /// breaking change at every call site.
    pub fn builder(
        gpus: usize,
        model: ModelId,
        bench: BenchId,
        method: Method,
        n_traces: usize,
        workload: ClusterWorkload,
    ) -> ClusterConfigBuilder {
        ClusterConfigBuilder {
            cfg: ClusterConfig::new(gpus, model, bench, method, n_traces, workload),
        }
    }

    /// Total engine slots: active fleet plus standby pool. Per-GPU
    /// vectors (views, peaks, results) are dense over this range so
    /// shard arithmetic stays valid as GPUs join and leave.
    pub fn total_gpus(&self) -> usize {
        self.gpus + self.standby
    }

    /// The effective shard size of the two-stage router:
    /// [`shard_size`](Self::shard_size), or the ≈√R automatic choice
    /// (over every slot, standby included) when it is 0.
    pub fn resolved_shard_size(&self) -> usize {
        if self.shard_size > 0 {
            self.shard_size
        } else {
            crate::sim::router::auto_shard_size(self.total_gpus())
        }
    }

    /// The capacity/speed profile of GPU `g`: its
    /// [`gpu_profiles`](Self::gpu_profiles) entry (cycled), or the
    /// uniform baseline built from [`mem_util`](Self::mem_util) /
    /// [`block_size`](Self::block_size) when none are configured.
    pub fn profile_for(&self, g: usize) -> GpuProfile {
        if self.gpu_profiles.is_empty() {
            GpuProfile {
                mem_util: self.mem_util,
                block_size: self.block_size,
                timing_scale: 1.0,
            }
        } else {
            self.gpu_profiles[g % self.gpu_profiles.len()]
        }
    }

    /// The engine configuration of GPU `g`, derived from its profile
    /// (the engine ignores the workload field — the cluster submits
    /// arrivals itself).
    fn engine_config_for(&self, g: usize) -> ServeSimConfig {
        let p = self.profile_for(g);
        let mut c = ServeSimConfig::new(
            self.model,
            self.bench,
            self.method,
            self.n_traces,
            WorkloadSpec::poisson(1.0, 0),
        );
        c.params = self.params.clone();
        c.mem_util = p.mem_util;
        c.block_size = p.block_size;
        c.timing_scale = p.timing_scale;
        c.seed = self.seed;
        c.score_agg = self.score_agg;
        c.quota_frac = self.quota_frac;
        // The router reads every engine's survivor-demand view on each
        // placement: keep it incrementally maintained.
        c.route_views = true;
        // Last-survivor rescue is the on-pressure policy's engine-side
        // half; the other policies leave memory events untouched.
        c.migrate_rescue = matches!(self.migration, MigrationPolicy::OnPressure { .. });
        c.prefix_cache = self.prefix_cache;
        c.signal = self.signal.clone();
        c
    }
}

/// Chainable builder over [`ClusterConfig`] ([`ClusterConfig::builder`]).
#[derive(Debug, Clone)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Set the master seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Set the uniform gpu_memory_utilization.
    pub fn mem_util(mut self, mem_util: f64) -> Self {
        self.cfg.mem_util = mem_util;
        self
    }

    /// Set the per-request KV quota fraction.
    pub fn quota_frac(mut self, quota_frac: Option<f64>) -> Self {
        self.cfg.quota_frac = quota_frac;
        self
    }

    /// Set the placement policy.
    pub fn router(mut self, router: RouterKind) -> Self {
        self.cfg.router = router;
        self
    }

    /// Set the admission-control policy.
    pub fn admission(mut self, admission: AdmissionConfig) -> Self {
        self.cfg.admission = admission;
        self
    }

    /// Set the per-GPU capacity/speed profiles.
    pub fn gpu_profiles(mut self, profiles: Vec<GpuProfile>) -> Self {
        self.cfg.gpu_profiles = profiles;
        self
    }

    /// Set the cross-GPU migration policy.
    pub fn migration(mut self, migration: MigrationPolicy) -> Self {
        self.cfg.migration = migration;
        self
    }

    /// Set the two-stage router's shard size (0 = automatic).
    pub fn shard_size(mut self, shard_size: usize) -> Self {
        self.cfg.shard_size = shard_size;
        self
    }

    /// Set the engine-stepping worker threads.
    pub fn step_threads(mut self, step_threads: usize) -> Self {
        self.cfg.step_threads = step_threads;
        self
    }

    /// Set the deterministic fleet-lifecycle schedule.
    pub fn fleet_events(mut self, events: Vec<FleetEvent>) -> Self {
        self.cfg.fleet_events = events;
        self
    }

    /// Set the standby pool size.
    pub fn standby(mut self, standby: usize) -> Self {
        self.cfg.standby = standby;
        self
    }

    /// Set the scaling controller's queue-depth trigger.
    pub fn scale_up_queue_depth(mut self, depth: usize) -> Self {
        self.cfg.scale_up_queue_depth = depth;
        self
    }

    /// Attach per-lane event recorders (`Some(cap)`; `0` = unbounded).
    pub fn event_log(mut self, cap: Option<usize>) -> Self {
        self.cfg.event_log = cap;
        self
    }

    /// Share prompt-prefix KV copy-on-write on every engine.
    pub fn prefix_cache(mut self, on: bool) -> Self {
        self.cfg.prefix_cache = on;
        self
    }

    /// Set the routers' prefix-affinity credit.
    pub fn affinity_weight(mut self, w: f64) -> Self {
        self.cfg.affinity_weight = w;
        self
    }

    /// Set the pruning signal of every engine.
    pub fn signal(mut self, signal: SignalSpec) -> Self {
        self.cfg.signal = signal;
        self
    }

    /// Finish: the configured [`ClusterConfig`].
    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

/// What admission ultimately did with one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqDisposition {
    /// Arrived or waiting in the cluster admission queue.
    Queued,
    /// Submitted to a GPU engine (running or complete).
    Placed,
    /// Rejected by admission control.
    Shed,
}

/// Cluster-side bookkeeping per issued request.
struct ReqMeta {
    qid: usize,
    t_arrive: f64,
    /// Issuing closed-loop client (`usize::MAX` for open loop).
    client: usize,
    disposition: ReqDisposition,
    /// Expected KV tokens (prompt + N expected-length traces) — what
    /// per-GPU views quantize by their own block size.
    expected_tokens: f64,
    /// The same footprint in the cluster's reference block size (the
    /// admission layer's drain-rate unit).
    expected_blocks: f64,
}

/// A scheduled arrival in the global heap, min-ordered by
/// `(time, issue sequence)`. Times are non-negative finite f64s, so
/// their IEEE-754 bit patterns order identically to the values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Pending {
    t_bits: u64,
    seq: u64,
    rid: usize,
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t_bits, self.seq).cmp(&(other.t_bits, other.seq))
    }
}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Aggregate result of one cluster serving simulation.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    /// One outcome per *completed* request, sorted by cluster-global
    /// request id (shed requests have no outcome).
    pub outcomes: Vec<RequestOutcome>,
    /// Request ids dropped — shed by admission, or abandoned by a
    /// revocation force-clear — in drop order.
    pub shed_rids: Vec<usize>,
    /// Wall-clock from the first arrival to the last completion.
    pub makespan_s: f64,
    /// Cluster-wide end-to-end latency sketch (the per-GPU sketches
    /// merged bucket-wise).
    pub latency: LatencySketch,
    /// Cluster-wide time-to-first-vote sketch.
    pub ttfv: LatencySketch,
    /// Admission/goodput accounting.
    pub counters: ClusterCounters,
    /// Per-GPU engine counters summed across the cluster.
    pub engine_counters: EngineCounters,
    /// Requests served per GPU.
    pub per_gpu_requests: Vec<usize>,
    /// Peak outstanding requests observed per GPU.
    pub per_gpu_peak_outstanding: Vec<usize>,
    /// Peak KV-block usage fraction per GPU.
    pub per_gpu_peak_block_frac: Vec<f64>,
    /// Fleet-lifecycle audit log, in transition order (empty for a
    /// static fleet).
    pub fleet_log: Vec<FleetLogEntry>,
    /// The merged observability event stream, in canonical
    /// `(time, lane, emission)` order — empty unless
    /// [`ClusterConfig::event_log`] was set. Never serialized into
    /// metric blocks, so traced and untraced metric bytes stay
    /// identical.
    pub events: Vec<SimEvent>,
    /// Events discarded by bounded flight-recorder rings (0 for
    /// unbounded logs and the disabled path).
    pub events_dropped: u64,
}

impl ClusterResult {
    /// Completed requests per second of cluster makespan.
    pub fn goodput_rps(&self) -> f64 {
        self.counters.goodput_rps(self.makespan_s)
    }
}

/// The cluster simulation: a configuration bound to a trace generator
/// and step scorer. [`run`](ClusterSim::run) owns the global event
/// loop.
pub struct ClusterSim<'a> {
    cfg: &'a ClusterConfig,
    gen: &'a TraceGen,
    scorer: &'a StepScorer,
}

/// Everything the event loop mutates, bundled so helper methods can
/// borrow it disjointly from the engines.
struct FrontDoor {
    meta: Vec<ReqMeta>,
    pending: BinaryHeap<Reverse<Pending>>,
    seq: u64,
    queue: VecDeque<usize>,
    clients: Option<ClosedLoopClients>,
    router: Box<dyn RouterPolicy>,
    counters: ClusterCounters,
    shed_rids: Vec<usize>,
    per_gpu_peak_outstanding: Vec<usize>,
    /// Expected-footprint drain statistics for the SLO early reject.
    completed_blocks: f64,
    epoch: Option<f64>,
    t_last_done: f64,
    /// Scratch for harvested completions.
    done_buf: Vec<(usize, f64)>,
    /// Scratch for harvested last-survivor rescues awaiting relocation.
    migrations_buf: Vec<MigratedRequest>,
    /// Scratch for router views (reused across placements).
    views_buf: Vec<GpuView>,
    /// Cached per-GPU router views, dense by GPU id. An entry is
    /// rebuilt only when its engine's state-change
    /// [`version`](ServeEngine::version) moved since the last
    /// placement, so idle engines cost one u64 compare instead of a
    /// survivor-demand fold per placement.
    view_cache: Vec<GpuView>,
    /// Engine version each cached view reflects (`u64::MAX` = never
    /// built, forcing the first refresh).
    view_version: Vec<u64>,
    /// Staleness flags for `shard_agg`, set whenever a member view is
    /// rebuilt (two-stage router only).
    shard_dirty: Vec<bool>,
    /// Cached stage-one aggregate per shard: the minimal
    /// request-independent base key over the shard's eligible
    /// (below-quota) members; `None` = no eligible member.
    shard_agg: Vec<Option<(bool, f64)>>,
    /// Lazy min-heap over busy engines' `(clock bits, gpu)` for the
    /// drain phase's laggard pick — O(log R) per event instead of the
    /// O(R) argmin fold. Entries go stale as clocks move; pops validate
    /// against the engines' current clocks.
    lag_heap: BinaryHeap<Reverse<(u64, usize)>>,
    /// Whether `lag_heap` currently covers every busy engine (it is
    /// rebuilt on entering the drain phase and invalidated whenever the
    /// arrival phase advances engines wholesale).
    lag_live: bool,
    /// Lifecycle state per GPU slot (dense over active + standby).
    state: Vec<GpuState>,
    /// The time-sorted fleet-event schedule; `fleet_next` indexes the
    /// next unapplied entry.
    fleet_events: Vec<FleetEvent>,
    fleet_next: usize,
    /// Min-heap of pending force-clear deadlines `(deadline bits, gpu)`.
    /// Entries go stale when a draining GPU empties early (or the slot
    /// later rejoins and is revoked again); pops validate against the
    /// GPU's current `Draining` deadline.
    deadline_heap: BinaryHeap<Reverse<(u64, usize)>>,
    /// Number of GPUs currently in a `Draining` state.
    draining: usize,
    /// Fleet-lifecycle audit log.
    fleet_log: Vec<FleetLogEntry>,
    /// Front-door event recorder (lane 0 of the merged stream); `None`
    /// is the zero-cost disabled path.
    rec: Option<EventBuf>,
}

impl FrontDoor {
    /// Register a newly issued request and schedule its arrival.
    fn schedule(&mut self, arr: &Arrival, client: usize, expected: (f64, f64)) {
        let (expected_tokens, expected_blocks) = expected;
        debug_assert_eq!(arr.rid, self.meta.len(), "request ids are dense in issue order");
        self.meta.push(ReqMeta {
            qid: arr.qid,
            t_arrive: arr.t_arrive,
            client,
            disposition: ReqDisposition::Queued,
            expected_tokens,
            expected_blocks,
        });
        self.pending.push(Reverse(Pending {
            t_bits: arr.t_arrive.to_bits(),
            seq: self.seq,
            rid: arr.rid,
        }));
        self.seq += 1;
        self.epoch = Some(self.epoch.map_or(arr.t_arrive, |e| e.min(arr.t_arrive)));
    }

    /// Sum of expected footprints currently waiting in the queue.
    fn queued_blocks(&self) -> f64 {
        self.queue.iter().map(|&rid| self.meta[rid].expected_blocks).sum()
    }

    /// Emit one event if a recorder is attached. The builder runs only
    /// on the enabled path; recorders observe admission decisions, they
    /// never influence them.
    #[inline]
    fn emit(&mut self, build: impl FnOnce() -> SimEvent) {
        if let Some(rec) = self.rec.as_mut() {
            rec.record(build());
        }
    }
}

impl<'a> ClusterSim<'a> {
    /// Bind a configuration to a trace generator and step scorer.
    ///
    /// Panics if `cfg.method` is [`Method::DeepConf`] (unsupported by
    /// the serving engines; see [`crate::sim::serve::ServeSim::new`]).
    pub fn new(cfg: &'a ClusterConfig, gen: &'a TraceGen, scorer: &'a StepScorer) -> Self {
        assert!(
            cfg.admission.max_outstanding_per_gpu >= 1,
            "max_outstanding_per_gpu must be >= 1 (a zero quota can never place)"
        );
        ClusterSim { cfg, gen, scorer }
    }

    /// Expected KV footprint of a request asking question `qid` as
    /// `(tokens, reference blocks)`: N traces, each a prompt copy plus
    /// the question's expected trace length
    /// ([`TraceGen::expected_trace_tokens`]). This is the
    /// scheduler-visible estimate (sampled lengths stay hidden); the
    /// SLO early reject consumes the reference-block form, while the
    /// kv-pressure router quantizes the token form by each GPU's own
    /// block size.
    fn expected_footprint(&self, qid: usize) -> (f64, f64) {
        let q = self.gen.question(qid);
        let n = if self.cfg.method == Method::Cot { 1 } else { self.cfg.n_traces };
        let tokens =
            n as f64 * (self.gen.expected_trace_tokens(&q) + q.prompt_tokens as f64);
        (tokens, tokens / self.cfg.block_size as f64)
    }

    /// Run the whole workload to completion.
    pub fn run(&self) -> ClusterResult {
        let cfg = self.cfg;
        let total = cfg.total_gpus();
        let ecfgs: Vec<ServeSimConfig> =
            (0..total).map(|g| cfg.engine_config_for(g)).collect();
        let mut engines: Vec<ServeEngine<'_>> = ecfgs
            .iter()
            .map(|ecfg| ServeEngine::new(ecfg, self.gen, self.scorer))
            .collect();
        if let Some(cap) = cfg.event_log {
            for eng in engines.iter_mut() {
                eng.set_recorder(Box::new(EventBuf::new(cap)));
            }
        }
        let nq = self.gen.bench.n_questions;
        let n_shards = total.div_ceil(cfg.resolved_shard_size());

        // The schedule runs in time order whatever order it was given
        // in; entries aimed past the slot range are dropped up front.
        // The stable sort keeps same-instant events in authored order.
        let mut schedule = cfg.fleet_events.clone();
        schedule.retain(|e| e.gpu < total && e.t_s.is_finite() && e.t_s >= 0.0);
        schedule.sort_by_key(|e| e.t_s.to_bits());

        let mut fd = FrontDoor {
            meta: Vec::new(),
            pending: BinaryHeap::new(),
            seq: 0,
            queue: VecDeque::new(),
            clients: None,
            router: cfg.router.build_with(cfg.resolved_shard_size()),
            counters: ClusterCounters::default(),
            shed_rids: Vec::new(),
            per_gpu_peak_outstanding: vec![0; total],
            completed_blocks: 0.0,
            epoch: None,
            t_last_done: 0.0,
            done_buf: Vec::new(),
            migrations_buf: Vec::new(),
            views_buf: Vec::new(),
            // Placeholder views: `view_version` starts at u64::MAX while
            // engine versions start at 0, so every entry is rebuilt
            // before its first read.
            view_cache: (0..total)
                .map(|g| GpuView {
                    gpu: g,
                    outstanding: 0,
                    live_traces: 0,
                    free_blocks: 0,
                    pool_blocks: 0,
                    block_size: 1,
                    timing_scale: 1.0,
                    survivor_demand_blocks: 0.0,
                    prefix_hit_blocks: 0.0,
                    affinity_weight: 0.0,
                })
                .collect(),
            view_version: vec![u64::MAX; total],
            shard_dirty: vec![true; n_shards],
            shard_agg: vec![None; n_shards],
            lag_heap: BinaryHeap::new(),
            lag_live: false,
            state: (0..total)
                .map(|g| if g < cfg.gpus { GpuState::Active } else { GpuState::Standby })
                .collect(),
            fleet_events: schedule,
            fleet_next: 0,
            deadline_heap: BinaryHeap::new(),
            draining: 0,
            fleet_log: Vec::new(),
            rec: cfg.event_log.map(EventBuf::new),
        };

        // ---- seed the arrival stream.
        match &cfg.workload {
            ClusterWorkload::Open(spec) => {
                let arrivals = spec.generate(nq, cfg.seed ^ 0xA331_4A11_D00D_FEED);
                for a in &arrivals {
                    let eb = self.expected_footprint(a.qid);
                    fd.schedule(a, usize::MAX, eb);
                }
            }
            ClusterWorkload::Closed(spec) => {
                let heavy = self.heavy_qids(nq);
                let mut clients = spec.clients(nq, heavy, cfg.seed ^ 0xC105_ED00);
                for a in clients.initial_arrivals() {
                    let eb = self.expected_footprint(a.qid);
                    fd.schedule(&a, clients.client_of(a.rid), eb);
                }
                fd.clients = Some(clients);
            }
        }

        // Between interaction points the R engines share no state, so
        // they may advance concurrently; completions are still
        // harvested in GPU order, so the result is bit-identical to the
        // serial loop for any thread count.
        let step_threads = pool::resolve_threads(cfg.step_threads).min(engines.len());

        // ---- the global event loop.
        loop {
            // Control events (fleet joins/leaves/revocations and
            // force-clear deadlines) interleave with arrivals on the
            // same clock; ties go to the control event, so a revocation
            // firing exactly at an arrival instant stops admission
            // before the arrival is offered. All control handling runs
            // serially after the wholesale advancement, so the sequence
            // is identical for every `step_threads` value.
            let t_ctl = Self::next_control_time(&fd);
            let t_arr = fd.pending.peek().map(|&Reverse(h)| f64::from_bits(h.t_bits));
            let ctl_first = match (t_ctl, t_arr) {
                (Some(tc), Some(ta)) => tc <= ta,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if ctl_first {
                let tc = t_ctl.expect("checked Some above");
                self.advance_engines(&mut engines, &fd, step_threads, tc);
                fd.lag_live = false;
                self.harvest(&mut engines, &mut fd);
                self.apply_control(&mut engines, &mut fd, tc);
                self.drain_queue(&mut engines, &mut fd);
                continue;
            }
            if let Some(ta) = t_arr {
                // Advance every engine to the arrival instant; harvest
                // completions (which may spawn earlier closed-loop
                // arrivals — the heap reorders) and drain the queue.
                self.advance_engines(&mut engines, &fd, step_threads, ta);
                // Every clock moved: the laggard heap is stale wholesale.
                fd.lag_live = false;
                self.harvest(&mut engines, &mut fd);
                self.drain_queue(&mut engines, &mut fd);
                let Reverse(p) = fd.pending.pop().expect("peeked non-empty");
                self.offer(&mut engines, &mut fd, p.rid);
            } else {
                if !fd.lag_live {
                    fd.lag_heap.clear();
                    for (g, e) in engines.iter().enumerate() {
                        if fd.state[g].steppable() && !e.is_idle() {
                            fd.lag_heap.push(Reverse((e.clock().to_bits(), g)));
                        }
                    }
                    fd.lag_live = true;
                }
                // Laggard pick: pop until a live entry surfaces. Clock
                // bits order like the non-negative finite clocks, and
                // the `(bits, gpu)` key reproduces the serial fold's
                // lowest-GPU tie-break. Keys of engines that left the
                // fleet are stale by definition — skipped here, never
                // advanced.
                let next = loop {
                    match fd.lag_heap.peek() {
                        None => break None,
                        Some(&Reverse((bits, g)))
                            if fd.state[g].steppable()
                                && !engines[g].is_idle()
                                && engines[g].clock().to_bits() == bits =>
                        {
                            break Some(g)
                        }
                        _ => {
                            fd.lag_heap.pop();
                        }
                    }
                };
                match next {
                    Some(g) => {
                        // Tail phase: step the laggard one event so
                        // completion-driven interactions stay in near-
                        // global order.
                        engines[g].run_one_event();
                        if !engines[g].is_idle() {
                            fd.lag_heap.push(Reverse((engines[g].clock().to_bits(), g)));
                        }
                        self.harvest(&mut engines, &mut fd);
                        self.drain_queue(&mut engines, &mut fd);
                    }
                    None if !fd.queue.is_empty() => {
                        if self.any_eligible(&engines, &fd) {
                            // Engines idle with requests still queued:
                            // quota is free again, so placements resume
                            // (possibly only partially — the next loop
                            // pass advances the now-busy engines).
                            self.drain_queue(&mut engines, &mut fd);
                        } else {
                            // No active GPU, nothing in flight, and no
                            // control event left to change either: the
                            // queue can never drain. Shed it so the run
                            // terminates (closed-loop clients re-issue
                            // until their budget is fully offered).
                            while let Some(rid) = fd.queue.pop_front() {
                                self.shed(&mut fd, rid, "stuck-queue");
                            }
                        }
                    }
                    None => break,
                }
            }
        }

        // ---- recorders: drain the per-lane streams (front door =
        // lane 0, GPU g = lane g + 1; the gpu stamp is applied here —
        // engines do not know their cluster slot) into the canonical
        // merged order.
        let mut events: Vec<SimEvent> = Vec::new();
        let mut events_dropped = 0u64;
        if cfg.event_log.is_some() {
            let mut streams = Vec::with_capacity(engines.len() + 1);
            if let Some(rec) = fd.rec.as_mut() {
                events_dropped += rec.dropped();
                streams.push((0usize, rec.drain()));
            }
            for (g, eng) in engines.iter_mut().enumerate() {
                if let Some(mut rec) = eng.take_recorder() {
                    events_dropped += rec.dropped();
                    let evs: Vec<SimEvent> =
                        rec.drain().into_iter().map(|e| e.gpu(g)).collect();
                    streams.push((g + 1, evs));
                }
            }
            events = merge_streams(streams);
            // Flight recorder: a broken conservation law dumps the tail
            // of the stream before the assertions below fire.
            let conserved = fd.counters.offered == fd.counters.placed + fd.counters.shed
                && fd.counters.completed + fd.counters.shed_on_revoke
                    == fd.counters.placed;
            if !conserved {
                eprintln!("{}", dump_tail("cluster invariant violation", &events, 64));
            }
        }

        debug_assert_eq!(
            fd.counters.offered,
            fd.counters.placed + fd.counters.shed,
            "placement conservation"
        );
        debug_assert_eq!(
            fd.counters.completed + fd.counters.shed_on_revoke,
            fd.counters.placed,
            "every placed request completes or is abandoned by a force-clear"
        );
        debug_assert_eq!(
            fd.fleet_next,
            fd.fleet_events.len(),
            "the fleet schedule is fully consumed"
        );
        debug_assert!(fd.deadline_heap.is_empty(), "no force-clear left pending");

        // ---- aggregate: per-GPU results merge into cluster metrics.
        let mut outcomes: Vec<RequestOutcome> = Vec::new();
        let mut latency = LatencySketch::new();
        let mut ttfv = LatencySketch::new();
        let mut engine_counters = EngineCounters::default();
        let mut per_gpu_requests = Vec::with_capacity(engines.len());
        let mut per_gpu_peak_block_frac = Vec::with_capacity(engines.len());
        for eng in engines {
            let r = eng.finish();
            let mut lat_g = LatencySketch::new();
            let mut ttfv_g = LatencySketch::new();
            for o in &r.outcomes {
                lat_g.record(o.latency_s);
                ttfv_g.record(o.ttfv_s);
            }
            // Exact bucket-wise merge: the cluster percentiles equal a
            // single sketch over every request.
            latency.merge(&lat_g);
            ttfv.merge(&ttfv_g);
            engine_counters.add(&r.counters);
            per_gpu_requests.push(r.outcomes.len());
            per_gpu_peak_block_frac
                .push(r.peak_used_blocks as f64 / r.pool_blocks.max(1) as f64);
            outcomes.extend(r.outcomes);
        }
        outcomes.sort_by_key(|o| o.rid);

        let epoch = fd.epoch.unwrap_or(0.0);
        ClusterResult {
            outcomes,
            shed_rids: fd.shed_rids,
            makespan_s: (fd.t_last_done - epoch).max(0.0),
            latency,
            ttfv,
            counters: fd.counters,
            engine_counters,
            per_gpu_requests,
            per_gpu_peak_outstanding: fd.per_gpu_peak_outstanding,
            per_gpu_peak_block_frac,
            fleet_log: fd.fleet_log,
            events,
            events_dropped,
        }
    }

    /// Advance every steppable engine to `t` — the wholesale catch-up
    /// before an arrival or control instant, fanned out across
    /// `step_threads` when two or more engines actually lag. Standby
    /// and departed engines hold no work and are skipped entirely.
    fn advance_engines(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &FrontDoor,
        step_threads: usize,
        t: f64,
    ) {
        if step_threads > 1 {
            let mut lagging: Vec<&mut ServeEngine<'_>> = engines
                .iter_mut()
                .enumerate()
                .filter(|(g, e)| fd.state[*g].steppable() && !e.is_idle() && e.clock() < t)
                .map(|(_, e)| e)
                .collect();
            if lagging.len() > 1 {
                pool::parallel_for_each_mut(step_threads, &mut lagging, |_, e| {
                    e.run_until(t)
                });
            } else if let Some(e) = lagging.first_mut() {
                e.run_until(t);
            }
        } else {
            for (g, e) in engines.iter_mut().enumerate() {
                if fd.state[g].steppable() {
                    e.run_until(t);
                }
            }
        }
    }

    /// The next control instant: the earlier of the next unapplied
    /// schedule entry and the earliest pending force-clear deadline.
    /// A stale deadline entry (its GPU emptied early and departed) may
    /// surface here; it costs one harmless extra control step and is
    /// discarded by [`apply_control`](Self::apply_control) —
    /// deterministically, so every thread count sees the same sequence.
    fn next_control_time(fd: &FrontDoor) -> Option<f64> {
        let sched = fd.fleet_events.get(fd.fleet_next).map(|e| e.t_s);
        let dl = fd.deadline_heap.peek().map(|&Reverse((bits, _))| f64::from_bits(bits));
        match (sched, dl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        }
    }

    /// Apply every control event due at `t`: schedule entries first (in
    /// schedule order), then force-clear deadlines (in deadline order).
    /// The engines have already been advanced and harvested to `t`.
    fn apply_control(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor, t: f64) {
        while let Some(&ev) = fd.fleet_events.get(fd.fleet_next) {
            if ev.t_s > t {
                break;
            }
            fd.fleet_next += 1;
            match ev.action {
                FleetAction::Join => self.fleet_join(&*engines, fd, ev.gpu, t),
                FleetAction::Leave => {
                    if self.fleet_drain(engines, fd, ev.gpu, f64::INFINITY, t) {
                        let g = ev.gpu;
                        fd.emit(|| SimEvent::new(t, EventKind::FleetLeave).gpu(g));
                    }
                }
                FleetAction::Revoke { deadline_s } => {
                    if self.fleet_drain(engines, fd, ev.gpu, t + deadline_s, t) {
                        fd.counters.revocations += 1;
                        let g = ev.gpu;
                        fd.emit(|| {
                            SimEvent::new(t, EventKind::Revoke { deadline_s }).gpu(g)
                        });
                        fd.deadline_heap
                            .push(Reverse(((t + deadline_s).to_bits(), ev.gpu)));
                    }
                }
            }
        }
        while let Some(&Reverse((bits, g))) = fd.deadline_heap.peek() {
            if f64::from_bits(bits) > t {
                break;
            }
            fd.deadline_heap.pop();
            // An entry is live only while its GPU still drains toward
            // exactly this deadline — it may have emptied and departed,
            // or rejoined (and even been revoked again), since the push.
            let live = matches!(fd.state[g], GpuState::Draining { deadline_s }
                if deadline_s.to_bits() == bits);
            if live {
                self.fleet_force_clear(engines, fd, g, f64::from_bits(bits));
            }
        }
    }

    /// Activate GPU `g` (standby activation or rejoin after a
    /// departure). Joining a GPU that is active or still draining is a
    /// no-op, so arbitrary schedules stay safe.
    fn fleet_join(&self, engines: &[ServeEngine<'_>], fd: &mut FrontDoor, g: usize, t: f64) {
        if !matches!(fd.state[g], GpuState::Standby | GpuState::Revoked) {
            return;
        }
        debug_assert_eq!(engines[g].outstanding(), 0, "a joining GPU is empty");
        fd.state[g] = GpuState::Active;
        // Force a view rebuild: the at-quota sentinel must clear.
        fd.view_version[g] = u64::MAX;
        fd.fleet_log.push(FleetLogEntry {
            t_s: t,
            gpu: g,
            kind: FleetLogKind::Joined,
            residents_after: engines[g].outstanding(),
        });
        fd.emit(|| SimEvent::new(t, EventKind::FleetJoin).gpu(g));
        // A joining engine is empty and idle; the laggard heap tracks
        // busy engines only, so no entry is needed until work lands.
    }

    /// Stop admission to GPU `g` and start draining it toward the
    /// absolute `deadline_s` (`f64::INFINITY` = graceful leave).
    /// Returns whether the drain actually started (the GPU was
    /// active). An already-empty GPU departs on the spot.
    fn fleet_drain(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &mut FrontDoor,
        g: usize,
        deadline_s: f64,
        t: f64,
    ) -> bool {
        if !matches!(fd.state[g], GpuState::Active) {
            return false;
        }
        fd.state[g] = GpuState::Draining { deadline_s };
        fd.draining += 1;
        fd.view_version[g] = u64::MAX;
        let residents = engines[g].outstanding();
        fd.fleet_log.push(FleetLogEntry {
            t_s: t,
            gpu: g,
            kind: FleetLogKind::DrainStarted,
            residents_after: residents,
        });
        let cause = if deadline_s.is_infinite() { "leave" } else { "revoke" };
        fd.emit(|| {
            SimEvent::new(t, EventKind::Drain { residents }).gpu(g).cause(cause)
        });
        // First relocation pass right away; an emptied victim departs
        // immediately.
        self.drain_step_gpu(engines, fd, g);
        if engines[g].outstanding() == 0 {
            self.depart(&*engines, fd, g, t);
        }
        true
    }

    /// One relocation pass of the drain controller over draining GPU
    /// `g`: while the migration policy permits and some *active*
    /// below-quota GPU has room, extract residents and move them out
    /// (rescue migrations). Quota-respecting — the drain must not
    /// overload survivors, which is what makes the deadline
    /// meaningful. With [`MigrationPolicy::Never`] this is a no-op:
    /// the shed-everything baseline, where residents either finish
    /// before the deadline or are abandoned by the force-clear.
    fn drain_step_gpu(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor, g: usize) {
        if !self.cfg.migration.on_shed() {
            return;
        }
        let quota = self.cfg.admission.max_outstanding_per_gpu;
        loop {
            if engines[g].outstanding() == 0 {
                return;
            }
            // Target: lowest-pressure active GPU with quota headroom
            // (first minimum in GPU order).
            let mut tgt: Option<(f64, usize)> = None;
            for o in 0..engines.len() {
                if o == g || !fd.state[o].placeable() || engines[o].outstanding() >= quota
                {
                    continue;
                }
                let p = self.pressure(engines, o);
                let better = match tgt {
                    None => true,
                    Some((bp, _)) => p < bp,
                };
                if better {
                    tgt = Some((p, o));
                }
            }
            let Some((_, tgt_g)) = tgt else { return };
            let Some(victim) = engines[g].migration_victim() else { return };
            let m = engines[g]
                .extract_request(victim)
                .expect("the victim is outstanding on its source");
            fd.counters.rescue_migrated += 1;
            self.relocate(engines, fd, m, tgt_g, "drain");
        }
    }

    /// The revocation deadline fired with residents still on GPU `g`:
    /// one last relocation pass, then abandon whatever is left —
    /// placed work that never completes, counted as
    /// [`ClusterCounters::shed_on_revoke`].
    fn fleet_force_clear(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &mut FrontDoor,
        g: usize,
        t: f64,
    ) {
        self.drain_step_gpu(engines, fd, g);
        while let Some(victim) = engines[g].migration_victim() {
            let m = engines[g]
                .extract_request(victim)
                .expect("the victim is outstanding on its source");
            self.abandon(fd, m.rid, t);
        }
        debug_assert_eq!(
            engines[g].outstanding(),
            0,
            "every resident relocated, completed, or was abandoned"
        );
        self.depart(&*engines, fd, g, t);
    }

    /// Count an abandoned (force-cleared) request: placed work that
    /// never completes. Its closed-loop client re-enters the think
    /// state, so the configured budget is still fully offered.
    fn abandon(&self, fd: &mut FrontDoor, rid: usize, t: f64) {
        fd.counters.shed_on_revoke += 1;
        fd.shed_rids.push(rid);
        fd.emit(|| SimEvent::new(t, EventKind::Abandon).rid(rid).cause("deadline"));
        let client = fd.meta[rid].client;
        if client != usize::MAX {
            let next = fd
                .clients
                .as_mut()
                .expect("closed loop has clients")
                .next_arrival(client, t);
            if let Some(a) = next {
                let eb = self.expected_footprint(a.qid);
                fd.schedule(&a, client, eb);
            }
        }
    }

    /// Remove an emptied draining GPU from the fleet: it stops
    /// stepping, leaves the router's eligible set, and exits the
    /// laggard heap lazily (its stale keys are skipped on pop).
    fn depart(&self, engines: &[ServeEngine<'_>], fd: &mut FrontDoor, g: usize, t: f64) {
        debug_assert_eq!(engines[g].outstanding(), 0, "departure requires an empty GPU");
        debug_assert!(matches!(fd.state[g], GpuState::Draining { .. }));
        fd.state[g] = GpuState::Revoked;
        fd.draining -= 1;
        fd.view_version[g] = u64::MAX;
        fd.fleet_log.push(FleetLogEntry {
            t_s: t,
            gpu: g,
            kind: FleetLogKind::Departed,
            residents_after: 0,
        });
        fd.emit(|| SimEvent::new(t, EventKind::Depart).gpu(g));
    }

    /// The scaling controller's one move: activate the lowest-indexed
    /// standby GPU, if any. Departed (revoked) slots do not come back
    /// this way — the spot market reclaimed them; only an explicit
    /// join event revives those. Returns whether the fleet grew.
    fn scale_up(&self, engines: &[ServeEngine<'_>], fd: &mut FrontDoor, t: f64) -> bool {
        let Some(g) =
            (0..engines.len()).find(|&g| matches!(fd.state[g], GpuState::Standby))
        else {
            return false;
        };
        fd.emit(|| SimEvent::new(t, EventKind::ScaleUp).gpu(g));
        self.fleet_join(engines, fd, g, t);
        true
    }

    /// Is any active GPU below its admission quota?
    fn any_eligible(&self, engines: &[ServeEngine<'_>], fd: &FrontDoor) -> bool {
        let quota = self.cfg.admission.max_outstanding_per_gpu;
        engines
            .iter()
            .enumerate()
            .any(|(g, e)| fd.state[g].placeable() && e.outstanding() < quota)
    }

    /// The benchmark's top trace-length quartile — the question subset
    /// skewed closed-loop clients hammer.
    fn heavy_qids(&self, n_questions: usize) -> Vec<usize> {
        let mut by_len: Vec<(usize, f64)> = (0..n_questions)
            .map(|qid| (qid, self.gen.question(qid).len_mult))
            .collect();
        by_len.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        by_len.truncate((n_questions / 4).max(1));
        by_len.into_iter().map(|(qid, _)| qid).collect()
    }

    /// Drain every engine's completions: record drain statistics, spawn
    /// the closed-loop clients' next arrivals, and track the last
    /// completion time. Engines are visited in GPU order. Last-survivor
    /// rescues (requests the engines evicted instead of pruning, under
    /// [`MigrationPolicy::OnPressure`]) are harvested the same way and
    /// relocated to the least-pressured GPU.
    fn harvest(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor) {
        for g in 0..engines.len() {
            let mut done = std::mem::take(&mut fd.done_buf);
            done.clear();
            engines[g].drain_completions_into(&mut done);
            for &(rid, t_done) in &done {
                fd.counters.completed += 1;
                let drained_now = matches!(fd.state[g], GpuState::Draining { .. });
                if drained_now {
                    // A natural completion on a draining GPU beat the
                    // deadline.
                    fd.counters.drained += 1;
                }
                fd.emit(|| {
                    let ev = SimEvent::new(t_done, EventKind::Complete).rid(rid).gpu(g);
                    if drained_now {
                        ev.cause("drain")
                    } else {
                        ev
                    }
                });
                fd.completed_blocks += fd.meta[rid].expected_blocks;
                fd.t_last_done = fd.t_last_done.max(t_done);
                let client = fd.meta[rid].client;
                if client != usize::MAX {
                    let next = fd
                        .clients
                        .as_mut()
                        .expect("closed loop has clients")
                        .next_arrival(client, t_done);
                    if let Some(a) = next {
                        let eb = self.expected_footprint(a.qid);
                        fd.schedule(&a, client, eb);
                    }
                }
            }
            fd.done_buf = done;
        }
        // Rescued requests re-place on whichever GPU projects the least
        // pressure right now — the source included, whose pool the
        // eviction just relieved. Quota does not apply: the request was
        // already admitted once.
        let mut migs = std::mem::take(&mut fd.migrations_buf);
        migs.clear();
        for e in engines.iter_mut() {
            e.drain_migrations_into(&mut migs);
        }
        for m in migs.drain(..) {
            // Prefer an active target; with none left (every survivor
            // draining), fall back to any still-stepping GPU so the
            // rescue lands somewhere rather than vanishing — the
            // rescuing engine itself is steppable, so one always
            // exists.
            let mut target: Option<(f64, usize)> = None;
            for pass in 0..2 {
                for g in 0..engines.len() {
                    let ok = if pass == 0 {
                        fd.state[g].placeable()
                    } else {
                        fd.state[g].steppable()
                    };
                    if !ok {
                        continue;
                    }
                    let p = self.pressure(engines, g);
                    let better = match target {
                        None => true,
                        Some((bp, _)) => p < bp,
                    };
                    if better {
                        target = Some((p, g));
                    }
                }
                if target.is_some() {
                    break;
                }
            }
            let (_, target) = target.expect("a rescuing engine is itself steppable");
            fd.counters.migration_saved += 1;
            self.relocate(engines, fd, m, target, "rescue");
        }
        fd.migrations_buf = migs;
        // Drain controller: while any GPU is draining, every harvest
        // retries relocation (capacity elsewhere may just have freed
        // up), and a GPU that emptied departs at its own clock.
        if fd.draining > 0 {
            for g in 0..engines.len() {
                if !matches!(fd.state[g], GpuState::Draining { .. }) {
                    continue;
                }
                self.drain_step_gpu(engines, fd, g);
                if engines[g].outstanding() == 0 {
                    let t = engines[g].clock();
                    self.depart(&*engines, fd, g, t);
                }
            }
        }
    }

    /// Projected drain pressure of GPU `g`: its surviving traces' KV
    /// demand relative to its free pool, weighted by its relative
    /// slowness — the same signal the kv-pressure router scores, minus
    /// the candidate request's own footprint.
    fn pressure(&self, engines: &[ServeEngine<'_>], g: usize) -> f64 {
        let p = self.cfg.profile_for(g);
        p.timing_scale * engines[g].survivor_demand_blocks()
            / engines[g].available_blocks().max(1) as f64
    }

    /// Hand a migrated request to `target`: charge the recompute bill,
    /// count the hop, and re-admit through the target's wait queue.
    fn relocate(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &mut FrontDoor,
        m: MigratedRequest,
        target: usize,
        cause: &'static str,
    ) {
        fd.counters.migrated += 1;
        let recompute_tokens = m.recompute_tokens();
        fd.counters.migration_recompute_tokens += recompute_tokens;
        let rid = m.rid;
        let t_evict = m.t_evict;
        fd.emit(|| {
            SimEvent::new(t_evict, EventKind::Migrate { dst: target, recompute_tokens })
                .rid(rid)
                .gpu(target)
                .cause(cause)
        });
        engines[target].submit_migrated(m);
        // Keep the drain-phase laggard heap covering the target (an
        // idle engine may just have become busy).
        if fd.lag_live {
            fd.lag_heap.push(Reverse((engines[target].clock().to_bits(), target)));
        }
        let out = engines[target].outstanding();
        fd.per_gpu_peak_outstanding[target] = fd.per_gpu_peak_outstanding[target].max(out);
    }

    /// Move one request between GPUs instead of losing work. Two modes:
    ///
    /// * **Shed rescue** (`min_ratio == None`): admission is about to
    ///   shed. The source must sit *exactly at* its admission quota —
    ///   extracting a request then opens the slot that absorbs the
    ///   queue head or the arrival itself, which is the whole point —
    ///   and the target (lowest pressure among the other GPUs) may go
    ///   over quota: the moved request was already admitted once, and
    ///   parking it beats rejecting fresh work outright.
    /// * **Proactive rebalance** (`min_ratio == Some(r)`): move from
    ///   the highest-pressure GPU holding migratable work to the
    ///   lowest-pressure *below-quota* GPU, only while the pressure gap
    ///   clears the hysteresis (`src > r × tgt`), so near-balanced
    ///   pools stay still.
    ///
    /// Returns whether a migration happened.
    fn try_migrate(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &mut FrontDoor,
        min_ratio: Option<f64>,
    ) -> bool {
        if engines.len() < 2 {
            return false;
        }
        let quota = self.cfg.admission.max_outstanding_per_gpu;
        let rescuing = min_ratio.is_none();
        if let Some(r) = min_ratio {
            // Cheap O(R) early-out for the common balanced case: if even
            // the *global* max-to-min pressure gap is inside the
            // hysteresis band, no eligible (source, target) pair can
            // clear it — skip the per-GPU victim scans entirely.
            let mut max_p = f64::NEG_INFINITY;
            let mut min_p = f64::INFINITY;
            for g in 0..engines.len() {
                if !fd.state[g].placeable() {
                    continue;
                }
                let p = self.pressure(engines, g);
                max_p = max_p.max(p);
                min_p = min_p.min(p);
            }
            if max_p <= r * min_p {
                return false;
            }
        }
        // Source: highest pressure among eligible *active* GPUs with
        // something to move (first maximum in GPU order). Draining GPUs
        // are the drain controller's business, not the rebalancer's.
        let mut src: Option<(f64, usize, usize)> = None;
        for g in 0..engines.len() {
            if !fd.state[g].placeable() || (rescuing && engines[g].outstanding() != quota)
            {
                continue;
            }
            let Some(victim) = engines[g].migration_victim() else { continue };
            let p = self.pressure(engines, g);
            let better = match src {
                None => true,
                Some((bp, _, _)) => p > bp,
            };
            if better {
                src = Some((p, g, victim));
            }
        }
        let Some((src_p, src_g, victim)) = src else { return false };
        // Target: lowest pressure among the *other* active GPUs (first
        // minimum in GPU order), quota-respecting unless rescuing.
        let mut tgt: Option<(f64, usize)> = None;
        for g in 0..engines.len() {
            if g == src_g
                || !fd.state[g].placeable()
                || (!rescuing && engines[g].outstanding() >= quota)
            {
                continue;
            }
            let p = self.pressure(engines, g);
            let better = match tgt {
                None => true,
                Some((bp, _)) => p < bp,
            };
            if better {
                tgt = Some((p, g));
            }
        }
        let Some((tgt_p, tgt_g)) = tgt else { return false };
        if let Some(r) = min_ratio {
            // Proactive hysteresis: only a clear imbalance moves work.
            if src_p <= r * tgt_p {
                return false;
            }
        }
        let m = engines[src_g]
            .extract_request(victim)
            .expect("the victim is outstanding on its source");
        let cause = if rescuing { "shed-rescue" } else { "rebalance" };
        self.relocate(engines, fd, m, tgt_g, cause);
        true
    }

    /// Offer one arrival to admission control: place it if any GPU is
    /// eligible, otherwise queue (bounded) or shed. Under
    /// [`MigrationPolicy::OnPressure`], a proactive rebalance may run
    /// first; under any migrating policy, an imminent shed first tries
    /// a work-preserving relocation ([`Self::try_migrate`]) whose freed
    /// quota slot absorbs the queue head (or the arrival itself).
    fn offer(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor, rid: usize) {
        fd.counters.offered += 1;
        let t_arrive = fd.meta[rid].t_arrive;
        fd.emit(|| SimEvent::new(t_arrive, EventKind::Offer).rid(rid));
        if let MigrationPolicy::OnPressure { ratio } = self.cfg.migration {
            // Proactive, quota-respecting rebalance with hysteresis —
            // at most one move per offered arrival, so near-balanced
            // pools stay still and thrash is bounded by the offer rate.
            if self.try_migrate(engines, fd, Some(ratio)) {
                self.drain_queue(engines, fd);
            }
        }
        if self.any_eligible(engines, fd) {
            self.place(engines, fd, rid);
            return;
        }
        self.queue_or_shed(engines, fd, rid, self.cfg.migration.on_shed());
    }

    /// Would the SLO-aware early reject shed this arrival right now?
    /// Expected queue wait is the queued-ahead footprint over the
    /// measured drain rate; no evidence (no completions yet) means no
    /// early reject.
    fn slo_would_shed(&self, fd: &FrontDoor, rid: usize) -> bool {
        let Some(slo) = self.cfg.admission.slo_s else {
            return false;
        };
        let epoch = fd.epoch.unwrap_or(0.0);
        let elapsed = fd.meta[rid].t_arrive - epoch;
        if fd.completed_blocks > 0.0 && elapsed > 0.0 {
            let drain_rate = fd.completed_blocks / elapsed; // blocks/s
            let ahead = fd.queued_blocks() + fd.meta[rid].expected_blocks;
            ahead / drain_rate > slo
        } else {
            false
        }
    }

    /// Every active GPU is at quota: queue the arrival, or shed it —
    /// unless the scaling controller or a migration can absorb the
    /// pressure. A successful migration frees a quota slot on the
    /// (hot) source; the FIFO queue head takes it, and the loop
    /// re-evaluates admission with the shorter queue — so a would-be
    /// shed becomes a placement or a queue entry instead. At most one
    /// migration per offered arrival; scale-ups are bounded by the
    /// standby pool.
    fn queue_or_shed(
        &self,
        engines: &mut [ServeEngine<'_>],
        fd: &mut FrontDoor,
        rid: usize,
        mut may_migrate: bool,
    ) {
        let t = fd.meta[rid].t_arrive;
        loop {
            if self.any_eligible(engines, fd) {
                self.place(engines, fd, rid);
                return;
            }
            let would_shed = self.slo_would_shed(fd, rid)
                || fd.queue.len() >= self.cfg.admission.queue_cap;
            let queue_deep = self.cfg.scale_up_queue_depth > 0
                && fd.queue.len() >= self.cfg.scale_up_queue_depth;
            // Scaling controller: admission pressure (an imminent shed,
            // or a deep queue) activates a standby GPU; the loop then
            // re-evaluates with the larger fleet.
            if (would_shed || queue_deep) && self.scale_up(engines, fd, t) {
                continue;
            }
            if !would_shed {
                fd.queue.push_back(rid);
                fd.counters.queue_peak = fd.counters.queue_peak.max(fd.queue.len() as u64);
                let depth = fd.queue.len();
                fd.emit(|| SimEvent::new(t, EventKind::Queue { depth }).rid(rid));
                return;
            }
            if may_migrate && self.try_migrate(engines, fd, None) {
                may_migrate = false;
                self.drain_queue(engines, fd);
                continue;
            }
            let cause = if self.slo_would_shed(fd, rid) { "slo" } else { "queue-full" };
            self.shed(fd, rid, cause);
            return;
        }
    }

    /// Mark a request shed. A shed closed-loop client goes back to
    /// thinking and issues its next request after a fresh think gap
    /// (the user walks away and comes back with new work), so the
    /// request budget is always fully offered and the run terminates.
    fn shed(&self, fd: &mut FrontDoor, rid: usize, cause: &'static str) {
        fd.meta[rid].disposition = ReqDisposition::Shed;
        fd.counters.shed += 1;
        fd.shed_rids.push(rid);
        let t_arrive = fd.meta[rid].t_arrive;
        fd.emit(|| SimEvent::new(t_arrive, EventKind::Shed).rid(rid).cause(cause));
        let client = fd.meta[rid].client;
        if client != usize::MAX {
            let t = fd.meta[rid].t_arrive;
            let next = fd
                .clients
                .as_mut()
                .expect("closed loop has clients")
                .next_arrival(client, t);
            if let Some(a) = next {
                let eb = self.expected_footprint(a.qid);
                fd.schedule(&a, client, eb);
            }
        }
    }

    /// Refresh the cached per-GPU router views: only engines whose
    /// state-change [`version`](ServeEngine::version) moved since the
    /// last placement rebuild their view (and dirty their shard's
    /// stage-one aggregate). An unchanged version guarantees an
    /// identical view, so the cached placement inputs are byte-equal to
    /// a full rebuild.
    fn refresh_views(&self, engines: &[ServeEngine<'_>], fd: &mut FrontDoor) {
        let shard_size = self.cfg.resolved_shard_size();
        for (g, e) in engines.iter().enumerate() {
            let v = e.version();
            if fd.view_version[g] == v {
                continue;
            }
            fd.view_version[g] = v;
            fd.shard_dirty[g / shard_size] = true;
            if !fd.state[g].placeable() {
                // Sentinel: a standby, draining, or departed GPU reads
                // as permanently at-quota, so every
                // `outstanding < quota` filter — the flat eligible
                // slice, the shard aggregates, and the debug
                // cross-check — excludes it without special-casing
                // fleet state. State transitions bump `view_version`
                // to `u64::MAX`, so the sentinel is (re)built on the
                // next placement.
                fd.view_cache[g] = GpuView {
                    gpu: g,
                    outstanding: usize::MAX,
                    live_traces: 0,
                    free_blocks: 0,
                    pool_blocks: 0,
                    block_size: 1,
                    timing_scale: 1.0,
                    survivor_demand_blocks: 0.0,
                    prefix_hit_blocks: 0.0,
                    affinity_weight: 0.0,
                };
                continue;
            }
            let p = self.cfg.profile_for(g);
            fd.view_cache[g] = GpuView {
                gpu: g,
                outstanding: e.outstanding(),
                live_traces: e.live_traces(),
                // Zero-ref registry entries are reclaimable on demand,
                // so the router sees them as placeable capacity. With
                // the prefix cache off the registry is empty and this
                // is exactly `free_blocks()`.
                free_blocks: e.available_blocks(),
                pool_blocks: e.pool_blocks(),
                block_size: p.block_size,
                timing_scale: p.timing_scale,
                survivor_demand_blocks: e.survivor_demand_blocks(),
                // Affinity data is per-(request, GPU): it is stamped
                // into per-placement stack copies, never into this
                // version-keyed cache.
                prefix_hit_blocks: 0.0,
                affinity_weight: 0.0,
            };
        }
    }

    /// Stamp the candidate request's prefix affinity into a
    /// per-placement stack copy of a cached view: how many registry
    /// blocks of the request's question this GPU already pins, and the
    /// configured credit weight. The version-keyed view cache stays
    /// request-independent; with the cache off or the weight at zero
    /// the copy comes back untouched, so placement arithmetic — and
    /// therefore every placement — is bit-identical to today.
    #[inline]
    fn affine_view(&self, engines: &[ServeEngine<'_>], v: &GpuView, qid: usize) -> GpuView {
        let mut v = *v;
        if self.cfg.prefix_cache && self.cfg.affinity_weight > 0.0 {
            v.affinity_weight = self.cfg.affinity_weight;
            v.prefix_hit_blocks = engines[v.gpu].prefix_hit_blocks(qid) as f64;
        }
        v
    }

    /// The incremental two-stage placement behind
    /// [`RouterKind::KvPressureSharded`]: recompute the stage-one
    /// aggregates of dirty shards only (O(dirty × shard size)), pick
    /// the winning shard from the cached minima (O(S)), then run the
    /// exact within-shard scan (O(shard size)). Byte-identical to the
    /// O(R) reference [`crate::sim::router::ShardedKvPressure`] over
    /// the full eligible slice — debug builds assert it on every
    /// placement. Affinity credit enters only the stage-two scan (the
    /// stage-one aggregates are request-independent by construction),
    /// exactly mirroring the reference. Returns the chosen GPU id.
    fn place_sharded(
        &self,
        engines: &[ServeEngine<'_>],
        fd: &mut FrontDoor,
        req: &RouteRequest,
        quota: usize,
    ) -> usize {
        let shard_size = self.cfg.resolved_shard_size();
        let n_gpus = fd.view_cache.len();
        for s in 0..fd.shard_agg.len() {
            if !fd.shard_dirty[s] {
                continue;
            }
            fd.shard_dirty[s] = false;
            let lo = s * shard_size;
            let hi = (lo + shard_size).min(n_gpus);
            let mut agg: Option<(bool, f64)> = None;
            for v in &fd.view_cache[lo..hi] {
                if v.outstanding >= quota {
                    continue;
                }
                let key = shard_base_key(v);
                let better = match agg {
                    None => true,
                    Some(bk) => key < bk,
                };
                if better {
                    agg = Some(key);
                }
            }
            fd.shard_agg[s] = agg;
        }
        // Stage one: lexicographically smallest (min base key, shard id)
        // — ascending shard order with a strict < keeps the lower shard
        // on ties, matching the reference.
        let mut win: Option<((bool, f64), usize)> = None;
        for (s, agg) in fd.shard_agg.iter().enumerate() {
            let Some(key) = *agg else { continue };
            let better = match win {
                None => true,
                Some((bk, _)) => key < bk,
            };
            if better {
                win = Some((key, s));
            }
        }
        let (_, s) = win.expect("place requires an eligible GPU");
        // Stage two: exact first-minimum kv-pressure scan within the
        // winning shard, in ascending GPU order (= view order of the
        // reference's eligible slice).
        let lo = s * shard_size;
        let hi = (lo + shard_size).min(n_gpus);
        let mut best: Option<((bool, f64), usize)> = None;
        for v in &fd.view_cache[lo..hi] {
            if v.outstanding >= quota {
                continue;
            }
            let av = self.affine_view(engines, v, req.qid);
            let key = kv_pressure_key(req, &av);
            let better = match best {
                None => true,
                Some((bk, _)) => key < bk,
            };
            if better {
                best = Some((key, v.gpu));
            }
        }
        let (_, g) = best.expect("the winning shard has an eligible member");
        #[cfg(debug_assertions)]
        {
            let views: Vec<GpuView> = fd
                .view_cache
                .iter()
                .filter(|v| v.outstanding < quota)
                .map(|v| self.affine_view(engines, v, req.qid))
                .collect();
            let want = views[fd.router.place(req, &views)].gpu;
            debug_assert_eq!(
                g, want,
                "incremental two-stage placement must match the reference router"
            );
        }
        g
    }

    /// Route a request onto an eligible GPU and submit it there. The
    /// caller guarantees at least one GPU is below quota.
    fn place(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor, rid: usize) {
        let quota = self.cfg.admission.max_outstanding_per_gpu;
        self.refresh_views(engines, fd);
        debug_assert!(
            matches!(fd.meta[rid].disposition, ReqDisposition::Queued),
            "a request is placed at most once and never after a shed"
        );
        let meta = &fd.meta[rid];
        let req = RouteRequest {
            rid,
            qid: meta.qid,
            n_traces: self.cfg.n_traces,
            expected_tokens: meta.expected_tokens,
        };
        let arr = Arrival { rid, qid: meta.qid, t_arrive: meta.t_arrive };
        let g = if matches!(self.cfg.router, RouterKind::KvPressureSharded) {
            self.place_sharded(&*engines, fd, &req, quota)
        } else {
            // Flat routers see the eligible slice of the cached views —
            // the same values a full rebuild would produce — with the
            // candidate's affinity stamped into the per-placement
            // copies (a no-op unless the prefix cache and a positive
            // weight are both configured).
            let mut views = std::mem::take(&mut fd.views_buf);
            views.clear();
            views.extend(
                fd.view_cache
                    .iter()
                    .filter(|v| v.outstanding < quota)
                    .map(|v| self.affine_view(&*engines, v, req.qid)),
            );
            debug_assert!(!views.is_empty(), "place requires an eligible GPU");
            let g = views[fd.router.place(&req, &views)].gpu;
            fd.views_buf = views;
            g
        };
        // A lagging busy engine first catches up to the arrival instant
        // (service cannot start before the request exists); idle engines
        // jump inside submit.
        if engines[g].clock() < arr.t_arrive {
            engines[g].run_until(arr.t_arrive);
        }
        engines[g].submit(&arr);
        // Keep the drain-phase laggard heap covering this engine (its
        // clock may have moved, and an idle engine just became busy).
        if fd.lag_live {
            fd.lag_heap.push(Reverse((engines[g].clock().to_bits(), g)));
        }
        fd.meta[rid].disposition = ReqDisposition::Placed;
        fd.counters.placed += 1;
        let t_place = engines[g].clock();
        let live = engines[g].live_traces();
        let used = engines[g].pool_blocks().saturating_sub(engines[g].free_blocks());
        fd.emit(|| {
            SimEvent::new(t_place, EventKind::Place).rid(rid).gpu(g).load(live, used)
        });
        let out = engines[g].outstanding();
        debug_assert!(out <= quota, "placement must respect the per-GPU quota");
        fd.per_gpu_peak_outstanding[g] = fd.per_gpu_peak_outstanding[g].max(out);
    }

    /// Place queued requests (FIFO) while some active GPU is below
    /// quota.
    fn drain_queue(&self, engines: &mut [ServeEngine<'_>], fd: &mut FrontDoor) {
        while !fd.queue.is_empty() {
            if !self.any_eligible(engines, fd) {
                return;
            }
            let rid = fd.queue.pop_front().expect("checked non-empty");
            self.place(engines, fd, rid);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::cells::projection_scorer;
    use crate::sim::tracegen::GenParams;

    fn light_cfg(method: Method, workload: ClusterWorkload) -> ClusterConfig {
        let mut c = ClusterConfig::new(
            2,
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            method,
            4,
            workload,
        );
        c.seed = 11;
        c
    }

    fn pressured_cfg(method: Method, gpus: usize) -> ClusterConfig {
        let mut c = ClusterConfig::new(
            gpus,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            method,
            6,
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(4, 60.0, 8, 0.5)),
        );
        c.mem_util = 0.45;
        c.seed = 13;
        c
    }

    fn run(cfg: &ClusterConfig) -> ClusterResult {
        let gp = GenParams::default_d64();
        let scorer = projection_scorer(&gp);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
        ClusterSim::new(cfg, &gen, &scorer).run()
    }

    #[test]
    fn open_loop_completes_every_request() {
        for method in [Method::Sc, Method::Step] {
            let cfg = light_cfg(
                method,
                ClusterWorkload::Open(WorkloadSpec::poisson(0.02, 6)),
            );
            let r = run(&cfg);
            assert_eq!(r.outcomes.len(), 6, "{method:?}");
            assert!(r.shed_rids.is_empty());
            assert_eq!(r.counters.offered, 6);
            assert_eq!(r.counters.placed, 6);
            assert_eq!(r.counters.completed, 6);
            assert_eq!(r.latency.count(), 6);
            assert!(r.makespan_s > 0.0);
            assert!(r.goodput_rps() > 0.0);
            // Outcomes come back sorted by global rid, exactly once.
            for (i, o) in r.outcomes.iter().enumerate() {
                assert_eq!(o.rid, i);
                assert!(o.latency_s > 0.0);
            }
            // Every completion is attributed to exactly one GPU.
            assert_eq!(r.per_gpu_requests.iter().sum::<usize>(), 6);
        }
    }

    #[test]
    fn closed_loop_completes_budget() {
        let cfg = light_cfg(
            Method::Step,
            ClusterWorkload::Closed(ClosedLoopSpec::new(3, 30.0, 9)),
        );
        let r = run(&cfg);
        assert_eq!(r.outcomes.len(), 9);
        assert_eq!(r.counters.completed, 9);
        assert!(r.shed_rids.is_empty(), "light closed loop must not shed");
        for (i, o) in r.outcomes.iter().enumerate() {
            assert_eq!(o.rid, i);
        }
    }

    #[test]
    fn pressured_closed_loop_conserves_and_respects_quota() {
        for method in [Method::Sc, Method::Step] {
            let mut cfg = pressured_cfg(method, 2);
            cfg.admission.max_outstanding_per_gpu = 2;
            cfg.admission.queue_cap = 2;
            let r = run(&cfg);
            assert_eq!(
                r.counters.offered,
                r.counters.placed + r.counters.shed,
                "{method:?}: conservation"
            );
            assert_eq!(r.counters.completed, r.counters.placed, "{method:?}");
            assert_eq!(r.outcomes.len() as u64, r.counters.completed, "{method:?}");
            assert_eq!(r.shed_rids.len() as u64, r.counters.shed, "{method:?}");
            for &g in &r.per_gpu_peak_outstanding {
                assert!(g <= 2, "{method:?}: quota exceeded ({g})");
            }
            // A shed request never produces an outcome.
            for rid in &r.shed_rids {
                assert!(r.outcomes.iter().all(|o| o.rid != *rid), "{method:?}");
            }
        }
    }

    #[test]
    fn tiny_queue_cap_sheds_under_pressure() {
        let mut cfg = pressured_cfg(Method::Sc, 1);
        cfg.admission.max_outstanding_per_gpu = 1;
        cfg.admission.queue_cap = 0;
        let r = run(&cfg);
        assert!(r.counters.shed > 0, "queue_cap 0 must shed under load");
        assert!(r.counters.shed_rate() > 0.0);
        assert_eq!(r.counters.offered, r.counters.placed + r.counters.shed);
    }

    #[test]
    fn slo_early_reject_sheds_more_than_plain_bound() {
        let mut base = pressured_cfg(Method::Sc, 1);
        base.admission.max_outstanding_per_gpu = 1;
        base.admission.queue_cap = 8;
        let plain = run(&base);
        let mut slo = base.clone();
        slo.admission.slo_s = Some(1.0); // far tighter than service time
        let tight = run(&slo);
        assert!(
            tight.counters.shed >= plain.counters.shed,
            "an SLO bound can only shed more ({} < {})",
            tight.counters.shed,
            plain.counters.shed
        );
        assert_eq!(tight.counters.offered, tight.counters.placed + tight.counters.shed);
    }

    #[test]
    fn deterministic_given_seed() {
        for router in RouterKind::ALL {
            let mut a_cfg = pressured_cfg(Method::Step, 2);
            a_cfg.router = router;
            let a = run(&a_cfg);
            let b = run(&a_cfg);
            assert_eq!(a.makespan_s, b.makespan_s, "{router:?}");
            assert_eq!(a.counters.report(), b.counters.report(), "{router:?}");
            assert_eq!(a.outcomes.len(), b.outcomes.len());
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.rid, y.rid);
                assert_eq!(x.latency_s, y.latency_s, "{router:?}");
                assert_eq!(x.chosen, y.chosen);
            }
        }
    }

    /// The tentpole's determinism contract: attaching recorders must
    /// not change one metric byte (across `step_threads` values), the
    /// merged stream passes every lifecycle/conservation check, and
    /// [`crate::obs::replay::replay_counters`] re-derives the cluster
    /// counters byte-for-byte from events alone.
    #[test]
    fn event_log_is_invisible_and_replays_counters() {
        let mut cfg = pressured_cfg(Method::Step, 3);
        cfg.standby = 1;
        cfg.scale_up_queue_depth = 2;
        cfg.migration = MigrationPolicy::OnShed;
        cfg.admission.max_outstanding_per_gpu = 2;
        cfg.admission.queue_cap = 2;
        cfg.fleet_events = vec![
            FleetEvent {
                t_s: 40.0,
                gpu: 1,
                action: FleetAction::Revoke { deadline_s: 5.0 },
            },
            FleetEvent { t_s: 120.0, gpu: 1, action: FleetAction::Join },
        ];
        let untraced = run(&cfg);
        assert!(untraced.events.is_empty() && untraced.events_dropped == 0);
        let mut traced_cfg = cfg.clone();
        traced_cfg.event_log = Some(0);
        for step_threads in [1, 2] {
            let mut c = traced_cfg.clone();
            c.step_threads = step_threads;
            let traced = run(&c);
            assert_eq!(untraced.makespan_s, traced.makespan_s);
            assert_eq!(untraced.counters.report(), traced.counters.report());
            assert_eq!(untraced.outcomes.len(), traced.outcomes.len());
            for (x, y) in untraced.outcomes.iter().zip(&traced.outcomes) {
                assert_eq!(x.rid, y.rid);
                assert_eq!(x.latency_s, y.latency_s);
                assert_eq!(x.chosen, y.chosen);
            }
            assert!(!traced.events.is_empty());
            assert_eq!(traced.events_dropped, 0, "unbounded lanes never drop");
            let report = crate::obs::replay::check(&traced.events);
            assert!(report.ok(), "trace violations: {:?}", report.violations);
            assert_eq!(
                report.counters.report(),
                traced.counters.report(),
                "counters re-derived from events alone match byte-for-byte"
            );
        }
        // The flight-recorder variant keeps each lane's tail and counts
        // what it drops.
        let mut ring = traced_cfg.clone();
        ring.event_log = Some(8);
        let r = run(&ring);
        assert!(r.events.len() <= 8 * (ring.total_gpus() + 1));
        assert!(r.events_dropped > 0, "the tiny ring must drop under this load");
        assert_eq!(untraced.counters.report(), r.counters.report());
    }

    #[test]
    fn merged_sketch_covers_all_completions() {
        let cfg = light_cfg(
            Method::Sc,
            ClusterWorkload::Open(WorkloadSpec::bursty(0.05, 3, 6)),
        );
        let r = run(&cfg);
        assert_eq!(r.latency.count(), r.counters.completed);
        assert_eq!(r.ttfv.count(), r.counters.completed);
        // The merged sketch's extremes bound every outcome.
        for o in &r.outcomes {
            assert!(o.latency_s <= r.latency.max_s() + 1e-9);
            assert!(o.latency_s >= r.latency.min_s() - 1e-9);
        }
    }

    #[test]
    fn gpu_profile_and_migration_policy_parse_roundtrip() {
        let p = GpuProfile::parse("0.45:32:2.5").expect("valid spec");
        assert_eq!(p, GpuProfile { mem_util: 0.45, block_size: 32, timing_scale: 2.5 });
        assert_eq!(GpuProfile::parse(&p.spec()), Some(p));
        let bad_specs =
            ["", "0.9", "0.9:16", "1.5:16:1", "0:16:1", "0.9:0:1", "0.9:16:0", "0.9:16:1:1"];
        for bad in bad_specs {
            assert!(GpuProfile::parse(bad).is_none(), "{bad:?} must not parse");
        }
        for pol in [
            MigrationPolicy::Never,
            MigrationPolicy::OnShed,
            MigrationPolicy::OnPressure { ratio: 2.0 },
            MigrationPolicy::OnPressure { ratio: 3.5 },
        ] {
            assert_eq!(MigrationPolicy::parse(&pol.spec()), Some(pol));
        }
        assert_eq!(
            MigrationPolicy::parse("on-pressure"),
            Some(MigrationPolicy::OnPressure {
                ratio: MigrationPolicy::DEFAULT_PRESSURE_RATIO
            })
        );
        assert!(MigrationPolicy::parse("on-pressure:0.5").is_none(), "ratio < 1 invalid");
        assert!(MigrationPolicy::parse("sometimes").is_none());
    }

    /// An explicit uniform profile list is byte-identical to the
    /// profile-free configuration — the contract that keeps
    /// `MigrationPolicy::Never` + empty profiles equal to the
    /// pre-heterogeneity cluster output.
    #[test]
    fn uniform_profiles_match_the_default_pool() {
        let plain = pressured_cfg(Method::Step, 2);
        let mut explicit = plain.clone();
        explicit.gpu_profiles = vec![
            GpuProfile {
                mem_util: plain.mem_util,
                block_size: plain.block_size,
                timing_scale: 1.0,
            };
            2
        ];
        let a = run(&plain);
        let b = run(&explicit);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.report(), b.counters.report());
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.gen_tokens, y.gen_tokens);
            assert_eq!(x.chosen, y.chosen);
        }
    }

    /// When admission never sheds, the on-shed policy never fires, so
    /// its output is byte-identical to `Never` — migration plumbing is
    /// inert until the moment it is needed.
    #[test]
    fn on_shed_is_inert_without_sheds() {
        let base = light_cfg(
            Method::Step,
            ClusterWorkload::Closed(ClosedLoopSpec::new(3, 30.0, 9)),
        );
        let mut migrating = base.clone();
        migrating.migration = MigrationPolicy::OnShed;
        let a = run(&base);
        let b = run(&migrating);
        assert!(a.shed_rids.is_empty(), "light load must not shed");
        assert_eq!(b.counters.migrated, 0, "nothing shed, nothing migrated");
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.report(), b.counters.report());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.chosen, y.chosen);
        }
    }

    /// A harshly heterogeneous, tightly-quota'd pool: the migration
    /// grid's core claim. Under `Never` admission sheds; under
    /// `OnShed` the same offered load sheds strictly less (each
    /// imminent shed relocates work hottest → coolest and the freed
    /// slot absorbs the arrival), completes more requests, and every
    /// conservation law still holds.
    #[test]
    fn on_shed_migration_sheds_less_than_never() {
        let mut base = ClusterConfig::new(
            2,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            Method::Step,
            4,
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(4, 10.0, 12, 0.5)),
        );
        base.seed = 13;
        base.gpu_profiles = GpuProfile::default_hetero(2);
        base.admission.max_outstanding_per_gpu = 1;
        base.admission.queue_cap = 0;
        let never = run(&base);
        assert!(
            never.counters.shed > 0,
            "the harsh config must shed under Never (got {})",
            never.counters.report()
        );
        let mut migrating = base.clone();
        migrating.migration = MigrationPolicy::OnShed;
        let shed = run(&migrating);
        assert!(
            shed.counters.shed < never.counters.shed,
            "on-shed must shed less: {} vs {}",
            shed.counters.report(),
            never.counters.report()
        );
        assert!(shed.counters.migrated > 0, "rescues actually happened");
        assert!(
            shed.counters.migration_recompute_tokens > 0,
            "moved KV is recomputed, not teleported"
        );
        assert!(shed.counters.completed > never.counters.completed);
        for r in [&never, &shed] {
            assert_eq!(r.counters.offered, r.counters.placed + r.counters.shed);
            assert_eq!(r.counters.completed, r.counters.placed);
            for w in r.outcomes.windows(2) {
                assert!(w[0].rid < w[1].rid, "outcomes unique by rid");
            }
        }
    }

    /// The on-pressure policy proactively rebalances a heterogeneous
    /// pool and upholds the same conservation laws; its runs stay
    /// deterministic.
    #[test]
    fn on_pressure_migration_conserves_and_is_deterministic() {
        let mut cfg = pressured_cfg(Method::Step, 3);
        cfg.gpu_profiles = GpuProfile::default_hetero(3);
        cfg.admission.max_outstanding_per_gpu = 2;
        cfg.admission.queue_cap = 1;
        cfg.migration = MigrationPolicy::OnPressure { ratio: 1.5 };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.counters.report(), b.counters.report(), "deterministic");
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.offered, a.counters.placed + a.counters.shed);
        assert_eq!(a.counters.completed, a.counters.placed);
        assert!(a.counters.migrated >= a.counters.migration_saved);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
        }
        // Every outcome's trace accounting stays within its budget: no
        // trace lost or duplicated across hops.
        for o in &a.outcomes {
            assert!(o.n_finished + o.n_pruned <= cfg.n_traces);
        }
    }

    #[test]
    fn fleet_event_parse_roundtrip() {
        let evs = parse_fleet_events("0:1:join; 30:0:revoke:20; 45:1:leave", 2, 1)
            .expect("valid spec");
        assert_eq!(
            evs,
            vec![
                FleetEvent { t_s: 0.0, gpu: 1, action: FleetAction::Join },
                FleetEvent {
                    t_s: 30.0,
                    gpu: 0,
                    action: FleetAction::Revoke { deadline_s: 20.0 }
                },
                FleetEvent { t_s: 45.0, gpu: 1, action: FleetAction::Leave },
            ]
        );
        // Round-trips through the per-event spec spelling.
        let respelled: Vec<String> = evs.iter().map(|e| e.spec()).collect();
        assert_eq!(parse_fleet_events(&respelled.join(";"), 2, 1), Some(evs));
        assert_eq!(parse_fleet_events("", 4, 0), Some(Vec::new()));
        let bad_specs = [
            "x",
            "1:0",
            "1:0:explode",
            "1:0:revoke",
            "1:9:join",
            "-1:0:join",
            "1:0:revoke:-2",
            "1:0:join:1",
        ];
        for bad in bad_specs {
            assert!(parse_fleet_events(bad, 2, 1).is_none(), "{bad:?} must not parse");
        }
        // The rand: spelling is the shared chaos generator, verbatim.
        let rand = parse_fleet_events("rand:7:6:600", 4, 2).expect("valid rand spec");
        assert_eq!(rand, random_fleet_events(7, 4, 2, 6, 600.0));
        assert_eq!(rand.len(), 6);
        for w in rand.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "generated schedules are time-sorted");
        }
        for e in &rand {
            assert!(e.gpu < 6 && e.t_s >= 0.0 && e.t_s <= 600.0);
            if let FleetAction::Revoke { deadline_s } = e.action {
                assert!((30.0..=150.0).contains(&deadline_s), "5-25% of the horizon");
            }
        }
    }

    /// An empty schedule — and an untouched standby pool — is
    /// byte-identical to today's static fleet: the elastic plumbing is
    /// inert until an event or the scaling controller fires.
    #[test]
    fn empty_schedule_and_inert_standby_match_the_static_fleet() {
        let base = pressured_cfg(Method::Step, 2);
        let mut elastic = base.clone();
        elastic.fleet_events = Vec::new();
        elastic.standby = 2;
        let a = run(&base);
        let b = run(&elastic);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.report(), b.counters.report());
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.chosen, y.chosen);
        }
        assert!(b.fleet_log.is_empty(), "no event fired, nothing logged");
        assert_eq!(b.per_gpu_requests[2], 0, "standby slots never served");
        assert_eq!(b.per_gpu_requests[3], 0);
    }

    /// A revocation mid-run: under `Never` the deadline force-clear
    /// abandons the victim's residents (the shed-everything baseline);
    /// under `OnShed` the drain controller relocates them and strictly
    /// less goodput is lost per revocation. Conservation holds both
    /// ways, and the victim departs empty by its deadline.
    #[test]
    fn revocation_drains_relocates_and_conserves() {
        let mut base = ClusterConfig::new(
            2,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            Method::Step,
            4,
            ClusterWorkload::Open(WorkloadSpec::poisson(1.0, 6)),
        );
        base.seed = 13;
        base.fleet_events =
            parse_fleet_events("30:0:revoke:20", base.gpus, 0).expect("valid spec");
        let never = run(&base);
        let mut migrating = base.clone();
        migrating.migration = MigrationPolicy::OnShed;
        let drained = run(&migrating);
        for r in [&never, &drained] {
            assert_eq!(r.counters.offered, 6);
            assert_eq!(r.counters.offered, r.counters.placed + r.counters.shed);
            assert_eq!(
                r.counters.completed + r.counters.shed_on_revoke,
                r.counters.placed,
                "every placed request completes or is abandoned: {}",
                r.counters.report()
            );
            assert_eq!(r.counters.revocations, 1);
            assert_eq!(
                r.outcomes.len() as u64 + r.shed_rids.len() as u64,
                r.counters.offered,
                "exactly once: every request completes or is dropped"
            );
            let dep = r
                .fleet_log
                .iter()
                .find(|e| e.kind == FleetLogKind::Departed && e.gpu == 0)
                .expect("the revoked GPU departs");
            assert!(dep.t_s <= 50.0 + 1e-9, "departed by the deadline");
            assert_eq!(dep.residents_after, 0);
        }
        assert!(
            never.counters.shed_on_revoke > 0,
            "shed-everything abandons residents: {}",
            never.counters.report()
        );
        assert!(
            drained.counters.rescue_migrated > 0,
            "the drain controller relocated residents: {}",
            drained.counters.report()
        );
        assert!(
            drained.counters.goodput_lost_per_revocation()
                < never.counters.goodput_lost_per_revocation(),
            "drain-relocate loses strictly less: {} vs {}",
            drained.counters.report(),
            never.counters.report()
        );
        assert!(drained.counters.completed > never.counters.completed);
    }

    /// Regression for the drain-phase laggard heap: a graceful leave
    /// under `Never` keeps its residents until they complete naturally
    /// during tail-phase laggard stepping, so the GPU departs while the
    /// heap is live — its stale `(clock, gpu)` keys must be skipped,
    /// not advanced, and the run must stay byte-identical across
    /// `step_threads`.
    #[test]
    fn laggard_heap_tolerates_departed_engines() {
        let mut cfg = ClusterConfig::new(
            2,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            Method::Step,
            4,
            ClusterWorkload::Open(WorkloadSpec::poisson(1.0, 6)),
        );
        cfg.seed = 11;
        cfg.fleet_events =
            parse_fleet_events("40:1:leave", cfg.gpus, 0).expect("valid spec");
        let r = run(&cfg);
        assert_eq!(r.counters.revocations, 0, "a leave is not a revocation");
        assert_eq!(r.counters.shed_on_revoke, 0, "a leave never force-clears");
        assert_eq!(r.counters.completed, r.counters.placed);
        assert_eq!(r.outcomes.len() as u64 + r.shed_rids.len() as u64, 6);
        let dep = r
            .fleet_log
            .iter()
            .find(|e| e.kind == FleetLogKind::Departed)
            .expect("the leaving GPU departs once empty");
        assert_eq!(dep.gpu, 1);
        assert_eq!(dep.residents_after, 0);
        assert!(dep.t_s >= 40.0, "it held residents at the leave notice");
        assert!(r.counters.drained > 0, "residents completed while draining");
        // Byte-identical across step-thread counts with the departure
        // in flight.
        let mut par = cfg.clone();
        par.step_threads = 4;
        let p = run(&par);
        assert_eq!(r.counters.report(), p.counters.report());
        assert_eq!(r.makespan_s, p.makespan_s);
        for (x, y) in r.outcomes.iter().zip(&p.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
        }
    }

    /// The scaling controller: an imminent shed activates standby
    /// capacity instead of rejecting work, and the grown fleet sheds
    /// strictly less than the fixed one.
    #[test]
    fn scale_up_activates_standby_before_shedding() {
        let mut cfg = pressured_cfg(Method::Sc, 1);
        cfg.admission.max_outstanding_per_gpu = 1;
        cfg.admission.queue_cap = 0;
        let base = run(&cfg);
        assert!(base.counters.shed > 0, "the harsh config sheds without standby");
        let mut scaled = cfg.clone();
        scaled.standby = 2;
        let r = run(&scaled);
        let joins =
            r.fleet_log.iter().filter(|e| e.kind == FleetLogKind::Joined).count();
        assert!(joins >= 1, "pressure activated standby capacity");
        assert!(
            r.counters.shed < base.counters.shed,
            "a grown fleet sheds less: {} vs {}",
            r.counters.report(),
            base.counters.report()
        );
        assert_eq!(r.counters.offered, r.counters.placed + r.counters.shed);
        assert_eq!(r.counters.completed, r.counters.placed);
        assert!(
            r.per_gpu_requests[1] + r.per_gpu_requests[2] > 0,
            "activated standby GPUs actually served: {:?}",
            r.per_gpu_requests
        );
    }

    /// Prefix-cache off is byte-identical to today's cluster whatever
    /// the affinity weight says: the registry plumbing and the router
    /// stamping are both structurally inert until `--prefix-cache`
    /// turns them on.
    #[test]
    fn prefix_cache_off_matches_the_default_cluster() {
        for router in [RouterKind::KvPressure, RouterKind::KvPressureSharded] {
            let mut base = pressured_cfg(Method::Step, 2);
            base.router = router;
            let mut off = base.clone();
            off.affinity_weight = 0.7; // ignored without the cache
            let a = run(&base);
            let b = run(&off);
            assert_eq!(a.makespan_s, b.makespan_s, "{router:?}");
            assert_eq!(a.counters.report(), b.counters.report(), "{router:?}");
            assert_eq!(a.engine_counters.prefix_hits, 0);
            assert_eq!(b.engine_counters.prefix_misses, 0);
            assert_eq!(a.outcomes.len(), b.outcomes.len());
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.rid, y.rid);
                assert_eq!(x.latency_s, y.latency_s, "{router:?}");
                assert_eq!(x.chosen, y.chosen);
            }
        }
    }

    /// A prefix-cache cluster under pressure: prompts actually share
    /// (hit rate above zero — every sibling trace reuses the first
    /// trace's pinned prompt), the admission conservation laws hold,
    /// and the run is byte-identical across repeats and
    /// `step_threads` values for both kv-pressure routers (the sharded
    /// router's debug cross-check vs the reference runs on every
    /// placement).
    #[test]
    fn prefix_cache_cluster_shares_conserves_and_stays_deterministic() {
        for router in [RouterKind::KvPressure, RouterKind::KvPressureSharded] {
            let mut cfg = pressured_cfg(Method::Step, 2);
            cfg.router = router;
            cfg.prefix_cache = true;
            cfg.affinity_weight = 0.5;
            let a = run(&cfg);
            assert!(a.engine_counters.prefix_hits > 0, "{router:?}: prompts shared");
            assert!(a.engine_counters.prefix_saved_blocks > 0, "{router:?}");
            assert!(a.engine_counters.prefix_hit_rate() > 0.0, "{router:?}");
            assert_eq!(a.counters.offered, a.counters.placed + a.counters.shed);
            assert_eq!(a.counters.completed, a.counters.placed);
            let b = run(&cfg);
            assert_eq!(a.counters.report(), b.counters.report(), "{router:?}");
            assert_eq!(
                a.engine_counters.prefix_hits,
                b.engine_counters.prefix_hits,
                "{router:?}"
            );
            let mut par = cfg.clone();
            par.step_threads = 4;
            let p = run(&par);
            assert_eq!(a.counters.report(), p.counters.report(), "{router:?}");
            assert_eq!(a.makespan_s, p.makespan_s, "{router:?}");
            assert_eq!(a.outcomes.len(), p.outcomes.len());
            for (x, y) in a.outcomes.iter().zip(&p.outcomes) {
                assert_eq!(x.rid, y.rid);
                assert_eq!(x.latency_s, y.latency_s, "{router:?}");
            }
        }
    }

    #[test]
    fn routers_spread_load_across_gpus() {
        for router in RouterKind::ALL {
            let mut cfg = light_cfg(
                Method::Sc,
                // Near-zero think time: the population overlaps, so any
                // load-aware policy must fan out past GPU 0.
                ClusterWorkload::Closed(ClosedLoopSpec::new(4, 0.5, 12)),
            );
            cfg.gpus = 4;
            cfg.router = router;
            let r = run(&cfg);
            assert_eq!(r.outcomes.len(), 12, "{router:?}");
            let served = r.per_gpu_requests.iter().filter(|&&n| n > 0).count();
            assert!(served >= 2, "{router:?}: load never spread ({:?})", r.per_gpu_requests);
        }
    }
}
