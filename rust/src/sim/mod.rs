//! Simulation substrates: the GPU memory model, serving latency model,
//! synthetic trace generator, benchmark/model profiles, the rule-based
//! verifier, and the discrete-event serving engine that drives every
//! paper-scale experiment.

pub mod des;
pub mod gpu;
pub mod profiles;
pub mod timing;
pub mod tracegen;
pub mod verifier;
