//! Simulation substrates: the GPU memory model, serving latency model,
//! synthetic trace generator, benchmark/model profiles, the rule-based
//! verifier, and two discrete-event serving engines — the
//! single-question engine ([`des`]) that drives every paper table/figure,
//! and the multi-request serving simulator ([`serve`]) that runs an
//! open-loop workload ([`workload`]) with continuous batching against one
//! shared KV pool (`step serve-sim`).

pub mod des;
pub mod gpu;
pub mod profiles;
pub mod serve;
pub mod timing;
pub mod tracegen;
pub mod verifier;
pub mod workload;
