//! Simulation substrates: the GPU memory model, serving latency model,
//! synthetic trace generator, benchmark/model profiles, the rule-based
//! verifier, and three discrete-event serving engines — the
//! single-question engine ([`des`]) that drives every paper table/figure,
//! the multi-request serving simulator ([`serve`]) that runs an
//! open-loop workload ([`workload`]) with continuous batching against one
//! shared KV pool (`step serve-sim`), and the multi-GPU cluster
//! simulator ([`cluster`]) that routes open- or closed-loop traffic
//! across R per-GPU engines through pluggable placement policies
//! ([`router`]) and admission control (`step cluster-sim`). The
//! scheduler machinery all engines share lives in [`sched`].

pub mod cluster;
pub mod des;
pub mod gpu;
pub mod profiles;
pub mod router;
pub mod sched;
pub mod serve;
pub mod timing;
pub mod tracegen;
pub mod verifier;
pub mod workload;
