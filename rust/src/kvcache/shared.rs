//! Shared KV pool: per-owner accounting and quotas over one
//! [`KvCacheManager`].
//!
//! The multi-request serving simulator ([`crate::sim::serve`]) admits
//! many requests against a *single* physical block pool — the regime
//! where one tenant's growth can starve every other. [`SharedKvPool`]
//! wraps the block-table manager with two additions:
//!
//! * **ownership** — every sequence is registered to an [`OwnerId`]
//!   (one owner per request), and the pool tracks blocks held per owner;
//! * **quotas** — an optional per-owner block cap. With a quota set, an
//!   owner saturating its share triggers a memory event *for that owner*
//!   even while the pool has free blocks, bounding cross-tenant
//!   interference; without one, only pool exhaustion triggers events and
//!   STEP's cross-request pruning picks the globally weakest trace.

use super::{KvCacheManager, SeqId};

/// Owner (request / tenant) identifier within a [`SharedKvPool`].
pub type OwnerId = u32;

/// Sentinel in the dense `owner_of` arena: this sequence slot has no
/// live owner. Keeps the arena a flat `Vec<u32>` (half the width and
/// none of the niche-check branches of `Vec<Option<OwnerId>>`), which
/// matters when a cluster run steps 1024 engines' pools.
const NO_OWNER: OwnerId = OwnerId::MAX;

/// A [`KvCacheManager`] with per-owner block accounting and optional
/// per-owner quotas. All accounting lives in dense index-keyed arenas
/// (`u32` entries, sequence- and owner-id keyed) — no per-pool maps.
#[derive(Debug, Clone)]
pub struct SharedKvPool {
    mgr: KvCacheManager,
    /// Sequence id -> owning request (dense, like the manager's tables;
    /// [`NO_OWNER`] marks free slots).
    owner_of: Vec<OwnerId>,
    /// Blocks currently held per owner (dense by owner id).
    used_by: Vec<u32>,
    /// Per-owner block cap; `None` = pool-bound only.
    quota_blocks: Option<usize>,
}

impl SharedKvPool {
    /// A pool of `num_blocks` blocks of `block_size` token slots, with
    /// an optional per-owner quota in blocks.
    pub fn new(num_blocks: usize, block_size: usize, quota_blocks: Option<usize>) -> Self {
        SharedKvPool {
            mgr: KvCacheManager::new(num_blocks, block_size),
            owner_of: Vec::new(),
            used_by: Vec::new(),
            quota_blocks,
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.mgr.block_size()
    }

    /// Total physical blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.mgr.capacity_tokens() / self.mgr.block_size()
    }

    /// Currently free blocks.
    #[inline]
    pub fn free_blocks(&self) -> usize {
        self.mgr.free_blocks()
    }

    /// Currently allocated blocks.
    pub fn used_blocks(&self) -> usize {
        self.mgr.used_blocks()
    }

    /// Peak allocated blocks observed over the pool's lifetime.
    pub fn peak_used_blocks(&self) -> usize {
        self.mgr.peak_used_blocks
    }

    /// Number of live sequences.
    pub fn num_seqs(&self) -> usize {
        self.mgr.num_seqs()
    }

    /// The configured per-owner quota, if any.
    pub fn quota_blocks(&self) -> Option<usize> {
        self.quota_blocks
    }

    /// Blocks currently held by `owner`.
    #[inline]
    pub fn owner_used(&self, owner: OwnerId) -> usize {
        self.used_by.get(owner as usize).copied().unwrap_or(0) as usize
    }

    /// Blocks `owner` may still allocate before hitting its quota;
    /// `None` when no quota is configured (pool-bound only). Called per
    /// active owner on every probe of the serving engine's quota-bound
    /// memory-horizon search (the per-owner *demands* come from the
    /// scheduler's incremental index; this is only the headroom side).
    #[inline]
    pub fn owner_headroom(&self, owner: OwnerId) -> Option<usize> {
        self.quota_blocks.map(|q| q.saturating_sub(self.owner_used(owner)))
    }

    /// The owner a live sequence is registered to.
    pub fn owner_of(&self, seq: SeqId) -> Option<OwnerId> {
        self.owner_of.get(seq as usize).copied().filter(|&o| o != NO_OWNER)
    }

    /// Resident tokens of a sequence (0 if unknown).
    #[inline]
    pub fn seq_tokens(&self, seq: SeqId) -> usize {
        self.mgr.seq_tokens(seq)
    }

    /// Blocks required to admit a new sequence of `tokens` tokens.
    pub fn blocks_needed_for_new(&self, tokens: usize) -> usize {
        self.mgr.blocks_needed_for_new(tokens)
    }

    /// Blocks required to append `n` tokens to a live sequence.
    #[inline]
    pub fn blocks_needed_for_append(&self, seq: SeqId, n: usize) -> usize {
        self.mgr.blocks_needed_for_append(seq, n)
    }

    /// Would allocating `blocks` for `owner` satisfy both the pool and
    /// the owner's quota right now?
    #[inline]
    pub fn can_admit(&self, owner: OwnerId, blocks: usize) -> bool {
        self.mgr.can_allocate(blocks)
            && match self.owner_headroom(owner) {
                Some(h) => blocks <= h,
                None => true,
            }
    }

    /// Admit a sequence of `tokens` prefilled tokens for `owner`.
    /// All-or-nothing: returns false (changing nothing) when either the
    /// pool or the owner's quota cannot take the allocation.
    pub fn allocate_seq(&mut self, owner: OwnerId, seq: SeqId, tokens: usize) -> bool {
        let need = self.mgr.blocks_needed_for_new(tokens);
        if !self.can_admit(owner, need) {
            return false;
        }
        debug_assert!(owner != NO_OWNER, "owner id collides with the arena sentinel");
        let ok = self.mgr.allocate_seq(seq, tokens);
        debug_assert!(ok, "can_admit guaranteed the allocation");
        let idx = seq as usize;
        if self.owner_of.len() <= idx {
            self.owner_of.resize(idx + 1, NO_OWNER);
        }
        self.owner_of[idx] = owner;
        let oidx = owner as usize;
        if self.used_by.len() <= oidx {
            self.used_by.resize(oidx + 1, 0);
        }
        self.used_by[oidx] += need as u32;
        true
    }

    /// Append `n` tokens to a live sequence, charging any new blocks to
    /// its owner. Returns false (changing nothing) if the pool or the
    /// owner's quota is short.
    pub fn append_tokens(&mut self, seq: SeqId, n: usize) -> bool {
        let owner = self.owner_of(seq).expect("appending to unknown seq");
        let need = self.mgr.blocks_needed_for_append(seq, n);
        if need > 0 && !self.can_admit(owner, need) {
            return false;
        }
        let ok = self.mgr.append_tokens(seq, n);
        debug_assert!(ok, "can_admit guaranteed the append");
        self.used_by[owner as usize] += need as u32;
        true
    }

    /// Release a sequence entirely, crediting its blocks back to the
    /// owner. Returns the number of blocks released.
    pub fn free_seq(&mut self, seq: SeqId) -> usize {
        let owner = std::mem::replace(&mut self.owner_of[seq as usize], NO_OWNER);
        assert!(owner != NO_OWNER, "freeing unknown seq");
        let freed = self.mgr.free_seq(seq);
        self.used_by[owner as usize] -= freed as u32;
        freed
    }

    /// Invariant check for tests: per-owner charges reconcile with the
    /// manager's block tables.
    pub fn check_invariants(&self) {
        self.mgr.check_invariants();
        let charged: usize = self.used_by.iter().map(|&u| u as usize).sum();
        assert_eq!(charged, self.mgr.used_blocks(), "owner charge leak");
        let mut recomputed = vec![0u32; self.used_by.len()];
        for (seq, &owner) in self.owner_of.iter().enumerate() {
            if owner != NO_OWNER {
                let table =
                    self.mgr.block_table(seq as SeqId).expect("owned seq has a table");
                recomputed[owner as usize] += table.blocks.len() as u32;
            }
        }
        assert_eq!(recomputed, self.used_by, "per-owner accounting drift");
        if let Some(q) = self.quota_blocks {
            for (o, &u) in self.used_by.iter().enumerate() {
                assert!(u as usize <= q, "owner {o} over quota: {u} > {q}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize, quota: Option<usize>) -> SharedKvPool {
        SharedKvPool::new(blocks, 16, quota)
    }

    #[test]
    fn tracks_usage_per_owner() {
        let mut p = pool(8, None);
        assert!(p.allocate_seq(0, 0, 32)); // owner 0: 2 blocks
        assert!(p.allocate_seq(1, 1, 16)); // owner 1: 1 block
        assert!(p.append_tokens(1, 16)); // owner 1: +1 block
        assert_eq!(p.owner_used(0), 2);
        assert_eq!(p.owner_used(1), 2);
        assert_eq!(p.used_blocks(), 4);
        p.check_invariants();
        assert_eq!(p.free_seq(0), 2);
        assert_eq!(p.owner_used(0), 0);
        p.check_invariants();
    }

    #[test]
    fn quota_caps_an_owner_while_pool_has_room() {
        let mut p = pool(8, Some(2));
        assert!(p.allocate_seq(0, 0, 32)); // exactly at quota
        assert!(!p.append_tokens(0, 1), "quota must refuse the 3rd block");
        assert_eq!(p.seq_tokens(0), 32, "refused append must not change state");
        assert!(p.free_blocks() >= 6, "pool itself still has room");
        // A different owner is unaffected.
        assert!(p.allocate_seq(1, 1, 32));
        // Refusing admission over quota is all-or-nothing too.
        assert!(!p.allocate_seq(2, 2, 48));
        assert_eq!(p.owner_used(2), 0);
        p.check_invariants();
    }

    #[test]
    fn quota_headroom_reporting() {
        let mut p = pool(8, Some(3));
        assert_eq!(p.owner_headroom(0), Some(3));
        assert!(p.allocate_seq(0, 0, 17)); // 2 blocks
        assert_eq!(p.owner_headroom(0), Some(1));
        assert_eq!(pool(8, None).owner_headroom(0), None);
        p.check_invariants();
    }

    #[test]
    fn pool_exhaustion_still_refuses_without_quota() {
        let mut p = pool(2, None);
        assert!(p.allocate_seq(0, 0, 32));
        assert!(!p.allocate_seq(1, 1, 16));
        assert!(!p.append_tokens(0, 1));
        p.check_invariants();
    }

    #[test]
    fn freed_quota_is_reusable() {
        let mut p = pool(4, Some(2));
        assert!(p.allocate_seq(0, 0, 32));
        assert!(!p.allocate_seq(0, 1, 16), "owner 0 at quota");
        p.free_seq(0);
        assert!(p.allocate_seq(0, 1, 16), "credit restored after free");
        p.check_invariants();
    }

    #[test]
    #[should_panic(expected = "freeing unknown seq")]
    fn double_free_panics() {
        let mut p = pool(4, None);
        p.allocate_seq(0, 0, 16);
        p.free_seq(0);
        p.free_seq(0);
    }
}
