//! Shared KV pool: per-owner accounting, quotas, and copy-on-write
//! prompt-prefix sharing over one [`KvCacheManager`].
//!
//! The multi-request serving simulator ([`crate::sim::serve`]) admits
//! many requests against a *single* physical block pool — the regime
//! where one tenant's growth can starve every other. [`SharedKvPool`]
//! wraps the block-table manager with three additions:
//!
//! * **ownership** — every sequence is registered to an [`OwnerId`]
//!   (one owner per request), and the pool tracks blocks held per owner;
//! * **quotas** — an optional per-owner block cap. With a quota set, an
//!   owner saturating its share triggers a memory event *for that owner*
//!   even while the pool has free blocks, bounding cross-tenant
//!   interference; without one, only pool exhaustion triggers events and
//!   STEP's cross-request pruning picks the globally weakest trace.
//! * **prefix sharing** — an opt-in copy-on-write path
//!   ([`Self::allocate_seq_shared`]) that pins a question's *full*
//!   prompt blocks once in a per-pool registry and admits each sequence
//!   with only its private suffix (the partially-filled tail block is
//!   the CoW fork: generation appends into it, so it is never shared).
//!   Registry blocks are charged to the sentinel [`PREFIX_OWNER`],
//!   refcounted per question, and — once the last sharer releases —
//!   kept as a reclaimable cache that LRU-evicts under pressure.
//!
//! The sharing path is entirely additive: a pool that never calls
//! [`Self::allocate_seq_shared`] holds an empty registry, and every
//! legacy method then computes byte-for-byte what it did before the
//! registry existed (the determinism contract behind
//! `--prefix-cache` off).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use super::{BlockId, KvCacheManager, SeqId};

/// Owner (request / tenant) identifier within a [`SharedKvPool`].
pub type OwnerId = u32;

/// Sentinel in the dense `owner_of` arena: this sequence slot has no
/// live owner. Keeps the arena a flat `Vec<u32>` (half the width and
/// none of the niche-check branches of `Vec<Option<OwnerId>>`), which
/// matters when a cluster run steps 1024 engines' pools.
const NO_OWNER: OwnerId = OwnerId::MAX;

/// Sentinel owner the prefix registry's pinned blocks are charged to.
/// Shared blocks belong to every sharer and therefore to no request:
/// charging them once here keeps the per-owner ledger reconciling with
/// the manager ([`SharedKvPool::check_invariants`]) without
/// double-charging any tenant, and quotas never apply to it.
pub const PREFIX_OWNER: OwnerId = OwnerId::MAX - 1;

/// Sentinel in the dense `prefix_of` arena: this sequence shares no
/// prefix.
const NO_PREFIX: u32 = u32::MAX;

/// Outcome of a copy-on-write admission
/// ([`SharedKvPool::allocate_seq_shared`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixShare {
    /// Did the registry already hold this question's prompt blocks?
    /// A hit reuses them (no prefill for the shared span); a miss pins
    /// them fresh.
    pub hit: bool,
    /// Full prompt blocks pinned in (or reused from) the registry.
    /// Zero when the prompt is shorter than one block.
    pub shared_blocks: usize,
}

/// One pinned prompt prefix: the question's full blocks, how many live
/// sequences share them, and the LRU tick stamped when the refcount
/// last dropped to zero.
#[derive(Debug, Clone)]
struct PrefixEntry {
    blocks: Vec<BlockId>,
    refs: u32,
    tick: u64,
}

/// A [`KvCacheManager`] with per-owner block accounting, optional
/// per-owner quotas, and a copy-on-write prompt-prefix registry. All
/// per-sequence accounting lives in dense index-keyed arenas (`u32`
/// entries, sequence- and owner-id keyed) — no per-pool maps on the
/// decode hot path.
#[derive(Debug, Clone)]
pub struct SharedKvPool {
    mgr: KvCacheManager,
    /// Sequence id -> owning request (dense, like the manager's tables;
    /// [`NO_OWNER`] marks free slots).
    owner_of: Vec<OwnerId>,
    /// Blocks currently held per owner (dense by owner id).
    used_by: Vec<u32>,
    /// Per-owner block cap; `None` = pool-bound only.
    quota_blocks: Option<usize>,
    /// Pinned prompt prefixes by question id. The authoritative store;
    /// iterated only by invariant checks and the scan reference.
    registry: BTreeMap<u32, PrefixEntry>,
    /// O(1) registry digest: blocks a share of `qid` would reuse right
    /// now (dense by question id; the router's affinity lookups and the
    /// admission hot path read this, never the map).
    hit_blocks: Vec<u32>,
    /// Sequence id -> shared question id ([`NO_PREFIX`] = private).
    prefix_of: Vec<u32>,
    /// Blocks charged to [`PREFIX_OWNER`] (Σ registry entry sizes).
    prefix_used: usize,
    /// Blocks held by zero-ref registry entries — allocated, but
    /// evictable on demand. `free_blocks()` stays *hard* free;
    /// [`Self::available_blocks`] adds this reclaimable slack.
    reclaimable: usize,
    /// Lazy min-heap of `(tick, qid)` for zero-ref entries; stale keys
    /// (resurrected or re-retired entries) are skipped on pop.
    zero_ref: BinaryHeap<Reverse<(u64, u32)>>,
    /// Monotone LRU clock, bumped each time a refcount drops to zero.
    tick: u64,
    /// Evictions performed since the last drain: `(qid, blocks)`. The
    /// serving engine drains this to emit `PrefixEvict` events.
    evictions: Vec<(u32, u32)>,
}

impl SharedKvPool {
    /// A pool of `num_blocks` blocks of `block_size` token slots, with
    /// an optional per-owner quota in blocks.
    pub fn new(num_blocks: usize, block_size: usize, quota_blocks: Option<usize>) -> Self {
        SharedKvPool {
            mgr: KvCacheManager::new(num_blocks, block_size),
            owner_of: Vec::new(),
            used_by: Vec::new(),
            quota_blocks,
            registry: BTreeMap::new(),
            hit_blocks: Vec::new(),
            prefix_of: Vec::new(),
            prefix_used: 0,
            reclaimable: 0,
            zero_ref: BinaryHeap::new(),
            tick: 0,
            evictions: Vec::new(),
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.mgr.block_size()
    }

    /// Total physical blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.mgr.capacity_tokens() / self.mgr.block_size()
    }

    /// Currently free blocks (hard free: not allocated to anything,
    /// including zero-ref cached prefixes — see
    /// [`Self::available_blocks`] for the reclaimable view).
    #[inline]
    pub fn free_blocks(&self) -> usize {
        self.mgr.free_blocks()
    }

    /// Blocks held by zero-ref registry entries: allocated, but
    /// evictable the moment an admission or append needs them.
    #[inline]
    pub fn reclaimable_blocks(&self) -> usize {
        self.reclaimable
    }

    /// Hard-free plus reclaimable blocks — the capacity an allocation
    /// willing to evict cold prefixes can actually reach. Equal to
    /// [`Self::free_blocks`] whenever the registry is unused.
    #[inline]
    pub fn available_blocks(&self) -> usize {
        self.mgr.free_blocks() + self.reclaimable
    }

    /// Currently allocated blocks (pinned registry blocks included).
    pub fn used_blocks(&self) -> usize {
        self.mgr.used_blocks()
    }

    /// Peak allocated blocks observed over the pool's lifetime.
    pub fn peak_used_blocks(&self) -> usize {
        self.mgr.peak_used_blocks
    }

    /// Number of live sequences.
    pub fn num_seqs(&self) -> usize {
        self.mgr.num_seqs()
    }

    /// The configured per-owner quota, if any.
    pub fn quota_blocks(&self) -> Option<usize> {
        self.quota_blocks
    }

    /// Blocks currently held by `owner` ([`PREFIX_OWNER`] reports the
    /// registry's pinned total).
    #[inline]
    pub fn owner_used(&self, owner: OwnerId) -> usize {
        if owner == PREFIX_OWNER {
            return self.prefix_used;
        }
        self.used_by.get(owner as usize).copied().unwrap_or(0) as usize
    }

    /// Blocks `owner` may still allocate before hitting its quota;
    /// `None` when no quota is configured (pool-bound only). Called per
    /// active owner on every probe of the serving engine's quota-bound
    /// memory-horizon search (the per-owner *demands* come from the
    /// scheduler's incremental index; this is only the headroom side).
    /// Quotas never apply to [`PREFIX_OWNER`].
    #[inline]
    pub fn owner_headroom(&self, owner: OwnerId) -> Option<usize> {
        self.quota_blocks.map(|q| q.saturating_sub(self.owner_used(owner)))
    }

    /// The owner a live sequence is registered to.
    pub fn owner_of(&self, seq: SeqId) -> Option<OwnerId> {
        self.owner_of.get(seq as usize).copied().filter(|&o| o != NO_OWNER)
    }

    /// Resident tokens of a sequence (0 if unknown). For a shared
    /// sequence this is its *private* suffix only — the pinned prompt
    /// span lives in the registry, not the sequence's table.
    #[inline]
    pub fn seq_tokens(&self, seq: SeqId) -> usize {
        self.mgr.seq_tokens(seq)
    }

    /// Blocks required to admit a new sequence of `tokens` tokens.
    pub fn blocks_needed_for_new(&self, tokens: usize) -> usize {
        self.mgr.blocks_needed_for_new(tokens)
    }

    /// Blocks required to append `n` tokens to a live sequence.
    #[inline]
    pub fn blocks_needed_for_append(&self, seq: SeqId, n: usize) -> usize {
        self.mgr.blocks_needed_for_append(seq, n)
    }

    /// Would allocating `blocks` for `owner` satisfy both the pool and
    /// the owner's quota right now?
    #[inline]
    pub fn can_admit(&self, owner: OwnerId, blocks: usize) -> bool {
        self.mgr.can_allocate(blocks)
            && match self.owner_headroom(owner) {
                Some(h) => blocks <= h,
                None => true,
            }
    }

    /// Admit a sequence of `tokens` prefilled tokens for `owner`.
    /// All-or-nothing: returns false (changing nothing) when either the
    /// pool or the owner's quota cannot take the allocation.
    pub fn allocate_seq(&mut self, owner: OwnerId, seq: SeqId, tokens: usize) -> bool {
        let need = self.mgr.blocks_needed_for_new(tokens);
        if !self.can_admit(owner, need) {
            return false;
        }
        debug_assert!(
            owner != NO_OWNER && owner != PREFIX_OWNER,
            "owner id collides with a sentinel"
        );
        let ok = self.mgr.allocate_seq(seq, tokens);
        debug_assert!(ok, "can_admit guaranteed the allocation");
        self.bind_seq(owner, seq, need, NO_PREFIX);
        true
    }

    /// Split a prompt into its shareable full blocks and the private
    /// tail tokens (the partially-filled block generation appends into).
    #[inline]
    fn split_prompt(&self, prompt_tokens: usize) -> (usize, usize) {
        let bs = self.mgr.block_size();
        let full = prompt_tokens / bs;
        (full, prompt_tokens - full * bs)
    }

    /// Blocks a fresh share of question `qid` would reuse right now
    /// (0 when the registry misses). O(1) digest lookup — the router's
    /// affinity credit calls this per candidate GPU.
    #[inline]
    pub fn prefix_hit_blocks(&self, qid: usize) -> usize {
        self.hit_blocks.get(qid).copied().unwrap_or(0) as usize
    }

    /// Scan-based reference for [`Self::prefix_hit_blocks`]: walks the
    /// authoritative registry map. The micro-benchmark locks the digest
    /// against this the way the router views are locked against their
    /// scan.
    pub fn prefix_hit_blocks_scan(&self, qid: usize) -> usize {
        self.registry.get(&(qid as u32)).map(|e| e.blocks.len()).unwrap_or(0)
    }

    /// New blocks a shared admission of (`qid`, `prompt_tokens`) plus
    /// `extra_tokens` of already-generated suffix would consume right
    /// now: the private suffix, plus the full prompt blocks only on a
    /// registry miss.
    pub fn shared_blocks_needed(
        &self,
        qid: usize,
        prompt_tokens: usize,
        extra_tokens: usize,
    ) -> usize {
        let (full, tail) = self.split_prompt(prompt_tokens);
        let private = self.mgr.blocks_needed_for_new(tail + extra_tokens);
        if full > 0 && self.prefix_hit_blocks(qid) > 0 {
            private
        } else {
            private + full
        }
    }

    /// Would a shared admission ([`Self::allocate_seq_shared`]) of this
    /// shape succeed right now? Pool feasibility counts reclaimable
    /// blocks (cold prefixes are evicted on demand), minus the target
    /// question's own cached blocks when it is zero-ref — a hit repins
    /// them, so they stop being evictable. The owner's quota covers the
    /// private suffix only.
    pub fn can_admit_shared(
        &self,
        owner: OwnerId,
        qid: usize,
        prompt_tokens: usize,
        extra_tokens: usize,
    ) -> bool {
        let (full, tail) = self.split_prompt(prompt_tokens);
        let private = self.mgr.blocks_needed_for_new(tail + extra_tokens);
        let entry = if full > 0 { self.registry.get(&(qid as u32)) } else { None };
        let need = private + if entry.is_some() { 0 } else { full };
        let mut avail = self.available_blocks();
        if let Some(e) = entry {
            if e.refs == 0 {
                avail -= e.blocks.len();
            }
        }
        need <= avail
            && match self.owner_headroom(owner) {
                Some(h) => private <= h,
                None => true,
            }
    }

    /// Copy-on-write admission: pin (or reuse) the question's full
    /// prompt blocks in the registry and allocate only the private
    /// suffix — the prompt's tail tokens plus `extra_tokens` of
    /// already-generated context (resume / migration re-admits) — as
    /// the sequence's own table. All-or-nothing: returns `None`
    /// (changing nothing) when the pool (counting evictable cold
    /// prefixes) or the owner's quota cannot take it. Cold registry
    /// entries are LRU-evicted as needed; drain
    /// [`Self::take_prefix_evictions`] afterwards.
    pub fn allocate_seq_shared(
        &mut self,
        owner: OwnerId,
        seq: SeqId,
        qid: usize,
        prompt_tokens: usize,
        extra_tokens: usize,
    ) -> Option<PrefixShare> {
        if !self.can_admit_shared(owner, qid, prompt_tokens, extra_tokens) {
            return None;
        }
        debug_assert!(
            owner != NO_OWNER && owner != PREFIX_OWNER,
            "owner id collides with a sentinel"
        );
        let (full, tail) = self.split_prompt(prompt_tokens);
        let private_tokens = tail + extra_tokens;
        let need = self.mgr.blocks_needed_for_new(private_tokens);
        let qkey = qid as u32;
        let share = if full == 0 {
            // Sub-block prompt: nothing shareable, plain private admit.
            PrefixShare { hit: false, shared_blocks: 0 }
        } else if let Some(e) = self.registry.get_mut(&qkey) {
            // Hit: repin before any eviction can touch the entry.
            if e.refs == 0 {
                self.reclaimable -= e.blocks.len();
            }
            e.refs += 1;
            PrefixShare { hit: true, shared_blocks: e.blocks.len() }
        } else {
            // Miss: pin the prompt's full blocks, evicting cold
            // entries if the hard-free pool is short.
            self.ensure_free(full + need);
            let mut blocks = Vec::with_capacity(full);
            let ok = self.mgr.alloc_raw(full, &mut blocks);
            debug_assert!(ok, "can_admit_shared guaranteed the registry pin");
            self.prefix_used += full;
            if self.hit_blocks.len() <= qid {
                self.hit_blocks.resize(qid + 1, 0);
            }
            self.hit_blocks[qid] = full as u32;
            self.registry.insert(qkey, PrefixEntry { blocks, refs: 1, tick: self.tick });
            PrefixShare { hit: false, shared_blocks: full }
        };
        self.ensure_free(need);
        let ok = self.mgr.allocate_seq(seq, private_tokens);
        debug_assert!(ok, "can_admit_shared guaranteed the private suffix");
        self.bind_seq(owner, seq, need, if full > 0 { qkey } else { NO_PREFIX });
        Some(share)
    }

    /// Register a freshly-allocated sequence in the dense arenas.
    fn bind_seq(&mut self, owner: OwnerId, seq: SeqId, charged: usize, prefix: u32) {
        let idx = seq as usize;
        if self.owner_of.len() <= idx {
            self.owner_of.resize(idx + 1, NO_OWNER);
        }
        self.owner_of[idx] = owner;
        if self.prefix_of.len() <= idx {
            self.prefix_of.resize(idx + 1, NO_PREFIX);
        }
        self.prefix_of[idx] = prefix;
        let oidx = owner as usize;
        if self.used_by.len() <= oidx {
            self.used_by.resize(oidx + 1, 0);
        }
        self.used_by[oidx] += charged as u32;
    }

    /// Evict zero-ref registry entries (oldest tick first) until the
    /// manager has `need` hard-free blocks. The caller must have
    /// checked [`Self::available_blocks`] covers the need.
    fn ensure_free(&mut self, need: usize) {
        while self.mgr.free_blocks() < need {
            let evicted = self.evict_lru_prefix();
            debug_assert!(evicted, "available_blocks covered the need");
            if !evicted {
                break;
            }
        }
    }

    /// Drop the least-recently-retired zero-ref entry, returning
    /// whether one existed. Stale heap keys (resurrected entries) are
    /// skipped lazily.
    fn evict_lru_prefix(&mut self) -> bool {
        while let Some(Reverse((tick, qkey))) = self.zero_ref.pop() {
            let live = matches!(
                self.registry.get(&qkey),
                Some(e) if e.refs == 0 && e.tick == tick
            );
            if !live {
                continue;
            }
            let e = self.registry.remove(&qkey).expect("checked live");
            self.reclaimable -= e.blocks.len();
            self.prefix_used -= e.blocks.len();
            self.mgr.free_raw(&e.blocks);
            self.hit_blocks[qkey as usize] = 0;
            self.evictions.push((qkey, e.blocks.len() as u32));
            return true;
        }
        false
    }

    /// Evictions performed since the last drain, as `(qid, blocks)`.
    /// Empty unless an admission or append had to reclaim cold
    /// prefixes.
    pub fn take_prefix_evictions(&mut self) -> Vec<(u32, u32)> {
        if self.evictions.is_empty() {
            return Vec::new();
        }
        std::mem::take(&mut self.evictions)
    }

    /// Append `n` tokens to a live sequence, charging any new blocks to
    /// its owner. Returns false (changing nothing) if the pool — after
    /// reclaiming cold prefixes — or the owner's quota is short.
    pub fn append_tokens(&mut self, seq: SeqId, n: usize) -> bool {
        let owner = self.owner_of(seq).expect("appending to unknown seq");
        let need = self.mgr.blocks_needed_for_append(seq, n);
        if need > 0 {
            let pool_ok = self.available_blocks() >= need;
            let quota_ok = match self.owner_headroom(owner) {
                Some(h) => need <= h,
                None => true,
            };
            if !pool_ok || !quota_ok {
                return false;
            }
            self.ensure_free(need);
        }
        let ok = self.mgr.append_tokens(seq, n);
        debug_assert!(ok, "the feasibility check guaranteed the append");
        self.used_by[owner as usize] += need as u32;
        true
    }

    /// Release a sequence entirely, crediting its private blocks back
    /// to the owner. A shared sequence also drops its prefix reference;
    /// the last sharer retires the entry into the reclaimable LRU cache
    /// (its blocks stay pinned until pressure evicts them or a new
    /// share resurrects them). Returns the number of blocks
    /// *hard-freed* — a shared sequence releases only its private
    /// suffix.
    pub fn free_seq(&mut self, seq: SeqId) -> usize {
        let owner = std::mem::replace(&mut self.owner_of[seq as usize], NO_OWNER);
        assert!(owner != NO_OWNER, "freeing unknown seq");
        let freed = self.mgr.free_seq(seq);
        self.used_by[owner as usize] -= freed as u32;
        if let Some(slot) = self.prefix_of.get_mut(seq as usize) {
            let qkey = std::mem::replace(slot, NO_PREFIX);
            if qkey != NO_PREFIX {
                let e = self
                    .registry
                    .get_mut(&qkey)
                    .expect("shared seq has a registry entry");
                e.refs -= 1;
                if e.refs == 0 {
                    self.tick += 1;
                    e.tick = self.tick;
                    self.reclaimable += e.blocks.len();
                    self.zero_ref.push(Reverse((e.tick, qkey)));
                }
            }
        }
        freed
    }

    /// Invariant check for tests and the serving engine's debug builds:
    /// per-owner charges, registry pins, the O(1) digest, and the
    /// reclaimable ledger all reconcile with the manager's block
    /// accounting.
    pub fn check_invariants(&self) {
        self.mgr.check_invariants();
        let charged: usize = self.used_by.iter().map(|&u| u as usize).sum();
        assert_eq!(
            charged + self.prefix_used,
            self.mgr.used_blocks(),
            "owner charge leak"
        );
        let mut recomputed = vec![0u32; self.used_by.len()];
        for (seq, &owner) in self.owner_of.iter().enumerate() {
            if owner != NO_OWNER {
                let table =
                    self.mgr.block_table(seq as SeqId).expect("owned seq has a table");
                recomputed[owner as usize] += table.blocks.len() as u32;
            }
        }
        assert_eq!(recomputed, self.used_by, "per-owner accounting drift");
        let pinned: usize = self.registry.values().map(|e| e.blocks.len()).sum();
        assert_eq!(pinned, self.prefix_used, "registry pin drift");
        assert_eq!(pinned, self.mgr.raw_blocks(), "registry / raw-block drift");
        let cold: usize = self
            .registry
            .values()
            .filter(|e| e.refs == 0)
            .map(|e| e.blocks.len())
            .sum();
        assert_eq!(cold, self.reclaimable, "reclaimable ledger drift");
        for (&q, e) in &self.registry {
            assert!(!e.blocks.is_empty(), "empty registry entry for qid {q}");
            assert_eq!(
                self.prefix_hit_blocks(q as usize),
                e.blocks.len(),
                "digest drift for qid {q}"
            );
        }
        let live_digests = self.hit_blocks.iter().filter(|&&b| b > 0).count();
        assert_eq!(live_digests, self.registry.len(), "stale digest entries");
        let mut refs: BTreeMap<u32, u32> = BTreeMap::new();
        for &q in &self.prefix_of {
            if q != NO_PREFIX {
                *refs.entry(q).or_insert(0) += 1;
            }
        }
        for (&q, e) in &self.registry {
            assert_eq!(
                e.refs,
                refs.get(&q).copied().unwrap_or(0),
                "refcount drift for qid {q}"
            );
        }
        for &q in refs.keys() {
            assert!(self.registry.contains_key(&q), "sharer of an evicted prefix {q}");
        }
        if let Some(q) = self.quota_blocks {
            for (o, &u) in self.used_by.iter().enumerate() {
                assert!(u as usize <= q, "owner {o} over quota: {u} > {q}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize, quota: Option<usize>) -> SharedKvPool {
        SharedKvPool::new(blocks, 16, quota)
    }

    #[test]
    fn tracks_usage_per_owner() {
        let mut p = pool(8, None);
        assert!(p.allocate_seq(0, 0, 32)); // owner 0: 2 blocks
        assert!(p.allocate_seq(1, 1, 16)); // owner 1: 1 block
        assert!(p.append_tokens(1, 16)); // owner 1: +1 block
        assert_eq!(p.owner_used(0), 2);
        assert_eq!(p.owner_used(1), 2);
        assert_eq!(p.used_blocks(), 4);
        p.check_invariants();
        assert_eq!(p.free_seq(0), 2);
        assert_eq!(p.owner_used(0), 0);
        p.check_invariants();
    }

    #[test]
    fn quota_caps_an_owner_while_pool_has_room() {
        let mut p = pool(8, Some(2));
        assert!(p.allocate_seq(0, 0, 32)); // exactly at quota
        assert!(!p.append_tokens(0, 1), "quota must refuse the 3rd block");
        assert_eq!(p.seq_tokens(0), 32, "refused append must not change state");
        assert!(p.free_blocks() >= 6, "pool itself still has room");
        // A different owner is unaffected.
        assert!(p.allocate_seq(1, 1, 32));
        // Refusing admission over quota is all-or-nothing too.
        assert!(!p.allocate_seq(2, 2, 48));
        assert_eq!(p.owner_used(2), 0);
        p.check_invariants();
    }

    #[test]
    fn quota_headroom_reporting() {
        let mut p = pool(8, Some(3));
        assert_eq!(p.owner_headroom(0), Some(3));
        assert!(p.allocate_seq(0, 0, 17)); // 2 blocks
        assert_eq!(p.owner_headroom(0), Some(1));
        assert_eq!(pool(8, None).owner_headroom(0), None);
        p.check_invariants();
    }

    #[test]
    fn pool_exhaustion_still_refuses_without_quota() {
        let mut p = pool(2, None);
        assert!(p.allocate_seq(0, 0, 32));
        assert!(!p.allocate_seq(1, 1, 16));
        assert!(!p.append_tokens(0, 1));
        p.check_invariants();
    }

    #[test]
    fn freed_quota_is_reusable() {
        let mut p = pool(4, Some(2));
        assert!(p.allocate_seq(0, 0, 32));
        assert!(!p.allocate_seq(0, 1, 16), "owner 0 at quota");
        p.free_seq(0);
        assert!(p.allocate_seq(0, 1, 16), "credit restored after free");
        p.check_invariants();
    }

    #[test]
    #[should_panic(expected = "freeing unknown seq")]
    fn double_free_panics() {
        let mut p = pool(4, None);
        p.allocate_seq(0, 0, 16);
        p.free_seq(0);
        p.free_seq(0);
    }

    // --- prefix sharing ---

    #[test]
    fn shared_prompt_blocks_are_pinned_once() {
        let mut p = pool(16, None);
        // Prompt 40 tokens @ bs 16: 2 full blocks shared, 8-token tail.
        let a = p.allocate_seq_shared(0, 0, 7, 40, 0).expect("fits");
        assert_eq!(a, PrefixShare { hit: false, shared_blocks: 2 });
        let b = p.allocate_seq_shared(0, 1, 7, 40, 0).expect("fits");
        assert_eq!(b, PrefixShare { hit: true, shared_blocks: 2 });
        // 2 pinned + 2 private tails, not 6.
        assert_eq!(p.used_blocks(), 4);
        assert_eq!(p.owner_used(0), 2, "owner pays only the private tails");
        assert_eq!(p.owner_used(PREFIX_OWNER), 2);
        assert_eq!(p.prefix_hit_blocks(7), 2);
        assert_eq!(p.prefix_hit_blocks(7), p.prefix_hit_blocks_scan(7));
        assert_eq!(p.prefix_hit_blocks(3), 0);
        p.check_invariants();
    }

    #[test]
    fn last_ref_retires_to_reclaimable_and_resurrects() {
        let mut p = pool(16, None);
        assert!(p.allocate_seq_shared(0, 0, 5, 32, 0).is_some()); // 2 full, no tail
        assert_eq!(p.seq_tokens(0), 0, "block-aligned prompt has no private tail");
        assert_eq!(p.reclaimable_blocks(), 0);
        p.free_seq(0);
        // The entry survives as evictable cache.
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.reclaimable_blocks(), 2);
        assert_eq!(p.available_blocks(), 16);
        assert_eq!(p.prefix_hit_blocks(5), 2, "cached entry still hits");
        p.check_invariants();
        // A new share resurrects it without fresh allocation.
        let s = p.allocate_seq_shared(1, 1, 5, 32, 0).expect("fits");
        assert!(s.hit, "cached prefix must hit");
        assert_eq!(p.reclaimable_blocks(), 0);
        assert!(p.take_prefix_evictions().is_empty());
        p.check_invariants();
    }

    #[test]
    fn pressure_evicts_cold_prefixes_lru_first() {
        let mut p = pool(6, None);
        // Pin two 2-block prefixes, then retire both (qid 1 first).
        assert!(p.allocate_seq_shared(0, 0, 1, 32, 0).is_some());
        assert!(p.allocate_seq_shared(0, 1, 2, 32, 0).is_some());
        p.free_seq(0); // qid 1 retires first -> older tick
        p.free_seq(1);
        assert_eq!(p.reclaimable_blocks(), 4);
        assert_eq!(p.free_blocks(), 2);
        // The plain path is hard-free-bound: it never reclaims.
        assert!(!p.allocate_seq(1, 2, 64), "4 blocks > 2 hard-free");
        assert!(p.take_prefix_evictions().is_empty());
        // The CoW path evicts cold entries, oldest retirement first: a
        // 3-full-block miss plus a 1-block tail needs 4 hard-free.
        assert!(p.allocate_seq_shared(1, 2, 9, 56, 0).is_some(), "evicts cold prefixes");
        let ev = p.take_prefix_evictions();
        assert_eq!(ev, vec![(1, 2)], "LRU order: qid 1 retired first");
        assert_eq!(p.prefix_hit_blocks(1), 0);
        assert_eq!(p.prefix_hit_blocks(2), 2, "the warmer entry survives");
        assert_eq!(p.reclaimable_blocks(), 2);
        p.check_invariants();
    }

    #[test]
    fn append_reclaims_cold_prefixes_under_pressure() {
        let mut p = pool(4, None);
        assert!(p.allocate_seq_shared(0, 0, 1, 32, 0).is_some()); // 2 pinned
        assert!(p.allocate_seq(1, 1, 32)); // 2 private
        p.free_seq(0); // prefix qid 1 now cold (2 reclaimable)
        assert_eq!(p.free_blocks(), 0);
        assert!(p.append_tokens(1, 1), "append evicts the cold prefix");
        assert_eq!(p.take_prefix_evictions(), vec![(1, 2)]);
        assert_eq!(p.free_blocks(), 1);
        p.check_invariants();
    }

    #[test]
    fn a_hit_on_a_cold_entry_is_not_evictable_capacity() {
        let mut p = pool(4, None);
        assert!(p.allocate_seq_shared(0, 0, 1, 64, 0).is_some()); // all 4 pinned
        p.free_seq(0);
        assert_eq!(p.reclaimable_blocks(), 4);
        // A hit repins all 4; asking for a private tail too must fail
        // (the hit blocks stop being evictable).
        assert!(!p.can_admit_shared(1, 1, 72, 0), "tail block cannot fit");
        assert!(p.allocate_seq_shared(1, 1, 1, 64, 0).is_some(), "exact hit fits");
        assert_eq!(p.reclaimable_blocks(), 0);
        p.check_invariants();
    }

    #[test]
    fn shared_quota_covers_private_suffix_only() {
        let mut p = pool(16, Some(2));
        // 3 full shared blocks + 1 tail block: quota sees only the tail.
        assert!(p.allocate_seq_shared(0, 0, 4, 56, 0).is_some());
        assert_eq!(p.owner_used(0), 1);
        assert!(p.append_tokens(0, 8), "within the tail block");
        assert!(p.append_tokens(0, 16), "second private block = quota");
        assert!(!p.append_tokens(0, 16), "third private block over quota");
        p.check_invariants();
    }

    #[test]
    fn sub_block_prompts_share_nothing() {
        let mut p = pool(8, None);
        let s = p.allocate_seq_shared(0, 0, 3, 10, 0).expect("fits");
        assert_eq!(s, PrefixShare { hit: false, shared_blocks: 0 });
        assert_eq!(p.prefix_hit_blocks(3), 0);
        assert_eq!(p.owner_used(PREFIX_OWNER), 0);
        assert_eq!(p.free_seq(0), 1, "entirely private");
        p.check_invariants();
    }

    #[test]
    fn resumed_suffix_is_charged_with_the_tail() {
        let mut p = pool(16, None);
        // Resume re-admit: 40-token prompt (2 full + 8 tail) with 20
        // generated tokens -> private 28 tokens = 2 blocks.
        let s = p.allocate_seq_shared(0, 0, 2, 40, 20).expect("fits");
        assert_eq!(s.shared_blocks, 2);
        assert_eq!(p.seq_tokens(0), 28);
        assert_eq!(p.owner_used(0), 2);
        assert_eq!(
            p.shared_blocks_needed(2, 40, 20),
            2,
            "a second sharer pays only its private suffix"
        );
        assert_eq!(p.shared_blocks_needed(9, 40, 20), 4, "a miss pays the pin too");
        p.check_invariants();
    }

    #[test]
    fn legacy_paths_are_untouched_by_an_empty_registry() {
        let mut p = pool(8, None);
        assert_eq!(p.available_blocks(), p.free_blocks());
        assert!(p.allocate_seq(0, 0, 32));
        assert!(p.append_tokens(0, 64));
        assert_eq!(p.available_blocks(), p.free_blocks());
        assert_eq!(p.reclaimable_blocks(), 0);
        assert_eq!(p.free_seq(0), 6);
        p.check_invariants();
    }
}
