//! Paged KV-cache block allocator (vLLM-style PagedAttention accounting).
//!
//! GPU memory for KV cache is carved into fixed-size blocks of
//! `block_size` token slots. Allocation must be O(1) on the decode hot
//! path — a stack free-list over a fixed pool.

/// Identifier of one physical KV block.
pub type BlockId = u32;

/// Fixed-pool O(1) block allocator.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    num_blocks: usize,
    free_list: Vec<BlockId>,
    allocated: Vec<bool>,
}

impl BlockAllocator {
    /// A pool of `num_blocks` physical blocks, all free.
    pub fn new(num_blocks: usize) -> Self {
        BlockAllocator {
            num_blocks,
            // LIFO: freshly freed blocks are reused first (cache-warm).
            free_list: (0..num_blocks as BlockId).rev().collect(),
            allocated: vec![false; num_blocks],
        }
    }

    /// Total blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Currently free blocks.
    pub fn num_free(&self) -> usize {
        self.free_list.len()
    }

    /// Currently allocated blocks.
    pub fn num_used(&self) -> usize {
        self.num_blocks - self.free_list.len()
    }

    /// Allocate one block; `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free_list.pop()?;
        self.allocated[id as usize] = true;
        Some(id)
    }

    /// Allocate `n` blocks atomically: all or nothing.
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<BlockId>> {
        let mut out = Vec::new();
        self.alloc_n_into(n, &mut out).then_some(out)
    }

    /// Allocate `n` blocks atomically, appending them to `out` — the
    /// decode hot path grows a sequence's existing block table in place
    /// instead of collecting a temporary Vec per boundary crossing.
    /// Returns false (leaving `out` untouched) if the pool is short.
    pub fn alloc_n_into(&mut self, n: usize, out: &mut Vec<BlockId>) -> bool {
        if self.free_list.len() < n {
            return false;
        }
        out.reserve(n);
        for _ in 0..n {
            let id = self.free_list.pop().unwrap();
            self.allocated[id as usize] = true;
            out.push(id);
        }
        true
    }

    /// Return one block to the pool. Panics on double free.
    pub fn free(&mut self, id: BlockId) {
        assert!(
            self.allocated[id as usize],
            "double free of KV block {id}"
        );
        self.allocated[id as usize] = false;
        self.free_list.push(id);
    }

    /// Return a batch of blocks to the pool.
    pub fn free_all(&mut self, ids: &[BlockId]) {
        for &id in ids {
            self.free(id);
        }
    }

    /// Is this block currently allocated?
    pub fn is_allocated(&self, id: BlockId) -> bool {
        self.allocated[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.num_free(), 4);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_ne!(b0, b1);
        assert_eq!(a.num_used(), 2);
        a.free(b0);
        assert_eq!(a.num_free(), 3);
        // LIFO reuse.
        assert_eq!(a.alloc().unwrap(), b0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = BlockAllocator::new(2);
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_none());
    }

    #[test]
    fn alloc_n_atomic() {
        let mut a = BlockAllocator::new(3);
        assert!(a.alloc_n(4).is_none());
        assert_eq!(a.num_free(), 3, "failed alloc_n must not leak");
        let blocks = a.alloc_n(3).unwrap();
        assert_eq!(blocks.len(), 3);
        assert_eq!(a.num_free(), 0);
        a.free_all(&blocks);
        assert_eq!(a.num_free(), 3);
    }

    #[test]
    fn alloc_n_into_extends_in_place() {
        let mut a = BlockAllocator::new(4);
        let mut blocks = Vec::new();
        assert!(a.alloc_n_into(2, &mut blocks));
        assert_eq!(blocks.len(), 2);
        assert!(!a.alloc_n_into(3, &mut blocks), "short pool must refuse");
        assert_eq!(blocks.len(), 2, "failed alloc must not touch out");
        assert!(a.alloc_n_into(2, &mut blocks));
        assert_eq!(blocks.len(), 4);
        assert_eq!(a.num_free(), 0);
        for &b in &blocks {
            assert!(a.is_allocated(b));
        }
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.free(b);
        a.free(b);
    }
}
