//! Paged KV-cache management (the substrate whose exhaustion behaviour the
//! paper's §4.2 memory-triggered pruning targets).
//!
//! [`KvCacheManager`] tracks a block table per live sequence and answers
//! the scheduler's two hot-path questions:
//!   * can every running sequence take one more token this iteration?
//!   * how many blocks would admitting / resuming a sequence need?
//!
//! When the answer is no, the SC baseline *preempts* (frees the blocks and
//! moves the sequence to a waiting queue — vLLM recompute-on-resume),
//! while STEP *prunes* the lowest-scored trace and releases its blocks.

pub mod allocator;
pub mod shared;

pub use allocator::{BlockAllocator, BlockId};
pub use shared::{OwnerId, PrefixShare, SharedKvPool, PREFIX_OWNER};

/// Sequence identifier (one reasoning trace = one sequence).
pub type SeqId = u64;

/// Per-sequence block table.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    /// Physical block ids backing the sequence, in position order.
    pub blocks: Vec<BlockId>,
    /// Resident tokens (prompt + generated).
    pub num_tokens: usize,
}

/// Manager over the physical block pool.
///
/// Sequence ids index a dense slot vector: the scheduler's hot loop
/// touches every running sequence every iteration, and dense indexing
/// measured ~25% faster than hashing at 64-trace batches (§Perf).
#[derive(Debug, Clone)]
pub struct KvCacheManager {
    alloc: BlockAllocator,
    block_size: usize,
    tables: Vec<Option<BlockTable>>,
    num_seqs: usize,
    /// Blocks allocated outside any per-sequence table (the shared
    /// pool's pinned prompt-prefix blocks live here). Tracked so
    /// [`Self::check_invariants`] can still reconcile the allocator's
    /// used count against the tables.
    raw_blocks: usize,
    /// Peak block usage observed (for reports).
    pub peak_used_blocks: usize,
    /// Retired block-table Vecs recycled on the next admission. The DES
    /// engine churns one table per trace lifecycle (admit -> grow ->
    /// finish/prune); reusing the capacity keeps the steady-state hot
    /// path free of heap traffic.
    spare_tables: Vec<Vec<BlockId>>,
}

impl KvCacheManager {
    /// A pool of `num_blocks` blocks of `block_size` token slots.
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0);
        KvCacheManager {
            alloc: BlockAllocator::new(num_blocks),
            block_size,
            tables: Vec::new(),
            num_seqs: 0,
            raw_blocks: 0,
            peak_used_blocks: 0,
            spare_tables: Vec::new(),
        }
    }

    #[inline]
    fn slot(&self, seq: SeqId) -> Option<&BlockTable> {
        self.tables.get(seq as usize).and_then(|t| t.as_ref())
    }

    #[inline]
    fn slot_mut(&mut self, seq: SeqId) -> Option<&mut BlockTable> {
        self.tables.get_mut(seq as usize).and_then(|t| t.as_mut())
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total token capacity of the pool.
    pub fn capacity_tokens(&self) -> usize {
        self.alloc.num_blocks() * self.block_size
    }

    /// Currently free blocks.
    pub fn free_blocks(&self) -> usize {
        self.alloc.num_free()
    }

    /// Currently allocated blocks.
    pub fn used_blocks(&self) -> usize {
        self.alloc.num_used()
    }

    /// Number of live sequences.
    pub fn num_seqs(&self) -> usize {
        self.num_seqs
    }

    /// Resident tokens of a sequence (0 if unknown).
    #[inline]
    pub fn seq_tokens(&self, seq: SeqId) -> usize {
        self.slot(seq).map(|t| t.num_tokens).unwrap_or(0)
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Blocks required to admit a new sequence of `tokens` tokens.
    pub fn blocks_needed_for_new(&self, tokens: usize) -> usize {
        self.blocks_for(tokens)
    }

    /// Blocks required to append `n` tokens to an existing sequence.
    #[inline]
    pub fn blocks_needed_for_append(&self, seq: SeqId, n: usize) -> usize {
        let t = self.slot(seq).expect("unknown seq");
        self.blocks_for(t.num_tokens + n) - t.blocks.len()
    }

    /// Does the pool have `blocks` free blocks right now?
    pub fn can_allocate(&self, blocks: usize) -> bool {
        self.alloc.num_free() >= blocks
    }

    /// Admit a sequence with `tokens` prefilled tokens. All-or-nothing.
    pub fn allocate_seq(&mut self, seq: SeqId, tokens: usize) -> bool {
        assert!(self.slot(seq).is_none(), "seq {seq} already allocated");
        let need = self.blocks_for(tokens);
        let mut blocks = self.spare_tables.pop().unwrap_or_default();
        if !self.alloc.alloc_n_into(need, &mut blocks) {
            self.spare_tables.push(blocks);
            return false;
        }
        let idx = seq as usize;
        if self.tables.len() <= idx {
            self.tables.resize(idx + 1, None);
        }
        self.tables[idx] = Some(BlockTable { blocks, num_tokens: tokens });
        self.num_seqs += 1;
        self.peak_used_blocks = self.peak_used_blocks.max(self.alloc.num_used());
        true
    }

    /// Append `n` tokens; allocates new blocks at block boundaries,
    /// directly into the sequence's table (no temporary Vec).
    /// Returns false (and changes nothing) if the pool is short.
    pub fn append_tokens(&mut self, seq: SeqId, n: usize) -> bool {
        let need = self.blocks_needed_for_append(seq, n);
        if need > 0 {
            let (alloc, tables) = (&mut self.alloc, &mut self.tables);
            let t = tables
                .get_mut(seq as usize)
                .and_then(|t| t.as_mut())
                .expect("unknown seq");
            if !alloc.alloc_n_into(need, &mut t.blocks) {
                return false;
            }
            self.peak_used_blocks = self.peak_used_blocks.max(self.alloc.num_used());
        }
        let t = self.slot_mut(seq).unwrap();
        t.num_tokens += n;
        true
    }

    /// Release a sequence entirely (finish / prune / preempt-with-recompute).
    /// Returns the number of blocks released.
    pub fn free_seq(&mut self, seq: SeqId) -> usize {
        let mut t = self
            .tables
            .get_mut(seq as usize)
            .and_then(|t| t.take())
            .expect("freeing unknown seq");
        self.num_seqs -= 1;
        let n = t.blocks.len();
        self.alloc.free_all(&t.blocks);
        t.blocks.clear();
        self.spare_tables.push(t.blocks);
        n
    }

    /// Block table of a sequence (e2e backend uses it to address slots).
    pub fn block_table(&self, seq: SeqId) -> Option<&BlockTable> {
        self.slot(seq)
    }

    /// Blocks currently held outside any sequence table (see
    /// [`Self::alloc_raw`]).
    pub fn raw_blocks(&self) -> usize {
        self.raw_blocks
    }

    /// Allocate `n` blocks outside any sequence table, appending their
    /// ids to `into`. The shared pool's prefix registry pins prompt
    /// blocks this way: they back many sequences at once, so no single
    /// block table may list them. All-or-nothing; returns false (and
    /// changes nothing) if the pool is short.
    pub fn alloc_raw(&mut self, n: usize, into: &mut Vec<BlockId>) -> bool {
        if !self.alloc.alloc_n_into(n, into) {
            return false;
        }
        self.raw_blocks += n;
        self.peak_used_blocks = self.peak_used_blocks.max(self.alloc.num_used());
        true
    }

    /// Release blocks taken with [`Self::alloc_raw`].
    pub fn free_raw(&mut self, blocks: &[BlockId]) {
        debug_assert!(self.raw_blocks >= blocks.len(), "freeing more raw blocks than held");
        self.alloc.free_all(blocks);
        self.raw_blocks -= blocks.len();
    }

    /// True iff advancing every listed sequence by one token fits.
    pub fn can_step_all(&self, seqs: &[SeqId]) -> bool {
        let need: usize = seqs
            .iter()
            .map(|&s| self.blocks_needed_for_append(s, 1))
            .sum();
        self.can_allocate(need)
    }

    /// Invariant check for tests: internal accounting is consistent.
    pub fn check_invariants(&self) {
        let table_blocks: usize =
            self.tables.iter().flatten().map(|t| t.blocks.len()).sum();
        assert_eq!(table_blocks + self.raw_blocks, self.alloc.num_used(), "block leak");
        for t in self.tables.iter().flatten() {
            assert_eq!(
                t.blocks.len(),
                self.blocks_for(t.num_tokens),
                "table/token mismatch"
            );
            for &b in &t.blocks {
                assert!(self.alloc.is_allocated(b));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: usize) -> KvCacheManager {
        KvCacheManager::new(blocks, 16)
    }

    #[test]
    fn allocate_and_grow() {
        let mut m = mgr(4);
        assert!(m.allocate_seq(1, 10)); // 1 block
        assert_eq!(m.used_blocks(), 1);
        assert!(m.append_tokens(1, 6)); // fills block exactly (16)
        assert_eq!(m.used_blocks(), 1);
        assert!(m.append_tokens(1, 1)); // spills to block 2
        assert_eq!(m.used_blocks(), 2);
        assert_eq!(m.seq_tokens(1), 17);
        m.check_invariants();
    }

    #[test]
    fn exhaustion_and_release() {
        let mut m = mgr(2);
        assert!(m.allocate_seq(1, 16));
        assert!(m.allocate_seq(2, 16));
        assert!(!m.append_tokens(1, 1), "pool exhausted");
        assert_eq!(m.seq_tokens(1), 16, "failed append must not change state");
        let freed = m.free_seq(2);
        assert_eq!(freed, 1);
        assert!(m.append_tokens(1, 1));
        m.check_invariants();
    }

    #[test]
    fn can_step_all_counts_boundary_crossings() {
        let mut m = mgr(3);
        assert!(m.allocate_seq(1, 16)); // at boundary: next token needs a block
        assert!(m.allocate_seq(2, 8));  // mid-block: free append
        assert!(m.allocate_seq(3, 16)); // at boundary
        // 0 free blocks, two sequences need one each.
        assert!(!m.can_step_all(&[1, 2, 3]));
        assert!(m.can_step_all(&[2]));
        m.free_seq(3);
        assert!(m.can_step_all(&[1, 2]));
    }

    #[test]
    fn new_seq_admission_cost() {
        let m = mgr(10);
        assert_eq!(m.blocks_needed_for_new(1), 1);
        assert_eq!(m.blocks_needed_for_new(16), 1);
        assert_eq!(m.blocks_needed_for_new(17), 2);
        assert_eq!(m.blocks_needed_for_new(160), 10);
    }

    #[test]
    fn all_or_nothing_admission() {
        let mut m = mgr(2);
        assert!(!m.allocate_seq(1, 33)); // needs 3 blocks
        assert_eq!(m.used_blocks(), 0);
        assert!(m.allocate_seq(1, 32));
    }

    #[test]
    fn peak_tracking() {
        let mut m = mgr(8);
        m.allocate_seq(1, 64);
        assert_eq!(m.peak_used_blocks, 4);
        m.free_seq(1);
        m.allocate_seq(2, 16);
        assert_eq!(m.peak_used_blocks, 4);
    }

    #[test]
    fn table_vecs_recycle_across_lifecycles() {
        let mut m = mgr(8);
        assert!(m.allocate_seq(1, 64)); // 4 blocks
        let cap_before = m.block_table(1).unwrap().blocks.capacity();
        m.free_seq(1);
        // The next admission reuses the retired table's capacity.
        assert!(m.allocate_seq(2, 16));
        assert!(m.block_table(2).unwrap().blocks.capacity() >= cap_before);
        assert_eq!(m.seq_tokens(2), 16);
        m.check_invariants();
    }

    #[test]
    fn failed_admission_keeps_spare_table() {
        let mut m = mgr(2);
        assert!(m.allocate_seq(1, 32));
        m.free_seq(1);
        assert!(!m.allocate_seq(2, 48), "needs 3 of 2 blocks");
        assert_eq!(m.used_blocks(), 0);
        // The recycled Vec must not leak into a half-allocated state.
        assert!(m.allocate_seq(3, 32));
        m.check_invariants();
    }

    #[test]
    fn raw_blocks_share_the_pool_and_reconcile() {
        let mut m = mgr(4);
        let mut pinned = Vec::new();
        assert!(m.alloc_raw(2, &mut pinned));
        assert_eq!(m.used_blocks(), 2);
        assert_eq!(m.raw_blocks(), 2);
        assert!(m.allocate_seq(1, 32)); // the remaining 2 blocks
        assert!(!m.alloc_raw(1, &mut pinned), "pool exhausted");
        assert_eq!(pinned.len(), 2, "failed raw alloc must not touch the list");
        m.check_invariants();
        m.free_raw(&pinned);
        assert_eq!(m.raw_blocks(), 0);
        assert_eq!(m.used_blocks(), 2);
        assert_eq!(m.peak_used_blocks, 4);
        m.check_invariants();
    }

    #[test]
    #[should_panic(expected = "already allocated")]
    fn duplicate_seq_panics() {
        let mut m = mgr(4);
        m.allocate_seq(1, 1);
        m.allocate_seq(1, 1);
    }
}
