//! Request-level lifecycle: the unit the serving layer schedules.
//!
//! A *request* is one user question plus its N-trace STEP /
//! self-consistency job. The single-question engines (`sim::des` and the
//! PJRT-backed `coordinator::engine`) implicitly serve exactly one
//! request; the multi-request simulator (`sim::serve`) runs many
//! concurrently, and this module holds the shared lifecycle bookkeeping:
//!
//! ```text
//! Queued ──admit──▶ Running ──all traces terminal──▶ Complete
//! ```
//!
//! plus the three latency marks every serving metric derives from:
//! admission (queue delay), first vote (earliest usable answer), and
//! completion (end-to-end latency).

/// Dense request identifier (arrival order).
pub type RequestId = usize;

/// Lifecycle phase of a serving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestStatus {
    /// Arrived; no trace admitted yet (waiting on KV memory).
    Queued,
    /// At least one trace admitted; decoding (possibly with some traces
    /// preempted).
    Running,
    /// Every trace reached a terminal state; the answer is voted.
    Complete,
}

/// Timestamps and lifecycle state of one request.
///
/// # Examples
///
/// ```
/// use step::coordinator::request::{RequestState, RequestStatus};
///
/// let mut r = RequestState::new(0, 3, 10.0);
/// assert_eq!(r.status, RequestStatus::Queued);
/// r.admitted(10.5);
/// r.first_vote(12.0);
/// r.completed(13.0);
/// assert_eq!(r.status, RequestStatus::Complete);
/// assert_eq!(r.queue_s(), Some(0.5));
/// assert_eq!(r.ttfv_s(), Some(2.0));
/// assert_eq!(r.latency_s(), Some(3.0));
/// ```
#[derive(Debug, Clone)]
pub struct RequestState {
    /// Request id (dense, arrival order).
    pub rid: RequestId,
    /// Question index into the benchmark pool.
    pub qid: usize,
    /// Current lifecycle phase.
    pub status: RequestStatus,
    /// Arrival wall-clock, seconds.
    pub t_arrive: f64,
    /// Clock when the first trace was admitted (prefill started).
    pub t_admit: Option<f64>,
    /// Clock when the first trace finished and cast a vote.
    pub t_first_vote: Option<f64>,
    /// Clock when the last trace reached a terminal state.
    pub t_done: Option<f64>,
}

impl RequestState {
    /// A freshly arrived (queued) request.
    pub fn new(rid: RequestId, qid: usize, t_arrive: f64) -> RequestState {
        RequestState {
            rid,
            qid,
            status: RequestStatus::Queued,
            t_arrive,
            t_admit: None,
            t_first_vote: None,
            t_done: None,
        }
    }

    /// Record first admission (idempotent: only the first call sticks).
    pub fn admitted(&mut self, clock: f64) {
        if self.t_admit.is_none() {
            self.t_admit = Some(clock);
            self.status = RequestStatus::Running;
        }
    }

    /// Record the first finished trace (idempotent).
    pub fn first_vote(&mut self, clock: f64) {
        if self.t_first_vote.is_none() {
            self.t_first_vote = Some(clock);
        }
    }

    /// Record completion: every trace terminal, answer voted.
    pub fn completed(&mut self, clock: f64) {
        self.t_done = Some(clock);
        self.status = RequestStatus::Complete;
    }

    /// Queue delay: arrival to first admission. `None` until admitted.
    pub fn queue_s(&self) -> Option<f64> {
        self.t_admit.map(|t| t - self.t_arrive)
    }

    /// Time-to-first-vote: arrival until the first trace finished (or
    /// completion, when no trace finished at all). `None` while running.
    pub fn ttfv_s(&self) -> Option<f64> {
        self.t_first_vote.or(self.t_done).map(|t| t - self.t_arrive)
    }

    /// End-to-end latency: arrival to completion. `None` while running.
    pub fn latency_s(&self) -> Option<f64> {
        self.t_done.map(|t| t - self.t_arrive)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_transitions() {
        let mut r = RequestState::new(3, 9, 5.0);
        assert_eq!(r.status, RequestStatus::Queued);
        assert_eq!(r.queue_s(), None);
        assert_eq!(r.latency_s(), None);
        r.admitted(6.0);
        assert_eq!(r.status, RequestStatus::Running);
        r.admitted(7.0); // idempotent
        assert_eq!(r.queue_s(), Some(1.0));
        r.first_vote(8.0);
        r.first_vote(9.0); // idempotent
        r.completed(10.0);
        assert_eq!(r.status, RequestStatus::Complete);
        assert_eq!(r.ttfv_s(), Some(3.0));
        assert_eq!(r.latency_s(), Some(5.0));
    }

    #[test]
    fn ttfv_falls_back_to_completion_when_nothing_finished() {
        let mut r = RequestState::new(0, 0, 1.0);
        r.admitted(1.0);
        r.completed(4.0);
        assert_eq!(r.ttfv_s(), Some(3.0));
    }
}
