//! The step scorer (paper §4.1): a 2-layer MLP over step-boundary hidden
//! states, trained at build time (python/compile/scorer.py, Appendix-A
//! recipe) and executed here on the decode hot path.
//!
//! Two execution paths exist and are cross-validated in tests:
//!   * [`StepScorer::score`] — native f32 matvec (the production hot
//!     path; App. D bounds its cost at < 1e-6 of an LLM step).
//!   * the AOT `scorer_d{D}_b{B}.hlo.txt` graphs via `runtime::` (used by
//!     the e2e engine, where the hidden states already live on device).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Native MLP: sigmoid(w2 . relu(W1 h + b1) + b2).
#[derive(Debug, Clone)]
pub struct StepScorer {
    pub d: usize,
    pub hidden: usize,
    /// Row-major [d][hidden] — laid out so the inner loop walks
    /// contiguous memory per input feature (h-stationary accumulation).
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: f32,
}

impl StepScorer {
    pub fn new(d: usize, hidden: usize, w1: Vec<f32>, b1: Vec<f32>, w2: Vec<f32>, b2: f32) -> Result<Self> {
        if w1.len() != d * hidden || b1.len() != hidden || w2.len() != hidden {
            bail!(
                "scorer shape mismatch: d={d} hidden={hidden} w1={} b1={} w2={}",
                w1.len(),
                b1.len(),
                w2.len()
            );
        }
        Ok(StepScorer { d, hidden, w1, b1, w2, b2 })
    }

    /// Load from the JSON bundle `python/compile/scorer.py` exports.
    pub fn from_json(blob: &Json) -> Result<Self> {
        let d = blob.get("d").as_usize().context("scorer json: d")?;
        let hidden = blob.get("hidden").as_usize().context("scorer json: hidden")?;
        let w1 = blob.get("w1").as_f32_vec().context("scorer json: w1")?;
        let b1 = blob.get("b1").as_f32_vec().context("scorer json: b1")?;
        let w2 = blob.get("w2").as_f32_vec().context("scorer json: w2")?;
        let b2 = blob.get("b2").as_f32_vec().context("scorer json: b2")?;
        StepScorer::new(d, hidden, w1, b1, w2, *b2.first().context("b2 empty")?)
    }

    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scorer bundle {path:?}"))?;
        let blob = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&blob)
    }

    /// Score one hidden state -> correctness probability.
    pub fn score(&self, h: &[f32]) -> f32 {
        let mut z = vec![0.0f32; self.hidden];
        self.score_into(h, &mut z)
    }

    /// Allocation-free scoring using caller scratch (`z.len() == hidden`)
    /// — the DES hot path calls this ~1e4 times per simulated question.
    pub fn score_into(&self, h: &[f32], z: &mut [f32]) -> f32 {
        debug_assert_eq!(h.len(), self.d);
        debug_assert_eq!(z.len(), self.hidden);
        z.copy_from_slice(&self.b1);
        // z += W1^T h, h-stationary: input features walk contiguous rows.
        // Two-feature unroll keeps two independent FMA chains in flight.
        let mut j = 0;
        while j + 1 < self.d {
            let hj0 = h[j];
            let hj1 = h[j + 1];
            let row0 = &self.w1[j * self.hidden..(j + 1) * self.hidden];
            let row1 = &self.w1[(j + 1) * self.hidden..(j + 2) * self.hidden];
            for ((zi, &w0), &w1) in z.iter_mut().zip(row0).zip(row1) {
                *zi += hj0 * w0 + hj1 * w1;
            }
            j += 2;
        }
        if j < self.d {
            let hj = h[j];
            let row = &self.w1[j * self.hidden..(j + 1) * self.hidden];
            for (zi, &wij) in z.iter_mut().zip(row) {
                *zi += hj * wij;
            }
        }
        let mut logit = self.b2;
        for (zi, &w2i) in z.iter().zip(&self.w2) {
            if *zi > 0.0 {
                logit += *zi * w2i;
            }
        }
        sigmoid(logit)
    }

    /// Batched scoring (the engine scores all boundary-crossing traces of
    /// an iteration together).
    pub fn score_batch(&self, hs: &[Vec<f32>]) -> Vec<f32> {
        hs.iter().map(|h| self.score(h)).collect()
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> StepScorer {
        // d=2, hidden=2: z = relu([h0+h1, h0-h1]), logit = z0 - 0.5 z1.
        StepScorer::new(
            2,
            2,
            vec![1.0, 1.0, 1.0, -1.0],
            vec![0.0, 0.0],
            vec![1.0, -0.5],
            0.0,
        )
        .unwrap()
    }

    #[test]
    fn matches_hand_computation() {
        let s = tiny();
        // h = [1, 2]: z = relu([3, -1]) = [3, 0], logit = 3.
        assert!((s.score(&[1.0, 2.0]) - sigmoid(3.0)).abs() < 1e-6);
        // h = [2, 1]: z = [3, 1], logit = 3 - 0.5 = 2.5.
        assert!((s.score(&[2.0, 1.0]) - sigmoid(2.5)).abs() < 1e-6);
    }

    #[test]
    fn shape_validation() {
        assert!(StepScorer::new(2, 2, vec![0.0; 3], vec![0.0; 2], vec![0.0; 2], 0.0).is_err());
        assert!(StepScorer::new(2, 2, vec![0.0; 4], vec![0.0; 1], vec![0.0; 2], 0.0).is_err());
    }

    #[test]
    fn from_json_roundtrip() {
        let blob = Json::parse(
            r#"{"d": 2, "hidden": 2, "w1": [1,1,1,-1], "b1": [0,0],
                "w2": [1,-0.5], "b2": [0]}"#,
        )
        .unwrap();
        let s = StepScorer::from_json(&blob).unwrap();
        assert!((s.score(&[1.0, 2.0]) - tiny().score(&[1.0, 2.0])).abs() < 1e-7);
    }

    #[test]
    fn batch_matches_single() {
        let s = tiny();
        let hs = vec![vec![1.0, 2.0], vec![2.0, 1.0], vec![-1.0, -1.0]];
        let batch = s.score_batch(&hs);
        for (h, &b) in hs.iter().zip(&batch) {
            assert_eq!(s.score(h), b);
        }
    }

    #[test]
    fn probability_range() {
        let s = tiny();
        for h in [[-100.0, 0.0], [100.0, 100.0], [0.0, 0.0]] {
            let p = s.score(&h);
            assert!((0.0..=1.0).contains(&p));
        }
    }
}
