//! The step scorer (paper §4.1): a 2-layer MLP over step-boundary hidden
//! states, trained at build time (python/compile/scorer.py, Appendix-A
//! recipe) and executed here on the decode hot path.
//!
//! Two execution paths exist and are cross-validated in tests:
//!   * [`StepScorer::score`] — native f32 matvec (the production hot
//!     path; App. D bounds its cost at < 1e-6 of an LLM step).
//!   * the AOT `scorer_d{D}_b{B}.hlo.txt` graphs via `runtime::` (used by
//!     the e2e engine, where the hidden states already live on device).

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Native MLP: sigmoid(w2 . relu(W1 h + b1) + b2).
#[derive(Debug, Clone)]
pub struct StepScorer {
    /// Input dimension (the model's last-layer hidden size).
    pub d: usize,
    /// Hidden width of the MLP.
    pub hidden: usize,
    /// Row-major [d][hidden] — laid out so the inner loop walks
    /// contiguous memory per input feature (h-stationary accumulation).
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: f32,
}

impl StepScorer {
    /// Build from raw weights; validates the shapes.
    pub fn new(d: usize, hidden: usize, w1: Vec<f32>, b1: Vec<f32>, w2: Vec<f32>, b2: f32) -> Result<Self> {
        if w1.len() != d * hidden || b1.len() != hidden || w2.len() != hidden {
            bail!(
                "scorer shape mismatch: d={d} hidden={hidden} w1={} b1={} w2={}",
                w1.len(),
                b1.len(),
                w2.len()
            );
        }
        Ok(StepScorer { d, hidden, w1, b1, w2, b2 })
    }

    /// Load from the JSON bundle `python/compile/scorer.py` exports.
    pub fn from_json(blob: &Json) -> Result<Self> {
        let d = blob.get("d").as_usize().context("scorer json: d")?;
        let hidden = blob.get("hidden").as_usize().context("scorer json: hidden")?;
        let w1 = blob.get("w1").as_f32_vec().context("scorer json: w1")?;
        let b1 = blob.get("b1").as_f32_vec().context("scorer json: b1")?;
        let w2 = blob.get("w2").as_f32_vec().context("scorer json: w2")?;
        let b2 = blob.get("b2").as_f32_vec().context("scorer json: b2")?;
        StepScorer::new(d, hidden, w1, b1, w2, *b2.first().context("b2 empty")?)
    }

    /// Load a scorer bundle from a JSON file on disk.
    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scorer bundle {path:?}"))?;
        let blob = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&blob)
    }

    /// Score one hidden state -> correctness probability.
    #[deprecated(
        note = "allocates per call; use `score_into` with caller scratch \
                (or the `coordinator::signal::TraceSignal` trait)"
    )]
    pub fn score(&self, h: &[f32]) -> f32 {
        let mut z = vec![0.0f32; self.hidden];
        self.score_into(h, &mut z)
    }

    /// Allocation-free scoring using caller scratch (`z.len() == hidden`)
    /// — the DES hot path calls this ~1e4 times per simulated question.
    pub fn score_into(&self, h: &[f32], z: &mut [f32]) -> f32 {
        debug_assert_eq!(h.len(), self.d);
        debug_assert_eq!(z.len(), self.hidden);
        z.copy_from_slice(&self.b1);
        // z += W1^T h, h-stationary: input features walk contiguous rows.
        // Two-feature unroll keeps two independent FMA chains in flight.
        let mut j = 0;
        while j + 1 < self.d {
            let hj0 = h[j];
            let hj1 = h[j + 1];
            let row0 = &self.w1[j * self.hidden..(j + 1) * self.hidden];
            let row1 = &self.w1[(j + 1) * self.hidden..(j + 2) * self.hidden];
            for ((zi, &w0), &w1) in z.iter_mut().zip(row0).zip(row1) {
                *zi += hj0 * w0 + hj1 * w1;
            }
            j += 2;
        }
        if j < self.d {
            let hj = h[j];
            let row = &self.w1[j * self.hidden..(j + 1) * self.hidden];
            for (zi, &wij) in z.iter_mut().zip(row) {
                *zi += hj * wij;
            }
        }
        let mut logit = self.b2;
        for (zi, &w2i) in z.iter().zip(&self.w2) {
            if *zi > 0.0 {
                logit += *zi * w2i;
            }
        }
        sigmoid(logit)
    }

    /// Batched scoring for trace-sweep callers that score many hidden
    /// states at once (the Fig-5 RankAcc harness scores every step of
    /// 256 traces per question). Processes inputs in tiles of
    /// [`Self::BATCH_TILE`] so every row-major `w1` row is loaded from
    /// memory once per tile instead of once per input, with bias and
    /// ReLU fused into the activation init / final reduction. Arithmetic
    /// order per element is identical to [`StepScorer::score`], so the
    /// batched path is bit-exact with the one-at-a-time path.
    #[deprecated(
        note = "allocates per call; use `score_batch_into` with caller buffers \
                (or the `coordinator::signal::TraceSignal` trait)"
    )]
    pub fn score_batch(&self, hs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(hs.len());
        let mut z = Vec::new();
        self.score_batch_into(hs, &mut out, &mut z);
        out
    }

    /// Tile width of the batched path: large enough to amortize the w1
    /// stream, small enough that the z tile stays L1-resident
    /// (8 x hidden=512 x 4 B = 16 KB).
    pub const BATCH_TILE: usize = 8;

    /// Batched scoring into caller-owned buffers (`out` is cleared, `z`
    /// is the activation-tile scratch, resized on demand), so hot-path
    /// callers reuse both allocations across iterations.
    pub fn score_batch_into(&self, hs: &[Vec<f32>], out: &mut Vec<f32>, z: &mut Vec<f32>) {
        out.clear();
        let m = self.hidden;
        z.resize(m * Self::BATCH_TILE, 0.0);
        for tile in hs.chunks(Self::BATCH_TILE) {
            for (r, h) in tile.iter().enumerate() {
                debug_assert_eq!(h.len(), self.d);
                z[r * m..(r + 1) * m].copy_from_slice(&self.b1);
            }
            // z_r += W1^T h_r, feature-pair outer loop: each pair of w1
            // rows streams once and is reused by every input in the tile.
            let mut j = 0;
            while j + 1 < self.d {
                let row0 = &self.w1[j * m..(j + 1) * m];
                let row1 = &self.w1[(j + 1) * m..(j + 2) * m];
                for (r, h) in tile.iter().enumerate() {
                    let hj0 = h[j];
                    let hj1 = h[j + 1];
                    let zr = &mut z[r * m..(r + 1) * m];
                    for ((zi, &w0), &w1v) in zr.iter_mut().zip(row0).zip(row1) {
                        *zi += hj0 * w0 + hj1 * w1v;
                    }
                }
                j += 2;
            }
            if j < self.d {
                let row = &self.w1[j * m..(j + 1) * m];
                for (r, h) in tile.iter().enumerate() {
                    let hj = h[j];
                    for (zi, &wij) in z[r * m..(r + 1) * m].iter_mut().zip(row) {
                        *zi += hj * wij;
                    }
                }
            }
            for (r, _) in tile.iter().enumerate() {
                let mut logit = self.b2;
                for (zi, &w2i) in z[r * m..(r + 1) * m].iter().zip(&self.w2) {
                    if *zi > 0.0 {
                        logit += *zi * w2i;
                    }
                }
                out.push(sigmoid(logit));
            }
        }
    }
}

/// Logistic sigmoid (the scorer's output squash).
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The non-deprecated singular path with throwaway scratch.
    fn score1(s: &StepScorer, h: &[f32]) -> f32 {
        let mut z = vec![0.0f32; s.hidden];
        s.score_into(h, &mut z)
    }

    /// The non-deprecated batch path with throwaway buffers.
    fn batch(s: &StepScorer, hs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = Vec::new();
        let mut z = Vec::new();
        s.score_batch_into(hs, &mut out, &mut z);
        out
    }

    fn tiny() -> StepScorer {
        // d=2, hidden=2: z = relu([h0+h1, h0-h1]), logit = z0 - 0.5 z1.
        StepScorer::new(
            2,
            2,
            vec![1.0, 1.0, 1.0, -1.0],
            vec![0.0, 0.0],
            vec![1.0, -0.5],
            0.0,
        )
        .unwrap()
    }

    #[test]
    fn matches_hand_computation() {
        let s = tiny();
        // h = [1, 2]: z = relu([3, -1]) = [3, 0], logit = 3.
        assert!((score1(&s, &[1.0, 2.0]) - sigmoid(3.0)).abs() < 1e-6);
        // h = [2, 1]: z = [3, 1], logit = 3 - 0.5 = 2.5.
        assert!((score1(&s, &[2.0, 1.0]) - sigmoid(2.5)).abs() < 1e-6);
    }

    #[test]
    fn shape_validation() {
        assert!(StepScorer::new(2, 2, vec![0.0; 3], vec![0.0; 2], vec![0.0; 2], 0.0).is_err());
        assert!(StepScorer::new(2, 2, vec![0.0; 4], vec![0.0; 1], vec![0.0; 2], 0.0).is_err());
    }

    #[test]
    fn from_json_roundtrip() {
        let blob = Json::parse(
            r#"{"d": 2, "hidden": 2, "w1": [1,1,1,-1], "b1": [0,0],
                "w2": [1,-0.5], "b2": [0]}"#,
        )
        .unwrap();
        let s = StepScorer::from_json(&blob).unwrap();
        assert!((score1(&s, &[1.0, 2.0]) - score1(&tiny(), &[1.0, 2.0])).abs() < 1e-7);
    }

    #[test]
    fn batch_matches_single() {
        let s = tiny();
        let hs = vec![vec![1.0, 2.0], vec![2.0, 1.0], vec![-1.0, -1.0]];
        let batch = batch(&s, &hs);
        for (h, &b) in hs.iter().zip(&batch) {
            assert_eq!(score1(&s, h), b);
        }
    }

    #[test]
    fn batch_matches_single_across_tiles_and_odd_d() {
        // d=3 exercises the odd-feature tail; 19 inputs span three tiles
        // (8 + 8 + 3) of the fused path.
        let s = StepScorer::new(
            3,
            4,
            (0..12).map(|i| (i as f32 * 0.37).sin()).collect(),
            vec![0.05, -0.1, 0.0, 0.2],
            vec![0.9, -0.4, 0.3, -0.2],
            0.1,
        )
        .unwrap();
        let hs: Vec<Vec<f32>> = (0..19)
            .map(|i| (0..3).map(|j| ((i * 3 + j) as f32 * 0.61).cos()).collect())
            .collect();
        let batch = batch(&s, &hs);
        assert_eq!(batch.len(), 19);
        for (h, &b) in hs.iter().zip(&batch) {
            assert_eq!(score1(&s, h), b, "batched path must be bit-exact");
        }
    }

    #[test]
    fn probability_range() {
        let s = tiny();
        for h in [[-100.0, 0.0], [100.0, 100.0], [0.0, 0.0]] {
            let p = score1(&s, &h);
            assert!((0.0..=1.0).contains(&p));
        }
    }
}
