//! The e2e serving engine: the same STEP policy stack (step scoring,
//! memory-triggered pruning, weighted voting) running over the *real*
//! AOT-compiled tiny transformer via PJRT — no simulation anywhere on
//! this path. Proves the three layers compose: rust coordinator (L3) ->
//! jax-lowered decode graph (L2) -> Pallas decode-attention + scorer
//! kernels (L1).
//!
//! One request = one prompt fanned out into N traces decoded as one
//! static PJRT batch group (lane-per-trace). Finished/pruned lanes are
//! masked (their outputs ignored, their cache slot frozen). The KV block
//! budget is virtual — small enough to exercise the paper's §4.2 memory
//! trigger at demo scale.
//!
//! This engine serves one request at a time (the lifecycle of
//! `coordinator::request` collapses to Queued -> Running -> Complete
//! per call). The multi-request regime — concurrent requests, shared
//! KV pool, cross-request pruning, SLO metrics — lives in
//! `sim::serve` (`step serve-sim`); porting its scheduler onto this
//! PJRT backend is the natural next step for the e2e path.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::method::Method;
use crate::coordinator::scorer::StepScorer;
use crate::coordinator::trace::{TraceState, TraceStatus};
use crate::coordinator::voting::{weighted_vote, Vote};
use crate::kvcache::KvCacheManager;
use crate::model::{sample, SamplerConfig, Tokenizer};
use crate::runtime::{DecodeExec, PrefillExec, Runtime, ScorerExec};
use crate::sim::verifier;
use crate::util::rng::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Traces per request (<= the largest compiled decode batch).
    pub n_traces: usize,
    pub method: Method,
    pub max_new_tokens: usize,
    /// Virtual KV budget in blocks (small => the memory trigger fires).
    pub kv_blocks: usize,
    pub block_size: usize,
    pub sampler: SamplerConfig,
    /// Logit biases applied before sampling (token id, bias). The e2e
    /// demo model is random-init, so the serving-standard logit-bias
    /// knob is what makes structural tokens (step boundary, EOS,
    /// answer digits) reachable at realistic rates.
    pub logit_bias: Vec<(i32, f32)>,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_traces: 8,
            method: Method::Step,
            max_new_tokens: 160,
            kv_blocks: 80,
            block_size: 16,
            sampler: SamplerConfig::default(),
            logit_bias: Self::default_bias(),
            seed: 0,
        }
    }
}

impl ServeConfig {
    /// Structural-token biases giving ~6%/token step boundaries (a step
    /// every ~16 tokens), ~1%/token EOS (~100-token traces) and frequent
    /// digits — the tiny-LM analogue of a reasoning model's token mix.
    pub fn default_bias() -> Vec<(i32, f32)> {
        use crate::model::tokenizer::{DIGIT_BASE, EOS, STEP};
        let mut b = vec![(STEP, 4.0), (EOS, 2.3)];
        for d in 0..10 {
            b.push((DIGIT_BASE + d, 1.2));
        }
        b
    }
}

/// Per-trace outcome of a served request.
#[derive(Debug, Clone)]
pub struct ServedTrace {
    pub status: TraceStatus,
    pub generated: usize,
    pub steps_scored: usize,
    pub final_score: f64,
    pub answer: Option<String>,
}

/// Outcome of one served request.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub answer: Option<String>,
    pub correct: Option<bool>,
    pub latency_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub scoring_s: f64,
    pub generated_tokens: usize,
    pub decode_iterations: usize,
    pub pruned: usize,
    pub traces: Vec<ServedTrace>,
}

impl ServedRequest {
    pub fn tokens_per_second(&self) -> f64 {
        self.generated_tokens as f64 / self.latency_s.max(1e-9)
    }
}

/// The serving engine (owns the runtime + compiled graphs).
pub struct ServeEngine {
    pub cfg: ServeConfig,
    rt_model: ModelHandles,
    tokenizer: Tokenizer,
    scorer_native: StepScorer,
    max_len: usize,
    prompt_len: usize,
}

struct ModelHandles {
    params: Vec<xla::Literal>,
    prefill: PrefillExec,
    decode: DecodeExec,
    scorer: ScorerExec,
    group: usize,
}

impl ServeEngine {
    /// Load artifacts and compile the graph variants for the group size.
    pub fn new(mut rt: Runtime, cfg: ServeConfig) -> Result<ServeEngine> {
        let m = rt.artifacts.manifest.model;
        let group = *rt
            .artifacts
            .manifest
            .decode_batches
            .iter()
            .filter(|&&b| b >= cfg.n_traces)
            .min()
            .with_context(|| {
                format!("no decode graph variant fits n_traces={}", cfg.n_traces)
            })?;
        if !rt.artifacts.manifest.prefill_batches.contains(&group) {
            bail!("no prefill graph for batch {group}");
        }
        let params = rt.param_literals()?;
        let prefill = PrefillExec::load(&mut rt, group)?;
        let decode = DecodeExec::load(&mut rt, group)?;
        let scorer = ScorerExec::load(&mut rt, "e2e", 8)?;
        let scorer_native =
            StepScorer::from_json_file(&rt.artifacts.scorer_path("e2e")?)?;
        Ok(ServeEngine {
            cfg,
            rt_model: ModelHandles { params, prefill, decode, scorer, group },
            tokenizer: Tokenizer::new(m.vocab),
            scorer_native,
            max_len: m.max_len,
            prompt_len: m.prompt_len,
        })
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// Serve one request: fan the prompt into N traces, decode with the
    /// configured policy, vote.
    pub fn serve(&self, prompt: &str, ground_truth: Option<&str>) -> Result<ServedRequest> {
        let t_start = Instant::now();
        let h = &self.rt_model;
        let group = h.group;
        let n = self.cfg.n_traces.min(group);
        let mut rng = Rng::new(self.cfg.seed);

        // ---- prefill (identical prompt in every lane).
        let ids = self.tokenizer.encode(prompt);
        if ids.len() > self.prompt_len {
            bail!("prompt too long: {} > {}", ids.len(), self.prompt_len);
        }
        let mut flat = vec![tokenizerpad(); group * self.prompt_len];
        for b in 0..group {
            flat[b * self.prompt_len..b * self.prompt_len + ids.len()]
                .copy_from_slice(&ids);
        }
        let lens = vec![ids.len(); group];
        let t0 = Instant::now();
        let (logits0, _hidden0, mut kv) = h.prefill.run(&h.params, &flat, &lens)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        // ---- per-lane state.
        let mut kvm = KvCacheManager::new(self.cfg.kv_blocks, self.cfg.block_size);
        let mut traces: Vec<TraceState> =
            (0..n).map(|i| TraceState::new(i as u64, 8)).collect();
        let mut gen_tokens: Vec<Vec<i32>> = vec![Vec::new(); n];
        for t in traces.iter() {
            if !kvm.allocate_seq(t.id, ids.len()) {
                bail!("kv budget too small for the prompt");
            }
        }

        // First sampled token per lane (from prefill logits).
        let bias = |logits: &[f32]| -> Vec<f32> {
            let mut l = logits.to_vec();
            for &(t, b) in &self.cfg.logit_bias {
                if (t as usize) < l.len() {
                    l[t as usize] += b;
                }
            }
            l
        };
        let mut cur_tok = vec![tokenizerpad(); group];
        let mut cur_pos = vec![(ids.len() - 1) as i32; group];
        for (i, trace) in traces.iter().enumerate() {
            let mut lane_rng = rng.fork(trace.id);
            cur_tok[i] = sample(&bias(&logits0[i]), &self.cfg.sampler, &mut lane_rng) as i32;
            cur_pos[i] = ids.len() as i32;
        }

        // ---- decode loop.
        let mut decode_s = 0.0;
        let mut scoring_s = 0.0;
        let mut iterations = 0usize;
        let mut pruned = 0usize;
        let mut lane_rngs: Vec<Rng> = (0..n).map(|i| rng.fork(1000 + i as u64)).collect();

        while traces.iter().any(|t| t.status == TraceStatus::Running) {
            // Memory trigger (paper §4.2): if advancing the running lanes
            // one token does not fit, prune the lowest-scored lane.
            let running_ids: Vec<u64> = traces
                .iter()
                .filter(|t| t.status == TraceStatus::Running)
                .map(|t| t.id)
                .collect();
            if !kvm.can_step_all(&running_ids) {
                if self.cfg.method == Method::Step {
                    let victim = traces
                        .iter()
                        .enumerate()
                        .filter(|(_, t)| t.status == TraceStatus::Running)
                        .min_by(|a, b| {
                            a.1.mean_score(0.5)
                                .partial_cmp(&b.1.mean_score(0.5))
                                .unwrap()
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    traces[victim].status = TraceStatus::Pruned;
                    kvm.free_seq(victim as u64);
                    pruned += 1;
                    continue;
                } else {
                    // SC with a static group cannot preempt: stop lanes at
                    // the budget (documented demo limitation).
                    for t in traces.iter_mut() {
                        if t.status == TraceStatus::Running {
                            t.status = TraceStatus::Finished;
                        }
                    }
                    break;
                }
            }

            let t0 = Instant::now();
            let (logits, hidden, kv2) =
                h.decode.run(&h.params, &kv, &cur_tok, &cur_pos)?;
            kv = kv2;
            decode_s += t0.elapsed().as_secs_f64();
            iterations += 1;

            // Batched scoring of lanes that just emitted a step boundary.
            let boundary_lanes: Vec<usize> = (0..n)
                .filter(|&i| {
                    traces[i].status == TraceStatus::Running
                        && self.tokenizer.is_step(cur_tok[i])
                })
                .collect();
            let scores = if boundary_lanes.is_empty() {
                Vec::new()
            } else {
                let t0 = Instant::now();
                let d = h.scorer.d;
                let mut hbuf = vec![0.0f32; h.scorer.batch * d];
                for (slot, &lane) in boundary_lanes.iter().enumerate() {
                    hbuf[slot * d..(slot + 1) * d].copy_from_slice(&hidden[lane]);
                }
                let s = h.scorer.run(&hbuf)?;
                scoring_s += t0.elapsed().as_secs_f64();
                s
            };
            for (slot, &lane) in boundary_lanes.iter().enumerate() {
                traces[lane].push_score(scores[slot] as f64);
                // Cross-check the HLO scorer against the native MLP (the
                // two must agree; debug builds verify).
                debug_assert!({
                    let mut z = vec![0.0f32; self.scorer_native.hidden];
                    (scores[slot] - self.scorer_native.score_into(&hidden[lane], &mut z))
                        .abs()
                        < 1e-3
                });
            }

            // Advance lanes.
            for i in 0..n {
                if traces[i].status != TraceStatus::Running {
                    continue;
                }
                let tok = cur_tok[i];
                gen_tokens[i].push(tok);
                traces[i].generated += 1;
                let appended = kvm.append_tokens(traces[i].id, 1);
                debug_assert!(appended);
                let next_pos = cur_pos[i] + 1;
                let done = self.tokenizer.is_eos(tok)
                    || traces[i].generated as usize >= self.cfg.max_new_tokens
                    || next_pos as usize >= self.max_len;
                if done {
                    traces[i].status = TraceStatus::Finished;
                    kvm.free_seq(traces[i].id);
                    continue;
                }
                cur_tok[i] = sample(&bias(&logits[i]), &self.cfg.sampler, &mut lane_rngs[i]) as i32;
                cur_pos[i] = next_pos;
            }
        }

        // ---- voting (score-weighted for STEP, majority otherwise).
        let votes: Vec<Vote> = traces
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == TraceStatus::Finished)
            .map(|(i, t)| {
                let ans = self.tokenizer.extract_answer(&gen_tokens[i]);
                Vote {
                    answer: ans.as_deref().map(answer_key),
                    weight: if self.cfg.method == Method::Step {
                        t.mean_score(0.5)
                    } else {
                        1.0
                    },
                }
            })
            .collect();
        let winner_key = weighted_vote(&votes);
        let answer = traces.iter().enumerate().find_map(|(i, t)| {
            if t.status != TraceStatus::Finished {
                return None;
            }
            let a = self.tokenizer.extract_answer(&gen_tokens[i])?;
            (Some(answer_key(&a)) == winner_key).then_some(a)
        });
        let correct = match (&answer, ground_truth) {
            (Some(a), Some(gt)) => Some(verifier::verify(a, gt)),
            _ => ground_truth.map(|_| false),
        };

        Ok(ServedRequest {
            answer,
            correct,
            latency_s: t_start.elapsed().as_secs_f64(),
            prefill_s,
            decode_s,
            scoring_s,
            generated_tokens: traces.iter().map(|t| t.generated as usize).sum(),
            decode_iterations: iterations,
            pruned,
            traces: traces
                .iter()
                .enumerate()
                .map(|(i, t)| ServedTrace {
                    status: t.status,
                    generated: t.generated as usize,
                    steps_scored: t.scored_steps(),
                    final_score: t.mean_score(0.5),
                    answer: self.tokenizer.extract_answer(&gen_tokens[i]),
                })
                .collect(),
        })
    }
}

fn tokenizerpad() -> i32 {
    crate::model::tokenizer::PAD
}

/// Stable numeric key for an answer string (voting groups by value).
fn answer_key(a: &str) -> u32 {
    match verifier::parse_answer(a) {
        Some(verifier::AnswerValue::Rational(p, q)) => {
            (p.rem_euclid(65_521) as u32) << 16 | (q.rem_euclid(65_521) as u32) & 0xFFFF
        }
        None => u32::MAX,
    }
}
