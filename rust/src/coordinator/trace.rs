//! Per-trace runtime state shared by the simulation and e2e engines:
//! lifecycle, running-mean step scores (paper §4.3's score_t), DeepConf
//! sliding-window confidence, and wait/decode time accounting (Fig. 2c /
//! Table 3).

/// Lifecycle of a reasoning trace inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceStatus {
    /// Decoding normally.
    Running,
    /// Preempted by the memory manager; KV freed, waiting to resume
    /// (vLLM recompute-on-resume). Only the SC-family baselines enter
    /// this state — STEP's trigger exists to make it unreachable.
    Preempted,
    /// Completed naturally (EOS / length).
    Finished,
    /// Removed by a pruning policy (STEP lowest-score / Slim-SC similar).
    Pruned,
    /// DeepConf early termination (confidence under threshold).
    EarlyStopped,
}

impl TraceStatus {
    /// Still consuming scheduler attention (running or waiting to run)?
    pub fn is_active(&self) -> bool {
        matches!(self, TraceStatus::Running | TraceStatus::Preempted)
    }
}

/// Running-mean score accumulator + bookkeeping for one trace.
#[derive(Debug, Clone)]
pub struct TraceState {
    /// Sequence id in the KV manager.
    pub id: u64,
    /// Lifecycle state.
    pub status: TraceStatus,
    /// Tokens generated so far (excludes prompt).
    pub generated: u64,
    /// Index of the next un-crossed step boundary.
    pub next_step: usize,
    /// Sum / count of step scores (paper: score_t = mean of step scores).
    score_sum: f64,
    score_cnt: usize,
    /// Latest step score + exponential moving average (ablation
    /// alternatives to the paper's running mean, §4.3).
    last_score: f64,
    ema_score: f64,
    /// Accumulator of the current (non-overlapping) confidence group —
    /// DeepConf's ~2k-token "group confidence" maps to one group per
    /// `conf_window_cap` steps.
    conf_group_sum: f64,
    conf_group_cnt: usize,
    conf_window_cap: usize,
    /// Most recently completed group confidence.
    last_group_conf: Option<f64>,
    conf_sum_all: f64,
    conf_cnt_all: usize,
    /// Lowest completed group confidence (DeepConf's per-trace "lowest
    /// group confidence" statistic).
    min_window_conf: f64,
    /// Seconds spent decoding (running).
    pub decode_time: f64,
    /// Seconds spent waiting (preempted / resume recompute).
    pub wait_time: f64,
    /// Engine clock when the trace left the active set.
    pub finish_clock: f64,
    /// Number of times this trace was preempted.
    pub preemptions: usize,
}

impl TraceState {
    /// Fresh running trace; `conf_window_cap` is DeepConf's group size
    /// in steps.
    pub fn new(id: u64, conf_window_cap: usize) -> TraceState {
        TraceState {
            id,
            status: TraceStatus::Running,
            generated: 0,
            next_step: 0,
            score_sum: 0.0,
            score_cnt: 0,
            last_score: f64::NAN,
            ema_score: f64::NAN,
            conf_group_sum: 0.0,
            conf_group_cnt: 0,
            conf_window_cap,
            last_group_conf: None,
            conf_sum_all: 0.0,
            conf_cnt_all: 0,
            min_window_conf: f64::INFINITY,
            decode_time: 0.0,
            wait_time: 0.0,
            finish_clock: 0.0,
            preemptions: 0,
        }
    }

    /// Record a step score (paper §4.3 running average).
    pub fn push_score(&mut self, s: f64) {
        self.score_sum += s;
        self.score_cnt += 1;
        self.last_score = s;
        self.ema_score = if self.ema_score.is_nan() {
            s
        } else {
            0.85 * self.ema_score + 0.15 * s
        };
    }

    /// Latest step score (ablation: no averaging).
    pub fn last_score(&self, default: f64) -> f64 {
        if self.last_score.is_nan() { default } else { self.last_score }
    }

    /// EMA of step scores (ablation: recency-weighted averaging).
    pub fn ema_score(&self, default: f64) -> f64 {
        if self.ema_score.is_nan() { default } else { self.ema_score }
    }

    /// score_t: running mean; `default` before any boundary was scored.
    pub fn mean_score(&self, default: f64) -> f64 {
        if self.score_cnt == 0 {
            default
        } else {
            self.score_sum / self.score_cnt as f64
        }
    }

    /// Number of step boundaries scored so far.
    pub fn scored_steps(&self) -> usize {
        self.score_cnt
    }

    /// Record a step confidence. Returns the group confidence when this
    /// step completes a (non-overlapping) group — the moment DeepConf's
    /// online check fires.
    pub fn push_confidence(&mut self, c: f64) -> Option<f64> {
        self.conf_sum_all += c;
        self.conf_cnt_all += 1;
        self.conf_group_sum += c;
        self.conf_group_cnt += 1;
        if self.conf_group_cnt == self.conf_window_cap {
            let w = self.conf_group_sum / self.conf_window_cap as f64;
            self.conf_group_sum = 0.0;
            self.conf_group_cnt = 0;
            self.last_group_conf = Some(w);
            if w < self.min_window_conf {
                self.min_window_conf = w;
            }
            Some(w)
        } else {
            None
        }
    }

    /// Lowest completed group confidence; None until one group completed.
    pub fn min_window_confidence(&self) -> Option<f64> {
        self.min_window_conf.is_finite().then_some(self.min_window_conf)
    }

    /// Most recently completed group confidence.
    pub fn window_confidence(&self) -> Option<f64> {
        self.last_group_conf
    }

    /// Whole-trace mean confidence (DeepConf's voting weight).
    pub fn mean_confidence(&self, default: f64) -> f64 {
        if self.conf_cnt_all == 0 {
            default
        } else {
            self.conf_sum_all / self.conf_cnt_all as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_running_mean() {
        let mut t = TraceState::new(1, 4);
        assert_eq!(t.mean_score(0.5), 0.5);
        t.push_score(1.0);
        t.push_score(0.0);
        assert_eq!(t.mean_score(0.5), 0.5);
        t.push_score(1.0);
        assert!((t.mean_score(0.5) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(t.scored_steps(), 3);
    }

    #[test]
    fn score_aggregation_variants() {
        let mut t = TraceState::new(1, 4);
        assert_eq!(t.last_score(0.5), 0.5);
        assert_eq!(t.ema_score(0.5), 0.5);
        t.push_score(1.0);
        assert_eq!(t.last_score(0.5), 1.0);
        assert_eq!(t.ema_score(0.5), 1.0);
        t.push_score(0.0);
        assert_eq!(t.last_score(0.5), 0.0);
        assert!((t.ema_score(0.5) - 0.85).abs() < 1e-12);
    }

    #[test]
    fn confidence_groups_non_overlapping() {
        let mut t = TraceState::new(1, 2);
        assert_eq!(t.window_confidence(), None);
        assert_eq!(t.push_confidence(0.2), None);
        assert_eq!(t.push_confidence(0.4), Some(0.30000000000000004));
        assert_eq!(t.push_confidence(0.8), None); // starts a new group
        assert!((t.window_confidence().unwrap() - 0.3).abs() < 1e-9);
        assert_eq!(t.push_confidence(0.6), Some(0.7));
        assert!((t.min_window_confidence().unwrap() - 0.3).abs() < 1e-9);
        assert!((t.mean_confidence(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn status_activity() {
        assert!(TraceStatus::Running.is_active());
        assert!(TraceStatus::Preempted.is_active());
        assert!(!TraceStatus::Finished.is_active());
        assert!(!TraceStatus::Pruned.is_active());
        assert!(!TraceStatus::EarlyStopped.is_active());
    }
}
