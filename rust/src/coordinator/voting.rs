//! Answer aggregation (paper §4.3 + Table 2): majority voting,
//! score-weighted voting (STEP), and generic weight-carrying voting used
//! for the PRM / confidence-weighted baselines.

use std::collections::HashMap;

/// One vote: a trace's final answer and its aggregation weight.
#[derive(Debug, Clone, Copy)]
pub struct Vote {
    /// None = trace produced no parseable answer (truncated / early
    /// stopped) — abstains.
    pub answer: Option<u32>,
    /// Aggregation weight (1.0 for plain majority voting).
    pub weight: f64,
}

/// Weighted majority vote; ties broken toward the answer with the most
/// raw votes, then the smallest answer id (deterministic).
pub fn weighted_vote(votes: &[Vote]) -> Option<u32> {
    let mut weights: HashMap<u32, (f64, usize)> = HashMap::new();
    for v in votes {
        if let Some(a) = v.answer {
            let e = weights.entry(a).or_insert((0.0, 0));
            e.0 += v.weight.max(0.0);
            e.1 += 1;
        }
    }
    weights
        .into_iter()
        .max_by(|(a1, (w1, c1)), (a2, (w2, c2))| {
            w1.partial_cmp(w2)
                .unwrap()
                .then(c1.cmp(c2))
                .then(a2.cmp(a1)) // prefer smaller id on full tie
        })
        .map(|(a, _)| a)
}

/// Unweighted majority (self-consistency).
pub fn majority_vote(answers: &[Option<u32>]) -> Option<u32> {
    let votes: Vec<Vote> =
        answers.iter().map(|&answer| Vote { answer, weight: 1.0 }).collect();
    weighted_vote(&votes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(answer: u32, weight: f64) -> Vote {
        Vote { answer: Some(answer), weight }
    }

    #[test]
    fn majority_basic() {
        assert_eq!(majority_vote(&[Some(1), Some(2), Some(1)]), Some(1));
        assert_eq!(majority_vote(&[None, None]), None);
        assert_eq!(majority_vote(&[]), None);
    }

    #[test]
    fn weights_override_counts() {
        // Two low-weight votes for 1 vs one high-weight vote for 2.
        let votes = [v(1, 0.2), v(1, 0.2), v(2, 0.9)];
        assert_eq!(weighted_vote(&votes), Some(2));
    }

    #[test]
    fn abstentions_ignored() {
        let votes = [Vote { answer: None, weight: 5.0 }, v(3, 0.1)];
        assert_eq!(weighted_vote(&votes), Some(3));
    }

    #[test]
    fn tie_breaks_deterministic() {
        let votes = [v(2, 1.0), v(1, 1.0)];
        assert_eq!(weighted_vote(&votes), Some(1));
        // Equal weight, more raw votes wins.
        let votes = [v(2, 0.5), v(2, 0.5), v(1, 1.0)];
        assert_eq!(weighted_vote(&votes), Some(2));
    }

    #[test]
    fn negative_weights_clamped() {
        let votes = [v(1, -3.0), v(2, 0.1)];
        assert_eq!(weighted_vote(&votes), Some(2));
    }
}
