//! L3 coordination: the paper's contribution. Step scoring, trace state,
//! pruning/method policies, and answer aggregation — shared between the
//! discrete-event experiment engine (sim::des) and the PJRT-backed
//! serving engine (coordinator::engine).

/// The PJRT-backed serving engine needs the vendored `xla` crate; see
/// the `pjrt` feature in Cargo.toml.
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod method;
pub mod request;
pub mod scorer;
pub mod signal;
pub mod trace;
pub mod voting;
