//! Pluggable pruning signals: the `TraceSignal` trait and the signal
//! zoo raced by the serving/cluster harnesses.
//!
//! The paper's claim is that *hidden states* are the right early signal
//! for step-level trace pruning. This module makes that claim testable:
//! every engine scores step boundaries through the object-safe
//! [`TraceSignal`] trait, and the trained MLP ([`HiddenMlpSignal`],
//! wrapping [`StepScorer`]) is just the default implementation —
//! byte-identical to the pre-trait hot path, locked by
//! `tests/signal_differential.rs`. Rivals implemented against the same
//! simulated hidden states:
//!
//! * [`LatentTemporalSignal`] — EWMA + slope over the hidden-state
//!   trajectory's projection onto the signal direction (à la *Tracing
//!   the Traces*, arXiv:2510.10494);
//! * [`ConfidenceSignal`] — intrinsic token-confidence gating, no
//!   hidden states at all (à la *Guided by Gut*, arXiv:2505.20325);
//! * [`PrmOracleSignal`] — the simulated process-reward-model score, a
//!   full-trace verifier upper bound (paper Table 2's PRM baseline).
//!
//! **Determinism rules for signal authors.** A signal is a pure
//! function of the [`StepCtx`] it is handed: no interior mutability, no
//! RNG of its own, no clocks — all per-call state lives in the
//! caller-owned [`SignalScratch`], and reusing one scratch across calls
//! must not change any output bit (`scratch_reuse_is_pure` below).
//! Signals must be `Send + Sync` (cluster engines step in parallel
//! sharing the per-GPU signal boxes) and cheaply cloneable through
//! [`TraceSignal::clone_box`] so every per-GPU engine owns an
//! independent instance.
//!
//! Selection is a parsed [`SignalSpec`] (`--signal NAME[:PARAM=V,...]`
//! on `serve-sim` / `cluster-sim`), threaded through `SimConfig` /
//! `ServeSimConfig` / `ClusterConfig` and stamped into step-score and
//! prune [`crate::obs::SimEvent`]s so `step trace-check` attributes
//! prunes per signal.

use std::fmt::Debug;

use crate::coordinator::scorer::{sigmoid, StepScorer};
use crate::sim::tracegen::{Question, TraceGen, TraceSpec};

/// Everything a signal may look at when scoring one step boundary:
/// the deterministic trace generator (the simulated model), the
/// question, the trace, and the 1-based boundary index.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx<'a> {
    /// The trace generator (hidden states, confidences, PRM scores).
    pub gen: &'a TraceGen,
    /// Question the trace answers.
    pub q: &'a Question,
    /// The trace being scored.
    pub spec: &'a TraceSpec,
    /// 1-based step-boundary index (`1..=spec.n_steps()`).
    pub step_n: usize,
}

/// Caller-owned scratch for [`TraceSignal`] calls: hidden-state and
/// activation buffers, resized on demand and reused across calls. All
/// mutable per-call state lives here — signals themselves hold only
/// immutable parameters.
#[derive(Debug, Default, Clone)]
pub struct SignalScratch {
    /// Hidden-state buffer (`gen.gen.d` wide once warm).
    pub h: Vec<f32>,
    /// MLP activation buffer (`scorer.hidden` wide once warm).
    pub z: Vec<f32>,
}

impl SignalScratch {
    /// Empty scratch; buffers warm up on first use.
    pub fn new() -> SignalScratch {
        SignalScratch::default()
    }
}

/// One pruning signal: a pure scoring policy over step boundaries.
///
/// Object-safe so engines hold `Box<dyn TraceSignal>`; `Send + Sync`
/// because the cluster steps per-GPU engines in parallel. See the
/// module docs for the determinism rules implementations must obey.
pub trait TraceSignal: Debug + Send + Sync {
    /// The signal's canonical name (the `--signal` vocabulary, event
    /// stamps, and Pareto-grid labels).
    fn name(&self) -> &'static str;

    /// Score one step boundary → a pruning score in higher-is-better
    /// orientation (the engines prune the argmin aggregate).
    fn score_step(&self, ctx: &StepCtx<'_>, scratch: &mut SignalScratch) -> f32;

    /// Fused batch entry point: score each context in order into `out`
    /// (cleared first). The default loops [`score_step`]
    /// (Self::score_step); implementations may override with a fused
    /// kernel, but must stay bit-identical to the singular path.
    fn score_batch_into(
        &self,
        ctxs: &[StepCtx<'_>],
        scratch: &mut SignalScratch,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        for ctx in ctxs {
            out.push(self.score_step(ctx, scratch));
        }
    }

    /// Cheap clone into a fresh box, so per-GPU engines own independent
    /// instances built from one parsed spec.
    fn clone_box(&self) -> Box<dyn TraceSignal>;
}

impl Clone for Box<dyn TraceSignal> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// The paper's signal: the trained 2-layer MLP over the step-boundary
/// hidden state. This is the default and is byte-identical to the
/// pre-trait hot path (`hidden_state_into` → `score_into`).
#[derive(Debug, Clone)]
pub struct HiddenMlpSignal {
    /// The wrapped MLP.
    pub scorer: StepScorer,
}

impl TraceSignal for HiddenMlpSignal {
    fn name(&self) -> &'static str {
        "hidden-mlp"
    }

    fn score_step(&self, ctx: &StepCtx<'_>, scratch: &mut SignalScratch) -> f32 {
        scratch.h.resize(ctx.gen.gen.d, 0.0);
        scratch.z.resize(self.scorer.hidden, 0.0);
        ctx.gen.hidden_state_into(ctx.q, ctx.spec, ctx.step_n, &mut scratch.h);
        self.scorer.score_into(&scratch.h, &mut scratch.z)
    }

    fn clone_box(&self) -> Box<dyn TraceSignal> {
        Box::new(self.clone())
    }
}

/// Latent-temporal signal (à la arXiv:2510.10494): project the
/// hidden-state trajectory of the last `window` boundaries onto the
/// generator's signal direction, then squash an EWMA of the projections
/// plus a slope term. Trend-following: a trace whose latent quality is
/// still climbing scores above one that plateaued at the same level.
#[derive(Debug, Clone)]
pub struct LatentTemporalSignal {
    /// EWMA decay per step (weight on the newest projection).
    pub lambda: f64,
    /// Weight on the first-to-last slope of the window.
    pub slope: f64,
    /// Trajectory window (boundaries recomputed per call).
    pub window: usize,
}

impl LatentTemporalSignal {
    /// Projection of boundary `n`'s hidden state onto the signal
    /// direction, via the scratch hidden-state buffer.
    fn proj(&self, ctx: &StepCtx<'_>, n: usize, scratch: &mut SignalScratch) -> f64 {
        ctx.gen.hidden_state_into(ctx.q, ctx.spec, n, &mut scratch.h);
        scratch
            .h
            .iter()
            .zip(&ctx.gen.gen.signal_dir)
            .map(|(&hi, &di)| hi as f64 * di as f64)
            .sum()
    }
}

impl TraceSignal for LatentTemporalSignal {
    fn name(&self) -> &'static str {
        "latent-temporal"
    }

    fn score_step(&self, ctx: &StepCtx<'_>, scratch: &mut SignalScratch) -> f32 {
        scratch.h.resize(ctx.gen.gen.d, 0.0);
        let n = ctx.step_n;
        let first = n.saturating_sub(self.window.max(1) - 1).max(1);
        let p0 = self.proj(ctx, first, scratch);
        let mut ewma = p0;
        let mut last = p0;
        for k in (first + 1)..=n {
            last = self.proj(ctx, k, scratch);
            ewma = self.lambda * last + (1.0 - self.lambda) * ewma;
        }
        let span = (n - first).max(1) as f64;
        let slope = (last - p0) / span;
        sigmoid((ewma + self.slope * slope) as f32)
    }

    fn clone_box(&self) -> Box<dyn TraceSignal> {
        Box::new(self.clone())
    }
}

/// Intrinsic-confidence signal (à la arXiv:2505.20325): the simulated
/// mean token confidence of the step, optionally sharpened by `gamma`.
/// Needs no hidden states at all — the cheap rival the Pareto grid
/// races the MLP against.
#[derive(Debug, Clone)]
pub struct ConfidenceSignal {
    /// Sharpening exponent on the confidence (1 = raw).
    pub gamma: f64,
}

impl TraceSignal for ConfidenceSignal {
    fn name(&self) -> &'static str {
        "confidence"
    }

    fn score_step(&self, ctx: &StepCtx<'_>, _scratch: &mut SignalScratch) -> f32 {
        ctx.gen.step_confidence(ctx.spec, ctx.step_n).powf(self.gamma) as f32
    }

    fn clone_box(&self) -> Box<dyn TraceSignal> {
        Box::new(self.clone())
    }
}

/// PRM-oracle upper bound: the simulated full-trace process-reward
/// score, identical at every boundary of one trace. What a perfect(er)
/// whole-trace verifier would buy if it were free at step granularity.
#[derive(Debug, Clone)]
pub struct PrmOracleSignal;

impl TraceSignal for PrmOracleSignal {
    fn name(&self) -> &'static str {
        "prm-oracle"
    }

    fn score_step(&self, ctx: &StepCtx<'_>, _scratch: &mut SignalScratch) -> f32 {
        ctx.gen.prm_score(ctx.spec) as f32
    }

    fn clone_box(&self) -> Box<dyn TraceSignal> {
        Box::new(self.clone())
    }
}

/// The signal families the zoo knows, in [`SIGNAL_NAMES`] order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignalKind {
    /// The paper's trained MLP over hidden states (the default).
    HiddenMlp,
    /// EWMA/slope over the hidden-state trajectory.
    LatentTemporal,
    /// Intrinsic token-confidence gating.
    Confidence,
    /// Full-trace PRM score (oracle upper bound).
    PrmOracle,
}

/// Every signal's canonical name, in [`SignalKind`] order — the
/// `--signal` vocabulary and the event-stamp intern table.
pub const SIGNAL_NAMES: &[&str] =
    &["hidden-mlp", "latent-temporal", "confidence", "prm-oracle"];

impl SignalKind {
    /// The canonical name (stable; `--signal`, event stamps, labels).
    pub fn name(&self) -> &'static str {
        match self {
            SignalKind::HiddenMlp => "hidden-mlp",
            SignalKind::LatentTemporal => "latent-temporal",
            SignalKind::Confidence => "confidence",
            SignalKind::PrmOracle => "prm-oracle",
        }
    }
}

/// A parsed `--signal NAME[:PARAM=V,...]` selection: which signal plus
/// its parameters, with defaults matching the zoo's tuned values.
/// `Default` is the paper's `hidden-mlp`.
#[derive(Debug, Clone, PartialEq)]
pub struct SignalSpec {
    /// Which signal family.
    pub kind: SignalKind,
    /// `latent-temporal` EWMA decay (`lambda`, in (0, 1]).
    pub lambda: f64,
    /// `latent-temporal` slope weight (`slope`).
    pub slope: f64,
    /// `latent-temporal` trajectory window (`window`, >= 1).
    pub window: usize,
    /// `confidence` sharpening exponent (`gamma`, > 0).
    pub gamma: f64,
}

impl Default for SignalSpec {
    fn default() -> Self {
        SignalSpec {
            kind: SignalKind::HiddenMlp,
            lambda: 0.6,
            slope: 4.0,
            window: 8,
            gamma: 1.0,
        }
    }
}

impl SignalSpec {
    /// Parse `NAME[:PARAM=V,...]`. Unknown names list the vocabulary;
    /// a parameter that does not apply to the named signal (or fails
    /// to parse / violates its range) is rejected by name.
    pub fn parse(s: &str) -> Result<SignalSpec, String> {
        let (name, params) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let kind = match name {
            "hidden-mlp" => SignalKind::HiddenMlp,
            "latent-temporal" => SignalKind::LatentTemporal,
            "confidence" => SignalKind::Confidence,
            "prm-oracle" => SignalKind::PrmOracle,
            other => {
                return Err(format!(
                    "unknown signal '{other}' (expected one of: {})",
                    SIGNAL_NAMES.join(", ")
                ))
            }
        };
        let mut spec = SignalSpec { kind, ..SignalSpec::default() };
        let Some(params) = params else { return Ok(spec) };
        for kv in params.split(',').filter(|kv| !kv.is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| format!("signal param '{kv}' is not PARAM=V"))?;
            let f = || -> Result<f64, String> {
                val.parse::<f64>()
                    .map_err(|e| format!("signal param '{key}': bad value '{val}': {e}"))
            };
            match (kind, key) {
                (SignalKind::LatentTemporal, "lambda") => {
                    spec.lambda = f()?;
                    if !(spec.lambda > 0.0 && spec.lambda <= 1.0) {
                        return Err(format!(
                            "signal param 'lambda' must be in (0, 1], got {val}"
                        ));
                    }
                }
                (SignalKind::LatentTemporal, "slope") => spec.slope = f()?,
                (SignalKind::LatentTemporal, "window") => {
                    spec.window = val.parse::<usize>().map_err(|e| {
                        format!("signal param 'window': bad value '{val}': {e}")
                    })?;
                    if spec.window == 0 {
                        return Err("signal param 'window' must be >= 1".to_string());
                    }
                }
                (SignalKind::Confidence, "gamma") => {
                    spec.gamma = f()?;
                    if spec.gamma <= 0.0 {
                        return Err(format!(
                            "signal param 'gamma' must be > 0, got {val}"
                        ));
                    }
                }
                (_, other) => {
                    return Err(format!(
                        "signal param '{other}' does not apply to '{}'",
                        kind.name()
                    ))
                }
            }
        }
        Ok(spec)
    }

    /// The selected signal's canonical name.
    pub fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// Render back to `NAME[:PARAM=V,...]` form (non-default params
    /// only) — the config-block serialization.
    pub fn spec_string(&self) -> String {
        let d = SignalSpec::default();
        let mut params: Vec<String> = Vec::new();
        match self.kind {
            SignalKind::LatentTemporal => {
                if self.lambda != d.lambda {
                    params.push(format!("lambda={}", self.lambda));
                }
                if self.slope != d.slope {
                    params.push(format!("slope={}", self.slope));
                }
                if self.window != d.window {
                    params.push(format!("window={}", self.window));
                }
            }
            SignalKind::Confidence => {
                if self.gamma != d.gamma {
                    params.push(format!("gamma={}", self.gamma));
                }
            }
            _ => {}
        }
        if params.is_empty() {
            self.name().to_string()
        } else {
            format!("{}:{}", self.name(), params.join(","))
        }
    }

    /// Build the signal instance. `hidden-mlp` clones the engine's
    /// scorer; the rivals ignore it.
    pub fn build(&self, scorer: &StepScorer) -> Box<dyn TraceSignal> {
        match self.kind {
            SignalKind::HiddenMlp => {
                Box::new(HiddenMlpSignal { scorer: scorer.clone() })
            }
            SignalKind::LatentTemporal => Box::new(LatentTemporalSignal {
                lambda: self.lambda,
                slope: self.slope,
                window: self.window,
            }),
            SignalKind::Confidence => Box::new(ConfidenceSignal { gamma: self.gamma }),
            SignalKind::PrmOracle => Box::new(PrmOracleSignal),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profiles::{BenchId, ModelId};
    use crate::sim::tracegen::GenParams;

    fn gen() -> TraceGen {
        TraceGen::new(ModelId::Qwen3_4B, BenchId::Aime25, GenParams::default_d64(), 42)
    }

    fn mlp() -> StepScorer {
        crate::harness::cells::projection_scorer(&GenParams::default_d64())
    }

    fn all_signals() -> Vec<Box<dyn TraceSignal>> {
        let scorer = mlp();
        SIGNAL_NAMES
            .iter()
            .map(|n| SignalSpec::parse(n).unwrap().build(&scorer))
            .collect()
    }

    #[test]
    fn parse_accepts_names_and_params() {
        assert_eq!(SignalSpec::parse("hidden-mlp").unwrap(), SignalSpec::default());
        let lt = SignalSpec::parse("latent-temporal:lambda=0.5,window=4").unwrap();
        assert_eq!(lt.kind, SignalKind::LatentTemporal);
        assert_eq!(lt.lambda, 0.5);
        assert_eq!(lt.window, 4);
        let c = SignalSpec::parse("confidence:gamma=2").unwrap();
        assert_eq!(c.kind, SignalKind::Confidence);
        assert_eq!(c.gamma, 2.0);
        assert_eq!(SignalSpec::parse("prm-oracle").unwrap().kind, SignalKind::PrmOracle);
    }

    #[test]
    fn parse_rejects_and_names_the_offender() {
        assert!(SignalSpec::parse("entropy").unwrap_err().contains("entropy"));
        // A param that belongs to another signal is named.
        let e = SignalSpec::parse("confidence:lambda=0.5").unwrap_err();
        assert!(e.contains("lambda") && e.contains("confidence"), "{e}");
        let e = SignalSpec::parse("hidden-mlp:gamma=1").unwrap_err();
        assert!(e.contains("gamma"), "{e}");
        // Bad values and ranges are named too.
        assert!(SignalSpec::parse("confidence:gamma=zero").unwrap_err().contains("gamma"));
        assert!(SignalSpec::parse("confidence:gamma=-1").unwrap_err().contains("gamma"));
        assert!(SignalSpec::parse("latent-temporal:lambda=1.5")
            .unwrap_err()
            .contains("lambda"));
        assert!(SignalSpec::parse("latent-temporal:window=0")
            .unwrap_err()
            .contains("window"));
        assert!(SignalSpec::parse("latent-temporal:slope")
            .unwrap_err()
            .contains("PARAM=V"));
    }

    #[test]
    fn spec_string_round_trips() {
        for s in [
            "hidden-mlp",
            "latent-temporal",
            "latent-temporal:lambda=0.5,window=4",
            "confidence",
            "confidence:gamma=2",
            "prm-oracle",
        ] {
            let spec = SignalSpec::parse(s).unwrap();
            assert_eq!(SignalSpec::parse(&spec.spec_string()).unwrap(), spec);
        }
    }

    #[test]
    fn hidden_mlp_matches_raw_scorer_path() {
        let g = gen();
        let scorer = mlp();
        let sig = SignalSpec::default().build(&scorer);
        let mut scratch = SignalScratch::new();
        let q = g.question(0);
        for i in 0..4 {
            let t = g.trace(&q, i);
            for n in 1..=t.n_steps().min(6) {
                let ctx = StepCtx { gen: &g, q: &q, spec: &t, step_n: n };
                let via_trait = sig.score_step(&ctx, &mut scratch);
                let h = g.hidden_state(&q, &t, n);
                let mut z = vec![0.0f32; scorer.hidden];
                let direct = scorer.score_into(&h, &mut z);
                assert_eq!(via_trait, direct, "trace {i} step {n}: not bit-identical");
            }
        }
    }

    #[test]
    fn signals_are_deterministic_and_scratch_reuse_is_pure() {
        let g = gen();
        let q = g.question(1);
        let t = g.trace(&q, 2);
        for sig in all_signals() {
            let mut fresh_scores = Vec::new();
            for n in 1..=t.n_steps().min(8) {
                let ctx = StepCtx { gen: &g, q: &q, spec: &t, step_n: n };
                let mut fresh = SignalScratch::new();
                fresh_scores.push(sig.score_step(&ctx, &mut fresh));
            }
            // One reused scratch (dirtied between calls) must reproduce
            // every score bit-for-bit.
            let mut reused = SignalScratch::new();
            for (k, n) in (1..=t.n_steps().min(8)).enumerate() {
                let ctx = StepCtx { gen: &g, q: &q, spec: &t, step_n: n };
                let a = sig.score_step(&ctx, &mut reused);
                reused.h.iter_mut().for_each(|x| *x = f32::NAN);
                reused.z.iter_mut().for_each(|x| *x = f32::NAN);
                let b = sig.score_step(&ctx, &mut reused);
                assert_eq!(a, b, "{}: dirty scratch changed the score", sig.name());
                assert_eq!(a, fresh_scores[k], "{}: scratch reuse impure", sig.name());
            }
        }
    }

    #[test]
    fn batch_matches_singular_for_every_signal() {
        let g = gen();
        let q = g.question(3);
        let traces: Vec<TraceSpec> = (0..3).map(|i| g.trace(&q, i)).collect();
        let ctxs: Vec<StepCtx> = traces
            .iter()
            .flat_map(|t| {
                (1..=t.n_steps().min(5))
                    .map(move |n| StepCtx { gen: &g, q: &q, spec: t, step_n: n })
            })
            .collect();
        for sig in all_signals() {
            let mut scratch = SignalScratch::new();
            let mut out = vec![-1.0f32; 3]; // pre-dirtied: must be cleared
            sig.score_batch_into(&ctxs, &mut scratch, &mut out);
            assert_eq!(out.len(), ctxs.len(), "{}", sig.name());
            for (ctx, &b) in ctxs.iter().zip(&out) {
                assert_eq!(
                    sig.score_step(ctx, &mut scratch),
                    b,
                    "{}: batch diverges from singular",
                    sig.name()
                );
            }
        }
    }

    #[test]
    fn signals_rank_quality() {
        // Every signal must score a high-quality trace above a
        // low-quality one of the same question, late in the trace where
        // the signal has converged (mean over several traces to damp
        // per-trace noise).
        let g = gen();
        let q = g.question(5);
        let traces: Vec<TraceSpec> = (0..32).map(|i| g.trace(&q, i)).collect();
        for sig in all_signals() {
            let mut scratch = SignalScratch::new();
            let (mut good, mut ng) = (0.0f64, 0);
            let (mut bad, mut nb) = (0.0f64, 0);
            for t in &traces {
                let n = t.n_steps();
                let ctx = StepCtx { gen: &g, q: &q, spec: t, step_n: n };
                let s = sig.score_step(&ctx, &mut scratch) as f64;
                if t.label {
                    good += s;
                    ng += 1;
                } else {
                    bad += s;
                    nb += 1;
                }
            }
            assert!(ng >= 3 && nb >= 3, "degenerate label split");
            let (good, bad) = (good / ng as f64, bad / nb as f64);
            assert!(
                good > bad,
                "{}: correct traces must outscore incorrect ({good} vs {bad})",
                sig.name()
            );
        }
    }

    #[test]
    fn per_gpu_clones_are_independent_and_equal() {
        let g = gen();
        let q = g.question(0);
        let t = g.trace(&q, 0);
        let ctx = StepCtx { gen: &g, q: &q, spec: &t, step_n: 1 };
        for sig in all_signals() {
            let clone = sig.clone_box();
            assert_eq!(clone.name(), sig.name());
            let mut s1 = SignalScratch::new();
            let mut s2 = SignalScratch::new();
            assert_eq!(sig.score_step(&ctx, &mut s1), clone.score_step(&ctx, &mut s2));
        }
    }

    #[test]
    fn names_align_with_kinds() {
        let kinds = [
            SignalKind::HiddenMlp,
            SignalKind::LatentTemporal,
            SignalKind::Confidence,
            SignalKind::PrmOracle,
        ];
        assert_eq!(kinds.len(), SIGNAL_NAMES.len());
        for (k, name) in kinds.iter().zip(SIGNAL_NAMES) {
            assert_eq!(k.name(), *name);
            assert_eq!(SignalSpec::parse(name).unwrap().kind, *k);
        }
    }
}
