//! Test-time-scaling method configurations: the paper's STEP plus the
//! §5.1 baselines (CoT, SC, Slim-SC, DeepConf), each expressed as
//! scheduler policy knobs consumed by the engines.

/// Which parallel-scaling method drives the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Single chain-of-thought trace (N = 1).
    Cot,
    /// Self-consistency: N traces, majority voting, vLLM preemption when
    /// memory saturates (the paper's primary baseline).
    Sc,
    /// Slim-SC (Hong et al. 2025), Random-Pruning variant: periodically
    /// prune one of each pair of similar traces.
    SlimSc,
    /// DeepConf-low (Fu et al. 2025): warmup traces set a confidence
    /// threshold; online traces below it stop early.
    DeepConf,
    /// STEP (this paper): hidden-state step scorer + memory-triggered
    /// pruning + score-weighted voting.
    Step,
}

impl Method {
    /// Every method, in the paper's Table-1 row order.
    pub const ALL: [Method; 5] =
        [Method::Cot, Method::Sc, Method::SlimSc, Method::DeepConf, Method::Step];

    /// Display name (the paper's row label).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Cot => "CoT",
            Method::Sc => "SC",
            Method::SlimSc => "Slim-SC",
            Method::DeepConf => "DeepConf",
            Method::Step => "STEP",
        }
    }

    /// Parse a CLI/config method name (case-insensitive).
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "cot" => Some(Method::Cot),
            "sc" | "self-consistency" => Some(Method::Sc),
            "slim-sc" | "slimsc" | "slim" => Some(Method::SlimSc),
            "deepconf" | "deep-conf" => Some(Method::DeepConf),
            "step" => Some(Method::Step),
            _ => None,
        }
    }
}

/// Method hyper-parameters (paper §5.1 "Implementation Details" and
/// Appendix B.3 defaults).
#[derive(Debug, Clone)]
pub struct MethodParams {
    /// Slim-SC similarity threshold (paper: 0.95).
    pub slim_similarity_threshold: f64,
    /// Slim-SC check period, in reasoning steps ("thought level").
    pub slim_check_interval_steps: usize,
    /// DeepConf warmup trace count for N in {32, 64} (paper: 16; 8 for
    /// N = 16).
    pub deepconf_n_init: usize,
    /// DeepConf-low keeps traces above the top-`keep_top` percentile
    /// confidence of the warmup set (paper: 0.10).
    pub deepconf_keep_top: f64,
    /// Sliding window (in steps) of the online confidence estimate.
    pub deepconf_window: usize,
    /// Default score for a trace with no scored steps yet.
    pub default_score: f64,
}

impl Default for MethodParams {
    fn default() -> Self {
        MethodParams {
            slim_similarity_threshold: 0.95,
            slim_check_interval_steps: 8,
            deepconf_n_init: 16,
            deepconf_keep_top: 0.10,
            deepconf_window: 16,
            default_score: 0.5,
        }
    }
}

impl MethodParams {
    /// Appendix B.3: N_init = 8 when the trace budget is 16.
    pub fn deepconf_warmup_for_budget(&self, n_traces: usize) -> usize {
        if n_traces <= 16 {
            8.min(n_traces.saturating_sub(1)).max(1)
        } else {
            self.deepconf_n_init.min(n_traces)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("unknown"), None);
    }

    #[test]
    fn deepconf_warmup_scaling() {
        let p = MethodParams::default();
        assert_eq!(p.deepconf_warmup_for_budget(64), 16);
        assert_eq!(p.deepconf_warmup_for_budget(32), 16);
        assert_eq!(p.deepconf_warmup_for_budget(16), 8);
        assert_eq!(p.deepconf_warmup_for_budget(2), 1);
    }
}
