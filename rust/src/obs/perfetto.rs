//! Chrome-trace-event / Perfetto exporter (`--perfetto-out`).
//!
//! Renders a merged [`SimEvent`] stream as the Chrome trace-event JSON
//! format (`{"traceEvents": [...]}`), loadable in `ui.perfetto.dev` or
//! `chrome://tracing`:
//!
//! * **pid 1 "cluster"** — one track per scheduling locus: tid 0 is
//!   the front door, tid `g + 1` is GPU `g`. Non-span kinds (prunes,
//!   preemptions, fleet transitions, …) render as thread-scoped
//!   instants there.
//! * **pid 2 "requests"** — one track per request (tid = rid) carrying
//!   its `queued` (Offer→Place/Shed) and `running` (Place→Complete/
//!   Abandon) duration spans as `B`/`E` pairs.
//! * **Counter tracks** (`ph: "C"`) — `queue_depth` from `Queue`
//!   events, and per-GPU `kv[g*]` / `live[g*]` occupancy sampled from
//!   the load stamps engine events carry.
//!
//! Timestamps are the simulation clock in integer microseconds; the
//! input stream is already in canonical merged order
//! ([`crate::obs::merge_streams`]), so `ts` comes out monotone —
//! `tests/trace_replay.rs` keeps the exporter honest with a shape test
//! (valid JSON, monotone `ts`, matched `B`/`E` pairs, counter-track
//! names).

use std::collections::BTreeMap;

use crate::obs::{EventKind, SimEvent};
use crate::util::json::Json;

/// The `pid` of the per-locus (front door + GPUs) process group.
pub const PID_CLUSTER: usize = 1;
/// The `pid` of the per-request span process group.
pub const PID_REQUESTS: usize = 2;

fn str_json(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn meta(pid: usize, tid: usize, what: &str, name: &str) -> Json {
    Json::obj(vec![
        ("ph", str_json("M")),
        ("name", str_json(what)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", str_json(name))])),
    ])
}

fn span(ph: &str, name: &str, tid: usize, ts: f64) -> Json {
    Json::obj(vec![
        ("ph", str_json(ph)),
        ("name", str_json(name)),
        ("pid", Json::Num(PID_REQUESTS as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
    ])
}

fn instant(name: &str, tid: usize, ts: f64, args: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("ph", str_json("i")),
        ("name", str_json(name)),
        ("pid", Json::Num(PID_CLUSTER as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("s", str_json("t")),
        ("args", Json::obj(args)),
    ])
}

fn counter(name: &str, tid: usize, ts: f64, series: &str, value: f64) -> Json {
    Json::obj(vec![
        ("ph", str_json("C")),
        ("name", str_json(name)),
        ("pid", Json::Num(PID_CLUSTER as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts)),
        ("args", Json::obj(vec![(series, Json::Num(value))])),
    ])
}

/// The cluster-process track id of an event: its GPU's track, or the
/// front door's.
fn locus_tid(ev: &SimEvent) -> usize {
    ev.gpu.map_or(0, |g| g + 1)
}

/// Export a merged event stream as a Chrome trace-event JSON document.
///
/// Open request spans (a request still queued or running when the
/// stream ends — e.g. a filtered log) are closed at the last observed
/// timestamp so the document always balances its `B`/`E` pairs.
pub fn chrome_trace(events: &[SimEvent]) -> Json {
    let mut out: Vec<Json> = Vec::new();
    out.push(meta(PID_CLUSTER, 0, "process_name", "cluster"));
    out.push(meta(PID_REQUESTS, 0, "process_name", "requests"));
    out.push(meta(PID_CLUSTER, 0, "thread_name", "front-door"));
    let mut gpus: Vec<usize> = events.iter().filter_map(|e| e.gpu).collect();
    gpus.sort_unstable();
    gpus.dedup();
    for &g in &gpus {
        out.push(meta(PID_CLUSTER, g + 1, "thread_name", &format!("gpu{g}")));
    }

    // rid -> the currently open span name on its request track.
    let mut open: BTreeMap<usize, &'static str> = BTreeMap::new();
    let mut last_ts = 0.0f64;
    for ev in events {
        let ts = (ev.t_s * 1e6).round();
        last_ts = last_ts.max(ts);
        let tid = locus_tid(ev);
        match ev.kind {
            EventKind::Offer => {
                if let Some(rid) = ev.rid {
                    out.push(span("B", "queued", rid, ts));
                    open.insert(rid, "queued");
                }
            }
            EventKind::Place => {
                if let Some(rid) = ev.rid {
                    if open.remove(&rid).is_some() {
                        out.push(span("E", "queued", rid, ts));
                    }
                    out.push(span("B", "running", rid, ts));
                    open.insert(rid, "running");
                }
            }
            EventKind::Shed | EventKind::Complete | EventKind::Abandon => {
                if let Some(rid) = ev.rid {
                    if let Some(name) = open.remove(&rid) {
                        out.push(span("E", name, rid, ts));
                    }
                }
                if !matches!(ev.kind, EventKind::Complete) {
                    let mut args = Vec::new();
                    if let Some(c) = ev.cause {
                        args.push(("cause", str_json(c)));
                    }
                    out.push(instant(ev.kind.name(), tid, ts, args));
                }
            }
            EventKind::Queue { depth } => {
                out.push(counter("queue_depth", 0, ts, "depth", depth as f64));
            }
            _ => {
                let mut args = Vec::new();
                if let Some(rid) = ev.rid {
                    args.push(("rid", Json::Num(rid as f64)));
                }
                if let Some(c) = ev.cause {
                    args.push(("cause", str_json(c)));
                }
                out.push(instant(ev.kind.name(), tid, ts, args));
            }
        }
        // KV-occupancy / live-trace counter tracks, sampled at every
        // event boundary that carries a load stamp.
        if let Some(g) = ev.gpu {
            if let Some(kv) = ev.kv {
                out.push(counter(&format!("kv[g{g}]"), g + 1, ts, "blocks", kv as f64));
            }
            if let Some(live) = ev.live {
                out.push(counter(
                    &format!("live[g{g}]"),
                    g + 1,
                    ts,
                    "traces",
                    live as f64,
                ));
            }
        }
    }
    for (rid, name) in open {
        out.push(span("E", name, rid, last_ts));
    }
    Json::obj(vec![
        ("displayTimeUnit", str_json("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SimEvent;

    #[test]
    fn spans_pair_and_counters_sample() {
        let events = vec![
            SimEvent::new(0.0, EventKind::Offer).rid(0),
            SimEvent::new(0.0, EventKind::Queue { depth: 1 }).rid(0),
            SimEvent::new(0.5, EventKind::Place).rid(0).gpu(1),
            SimEvent::new(0.5, EventKind::Admit { traces: 4 })
                .rid(0)
                .gpu(1)
                .load(4, 10),
            SimEvent::new(1.0, EventKind::Prune).rid(0).gpu(1).cause("memory"),
            SimEvent::new(2.0, EventKind::Complete).rid(0).gpu(1),
            // Left open on purpose: closed at the final timestamp.
            SimEvent::new(2.5, EventKind::Offer).rid(1),
        ];
        let doc = chrome_trace(&events);
        let tes = doc.get("traceEvents").as_arr().unwrap();
        let mut b = 0;
        let mut e = 0;
        let mut counters = Vec::new();
        for te in tes {
            match te.get("ph").as_str().unwrap() {
                "B" => b += 1,
                "E" => e += 1,
                "C" => counters.push(te.get("name").as_str().unwrap().to_string()),
                _ => {}
            }
        }
        assert_eq!(b, e, "every B span has a matching E");
        assert_eq!(b, 3, "queued, running, and the dangling queued span");
        assert!(counters.iter().any(|n| n == "queue_depth"));
        assert!(counters.iter().any(|n| n == "kv[g1]"));
        assert!(counters.iter().any(|n| n == "live[g1]"));
    }

    #[test]
    fn ts_is_monotone_in_merged_order() {
        let events = vec![
            SimEvent::new(0.0, EventKind::Offer).rid(0),
            SimEvent::new(0.25, EventKind::Place).rid(0).gpu(0),
            SimEvent::new(0.75, EventKind::Complete).rid(0).gpu(0),
        ];
        let doc = chrome_trace(&events);
        let mut last = f64::NEG_INFINITY;
        for te in doc.get("traceEvents").as_arr().unwrap() {
            if te.get("ph").as_str() == Some("M") {
                continue;
            }
            let ts = te.get("ts").as_f64().unwrap();
            assert!(ts >= last, "ts must be monotone: {ts} < {last}");
            last = ts;
        }
        assert_eq!(last, 0.75e6);
    }
}
