//! Log-vs-counters consistency: re-derive [`ClusterCounters`] from a
//! recorded event stream alone, and check the admission conservation
//! laws event-by-event.
//!
//! Every counter the cluster front door maintains increments at exactly
//! one emission site, so a faithful trace must reproduce the counters
//! byte-for-byte ([`replay_counters`] + [`ClusterCounters::report`]).
//! [`check`] additionally walks each request's lifecycle — offered →
//! placed/shed → completed/abandoned, exactly once each — which is what
//! `step trace-check` runs against a `--trace-out` JSONL file in CI.

use std::collections::{BTreeMap, HashMap};

use crate::metrics::ClusterCounters;
use crate::obs::{EventKind, SimEvent};

/// Re-derive the cluster's admission/goodput counters from events
/// alone. Counter ↔ event mapping:
///
/// * `offered`/`placed`/`shed`/`completed` — `Offer`/`Place`/`Shed`/
///   `Complete` counts;
/// * `queue_peak` — max `Queue` depth;
/// * `migrated` — `Migrate` count, `migration_recompute_tokens` its
///   summed payload, `rescue_migrated` the `drain`-caused subset,
///   `migration_saved` the `rescue`-caused subset;
/// * `revocations` — `Revoke` count;
/// * `drained` — `drain`-caused `Complete`s;
/// * `shed_on_revoke` — `Abandon` count.
pub fn replay_counters(events: &[SimEvent]) -> ClusterCounters {
    let mut c = ClusterCounters::default();
    for ev in events {
        match ev.kind {
            EventKind::Offer => c.offered += 1,
            EventKind::Place => c.placed += 1,
            EventKind::Shed => c.shed += 1,
            EventKind::Queue { depth } => {
                c.queue_peak = c.queue_peak.max(depth as u64);
            }
            EventKind::Complete => {
                c.completed += 1;
                if ev.cause == Some("drain") {
                    c.drained += 1;
                }
            }
            EventKind::Abandon => c.shed_on_revoke += 1,
            EventKind::Migrate { recompute_tokens, .. } => {
                c.migrated += 1;
                c.migration_recompute_tokens += recompute_tokens;
                match ev.cause {
                    Some("drain") => c.rescue_migrated += 1,
                    Some("rescue") => c.migration_saved += 1,
                    _ => {}
                }
            }
            EventKind::Revoke { .. } => c.revocations += 1,
            _ => {}
        }
    }
    c
}

/// Per-pruning-signal activity re-derived from the `signal` stamps on
/// `step-score` and `prune` events — attributes each prune to the
/// signal whose scores selected the victim.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SignalAttribution {
    /// The signal name (a `--signal` vocabulary entry).
    pub signal: &'static str,
    /// Step-boundary evaluations stamped with this signal.
    pub step_scores: u64,
    /// Prunes stamped with this signal.
    pub prunes: u64,
}

/// Re-derive per-signal attribution from an event stream, in signal-name
/// order. Events without a `signal` stamp (pre-signal traces, or prunes
/// that never consulted a score) are excluded.
pub fn signal_attribution(events: &[SimEvent]) -> Vec<SignalAttribution> {
    let mut by_signal: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for ev in events {
        let Some(sig) = ev.signal else { continue };
        let e = by_signal.entry(sig).or_insert((0, 0));
        match ev.kind {
            EventKind::StepScore { .. } => e.0 += 1,
            EventKind::Prune => e.1 += 1,
            _ => {}
        }
    }
    by_signal
        .into_iter()
        .map(|(signal, (step_scores, prunes))| SignalAttribution {
            signal,
            step_scores,
            prunes,
        })
        .collect()
}

/// What [`check`] found in an event stream.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// The counters re-derived by [`replay_counters`].
    pub counters: ClusterCounters,
    /// Number of events examined.
    pub events: usize,
    /// Per-signal step-score/prune attribution ([`signal_attribution`]).
    pub attribution: Vec<SignalAttribution>,
    /// Conservation/lifecycle violations, human-readable (empty for a
    /// well-formed trace).
    pub violations: Vec<String>,
}

impl ReplayReport {
    /// Whether the trace is well-formed.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Per-request lifecycle state while replaying.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Lifecycle {
    Offered,
    Placed,
    Shed,
    Done,
}

/// Validate an event stream: time-ordering, per-request lifecycle
/// (each rid is offered at most once, placed or shed after an offer,
/// completed or abandoned exactly once after a placement), the
/// prefix-cache pin lifecycle (per `(gpu, qid)`: shares and evicts
/// strictly alternate with matching block counts and hits only land on
/// a live pin — shared blocks are freed exactly once), and the
/// end-of-run conservation laws `offered == placed + shed` and
/// `completed + shed_on_revoke == placed`.
pub fn check(events: &[SimEvent]) -> ReplayReport {
    let mut violations = Vec::new();
    let mut last_t = f64::NEG_INFINITY;
    let mut life: HashMap<usize, Lifecycle> = HashMap::new();
    // Live prefix pins: (gpu, qid) -> pinned block count.
    let mut pins: HashMap<(Option<usize>, usize), usize> = HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        if !(ev.t_s.is_finite() && ev.t_s >= 0.0) {
            violations.push(format!("event {i}: bad clock {}", ev.t_s));
        } else if ev.t_s < last_t {
            violations.push(format!(
                "event {i}: clock {} runs backwards past {last_t}",
                ev.t_s
            ));
        } else {
            last_t = ev.t_s;
        }
        let rid = ev.rid;
        match ev.kind {
            EventKind::Offer => {
                let Some(rid) = rid else {
                    violations.push(format!("event {i}: offer without rid"));
                    continue;
                };
                if life.insert(rid, Lifecycle::Offered).is_some() {
                    violations.push(format!("event {i}: rid {rid} offered twice"));
                }
            }
            EventKind::Place => {
                let Some(rid) = rid else {
                    violations.push(format!("event {i}: place without rid"));
                    continue;
                };
                match life.get(&rid) {
                    Some(Lifecycle::Offered) => {
                        life.insert(rid, Lifecycle::Placed);
                    }
                    other => violations.push(format!(
                        "event {i}: rid {rid} placed from state {other:?}"
                    )),
                }
            }
            EventKind::Shed => {
                let Some(rid) = rid else {
                    violations.push(format!("event {i}: shed without rid"));
                    continue;
                };
                match life.get(&rid) {
                    Some(Lifecycle::Offered) => {
                        life.insert(rid, Lifecycle::Shed);
                    }
                    other => violations.push(format!(
                        "event {i}: rid {rid} shed from state {other:?}"
                    )),
                }
            }
            EventKind::Complete | EventKind::Abandon => {
                let what = ev.kind.name();
                let Some(rid) = rid else {
                    violations.push(format!("event {i}: {what} without rid"));
                    continue;
                };
                match life.get(&rid) {
                    Some(Lifecycle::Placed) => {
                        life.insert(rid, Lifecycle::Done);
                    }
                    other => violations.push(format!(
                        "event {i}: rid {rid} {what} from state {other:?} \
                         (completion must be exactly-once after a placement)"
                    )),
                }
            }
            EventKind::PrefixShare { qid, blocks } => {
                if pins.insert((ev.gpu, qid), blocks).is_some() {
                    violations.push(format!(
                        "event {i}: qid {qid} prefix pinned twice on gpu {:?} \
                         without an evict between",
                        ev.gpu
                    ));
                }
            }
            EventKind::PrefixHit { qid, blocks } => match pins.get(&(ev.gpu, qid)) {
                Some(&pinned) if pinned == blocks => {}
                Some(&pinned) => violations.push(format!(
                    "event {i}: qid {qid} prefix hit for {blocks} blocks but \
                     {pinned} are pinned on gpu {:?}",
                    ev.gpu
                )),
                None => violations.push(format!(
                    "event {i}: qid {qid} prefix hit with no live pin on gpu {:?}",
                    ev.gpu
                )),
            },
            EventKind::PrefixEvict { qid, blocks } => {
                match pins.remove(&(ev.gpu, qid)) {
                    Some(pinned) if pinned == blocks => {}
                    Some(pinned) => violations.push(format!(
                        "event {i}: qid {qid} prefix evict freed {blocks} blocks \
                         but {pinned} were pinned on gpu {:?} (shared blocks must \
                         be freed exactly once)",
                        ev.gpu
                    )),
                    None => violations.push(format!(
                        "event {i}: qid {qid} prefix evict with no live pin on \
                         gpu {:?} (shared blocks must be freed exactly once)",
                        ev.gpu
                    )),
                }
            }
            _ => {}
        }
    }
    let counters = replay_counters(events);
    if counters.offered != counters.placed + counters.shed {
        violations.push(format!(
            "placement conservation broken: offered={} != placed={} + shed={}",
            counters.offered, counters.placed, counters.shed
        ));
    }
    if counters.completed + counters.shed_on_revoke != counters.placed {
        violations.push(format!(
            "completion conservation broken: completed={} + shed_on_revoke={} != \
             placed={}",
            counters.completed, counters.shed_on_revoke, counters.placed
        ));
    }
    ReplayReport {
        counters,
        events: events.len(),
        attribution: signal_attribution(events),
        violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SimEvent;

    fn ev(t: f64, kind: EventKind, rid: usize) -> SimEvent {
        SimEvent::new(t, kind).rid(rid)
    }

    #[test]
    fn well_formed_stream_passes_and_counts() {
        let events = vec![
            ev(0.0, EventKind::Offer, 0),
            ev(0.0, EventKind::Place, 0),
            ev(1.0, EventKind::Offer, 1),
            ev(1.0, EventKind::Queue { depth: 1 }, 1),
            ev(2.0, EventKind::Offer, 2),
            ev(2.0, EventKind::Shed, 2).cause("queue-full"),
            ev(3.0, EventKind::Place, 1),
            ev(4.0, EventKind::Complete, 0),
            ev(5.0, EventKind::Complete, 1),
        ];
        let report = check(&events);
        assert!(report.ok(), "unexpected violations: {:?}", report.violations);
        let c = report.counters;
        assert_eq!((c.offered, c.placed, c.shed, c.completed), (3, 2, 1, 2));
        assert_eq!(c.queue_peak, 1);
    }

    #[test]
    fn double_completion_is_flagged() {
        let events = vec![
            ev(0.0, EventKind::Offer, 0),
            ev(0.0, EventKind::Place, 0),
            ev(1.0, EventKind::Complete, 0),
            ev(2.0, EventKind::Complete, 0),
        ];
        let report = check(&events);
        assert!(!report.ok());
        assert!(report.violations.iter().any(|v| v.contains("exactly-once")));
    }

    #[test]
    fn unplaced_completion_and_lost_placement_are_flagged() {
        // A completion with no placement at all.
        let r = check(&[ev(0.0, EventKind::Complete, 3)]);
        assert!(r.violations.iter().any(|v| v.contains("rid 3")));
        // A placement that never resolves breaks conservation.
        let r = check(&[
            ev(0.0, EventKind::Offer, 0),
            ev(0.0, EventKind::Place, 0),
        ]);
        assert!(r.violations.iter().any(|v| v.contains("completion conservation")));
    }

    #[test]
    fn backwards_clock_is_flagged() {
        let r = check(&[
            ev(5.0, EventKind::Offer, 0),
            ev(1.0, EventKind::Place, 0),
            ev(6.0, EventKind::Complete, 0),
        ]);
        assert!(r.violations.iter().any(|v| v.contains("runs backwards")));
    }

    #[test]
    fn prefix_pin_lifecycle_alternates_share_and_evict() {
        // Well-formed: share → hits → evict → share again, per (gpu, qid).
        let ok = check(&[
            SimEvent::new(0.0, EventKind::PrefixShare { qid: 3, blocks: 4 }).gpu(0),
            SimEvent::new(0.5, EventKind::PrefixHit { qid: 3, blocks: 4 }).gpu(0),
            // The same qid on another GPU is an independent pin.
            SimEvent::new(0.6, EventKind::PrefixShare { qid: 3, blocks: 4 }).gpu(1),
            SimEvent::new(1.0, EventKind::PrefixEvict { qid: 3, blocks: 4 })
                .gpu(0)
                .cause("pressure"),
            SimEvent::new(2.0, EventKind::PrefixShare { qid: 3, blocks: 4 }).gpu(0),
        ]);
        assert!(ok.ok(), "unexpected violations: {:?}", ok.violations);

        // A double free of the shared blocks is flagged.
        let double = check(&[
            SimEvent::new(0.0, EventKind::PrefixShare { qid: 3, blocks: 4 }).gpu(0),
            SimEvent::new(1.0, EventKind::PrefixEvict { qid: 3, blocks: 4 }).gpu(0),
            SimEvent::new(2.0, EventKind::PrefixEvict { qid: 3, blocks: 4 }).gpu(0),
        ]);
        assert!(double.violations.iter().any(|v| v.contains("exactly once")));

        // A hit without a live pin, a re-pin without an evict, and a
        // block-count mismatch are all flagged.
        let r = check(&[
            SimEvent::new(0.0, EventKind::PrefixHit { qid: 1, blocks: 2 }).gpu(0),
        ]);
        assert!(r.violations.iter().any(|v| v.contains("no live pin")));
        let r = check(&[
            SimEvent::new(0.0, EventKind::PrefixShare { qid: 1, blocks: 2 }).gpu(0),
            SimEvent::new(1.0, EventKind::PrefixShare { qid: 1, blocks: 2 }).gpu(0),
        ]);
        assert!(r.violations.iter().any(|v| v.contains("pinned twice")));
        let r = check(&[
            SimEvent::new(0.0, EventKind::PrefixShare { qid: 1, blocks: 2 }).gpu(0),
            SimEvent::new(1.0, EventKind::PrefixEvict { qid: 1, blocks: 3 }).gpu(0),
        ]);
        assert!(r.violations.iter().any(|v| v.contains("freed 3")));
    }

    #[test]
    fn signal_attribution_groups_scores_and_prunes() {
        let events = vec![
            ev(0.0, EventKind::StepScore { score: 0.8 }, 0).signal("hidden-mlp"),
            ev(0.1, EventKind::StepScore { score: 0.4 }, 0).signal("hidden-mlp"),
            ev(0.2, EventKind::Prune, 0).cause("memory").signal("hidden-mlp"),
            ev(0.3, EventKind::StepScore { score: 0.6 }, 1).signal("confidence"),
            ev(0.4, EventKind::Prune, 1).cause("slim-sc").signal("confidence"),
            ev(0.5, EventKind::Prune, 1).cause("memory").signal("confidence"),
            // Unstamped events are excluded from attribution.
            ev(0.6, EventKind::Prune, 2).cause("stall-drop"),
        ];
        let attr = signal_attribution(&events);
        assert_eq!(
            attr,
            vec![
                SignalAttribution { signal: "confidence", step_scores: 1, prunes: 2 },
                SignalAttribution { signal: "hidden-mlp", step_scores: 2, prunes: 1 },
            ]
        );
    }

    #[test]
    fn migration_and_fleet_counters_replay() {
        let events = vec![
            ev(0.0, EventKind::Offer, 0),
            ev(0.0, EventKind::Place, 0),
            SimEvent::new(1.0, EventKind::Revoke { deadline_s: 5.0 }).gpu(1),
            ev(1.5, EventKind::Migrate { dst: 0, recompute_tokens: 64 }, 0)
                .cause("drain"),
            ev(2.0, EventKind::Migrate { dst: 1, recompute_tokens: 36 }, 0)
                .cause("rescue"),
            ev(3.0, EventKind::Complete, 0).cause("drain"),
        ];
        let c = replay_counters(&events);
        assert_eq!(c.migrated, 2);
        assert_eq!(c.migration_recompute_tokens, 100);
        assert_eq!(c.rescue_migrated, 1);
        assert_eq!(c.migration_saved, 1);
        assert_eq!(c.revocations, 1);
        assert_eq!(c.drained, 1);
    }
}
